"""Cross-pod hierarchical tuning: two TPU pods over a slow wide-area
fabric, gradient-accumulation overlap (ACCO) as tunable ``acc.*`` sites.

1. Builds the hierarchical workload: llama3-8b FSDP across 2 pods with 4
   accumulation steps — step k's grad reduce (pod-local reduce-scatter +
   cross-pod all-reduce) overlaps microbatch k+1's compute.
2. Tunes it twice: against the ``two_pod`` topology (per-tier pricing)
   and against the bare island profile (fabric-blind flat model).
3. Evaluates both plans on the *hierarchical* simulator: the
   topology-aware tune must win, and its trace must show the grad reduce
   hidden under the next microbatch's compute.
4. Installs the topology-tuned plan and runs the real chunked-psum
   gradient sync under ``shard_map`` — the ``acc.step0.rs_grads`` site
   picks its chunk count up from the plan.

    PYTHONPATH=src python examples/cross_pod_tuning.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (
    ParallelPlan,
    Simulator,
    extract_workload,
    tune,
    two_pod,
)

# the wan fabric's bandwidth/latency terms are far from the island's, so
# a fabric-blind tune visibly mis-provisions the overlap window
topo = two_pod("tpu-v5e", "wan")
cfg = get_config("llama3-8b")
pp = ParallelPlan(kind="fsdp", dp=8, pods=2, accum_steps=4)
wl = extract_workload(cfg, pp, seq=2048, global_batch=64, layers=4)
acc_sites = [c.site_id for g in wl.groups if g.name.startswith("acc.") for c in g.comms]
print(
    f"workload {wl.name}: {len(wl.groups)} groups, "
    f"{len(acc_sites)} accumulation comm sites on topology {topo.name}"
)

tuned = tune(wl, topology=topo)  # per-tier pricing
flat = tune(wl, "tpu-v5e")  # fabric-blind baseline
assert tuned.hardware == topo.name and tuned.topology["fingerprint"]

# both plans judged on the fabric-aware simulator — the deployment model
sim = Simulator(topo)
z_hier = sim.profile(wl, tuned.configs).Z
z_flat = sim.profile(wl, flat.configs).Z
print(
    f"hierarchical simulator: topology-tuned {z_hier * 1e3:.2f} ms vs "
    f"flat-model plan {z_flat * 1e3:.2f} ms "
    f"({z_flat / z_hier:.2f}x)"
)
assert z_hier < z_flat, "topology-aware tune must beat the flat-model plan"

# the cross-pod reduce carries its own config, distinct from intra-pod
site_of = {(s["group"], s["comm"]): s.get("site") or s["name"] for s in tuned.sites}
cfg_by_site = {site_of[k]: v for k, v in tuned.configs.items()}
ar = cfg_by_site["acc.step0.ar_grads"]
intra = next(v for s, v in sorted(cfg_by_site.items()) if s.startswith("fsdp."))
print(
    f"acc.step0.ar_grads (inter-pod): nc={ar.nc} chunk_kb={ar.chunk_kb}; "
    f"intra-pod fsdp site: nc={intra.nc} chunk_kb={intra.chunk_kb}"
)
assert ar != intra, "cross-pod sites must tune independently"

# the trace shows the reduce hidden under the next microbatch's compute
m = tuned.evaluate(wl)
acc0 = next(g for g in m.groups if g.name == "acc.step0")
hidden = acc0.X + acc0.Y - acc0.Z
print(
    f"acc.step0 busy windows: comm {acc0.X * 1e3:.2f} ms + compute "
    f"{acc0.Y * 1e3:.2f} ms in a {acc0.Z * 1e3:.2f} ms makespan -> "
    f"{hidden / acc0.X:.0%} of the grad reduce overlapped"
)
assert hidden > 0, "accumulation reduce must overlap next-mb compute"

# execution path: the tuned acc knobs reach the real chunked psum
from repro.core.apply import activate
from repro.launch.mesh import make_mesh
from repro.parallel import collectives as C

activate(tuned)
knobs, src = C.explain_runtime("acc.step0.rs_grads")
print(
    f"site acc.step0.rs_grads -> {knobs.strategy}/x{knobs.num_chunks} "
    f"(matched plan key {src!r})"
)

mesh = make_mesh((8,), ("dp",))
# leading dim sized from the resolved chunk count so the tuned knobs always
# divide evenly (an indivisible payload would degrade, LAG010)
grads = {
    "w": jax.random.normal(jax.random.PRNGKey(0), (8 * knobs.num_chunks, 16, 32))
}
from jax.sharding import PartitionSpec as P

from repro.parallel.collectives import shard_map


def sync(g):
    # no num_chunks — the active plan's acc.step0.rs_grads knobs apply
    return C.psum_tree_chunked(g, "dp", site="acc.step0.rs_grads")


fn = shard_map(sync, mesh=mesh, in_specs=({"w": P("dp")},), out_specs={"w": P("dp")})
ref = shard_map(
    lambda g: C.psum_tree(g, "dp"),
    mesh=mesh,
    in_specs=({"w": P("dp")},),
    out_specs={"w": P("dp")},
)
ok = bool(jnp.allclose(fn(grads)["w"], ref(grads)["w"]))
print(f"chunked accumulation psum (x{knobs.num_chunks}) matches monolithic: {ok}")
assert ok

# overlap verifier: the tuned chunk structure is really in the trace —
# MATERIALIZED under the plan, ABSENT when the plan is not installed
from repro.analysis.overlap import trace_and_verify

report = trace_and_verify(tuned, fn, grads)
v = next(x for x in report.verdicts if x.site == "acc.step0.rs_grads")
print(f"overlap verdict for acc.step0.rs_grads: {v.verdict} ({v.detail})")
assert v.verdict == "MATERIALIZED", report.format()

C.install_runtime_plan({})  # drop the activated plan: the ABSENT control
off = trace_and_verify(tuned, fn, grads, install=False)
v_off = next(x for x in off.verdicts if x.site == "acc.step0.rs_grads")
print(f"without the plan installed: {v_off.verdict}")
assert v_off.verdict == "ABSENT", off.format()
