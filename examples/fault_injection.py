"""Fault-aware plan lifecycle, end to end: tune nominal and robust plans
under a degraded-link ensemble, then serve while the link actually
degrades mid-run — the health monitor detects the per-site drift within
its window and the engine demotes the affected ``serve.*`` sites to
fallback knobs without dropping a single token.

    PYTHONPATH=src python examples/fault_injection.py
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import ParallelPlan, extract_decode_workload, tune
from repro.core.faults import FaultEvent, FaultSchedule
from repro.models import model as M
from repro.serving import make_engine

cfg = get_smoke_config("llama3-8b")
params = M.init_params(cfg, jax.random.PRNGKey(0))

# 1. tune the decode shape twice: a nominal plan, and a minimax-regret
#    robust plan over an ensemble of plausible degradation scenarios
pp = ParallelPlan(kind="tp", tp=2)
wl = extract_decode_workload(cfg, pp, global_batch=32, seq=128)
nominal = tune(wl, "tpu-v5e", method="lagom")
robust = tune(
    wl,
    "tpu-v5e",
    method="lagom",
    fault_ensemble=["degrade,scale=0.25", "degrade,site=ag,scale=0.1"],
)
meta = robust.faults
print(
    f"robust tuning picked {meta['selected']!r} "
    f"(worst-case regret {meta['worst_case_regret']:.3e}s, "
    f"{meta['total_profiles']} total profiles); nominal regret "
    f"{meta['regrets']['nominal']:.3e}s"
)

# 2. serve under the nominal plan while the fabric degrades at batch 2:
#    serve.* links drop to 10% bandwidth, the kind of silent brownout a
#    healthy-hardware plan cannot see coming
schedule = FaultSchedule(
    events=(FaultEvent("degrade", site="serve", scale=0.1, start=2),)
)
engine = make_engine(
    cfg,
    params,
    mode="fixed",
    batch_size=32,
    max_seq=128,
    plan=nominal,
    fault_schedule=schedule,
    health_window=2,
    health_tolerance=0.25,
)

rs = np.random.default_rng(0)
prompts = [
    rs.integers(0, cfg.vocab_size, size=8).astype(np.int32) for _ in range(32)
]
outs = engine.generate(prompts, max_new=8)
assert all(len(o) == 8 for o in outs), "generation must complete under faults"
print("served 32 requests x 8 tokens through the degradation window")

# 3. the structured degradation log: drift detected within the window,
#    then one transactional demotion of every affected serve.* site
for event in engine.health_events:
    print(f"  {event}")
demotions = [e for e in engine.health_events if e["event"] == "demotion"]
assert demotions and not demotions[0]["rolled_back"], engine.health_events
assert all(s.startswith("serve.") for s in demotions[0]["sites"])
print(engine.health_report())

# 4. how would each plan have fared on the degraded fabric?  Evaluate both
#    under the same scripted fault (open-ended, so every step is degraded)
fault = "degrade,site=serve,scale=0.1"
rows = [
    ("nominal", nominal.evaluate(wl).Z, nominal.evaluate(wl, faults=fault).Z),
    ("robust", robust.evaluate(wl).Z, robust.evaluate(wl, faults=fault).Z),
]
print("\nplan      healthy Z     degraded Z")
for name, healthy, degraded in rows:
    print(f"{name:8s}  {healthy:.4e}s  {degraded:.4e}s")
assert rows[1][2] <= rows[0][2] * 1.001, "robust plan must not lose degraded"
