"""Tune → apply → runtime: shows a Lagom-tuned configuration changing the
actual JAX collectives (DESIGN.md §2 "recompile-with-knobs").

1. Tunes the qwen2-moe EP workload on the TPU v5e profile via the session
   front door (``tune(...) -> TunedPlan``).
2. Installs the plan process-wide (``core.apply.activate`` — what the
   launchers' ``--tuned-plan`` flag does at startup).
3. Runs the chunked all-to-all on a host mesh with NO explicit chunk
   count: the call site picks the tuned ``a2a`` knobs up from the active
   plan (on a real pod the same code emits n× smaller all-to-alls).

    PYTHONPATH=src python examples/tune_then_lower.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core import ParallelPlan, TPU_V5E, extract_workload, tune
from repro.parallel.collectives import chunked_all_to_all

cfg = get_config("qwen2-moe-a2.7b")
plan = ParallelPlan(kind="ep", ep=16)
wl = extract_workload(cfg, plan, seq=4096, global_batch=256)
tuned = tune(wl, TPU_V5E, method="lagom", noise=0.01, seed=0)
from repro.core.apply import activate
rt = activate(tuned)          # install: collective call sites now see it
print(f"tuned runtime plan: {len(rt)} addressable site entries; class "
      "fallbacks:", {k: (v.strategy, v.num_chunks) for k, v in rt.items()
                     if "." not in k})

# every comm site is individually addressable: the EP workload's layer-0
# dispatch site resolves through the per-site hierarchy
from repro.parallel.collectives import explain_runtime
knobs, src = explain_runtime("ep.layer0.moe.a2a_disp.fwd.h0")
print(f"site ep.layer0.moe.a2a_disp.fwd.h0 -> {knobs.strategy}/"
      f"x{knobs.num_chunks} (matched plan key {src!r})")

a2a = rt.get("a2a")
from repro.launch.mesh import make_mesh
mesh = make_mesh((8,), ("model",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 16, 64))

# no num_chunks here — the active plan's a2a knobs apply
y = chunked_all_to_all(x, mesh, axis="model", split_axis=1, concat_axis=0,
                       x_spec=P("model", None, None),
                       out_spec=P("model", None, None))
ref = chunked_all_to_all(x, mesh, axis="model", split_axis=1, concat_axis=0,
                         x_spec=P("model", None, None),
                         out_spec=P("model", None, None), num_chunks=1)
print(f"chunked a2a (n={a2a.num_chunks}) matches monolithic:",
      bool(jnp.allclose(y, ref)))
