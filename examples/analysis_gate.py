"""Analysis CI gate: lint + overlap verification over the tuned model zoo.

Runs the two contracts the ``analysis`` CI lane enforces:

1. Healthy plans are clean — zero false positives.  For three zoo
   workloads (llama3-8b/fsdp, deepseek-moe-16b/ep, yi-34b/pp) a fresh
   ``tune()`` must lint to zero findings, and every tuned site must
   verify MATERIALIZED when its production chunked builder is traced
   under the plan (the ``repro.analysis.exercise`` synthetic program).
2. Seeded defects are caught, with stable codes.  A deliberately broken
   copy of the fsdp plan (dead config entry + indivisible chunking) must
   lint to exactly {LAG001, LAG010}, checked both in-process and through
   the CLI's ``--expect`` contract.

The healthy plans (and the broken fixture under ``broken/``) are saved
into OUTDIR (argv[1], default a fresh temp dir) so the CI lane re-runs
the ``python -m repro.analysis`` front door against the same artifacts.

    PYTHONPATH=src python examples/analysis_gate.py [OUTDIR]
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import copy
import sys
import tempfile

from repro.analysis import format_findings, lint_plan
from repro.analysis.__main__ import main as analysis_main
from repro.analysis.exercise import exercise_and_report
from repro.configs import get_config
from repro.core import ParallelPlan, extract_workload, tune
from repro.core.comm_params import CommConfig

ZOO = [
    ("llama3-8b/fsdp", get_config("llama3-8b"),
     ParallelPlan(kind="fsdp", dp=8), dict(layers=2)),
    ("deepseek-moe-16b/ep", get_config("deepseek-moe-16b"),
     ParallelPlan(kind="ep", ep=8), dict(layers=3)),
    ("yi-34b/pp", get_config("yi-34b"),
     ParallelPlan(kind="pp", pp=4, microbatches=4), dict()),
]

outdir = sys.argv[1] if len(sys.argv) > 1 else tempfile.mkdtemp(
    prefix="analysis-gate-")
os.makedirs(os.path.join(outdir, "broken"), exist_ok=True)

# -- contract 1: tuned plans across the zoo lint clean and materialize ------
plans, paths = [], []
for name, cfg, pp, kw in ZOO:
    wl = extract_workload(cfg, pp, seq=2048, global_batch=16, **kw)
    plan = tune(wl, "tpu-v5e")
    findings = lint_plan(plan, workload=wl)
    assert findings == [], (
        f"{name}: healthy tune must lint clean\n"
        + format_findings(findings, label=name))
    ok, text = exercise_and_report(plan, label=name)
    print(text)
    assert ok, f"{name}: every tuned site must be MATERIALIZED"
    path = os.path.join(outdir, name.replace("/", "_") + ".json")
    plan.save(path)
    plans.append(plan)
    paths.append(path)
print(f"zoo gate: {len(plans)} tuned plans lint clean, all sites "
      f"MATERIALIZED -> {outdir}")

# the CLI front door agrees with the in-process result
assert analysis_main(["lint", *paths]) == 0
assert analysis_main(["verify-overlap", *paths]) == 0

# -- contract 2: seeded defects produce exactly the expected codes ----------
broken = copy.deepcopy(plans[0])
broken.configs[(999, 0)] = CommConfig()              # LAG001: dead entry
row = next(s for s in broken.sites if s["kind"] != "reducescatter")
row["bytes"] = 1000003.0                             # prime-ish payload
broken.configs[(row["group"], row["comm"])] = CommConfig(
    algorithm="ring", chunk_kb=256)                  # LAG010: nc=4 won't divide
codes = sorted({f.code for f in lint_plan(broken)})
assert codes == ["LAG001", "LAG010"], codes
broken_path = os.path.join(outdir, "broken", "seeded.json")
broken.save(broken_path)

# --expect inverts the exit code: 0 iff the finding set matches exactly
assert analysis_main(["lint", broken_path]) == 1
assert analysis_main(["lint", broken_path, "--expect", "LAG001,LAG010"]) == 0
assert analysis_main(["lint", broken_path, "--expect", "LAG001"]) == 1
print("seeded-defect gate: broken fixture lints to exactly "
      "LAG001+LAG010 (CLI --expect contract holds)")
