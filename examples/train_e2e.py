"""End-to-end training driver: a ~100M-parameter llama-style model on the
synthetic corpus, with checkpointing and a loss curve.

Default invocation is sized for this CPU container (a ~25M variant, 60
steps); pass ``--full`` for the ~100M/300-step run on real hardware.

    PYTHONPATH=src python examples/train_e2e.py [--full] [--steps N]
"""
import argparse

import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.optim import adamw
from repro.train import checkpoint
from repro.train.trainer import TrainConfig, train_loop


def model_100m():
    return ModelConfig(name="repro-100m", family="dense", num_layers=12,
                       d_model=768, num_heads=12, num_kv_heads=4, d_ff=2048,
                       vocab_size=32000, attn_kind="gqa", pos_kind="rope")


def model_25m():
    return ModelConfig(name="repro-25m", family="dense", num_layers=6,
                       d_model=384, num_heads=6, num_kv_heads=2, d_ff=1024,
                       vocab_size=8192, attn_kind="gqa", pos_kind="rope")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    cfg = model_100m() if args.full else model_25m()
    steps = args.steps or (300 if args.full else 60)
    seq, batch = (512, 8) if args.full else (128, 4)

    n_params = cfg.param_count()
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{steps} steps @ seq={seq} batch={batch}")

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch)
    tcfg = TrainConfig(opt=adamw.AdamWConfig(lr=6e-4), warmup=steps // 10,
                       total_steps=steps)
    params, hist = train_loop(cfg, tcfg, iter(SyntheticCorpus(dc)),
                              steps=steps, log_every=max(1, steps // 15))
    checkpoint.save(args.ckpt, params, step=steps)

    first = float(np.mean(hist["loss"][:5]))
    last = float(np.mean(hist["loss"][-5:]))
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({(first-last)/first*100:.1f}% reduction); "
          f"median step {np.median(hist['step_time'][3:])*1e3:.0f} ms; "
          f"checkpoint at {args.ckpt}")
    assert last < first, "training must reduce loss"


if __name__ == "__main__":
    main()
