"""Quickstart: one front door — tune, persist the plan, reload, re-apply.

Builds the Llama-3-8B FSDP workload from the paper's Table 2, tunes it
with every registered method through ``repro.core.tune`` (NCCL defaults /
AutoCCL / Lagom — the Fig. 7a comparison for one model), then shows the
paper's actual deployment story: the tuned result is a portable
``TunedPlan`` artifact that survives JSON round-trips, refuses structurally
mismatched workloads, and lowers itself to JAX collective runtime knobs.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

from repro.configs import get_config
from repro.core import (A40_NVLINK, ParallelPlan, TunedPlan,
                        extract_workload, tune)

cfg = get_config("llama3-8b")
wl = extract_workload(cfg, ParallelPlan(kind="fsdp", dp=8), seq=2048,
                      global_batch=16)
hw = A40_NVLINK
print(f"workload: {wl.name} — {len(wl.groups)} overlap groups, "
      f"{wl.num_comms} tunable collectives")

# 1. tune once per method — every method returns the same artifact type
base = tune(wl, hw, method="nccl")
ac = tune(wl, hw, method="autoccl", noise=0.01, seed=1)
lag = tune(wl, hw, method="lagom", noise=0.01, seed=0)

# 2. compare — the speedup rows the benchmarks print
row = ac.compare(base, wl)
print(f"AutoCCL      : Z = {row['z_ms']:8.2f} ms   "
      f"({row['speedup']:.3f}x vs NCCL, {ac.profile_count} profiles)")
row = lag.compare(base, wl)
print(f"Lagom        : Z = {row['z_ms']:8.2f} ms   "
      f"({row['speedup']:.3f}x vs NCCL, "
      f"{lag.compare(ac, wl)['speedup']:.3f}x vs AutoCCL, "
      f"{lag.profile_count} profiles)")

# 3. persist -> reload -> apply: the plan IS the deployable artifact
path = os.path.join(tempfile.gettempdir(), "llama3_8b_fsdp_plan.json")
lag.save(path)
reloaded = TunedPlan.load(path)
assert reloaded.configs == lag.configs             # byte-identical configs
rt = reloaded.runtime_plan(wl)                     # fingerprint-checked
print(f"\nplan saved + reloaded: {path}")
per_layer = sorted(k for k in rt if k.startswith("fsdp.layer"))
print(f"runtime plan: {len(rt)} addressable site entries "
      f"(per-layer sites like {per_layer[0]} … {per_layer[-1]}); "
      "class fallbacks:",
      {k: (v.strategy, v.num_chunks) for k, v in sorted(rt.items())
       if "." not in k})
print("re-apply at launch:  python -m repro.launch.train --arch llama3-8b "
      f"--smoke --tuned-plan {path}")

s = lag.configs[(0, 0)]
print(f"\nexample tuned config (fwd layer-0 AllGather): "
      f"NC={s.nc} NT={s.nt} C={s.chunk_kb}KB {s.algorithm}/{s.protocol} "
      f"(NCCL default: NC={hw.default_nc} C={hw.default_chunk_kb}KB)")
