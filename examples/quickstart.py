"""Quickstart: tune a training iteration's collectives with Lagom.

Builds the Llama-3-8B FSDP workload from the paper's Table 2, profiles it
under NCCL defaults, AutoCCL, and Lagom, and prints the end-to-end speedups
(reproducing the Fig. 7a comparison for one model).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import get_config
from repro.core import (A40_NVLINK, ParallelPlan, Simulator, extract_workload)
from repro.core import autoccl, tuner
from repro.core.baselines import nccl_defaults

cfg = get_config("llama3-8b")
plan = ParallelPlan(kind="fsdp", dp=8)
wl = extract_workload(cfg, plan, seq=2048, global_batch=16)
hw = A40_NVLINK
print(f"workload: {wl.name} — {len(wl.groups)} overlap groups, "
      f"{wl.num_comms} tunable collectives")

sim = Simulator(hw, noise=0.01, seed=0)
base = sim.profile(wl, nccl_defaults(wl, hw))
print(f"NCCL default : Z = {base.Z*1e3:8.2f} ms   (X={base.X*1e3:.1f}, Y={base.Y*1e3:.1f})")

ac_cfgs, ac_iters = autoccl.tune_workload(Simulator(hw, noise=0.01, seed=1), wl)
ac = sim.profile(wl, ac_cfgs)
print(f"AutoCCL      : Z = {ac.Z*1e3:8.2f} ms   ({base.Z/ac.Z:.3f}x vs NCCL, "
      f"{ac_iters} profiles)")

lag_cfgs, lag_iters, _ = tuner.tune_workload(sim, wl)
lag = sim.profile(wl, lag_cfgs)
print(f"Lagom        : Z = {lag.Z*1e3:8.2f} ms   ({base.Z/lag.Z:.3f}x vs NCCL, "
      f"{ac.Z/lag.Z:.3f}x vs AutoCCL, {lag_iters} profiles)")

s = lag_cfgs[(0, 0)]
print(f"\nexample tuned config (fwd layer-0 AllGather): "
      f"NC={s.nc} NT={s.nt} C={s.chunk_kb}KB {s.algorithm}/{s.protocol} "
      f"(NCCL default: NC={hw.default_nc} C={hw.default_chunk_kb}KB)")
