"""Plan repository flow: tune once into a repository, relaunch resolves it.

1. Builds the Llama-3-8B (smoke) FSDP workload and tunes it with
   ``tune(..., repo=...)`` — the resulting ``TunedPlan`` is auto-``put``
   into a ``PlanRepository`` under its (workload structural fingerprint,
   hardware) key.
2. Relaunches training with ``--plan-repo``: the launcher rebuilds the
   workload from (arch × parallel spec × shape), resolves the exact key,
   and installs the stored plan with ZERO tuning work at startup.
3. Asserts the installed per-site knobs are exactly the stored plan's
   lowering.

    PYTHONPATH=src python examples/plan_repo_flow.py
"""
import tempfile

from repro.configs import get_smoke_config
from repro.core import ParallelPlan, PlanRepository, extract_workload, tune
from repro.launch import train
from repro.parallel import collectives

repo_dir = tempfile.mkdtemp(prefix="lagom-plan-repo-")
cfg = get_smoke_config("llama3-8b")
parallel = ParallelPlan(kind="fsdp", dp=8)
wl = extract_workload(cfg, parallel, seq=64, global_batch=4)

# 1. tune once; the plan lands in the repository automatically
plan = tune(wl, "tpu-v5e", method="lagom", repo=repo_dir)
entries = PlanRepository(repo_dir).entries()
print(f"repository {repo_dir}: {[(fp[:12] + '…', hw) for fp, hw, _ in entries]}")

# 2. relaunch: --plan-repo auto-resolves the matching (fingerprint,
#    hardware) entry — no tuning happens at startup
argv = ["--arch", "llama3-8b", "--smoke", "--steps", "2"]
argv += ["--seq", "64", "--batch", "4"]
argv += ["--plan-repo", repo_dir]
argv += ["--plan-parallel", "fsdp:8", "--plan-hardware", "tpu-v5e"]
train.main(argv)

# 3. the launcher installed exactly the stored plan's per-site lowering
rt = plan.runtime_plan(wl)
assert collectives.active_runtime_plan() == rt, "repo plan was not installed"
per_site = {k: v for k, v in rt.items() if k.startswith("fsdp.layer")}
print(
    f"installed {len(rt)} addressable site entries "
    f"({len(per_site)} per-layer fsdp sites) — zero tuning at launch"
)
