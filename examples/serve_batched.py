"""Plan-aware batched serving: tune a decode-shape plan once, store it in a
PlanRepository, then serve a *different* batch size — the engine's
tolerance-band lookup finds the nearest tuned shape (a banded, non-exact
hit) and decodes under its per-site chunked collectives at the
``serve.layer{i}.*`` SiteIds.

    PYTHONPATH=src python examples/serve_batched.py
"""
import tempfile

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import ParallelPlan, extract_decode_workload, tune
from repro.models import model as M
from repro.serving import make_engine

cfg = get_smoke_config("h2o-danube-1.8b")
params = M.init_params(cfg, jax.random.PRNGKey(0))

# 1. tune once at one decode shape (batch 4), auto-stored in the repository
repo = tempfile.mkdtemp(prefix="plan_repo_")
pp = ParallelPlan(kind="tp", tp=2)
wl = extract_decode_workload(cfg, pp, global_batch=4, seq=96)
plan = tune(wl, "tpu-v5e", method="lagom", repo=repo)
serve_sites = [s for s in plan.runtime_plan() if s.startswith("serve.")]
print(
    f"tuned decode plan: {len(serve_sites)} serve.* sites "
    f"(e.g. {serve_sites[0]}) stored in {repo}"
)

# 2. serve at a batch the repo was never tuned for (6 != 4): the band
#    resolves the nearest same-structure shape instead of missing
engine = make_engine(
    cfg,
    params,
    mode="fixed",
    batch_size=6,
    max_seq=96,
    repo=repo,
    plan_parallel="tp:2",
    plan_band=0.5,
)

rs = np.random.default_rng(0)
prompts = [rs.integers(0, cfg.vocab_size, size=12).astype(np.int32) for _ in range(6)]
outs = engine.generate(prompts, max_new=12)
for i, o in enumerate(outs):
    print(f"request {i}: prompt={prompts[i][:6].tolist()}... -> {o}")

stats = engine.plan_stats
print(
    f"\nplan resolution: {stats['exact']} exact, {stats['banded']} banded, "
    f"{stats['miss']} miss"
)
assert stats["banded"] == 1 and stats["miss"] == 0, stats

probe = engine.throughput_probe()
print(
    f"decode: {probe['tokens_per_s']:.1f} tok/s "
    f"({probe['s_per_token'] * 1e3:.2f} ms/step @ batch 6, banded plan)"
)
