"""Batched serving example: prefill + greedy decode on a small dense model,
then a decode-throughput probe (the serve_step the decode dry-runs lower).

    PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving.engine import Engine

cfg = get_smoke_config("h2o-danube-1.8b")
params = M.init_params(cfg, jax.random.PRNGKey(0))
engine = Engine(cfg, params, batch_size=4, max_seq=96)

rs = np.random.default_rng(0)
prompts = [rs.integers(0, cfg.vocab_size, size=12).astype(np.int32)
           for _ in range(4)]
outs = engine.generate(prompts, max_new=12)
for i, o in enumerate(outs):
    print(f"request {i}: prompt={prompts[i][:6].tolist()}... -> {o}")

probe = engine.throughput_probe()
print(f"\ndecode: {probe['tokens_per_s']:.1f} tok/s "
      f"({probe['s_per_token']*1e3:.2f} ms/step @ batch 4)")
