"""Fig. 7 reproduction: end-to-end iteration time across the paper's
Table-2 workloads (FSDP on 8/16 GPUs; TP with 2 AllReduce/layer ×
microbatches; EP with dual-batch AlltoAll) on both clusters, under NCCL
defaults / AutoCCL / Lagom."""
from __future__ import annotations

from repro.configs import get_config
from repro.core import (A40_NVLINK, A40_PCIE, ParallelPlan, Simulator,
                        extract_workload)
from repro.core import autoccl, tuner
from repro.core.baselines import nccl_defaults

# (model, plan, seq, global_batch) — Table 2
FSDP_WORKLOADS = [
    ("phi2-2b", ParallelPlan(kind="fsdp", dp=8), 2048, 16),
    ("phi2-2b", ParallelPlan(kind="fsdp", dp=16), 2048, 32),
    ("llama3-8b", ParallelPlan(kind="fsdp", dp=8), 2048, 16),
    ("llama3-8b", ParallelPlan(kind="fsdp", dp=16), 2048, 32),
    ("mpt-7b", ParallelPlan(kind="fsdp", dp=8), 2048, 16),
    ("mpt-7b", ParallelPlan(kind="fsdp", dp=16), 2048, 32),
]
TP_EP_WORKLOADS = [
    ("phi2-2b", ParallelPlan(kind="tp", tp=8), 2048, 512 // 8),
    ("llama3-8b", ParallelPlan(kind="tp", tp=8), 2048, 256 // 8),
    ("mpt-7b", ParallelPlan(kind="tp", tp=8), 2048, 256 // 8),
    ("deepseek-moe-16b", ParallelPlan(kind="ep", ep=8), 2048, 16),
    ("olmoe-1b-7b", ParallelPlan(kind="ep", ep=8), 2048, 16),
]


def _bench(model, plan, seq, gbs, hw, layers=None):
    cfg = get_config(model)
    wl = extract_workload(cfg, plan, seq=seq, global_batch=gbs, layers=layers)
    sim = Simulator(hw, noise=0.01, seed=0)
    base = sim.profile(wl, nccl_defaults(wl, hw))
    lag_cfgs, lag_iters, _ = tuner.tune_workload(sim, wl)
    lag = sim.profile(wl, lag_cfgs)
    ac_cfgs, ac_iters = autoccl.tune_workload(Simulator(hw, noise=0.01, seed=1), wl)
    ac = sim.profile(wl, ac_cfgs)
    return dict(model=model, parallelism=plan.kind,
                world=plan.world, cluster=hw.name,
                nccl_ms=base.Z * 1e3, autoccl_ms=ac.Z * 1e3, lagom_ms=lag.Z * 1e3,
                lagom_vs_nccl=base.Z / lag.Z, lagom_vs_autoccl=ac.Z / lag.Z,
                autoccl_vs_nccl=base.Z / ac.Z,
                lagom_profiles=lag_iters, autoccl_profiles=ac_iters)


def run(fast: bool = False):
    rows = []
    layers = 8 if fast else None
    for hw in (A40_NVLINK, A40_PCIE):
        for model, plan, seq, gbs in FSDP_WORKLOADS:
            r = _bench(model, plan, seq, gbs, hw, layers)
            r["table"] = "fig7a"
            rows.append(r)
        for model, plan, seq, gbs in TP_EP_WORKLOADS:
            r = _bench(model, plan, seq, gbs, hw, layers)
            r["table"] = "fig7b"
            rows.append(r)
    return rows


def headline(rows):
    f = [r for r in rows if r["table"] == "fig7a"]
    t = [r for r in rows if r["table"] == "fig7b" and r["parallelism"] == "tp"]
    e = [r for r in rows if r["table"] == "fig7b" and r["parallelism"] == "ep"]
    out = []
    if f:
        out.append(("fig7a.fsdp_lagom_vs_nccl_range",
                    f"{min(r['lagom_vs_nccl'] for r in f):.3f}-"
                    f"{max(r['lagom_vs_nccl'] for r in f):.3f}",
                    "paper: 1.10-1.33x"))
    if t:
        out.append(("fig7b.tp_lagom_vs_nccl_range",
                    f"{min(r['lagom_vs_nccl'] for r in t):.3f}-"
                    f"{max(r['lagom_vs_nccl'] for r in t):.3f}",
                    "paper: 1.08-1.16x"))
    if e:
        out.append(("fig7b.ep_lagom_vs_nccl_range",
                    f"{min(r['lagom_vs_nccl'] for r in e):.3f}-"
                    f"{max(r['lagom_vs_nccl'] for r in e):.3f}",
                    "paper: 1.07-1.08x"))
    out.append(("fig7.lagom_vs_autoccl_range",
                f"{min(r['lagom_vs_autoccl'] for r in rows):.3f}-"
                f"{max(r['lagom_vs_autoccl'] for r in rows):.3f}",
                "paper: 1.03-1.27x"))
    return out
