"""Fig. 7 reproduction: end-to-end iteration time across the paper's
Table-2 workloads (FSDP on 8/16 GPUs; TP with 2 AllReduce/layer ×
microbatches; EP with dual-batch AlltoAll) on both clusters, under NCCL
defaults / AutoCCL / Lagom."""
from __future__ import annotations

from repro.configs import get_config
from repro.core import (ParallelPlan, Simulator, by_name,
                        extract_workload, tune)

# (model, plan, seq, global_batch) — Table 2
FSDP_WORKLOADS = [
    ("phi2-2b", ParallelPlan(kind="fsdp", dp=8), 2048, 16),
    ("phi2-2b", ParallelPlan(kind="fsdp", dp=16), 2048, 32),
    ("llama3-8b", ParallelPlan(kind="fsdp", dp=8), 2048, 16),
    ("llama3-8b", ParallelPlan(kind="fsdp", dp=16), 2048, 32),
    ("mpt-7b", ParallelPlan(kind="fsdp", dp=8), 2048, 16),
    ("mpt-7b", ParallelPlan(kind="fsdp", dp=16), 2048, 32),
]
TP_EP_WORKLOADS = [
    ("phi2-2b", ParallelPlan(kind="tp", tp=8), 2048, 512 // 8),
    ("llama3-8b", ParallelPlan(kind="tp", tp=8), 2048, 256 // 8),
    ("mpt-7b", ParallelPlan(kind="tp", tp=8), 2048, 256 // 8),
    ("deepseek-moe-16b", ParallelPlan(kind="ep", ep=8), 2048, 16),
    ("olmoe-1b-7b", ParallelPlan(kind="ep", ep=8), 2048, 16),
]


def _bench(model, plan, seq, gbs, hw, layers=None):
    cfg = get_config(model)
    wl = extract_workload(cfg, plan, seq=seq, global_batch=gbs, layers=layers)
    # one tune() per strategy; each makespan measured on a FRESH CRN
    # simulator with one seed — CRN jitter is a pure function of
    # (structure, trajectory position), so the three evaluations see
    # identical draws and differ only by their configs (true common
    # random numbers; a shared default-noise sim would give independent
    # draws per evaluation)
    plans = dict(
        nccl=tune(wl, hw, method="nccl"),
        lagom=tune(wl, hw, method="lagom", noise=0.01, seed=0),
        autoccl=tune(wl, hw, method="autoccl", noise=0.01, seed=1))

    def ev():
        return Simulator(hw, noise=0.01, seed=0, noise_mode="crn")

    z = {name: p.evaluate(wl, sim=ev()).Z for name, p in plans.items()}
    return dict(model=model, parallelism=plan.kind,
                world=plan.world, cluster=hw.name,
                nccl_ms=z["nccl"] * 1e3, autoccl_ms=z["autoccl"] * 1e3,
                lagom_ms=z["lagom"] * 1e3,
                lagom_vs_nccl=z["nccl"] / z["lagom"],
                lagom_vs_autoccl=z["autoccl"] / z["lagom"],
                autoccl_vs_nccl=z["nccl"] / z["autoccl"],
                lagom_profiles=plans["lagom"].profile_count,
                autoccl_profiles=plans["autoccl"].profile_count)


def run(fast: bool = False):
    rows = []
    layers = 8 if fast else None
    for hw in (by_name("a40-nvlink"), by_name("a40-pcie")):
        for model, plan, seq, gbs in FSDP_WORKLOADS:
            r = _bench(model, plan, seq, gbs, hw, layers)
            r["table"] = "fig7a"
            rows.append(r)
        for model, plan, seq, gbs in TP_EP_WORKLOADS:
            r = _bench(model, plan, seq, gbs, hw, layers)
            r["table"] = "fig7b"
            rows.append(r)
    return rows


def headline(rows):
    f = [r for r in rows if r["table"] == "fig7a"]
    t = [r for r in rows if r["table"] == "fig7b" and r["parallelism"] == "tp"]
    e = [r for r in rows if r["table"] == "fig7b" and r["parallelism"] == "ep"]
    out = []
    if f:
        out.append(("fig7a.fsdp_lagom_vs_nccl_range",
                    f"{min(r['lagom_vs_nccl'] for r in f):.3f}-"
                    f"{max(r['lagom_vs_nccl'] for r in f):.3f}",
                    "paper: 1.10-1.33x"))
    if t:
        out.append(("fig7b.tp_lagom_vs_nccl_range",
                    f"{min(r['lagom_vs_nccl'] for r in t):.3f}-"
                    f"{max(r['lagom_vs_nccl'] for r in t):.3f}",
                    "paper: 1.08-1.16x"))
    if e:
        out.append(("fig7b.ep_lagom_vs_nccl_range",
                    f"{min(r['lagom_vs_nccl'] for r in e):.3f}-"
                    f"{max(r['lagom_vs_nccl'] for r in e):.3f}",
                    "paper: 1.07-1.08x"))
    out.append(("fig7.lagom_vs_autoccl_range",
                f"{min(r['lagom_vs_autoccl'] for r in rows):.3f}-"
                f"{max(r['lagom_vs_autoccl'] for r in rows):.3f}",
                "paper: 1.03-1.27x"))
    return out
