"""Fig. 8a/b reproduction: Phi-2-2B FSDP pattern-level breakdown on
cluster A (NVLink).  Pattern 1 = single-comm forward window (AllGather ‖
layer compute); Pattern 2 = two-comm backward window (AllGather +
ReduceScatter ‖ grad compute).  Reports per-strategy configs and pattern
speedups (paper: AutoCCL 0.87×, Lagom 1.35× / 1.43×)."""
from __future__ import annotations

from repro.configs import get_config
from repro.core import A40_NVLINK, ParallelPlan, Simulator, extract_workload
from repro.core import autoccl, tuner
from repro.core.baselines import nccl_defaults


def run():
    hw = A40_NVLINK
    cfg = get_config("phi2-2b")
    wl = extract_workload(cfg, ParallelPlan(kind="fsdp", dp=8), seq=2048,
                          global_batch=16)
    # pattern 1: a forward group (1 AllGather); pattern 2: a backward group
    p1 = next(g for g in wl.groups if g.name.startswith("fwd"))
    p2 = next(g for g in wl.groups if g.name.startswith("bwd"))
    rows = []
    for pname, g in (("pattern1", p1), ("pattern2", p2)):
        sim = Simulator(hw, noise=0.01, seed=0)
        base_cfg = list(nccl_defaults(wl, hw).values())[:len(g.comms)]
        base = sim.profile_group(g, base_cfg)       # batched-engine API
        lag = tuner.tune_group(sim, g)
        lag_m = sim.profile_group(g, lag.configs)
        ac_cfgs, _ = autoccl.tune_group(Simulator(hw, noise=0.01, seed=1), g)
        ac_m = sim.profile_group(g, ac_cfgs)
        for strat, m, cfgs in (("nccl", base, base_cfg), ("autoccl", ac_m, ac_cfgs),
                               ("lagom", lag_m, lag.configs)):
            c0 = cfgs[0]
            rows.append(dict(table="fig8ab", pattern=pname, strategy=strat,
                             z_ms=m.Z * 1e3, x_ms=m.X * 1e3, y_ms=m.Y * 1e3,
                             nc=c0.nc, chunk_kb=c0.chunk_kb,
                             speedup_vs_nccl=base.Z / m.Z))
    return rows


def headline(rows):
    by = {(r["pattern"], r["strategy"]): r for r in rows}
    return [
        ("fig8.pattern1_lagom_speedup", by[("pattern1", "lagom")]["speedup_vs_nccl"],
         "paper: 1.35x"),
        ("fig8.pattern1_autoccl_speedup", by[("pattern1", "autoccl")]["speedup_vs_nccl"],
         "paper: 0.87x"),
        ("fig8.pattern2_lagom_speedup", by[("pattern2", "lagom")]["speedup_vs_nccl"],
         "paper: 1.43x"),
        ("fig8.lagom_cfg_p1", f"NC={by[('pattern1','lagom')]['nc']} "
                              f"C={by[('pattern1','lagom')]['chunk_kb']}KB",
         "paper: NC=2 C=684KB (NCCL: NC=8 C=2MB)"),
    ]
