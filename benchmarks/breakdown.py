"""Fig. 8a/b reproduction: Phi-2-2B FSDP pattern-level breakdown on
cluster A (NVLink).  Pattern 1 = single-comm forward window (AllGather ‖
layer compute); Pattern 2 = two-comm backward window (AllGather +
ReduceScatter ‖ grad compute).  Reports per-strategy configs and pattern
speedups (paper: AutoCCL 0.87×, Lagom 1.35× / 1.43×)."""
from __future__ import annotations

from repro.configs import get_config
from repro.core import (ParallelPlan, Simulator, Workload, by_name,
                        extract_workload, tune)


def run():
    hw = by_name("a40-nvlink")
    cfg = get_config("phi2-2b")
    wl = extract_workload(cfg, ParallelPlan(kind="fsdp", dp=8), seq=2048,
                          global_batch=16)
    # pattern 1: a forward group (1 AllGather); pattern 2: a backward group
    p1 = next(g for g in wl.groups if g.name.startswith("fwd"))
    p2 = next(g for g in wl.groups if g.name.startswith("bwd"))
    rows = []
    for pname, g in (("pattern1", p1), ("pattern2", p2)):
        # one-group workload per pattern -> the session front door drives
        # the whole tune/evaluate/compare loop
        gwl = Workload(f"{wl.name}:{pname}", [g])
        plans = dict(
            nccl=tune(gwl, hw, method="nccl"),
            autoccl=tune(gwl, hw, method="autoccl", noise=0.01, seed=1),
            lagom=tune(gwl, hw, method="lagom", noise=0.01, seed=0))
        # fresh CRN sim per strategy: identical jitter draws, so the
        # pattern speedups isolate the config differences
        meas = {s: p.evaluate(gwl, sim=Simulator(hw, noise=0.01, seed=0,
                                                 noise_mode="crn"))
                for s, p in plans.items()}
        for strat in ("nccl", "autoccl", "lagom"):
            m, c0 = meas[strat], plans[strat].configs[(0, 0)]
            rows.append(dict(table="fig8ab", pattern=pname, strategy=strat,
                             z_ms=m.Z * 1e3, x_ms=m.X * 1e3, y_ms=m.Y * 1e3,
                             nc=c0.nc, chunk_kb=c0.chunk_kb,
                             speedup_vs_nccl=meas["nccl"].Z / m.Z))
    return rows


def headline(rows):
    by = {(r["pattern"], r["strategy"]): r for r in rows}
    return [
        ("fig8.pattern1_lagom_speedup", by[("pattern1", "lagom")]["speedup_vs_nccl"],
         "paper: 1.35x"),
        ("fig8.pattern1_autoccl_speedup", by[("pattern1", "autoccl")]["speedup_vs_nccl"],
         "paper: 0.87x"),
        ("fig8.pattern2_lagom_speedup", by[("pattern2", "lagom")]["speedup_vs_nccl"],
         "paper: 1.43x"),
        ("fig8.lagom_cfg_p1", f"NC={by[('pattern1','lagom')]['nc']} "
                              f"C={by[('pattern1','lagom')]['chunk_kb']}KB",
         "paper: NC=2 C=684KB (NCCL: NC=8 C=2MB)"),
    ]
