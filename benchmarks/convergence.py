"""Fig. 8c reproduction: tuning-iteration counts vs number of
communications — Lagom's profile count grows linearly (≈2× AutoCCL's
single-comm count for a 2-comm overlap, per the paper)."""
from __future__ import annotations

from repro.core import Workload, by_name, tune
from repro.core.workload import CommOp, OverlapGroup, matmul_comp


def _group(n_comms: int):
    # comp scales with n so the X:Y regime (and thus per-comm tuning depth)
    # is constant — isolating the complexity-in-N measurement
    comps = [matmul_comp(f"mm{i}", 4096, 2560, 10240) for i in range(4 * n_comms)]
    comms = [CommOp(f"c{i}", "allreduce", 64e6, 8) for i in range(n_comms)]
    return OverlapGroup(f"g{n_comms}", comps=comps, comms=comms)


def run():
    rows = []
    for n in (1, 2, 4, 8):
        wl = Workload(f"g{n}", [_group(n)])
        hw = by_name("a40-nvlink")
        lag = tune(wl, hw, noise=0.01, seed=0)
        ac = tune(wl, hw, method="autoccl", noise=0.01, seed=1)
        rows.append(dict(table="fig8c", n_comms=n,
                         lagom_iters=lag.profile_count,
                         autoccl_iters=ac.profile_count,
                         lagom_per_comm=lag.profile_count / n))
    return rows


def headline(rows):
    by = {r["n_comms"]: r for r in rows}
    ratio = by[2]["lagom_iters"] / by[1]["lagom_iters"]
    ratio8 = by[8]["lagom_iters"] / by[1]["lagom_iters"]
    return [("fig8c.lagom_iters_2comm_over_1comm", ratio, "paper: ~2 (linear)"),
            ("fig8c.lagom_iters_8comm_over_1comm", ratio8, "linear -> ~8")]
