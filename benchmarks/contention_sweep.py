"""Fig. 3 reproduction: FFN overlapped with AllReduce(32MB) on 8×A40-PCIe,
sweeping NC and C.  Reports computation/communication time per config and
the paper's two anchors: comm-equal configs with ≫ different comp times,
and the +30% comp slowdown from NC 16→32."""
from __future__ import annotations

from repro.core import CommConfig, by_name
from repro.core import contention as C
from repro.core.workload import CommOp, matmul_comp


def run():
    hw = by_name("a40-pcie")
    ffn = matmul_comp("ffn", 4096, 2560, 10240)       # the paper's FFN op
    ar = CommOp("ar32mb", "allreduce", 32e6, 8)
    rows = []
    # Fig 3a: NC × C grid
    for nc in (1, 2, 4, 8, 16, 32, 61):
        for c_kb in (16, 64, 256, 1024, 4096, 16384):
            cfg = CommConfig(nc=nc, chunk_kb=min(8192, c_kb))
            rows.append(dict(
                table="fig3a", nc=nc, chunk_kb=cfg.chunk_kb,
                comp_ms=C.comp_time(ffn, cfg, hw) * 1e3,
                comm_ms=C.comm_time(ar, cfg, hw, compute_active=True) * 1e3))
    # Fig 3b: NC sweep at C=16KB
    for nc in range(1, 33):
        cfg = CommConfig(nc=nc, chunk_kb=16)
        rows.append(dict(table="fig3b", nc=nc, chunk_kb=16,
                         comp_ms=C.comp_time(ffn, cfg, hw) * 1e3,
                         comm_ms=C.comm_time(ar, cfg, hw, compute_active=True) * 1e3))
    # Fig 3c: C sweep at NC=4
    for c_kb in (32, 64, 128, 256, 512, 1024, 2048, 4096, 8192):
        cfg = CommConfig(nc=4, chunk_kb=c_kb)
        rows.append(dict(table="fig3c", nc=4, chunk_kb=c_kb,
                         comp_ms=C.comp_time(ffn, cfg, hw) * 1e3,
                         comm_ms=C.comm_time(ar, cfg, hw, compute_active=True) * 1e3))
    return rows


def headline(rows):
    by = {(r["table"], r["nc"], r["chunk_kb"]): r for r in rows}
    t16 = by[("fig3b", 16, 16)]["comp_ms"]
    t32 = by[("fig3b", 32, 16)]["comp_ms"]
    return [("fig3.nc16to32_comp_slowdown_pct", (t32 / t16 - 1) * 100,
             "paper: +30.2%")]
