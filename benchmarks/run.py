"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]

(``pyproject.toml`` puts ``src`` on the path for pytest only; console runs
set ``PYTHONPATH=src`` — no in-module ``sys.path`` surgery.)

Prints the ``name,value,derived`` headline CSV (one row per paper claim)
and writes the full per-config tables to experiments/bench/<name>.csv.
"""
from __future__ import annotations

import argparse
import csv
import os
import time

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")

MODULES = ["contention_sweep", "priority_demo", "end_to_end", "breakdown",
           "convergence", "roofline", "tuning_throughput"]


def _write_csv(name, rows):
    if not rows:
        return
    os.makedirs(OUT, exist_ok=True)
    keys = sorted({k for r in rows for k in r})
    with open(os.path.join(OUT, f"{name}.csv"), "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys)
        w.writeheader()
        w.writerows(rows)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    mods = [args.only] if args.only else MODULES
    print("name,value,derived")
    for name in mods:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        kw = {}
        if name in ("end_to_end", "tuning_throughput") and args.fast:
            kw["fast"] = True
        rows = mod.run(**kw)
        _write_csv(name, rows)
        if name == "roofline":
            rows2 = mod.run(multi_pod=True)
            _write_csv("roofline_pod2", rows2)
        for key, val, derived in mod.headline(rows):
            if isinstance(val, float):
                val = f"{val:.4g}"
            print(f"{key},{val},{derived}")
        print(f"_timing.{name},{time.time()-t0:.1f}s,", flush=True)


if __name__ == "__main__":
    main()
