"""Tuning-throughput microbenchmark — seconds per ``tune_workload`` call.

Three comparisons, every repetition on a fresh Simulator (cold engine, cold
caches: fingerprinting, cache fills, and the vectorized replays are all
inside the measured time — the honest end-to-end cost):

  1. **Engine vs event loop** (PR 1's headline, regression guard): the
     batched profiling engine against the sequential pure-Python event
     loop on the llama3-8b FSDP workload.  Target: >= 5x.
  2. **Interleaved vs serial walk** (the cross-group scheduler): one
     lock-step ``profile_many_grouped`` call per step — with trajectory
     sharing across structurally identical groups — against the PR 1
     batched path that finishes each group before starting the next.
     Multi-group workloads: yi-34b pipeline, deepseek-moe-16b EP, llama3-8b
     FSDP.  Target: >= 2x (noise-free), with configs, traces, and
     ``profile_count`` byte-identical to the serial walk (asserted here on
     every run).
  3. **Noisy modes** (PR 3's headline): CRN noise (``noise_mode="crn"``,
     fingerprint-keyed counter-based draws — trajectory sharing is sound
     under jitter) against the default-noise interleaved path (the PR 2
     noisy configuration, where sharing is unsound and the win was call
     amortization only, ~1.1-1.5x).  Target: >= 3x on at least two of the
     multi-group workloads (full mode asserts the second-best speedup);
     CRN interleaved results are asserted byte-identical to the CRN
     serial walk on every run, and ``engine.cache_stats()`` telemetry is
     reported for every noisy row.

Run directly (``PYTHONPATH=src python -m benchmarks.tuning_throughput
[--fast] [--seed N] [--no-noisy]``) the equality and speedup-floor
assertions double as the CI
engine-regression smoke (the fast lane uses ``--fast``: fewer reps, trimmed
workloads, and conservative floors — 1.3x best-interleave, 2x best-CRN —
so shared-runner jitter cannot flake the lane while a real scheduling or
noise-engine regression, which sinks every workload at once, still fails
it).  The scheduled benchmark lane runs the full sweep and uploads the
``experiments/bench`` CSVs.
"""
from __future__ import annotations

import argparse
import time
from functools import partial

from repro.configs import get_config
from repro.core import ParallelPlan, Simulator, by_name, extract_workload
from repro.core import autoccl, tuner


def _best_of(make_a, call_a, make_b, call_b, reps):
    """Interleaved best-of-reps for two (simulator, call) strategies:
    alternating the paths rep-by-rep and taking each one's minimum makes
    the ratio robust to the bursty CPU noise of shared runners (min is the
    standard microbenchmark estimator — every rep does identical work, so
    the fastest rep is the least-perturbed one)."""
    t_a, t_b = [], []
    r_a = r_b = sim_b = None
    for _ in range(reps):
        sim = make_a()
        t0 = time.perf_counter()
        r_a = call_a(sim)
        t_a.append(time.perf_counter() - t0)
        sim_b = make_b()
        t0 = time.perf_counter()
        r_b = call_b(sim_b)
        t_b.append(time.perf_counter() - t0)
    return min(t_a), min(t_b), r_a, r_b, sim_b


def _tune(wl, mode="interleaved"):
    def call(sim):
        return tuner.search_workload(sim, wl, mode=mode)
    return call


def _tune_autoccl(wl, mode="interleaved"):
    def call(sim):
        return autoccl.search_workload(sim, wl, mode=mode)
    return call


def _stats_cols(sim):
    stats = sim.engine.cache_stats()
    return dict(meas_hits=stats["measurements"]["hits"],
                meas_misses=stats["measurements"]["misses"],
                meas_evictions=stats["measurements"]["evictions"],
                col_hits=stats["columns"]["hits"],
                col_misses=stats["columns"]["misses"],
                col_evictions=stats["columns"]["evictions"],
                dedup_shared=stats["dedup_shared"])


def _workloads(fast: bool):
    yi = extract_workload(get_config("yi-34b"),
                          ParallelPlan(kind="pp", pp=4, microbatches=4),
                          seq=2048, global_batch=16)
    ds = extract_workload(get_config("deepseek-moe-16b"),
                          ParallelPlan(kind="ep", ep=8), seq=2048,
                          global_batch=16, layers=4 if fast else None)
    ll = extract_workload(get_config("llama3-8b"),
                          ParallelPlan(kind="fsdp", dp=8), seq=2048,
                          global_batch=16, layers=8 if fast else None)
    return [("yi-34b/pp", yi), ("deepseek-moe-16b/ep", ds),
            ("llama3-8b/fsdp", ll)]


def run(fast: bool = False, seed: int = 0, noisy: bool = True):
    hw = by_name("tpu-v5e")
    reps = 2 if fast else 5
    floor = 1.3 if fast else 2.0
    rows = []
    workloads = _workloads(fast)
    noises = (0.0, 0.01) if noisy else (0.0,)

    def sim_of(noise, sd, mode="default", batched=True):
        return partial(Simulator, hw, noise=noise, seed=sd, noise_mode=mode,
                       batched=batched)

    # -- 1. engine vs sequential event loop (PR 1 regression guard) -------
    ll = workloads[2][1]
    for noise in noises:
        scenarios = [("lagom", _tune(ll, mode="serial"))]
        if noise:       # AutoCCL samples in-situ, i.e. always with jitter
            scenarios.append(("autoccl", _tune_autoccl(ll, mode="serial")))
        for tname, call in scenarios:
            t_seq, t_bat, r_seq, r_bat, sim_b = _best_of(
                sim_of(noise, seed, batched=False), call,
                sim_of(noise, seed), call, max(2, reps - 2))
            assert r_seq == r_bat, "batched path changed tuning results"
            if tname == "lagom" and not noise:
                assert t_seq / t_bat >= (2.0 if fast else 3.5), \
                    f"engine speedup regressed to {t_seq / t_bat:.2f}x"
            profiles = r_seq[1]
            stats = _stats_cols(sim_b) if noise else {}
            rows.append(dict(table="engine_vs_event_loop", tuner=tname,
                             workload="llama3-8b/fsdp", noise=noise,
                             profiles=profiles, seq_s=t_seq, batched_s=t_bat,
                             seq_us_per_profile=t_seq / profiles * 1e6,
                             batched_us_per_profile=t_bat / profiles * 1e6,
                             speedup=t_seq / t_bat, **stats))

    # -- 2. cross-group interleaved scheduler vs serial walk --------------
    clean_speedups = []
    for wname, wl in workloads:
        # small workloads finish in ~ms, where shared-runner jitter is large
        # relative to the measurement — buy stability with extra reps
        reps_w = reps * 3 if len(wl.groups) < 20 else reps
        for noise in noises:
            t_ser, t_int, r_ser, r_int, sim_i = _best_of(
                sim_of(noise, seed), _tune(wl, mode="serial"),
                sim_of(noise, seed), _tune(wl), reps_w)
            if not noise:
                # acceptance: byte-identical configs/traces/profile_count
                assert r_ser == r_int, \
                    f"{wname}: interleaved schedule changed tuning results"
                clean_speedups.append(t_ser / t_int)
            rows.append(dict(table="interleave_vs_serial", tuner="lagom",
                             workload=wname, noise=noise,
                             groups=len(wl.groups), profiles=r_int[1],
                             serial_s=t_ser, interleaved_s=t_int,
                             speedup=t_ser / t_int, **_stats_cols(sim_i)))
    # acceptance: >= 2x fewer seconds per call than the PR 1 path on a
    # multi-group workload.  Existential (best workload), not per-workload:
    # a real scheduling regression sinks every row at once, while the
    # smallest workloads (~ms per call) can individually flake on a noisy
    # shared runner.
    best = max(clean_speedups)
    assert best >= floor, \
        f"interleaved speedup peaked at {best:.2f}x, below the {floor}x floor"

    # -- 3. CRN noise vs the PR 2 noisy path (default-noise interleaved) --
    if noisy:
        crn_speedups = []
        for wname, wl in workloads:
            reps_w = reps * 3 if len(wl.groups) < 20 else reps
            t_def, t_crn, r_def, r_crn, sim_c = _best_of(
                sim_of(0.01, seed), _tune(wl),
                sim_of(0.01, seed, mode="crn"), _tune(wl), reps_w)
            # acceptance: CRN trajectory sharing is a pure re-scheduling —
            # shared interleaved results byte-identical to the serial walk
            crn_serial = _tune(wl, mode="serial")(
                sim_of(0.01, seed, mode="crn")())
            assert r_crn == crn_serial, \
                f"{wname}: CRN sharing changed tuning results"
            crn_speedups.append(t_def / t_crn)
            rows.append(dict(table="noisy_modes", tuner="lagom",
                             workload=wname, noise=0.01,
                             groups=len(wl.groups),
                             default_profiles=r_def[1],
                             crn_profiles=r_crn[1],
                             default_inter_s=t_def, crn_s=t_crn,
                             speedup=t_def / t_crn, **_stats_cols(sim_c)))
        # acceptance: >= 3x over the PR 2 noisy path on at least TWO
        # multi-group workloads (full mode asserts the second-best); the
        # fast smoke uses trimmed workloads with less layer repetition, so
        # it floors the best speedup conservatively instead.
        if fast:
            crn_best = max(crn_speedups)
            assert crn_best >= 2.0, \
                f"CRN speedup peaked at {crn_best:.2f}x, below the 2x floor"
        else:
            second = sorted(crn_speedups)[-2]
            assert second >= 3.0, \
                f"CRN speedup >=3x on fewer than two workloads " \
                f"(second-best {second:.2f}x)"

    # -- 4. AutoCCL through the same scheduler ----------------------------
    ds = workloads[1][1]
    for noise in noises:
        t_ser, t_int, a_ser, a_int, _ = _best_of(
            sim_of(noise, seed + 1), _tune_autoccl(ds, mode="serial"),
            sim_of(noise, seed + 1), _tune_autoccl(ds), reps)
        if not noise:
            assert a_ser == a_int, "autoccl interleaved changed results"
        rows.append(dict(table="autoccl_interleave", tuner="autoccl",
                         workload="deepseek-moe-16b/ep", noise=noise,
                         serial_s=t_ser, interleaved_s=t_int,
                         speedup=t_ser / t_int,
                         identical=(a_ser == a_int)))
    return rows


def headline(rows):
    eng = {(r["tuner"], r["noise"]): r for r in rows
           if r["table"] == "engine_vs_event_loop"}
    inter = {(r["workload"], r["noise"]): r for r in rows
             if r["table"] == "interleave_vs_serial"}
    crn = {r["workload"]: r for r in rows if r["table"] == "noisy_modes"}
    multi_min = min(r["speedup"] for (w, n), r in inter.items() if n == 0.0)
    out = [
        ("tuning_throughput.llama3_8b_engine_speedup",
         eng[("lagom", 0.0)]["speedup"],
         "batched engine vs event loop; target: >=5x (PR 1)"),
        ("tuning_throughput.multi_group_interleave_speedup_min",
         multi_min,
         "interleaved scheduler vs PR 1 serial walk, min over "
         "multi-group workloads; target: >=2x, results byte-identical"),
    ]
    if crn:
        second = sorted(r["speedup"] for r in crn.values())[-2]
        out.append(("tuning_throughput.noisy_crn_speedup_2nd_best",
                    second,
                    "CRN noise vs PR 2 noisy path (default-noise "
                    "interleaved), 2nd-best over multi-group workloads; "
                    "full-bench floor: >=3x (the --fast smoke instead "
                    "floors the best at 2x on trimmed workloads); CRN "
                    "shared == serial byte-identical"))
        for w, r in sorted(crn.items()):
            out.append((f"tuning_throughput.noisy_crn.{w}",
                        r["speedup"],
                        f"{r['groups']} groups, {r['crn_profiles']} logical "
                        f"profiles, dedup_shared={r['dedup_shared']}"))
    for (w, n), r in sorted(inter.items()):
        out.append((f"tuning_throughput.interleave.{w}.noise{n}",
                    r["speedup"],
                    f"{r['groups']} groups, {r['profiles']} profiles, "
                    f"dedup_shared={r['dedup_shared']}"))
    if ("autoccl", 0.01) in eng:
        out.append(("tuning_throughput.autoccl_engine_speedup",
                    eng[("autoccl", 0.01)]["speedup"],
                    "baseline tuner through the same engine"))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: fewer reps, trimmed workloads, "
                         "conservative floors")
    ap.add_argument("--seed", type=int, default=0,
                    help="base Simulator seed for every scenario")
    ap.add_argument("--noisy", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="include the noisy rows (--no-noisy for a "
                         "deterministic-only smoke)")
    args = ap.parse_args(argv)
    rows = run(fast=args.fast, seed=args.seed, noisy=args.noisy)
    for r in rows:
        print(r)
    for key, val, derived in headline(rows):
        print(f"{key},{val:.4g},{derived}")


if __name__ == "__main__":
    main()
