"""Tuning-throughput microbenchmark: seconds per ``tune_workload`` call on
the llama3-8b FSDP workload, batched profiling engine vs the sequential
event-loop path.  Every repetition uses a fresh Simulator (cold engine, cold
caches), so the reported batched time includes fingerprinting, cache fills,
and the vectorized replays — the honest end-to-end cost.  Headline target:
>= 5x fewer seconds per call (ISSUE 1 acceptance)."""
from __future__ import annotations

import time

from repro.configs import get_config
from repro.core import ParallelPlan, Simulator, TPU_V5E, extract_workload
from repro.core import autoccl, tuner


def _time_pair(make_seq, make_bat, call, reps):
    """Interleaved best-of-reps for both strategies: alternating the two
    paths rep-by-rep and taking each one's minimum makes the ratio robust
    to the bursty CPU noise of shared runners (min is the standard
    microbenchmark estimator — every rep does identical work, so the
    fastest rep is the least-perturbed one)."""
    t_seq, t_bat = [], []
    r_seq = r_bat = None
    for _ in range(reps):
        sim = make_seq()
        t0 = time.perf_counter()
        r_seq = call(sim)
        t_seq.append(time.perf_counter() - t0)
        sim = make_bat()
        t0 = time.perf_counter()
        r_bat = call(sim)
        t_bat.append(time.perf_counter() - t0)
    return min(t_seq), min(t_bat), r_seq, r_bat


def run(fast: bool = False):
    hw = TPU_V5E
    cfg = get_config("llama3-8b")
    wl = extract_workload(cfg, ParallelPlan(kind="fsdp", dp=8), seq=2048,
                          global_batch=16)
    reps = 3 if fast else 7
    rows = []

    for noise in (0.0, 0.01):
        scenarios = [("lagom", lambda sim: tuner.tune_workload(sim, wl)[:2])]
        if noise:       # AutoCCL samples in-situ, i.e. always with jitter
            scenarios.append(
                ("autoccl", lambda sim: autoccl.tune_workload(sim, wl)))
        for tname, call in scenarios:
            t_seq, t_bat, r_seq, r_bat = _time_pair(
                lambda: Simulator(hw, noise=noise, seed=0, batched=False),
                lambda: Simulator(hw, noise=noise, seed=0),
                call, reps)
            assert r_seq == r_bat, "batched path changed tuning results"
            profiles = r_seq[1]
            rows.append(dict(table="tuning_throughput", tuner=tname,
                             noise=noise, profiles=profiles,
                             seq_s=t_seq, batched_s=t_bat,
                             seq_us_per_profile=t_seq / profiles * 1e6,
                             batched_us_per_profile=t_bat / profiles * 1e6,
                             speedup=t_seq / t_bat))
    return rows


def headline(rows):
    by = {(r["tuner"], r["noise"]): r for r in rows}
    clean = by[("lagom", 0.0)]
    noisy = by[("lagom", 0.01)]
    return [
        ("tuning_throughput.llama3_8b_speedup", clean["speedup"],
         "target: >=5x vs sequential path (noise-free)"),
        ("tuning_throughput.llama3_8b_seq_s", clean["seq_s"],
         "seconds per tune_workload, sequential"),
        ("tuning_throughput.llama3_8b_batched_s", clean["batched_s"],
         "seconds per tune_workload, batched engine"),
        ("tuning_throughput.llama3_8b_noisy_speedup", noisy["speedup"],
         "jittered profiles: rate-column cache only"),
        ("tuning_throughput.autoccl_speedup", by[("autoccl", 0.01)]["speedup"],
         "baseline tuner through the same engine"),
    ]


if __name__ == "__main__":
    for r in run():
        print(r)
