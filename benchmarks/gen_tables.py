"""Markdown tables from the dry-run/roofline artifacts.

    PYTHONPATH=src python -m benchmarks.gen_tables
"""
import json
import os

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES
from benchmarks.roofline import analytic, load_dryrun

# --- dry-run table ---
print("## DRYRUN TABLE")
for pod, mp in (("pod1", False), ("pod2", True)):
    print(f"### {pod}")
    print("| arch | shape | status | peak GiB/dev | grad_accum | HLO coll ops | lower+compile s |")
    print("|---|---|---|---|---|---|---|")
    for a in ASSIGNED_ARCHS:
        for s in INPUT_SHAPES:
            d = load_dryrun(a, s, mp)
            if d is None:
                print(f"| {a} | {s} | MISSING | | | | |")
                continue
            if d["status"] != "ok":
                why = d.get("why","")[:40]
                print(f"| {a} | {s} | skipped: {why} | — | — | — | — |")
                continue
            mem = d["memory"]["peak_bytes"]/2**30
            print(f"| {a} | {s} | ok | {mem:.2f} | {d.get('grad_accum','—')} | {d['collectives']['count']} | {d.get('lower_s',0)}+{d.get('compile_s',0)} |")

print()
print("## ROOFLINE TABLE (single-pod 16x16, analytic-corrected; see caveat)")
print("| arch | shape | compute s | memory s | collective s | dominant | MODEL/HLO flops | note |")
print("|---|---|---|---|---|---|---|---|")
NOTES = {"collective": "reduce TP degree / tune overlap (Lagom)",
         "memory": "batch or quantize; params+cache traffic bound",
         "compute": "at MXU roofline; overlap remaining comms"}
for a in ASSIGNED_ARCHS:
    for s in INPUT_SHAPES:
        r = analytic(a, s)
        if r is None:
            print(f"| {a} | {s} | — | — | — | skipped (full attention @500k) | — | — |")
            continue
        print(f"| {a} | {s} | {r['compute_s']:.4f} | {r['memory_s']:.4f} | {r['collective_s']:.4f} | "
              f"{r['dominant']} | {r['useful_ratio']:.2f} | {NOTES[r['dominant']]} |")

# --- §Perf variant table (tagged dry-runs vs baselines) ---
print()
print("## PERF VARIANTS (tagged dry-runs)")
print("| file | peak GiB/dev | HLO coll ops |")
print("|---|---|---|")
import glob as _g
for p in sorted(_g.glob("experiments/dryrun/*_pod1_*.json")):
    d = json.load(open(p))
    if d.get("status") != "ok":
        continue
    name = os.path.basename(p)[:-5]
    print(f"| {name} | {d['memory']['peak_bytes']/2**30:.2f} | {d['collectives']['count']} |")
