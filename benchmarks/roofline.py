"""Roofline terms per (architecture × input shape × mesh) — deliverable (g).

Sources:
  * the dry-run JSONs (experiments/dryrun/*.json): compile proof, per-device
    memory analysis, RAW cost_analysis FLOPs/bytes and HLO-parsed collective
    bytes.  CAVEAT (documented in EXPERIMENTS.md): XLA's HLO cost analysis
    counts while/scan bodies ONCE, and this framework deliberately wraps
    layers, grad-accum, CE chunks and attention blocks in scans to keep
    512-way GSPMD compiles tractable — so the raw numbers undercount by the
    product of trip counts.
  * ANALYTIC per-op counts (this file): the corrected roofline inputs.
    Every formula is written out; MODEL_FLOPS = 6·N·D (dense) or
    6·N_active·D (MoE); the ratio MODEL_FLOPS / analytic-HLO-FLOPs exposes
    remat recompute (≈0.75 for 1-recompute training) and attention/router
    overheads.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI
per chip.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

from repro.configs import (ASSIGNED_ARCHS, INPUT_SHAPES, get_config,
                           shape_applicable)

PEAK = 197e12
HBM = 819e9
ICI = 50e9
DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def _attn_ctx(cfg, shape):
    """Average attended context length per query token."""
    S = shape.seq_len
    if shape.kind == "decode":
        return min(S, cfg.sliding_window) if cfg.sliding_window else S
    full = S / 2                                  # causal average
    return min(full, cfg.sliding_window) if cfg.sliding_window else full


def analytic(arch: str, shape_name: str, *, multi_pod: bool = False,
             tp: int = 16) -> Optional[Dict]:
    """``tp`` parameterizes the sharding plan: 16 = the 2-D baseline,
    1 = pure FSDP (no activation ARs, full-param gathers), 2/4/8 = hybrid."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return None
    chips = 512 if multi_pod else 256
    dp = chips // tp
    N_active = cfg.param_count(active_only=True)
    N_total = cfg.param_count()
    P_BYTES = 2                                   # bf16 params/activations

    # attention layers: all of the stack for dense/moe/vlm/audio; only the
    # shared-block applications for the zamba2 hybrid; none for RWKV6
    # (linear recurrence flops are folded into the projection param-flops).
    if cfg.family == "ssm":
        attn_layers = 0
    elif cfg.family == "hybrid":
        attn_layers = cfg.num_layers // max(1, cfg.shared_attn_every)
    else:
        attn_layers = cfg.num_layers + cfg.encoder_layers

    if shape.kind == "decode":
        tokens = shape.global_batch                 # one token per sequence
        passes = 1.0                                # no backward
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        passes = 1.0
    else:                                          # train: fwd + bwd(2x) + remat refwd
        tokens = shape.global_batch * shape.seq_len
        passes = 4.0

    param_flops = 2.0 * N_active * tokens * passes
    # per attention layer per token: 2·ctx·d_attn (QKᵀ) + 2·ctx·d_attn (AV)
    attn_flops = 4.0 * cfg.q_dim * _attn_ctx(cfg, shape) * tokens * passes * attn_layers
    hlo_flops = param_flops + attn_flops
    model_flops = 6.0 * N_active * tokens if shape.kind == "train" \
        else 2.0 * N_active * tokens

    # ---- memory bytes (per step,全 chips) --------------------------------
    if shape.kind == "train":
        # params read fwd+bwd+remat (3×bf16) + grad write (bf16)
        # + AdamW state read+write (2 moments + master, f32)
        param_traffic = N_total * (4 * P_BYTES + 10 * 4)
        act_traffic = tokens * cfg.d_model * (cfg.num_layers + cfg.encoder_layers) \
            * P_BYTES * 8          # ~8 activation r/w per layer after fusion
        ce_traffic = tokens * cfg.vocab_size * P_BYTES * 2 / 256 * 2  # chunked logits
        kv_traffic = 0.0
    elif shape.kind == "prefill":
        param_traffic = N_total * P_BYTES
        act_traffic = tokens * cfg.d_model * (cfg.num_layers + cfg.encoder_layers) * P_BYTES * 4
        ce_traffic = shape.global_batch * cfg.vocab_size * P_BYTES
        kv_traffic = 0.0
    else:
        param_traffic = N_active * P_BYTES          # every chip pass over its shard sums to one model pass
        act_traffic = tokens * cfg.d_model * cfg.num_layers * P_BYTES * 4
        ce_traffic = shape.global_batch * cfg.vocab_size * P_BYTES
        ctx = _attn_ctx(cfg, shape)
        if cfg.attn_kind == "mla":
            per_tok_cache = cfg.kv_lora_rank + cfg.qk_rope_head_dim
        elif cfg.family == "ssm":
            per_tok_cache = 0       # constant state
            ctx = cfg.num_heads * cfg.head_dim * cfg.head_dim / max(1, 1)  # state read once
        else:
            per_tok_cache = 2 * cfg.kv_dim
        n_cache_layers = attn_layers if cfg.family == "hybrid" else cfg.num_layers
        kv_traffic = (shape.global_batch * ctx * per_tok_cache * P_BYTES
                      * n_cache_layers) if per_tok_cache else \
            shape.global_batch * cfg.num_layers * cfg.num_heads * cfg.head_dim ** 2 * 4
    hbm_bytes = param_traffic + act_traffic + ce_traffic + kv_traffic

    # ---- collective bytes (wire, per chip) --------------------------------
    n_passes_comm = 3.0 if shape.kind == "train" else 1.0
    tokens_local = tokens / dp
    coll = 0.0
    if shape.kind == "train":
        # FSDP: AG(params) fwd + AG bwd + RS(grads) over the data axis;
        # payload per chip = its model-column slice of the params
        coll += 3.0 * (N_total * P_BYTES / tp) * (dp - 1) / dp
    # TP: 2 collectives per layer (attn out, mlp out), AR = 2× payload;
    # with sequence-parallel AG+RS it is the same wire volume
    coll += (2 * 2 * (cfg.num_layers + cfg.encoder_layers) * tokens_local
             * cfg.d_model * P_BYTES * (tp - 1) / tp) * n_passes_comm
    if cfg.is_moe:
        coll += (2 * cfg.top_k * tokens_local * cfg.d_model * P_BYTES
                 * (tp - 1) / tp) * n_passes_comm * (cfg.num_layers - cfg.first_dense_layers) / cfg.num_layers
    if multi_pod and shape.kind == "train":
        coll += 2.0 * (N_total * 4 / (dp * tp)) * 0.5   # cross-pod grad AR slice

    t_compute = hlo_flops / chips / PEAK
    t_memory = hbm_bytes / chips / HBM
    t_coll = coll / ICI
    dom = max((t_compute, "compute"), (t_memory, "memory"), (t_coll, "collective"))
    return dict(arch=arch, shape=shape_name, mesh="2x16x16" if multi_pod else "16x16",
                chips=chips,
                compute_s=t_compute, memory_s=t_memory, collective_s=t_coll,
                dominant=dom[1],
                model_flops=model_flops, hlo_flops_analytic=hlo_flops,
                useful_ratio=model_flops / hlo_flops,
                hbm_bytes=hbm_bytes, coll_bytes_per_chip=coll)


def load_dryrun(arch, shape_name, multi_pod=False, tag=""):
    pod = "pod2" if multi_pod else "pod1"
    suffix = f"_{tag}" if tag else ""
    path = os.path.join(DRYRUN_DIR, f"{arch}_{shape_name}_{pod}{suffix}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def run(multi_pod: bool = False):
    rows = []
    for arch in ASSIGNED_ARCHS:
        for shape_name in INPUT_SHAPES:
            a = analytic(arch, shape_name, multi_pod=multi_pod)
            if a is None:
                rows.append(dict(table="roofline", arch=arch, shape=shape_name,
                                 mesh="2x16x16" if multi_pod else "16x16",
                                 status="skipped"))
                continue
            d = load_dryrun(arch, shape_name, multi_pod)
            a.update(table="roofline",
                     status=(d or {}).get("status", "missing"),
                     peak_gib=round((d or {}).get("memory", {}).get("peak_bytes", 0) / 2 ** 30, 2),
                     raw_hlo_flops=(d or {}).get("flops", 0),
                     raw_coll_bytes=sum(v for k, v in (d or {}).get("collectives", {}).items()
                                        if k != "count"))
            rows.append(a)
    return rows


def headline(rows):
    ok = [r for r in rows if r.get("status") == "ok"]
    doms = {}
    for r in ok:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    return [("roofline.compiled_combos", len(ok), "of 33 applicable"),
            ("roofline.dominant_split", str(doms), "bottleneck census")]
