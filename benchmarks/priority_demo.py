"""Fig. 5 reproduction: 2 AllReduce + 7 MatMul concurrent; tuning each
communication's NC from 1→16 yields different comm-gain/comp-cost
trade-offs — the motivation for metric H."""
from __future__ import annotations

from repro.core import CommConfig, Simulator, by_name
from repro.core.priority import metric_h
from repro.core.workload import CommOp, OverlapGroup, matmul_comp


def _group():
    comps = [matmul_comp(f"mm{i}", 8192, 2560, 10240) for i in range(7)]
    # commB first in the serialized comm stream so both overlap the matmuls
    comms = [CommOp("commB", "allreduce", 48e6, 8),
             CommOp("commA", "allreduce", 256e6, 8)]
    return OverlapGroup("fig5", comps=comps, comms=comms)


def run():
    hw = by_name("a40-pcie")
    sim = Simulator(hw)
    g = _group()
    base_cfgs = [CommConfig(nc=2, chunk_kb=512), CommConfig(nc=2, chunk_kb=512)]
    base = sim.profile_group(g, base_cfgs)
    rows = []
    for j, name in enumerate(("commB", "commA")):
        # the NC sweep is embarrassingly parallel: one batched engine call
        sweep = []
        for nc in (2, 4, 8, 16):
            cfgs = list(base_cfgs)
            cfgs[j] = CommConfig(nc=nc, chunk_kb=512)
            sweep.append(cfgs)
        for nc, m in zip((2, 4, 8, 16), sim.profile_many(g, sweep)):
            h = metric_h(base.Y, m.Y, base.comm_times[j], m.comm_times[j])
            rows.append(dict(table="fig5", comm=name, nc=nc,
                             comp_ms=m.Y * 1e3, comm_ms=m.comm_times[j] * 1e3,
                             total_ms=m.Z * 1e3,
                             H=h if h != float("inf") else -1.0))
    return rows


def headline(rows):
    # the paper's point: different comms have DIFFERENT comm-gain/comp-cost
    # trade-offs (arrow slopes in Fig. 5), quantified by H at NC=16
    h = {(r["comm"], r["nc"]): r["H"] for r in rows}
    z = {(r["comm"], r["nc"]): r["total_ms"] for r in rows}
    return [("fig5.H_commA_at_nc16", h[("commA", 16)], "comp cost per comm gain"),
            ("fig5.H_commB_at_nc16", h[("commB", 16)], "smaller H -> tune B first"),
            ("fig5.best_total_tuning_B_ms", min(z[("commB", n)] for n in (2, 4, 8, 16)),
             "vs tuning A: " + f"{min(z[('commA', n)] for n in (2, 4, 8, 16)):.1f} ms")]
