"""Deterministic synthetic-corpus LM data pipeline.

Generates a reproducible token stream from a seeded Markov-ish mixture so
training loss actually *decreases* (the stream has learnable structure:
skewed unigram + bigram correlations), sharded by (host, data-parallel
rank), with packing into fixed-length sequences and next-token targets.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # learnable structure knobs
    zipf_a: float = 1.2
    bigram_weight: float = 0.5


class SyntheticCorpus:
    """Infinite deterministic stream: each (epoch, shard) slice is pure."""

    def __init__(self, cfg: DataConfig, *, shard: int = 0, num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # skewed unigram distribution
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._unigram = (ranks ** -cfg.zipf_a)
        self._unigram /= self._unigram.sum()
        # low-rank bigram structure: next ~ permutation(prev) half the time
        self._perm = rng.permutation(v)

    def _batch_rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.cfg.seed * 1_000_003 + step) * 65_537 + self.shard)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = self._batch_rng(step)
        B, S = self.local_batch, cfg.seq_len
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.choice(cfg.vocab_size, size=B, p=self._unigram)
        iid = rng.choice(cfg.vocab_size, size=(B, S), p=self._unigram)
        use_bigram = rng.random((B, S)) < cfg.bigram_weight
        for t in range(S):
            follow = self._perm[toks[:, t]]
            toks[:, t + 1] = np.where(use_bigram[:, t], follow, iid[:, t])
        return {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
            "mask": np.ones((B, S), np.float32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_batch(cfg, shape, *, step: int = 0, seed: int = 0,
               d_model: Optional[int] = None) -> Dict[str, np.ndarray]:
    """One global batch for (ModelConfig, InputShape) incl. frontend stubs."""
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
                    global_batch=shape.global_batch, seed=seed)
    b = SyntheticCorpus(dc).batch(step)
    rng = np.random.default_rng(seed + 17)
    if cfg.family == "audio":
        b["frames"] = rng.standard_normal(
            (shape.global_batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32) * 0.02
    if cfg.family == "vlm":
        from repro.models.model import N_PATCHES
        b["patches"] = rng.standard_normal(
            (shape.global_batch, N_PATCHES, cfg.d_model)).astype(np.float32) * 0.02
    return b
