"""Training metrics: analytic step FLOPs and MFU accounting.

MFU = model FLOPs (6·N_active·tokens, no remat credit) / wall / peak —
the MaxText/PaLM convention; hardware peaks default to TPU v5e.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

TPU_V5E_PEAK = 197e12


@dataclass
class StepFlops:
    model: float        # 6·N_active·tokens (the MFU numerator)
    executed: float     # incl. remat recompute (8·N_active·tokens)


def train_step_flops(cfg, tokens: int, *, remat: bool = True) -> StepFlops:
    n = cfg.param_count(active_only=True)
    return StepFlops(model=6.0 * n * tokens,
                     executed=(8.0 if remat else 6.0) * n * tokens)


def mfu(cfg, tokens: int, step_seconds: float, *, chips: int = 1,
        peak: float = TPU_V5E_PEAK) -> float:
    f = train_step_flops(cfg, tokens)
    return f.model / max(step_seconds, 1e-12) / (chips * peak)


class Tracker:
    """Rolling window over step metrics; used by the train loop."""

    def __init__(self, cfg, tokens_per_step: int, *, chips: int = 1,
                 peak: float = TPU_V5E_PEAK, window: int = 20):
        self.cfg = cfg
        self.tokens = tokens_per_step
        self.chips = chips
        self.peak = peak
        self.window = window
        self.times: list = []

    def update(self, step_seconds: float) -> Dict[str, float]:
        self.times.append(step_seconds)
        recent = self.times[-self.window:]
        avg = sum(recent) / len(recent)
        return {
            "step_s": step_seconds,
            "tokens_per_s": self.tokens / avg,
            "mfu": mfu(self.cfg, self.tokens, avg, chips=self.chips,
                       peak=self.peak),
        }
