"""Training loop: jitted train_step (pjit/GSPMD) with optional Domino-style
dual-microbatch interleave (the TP/EP overlap pattern the paper tunes).

``make_train_step`` builds the function the dry-run lowers: params/opt-state
sharded by ``parallel.sharding`` rules, batch over the data axes, loss via
chunked cross-entropy, gradients averaged implicitly by GSPMD.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.optim import adamw, schedules
from repro.train import metrics as MET


@dataclass
class TrainConfig:
    opt: adamw.AdamWConfig = adamw.AdamWConfig()
    schedule: str = "warmup_cosine"
    warmup: int = 100
    total_steps: int = 10_000
    remat: bool = True
    microbatches: int = 1      # >1: dual-batch interleave (EP/TP overlap)
    grad_accum: int = 1        # sequential microbatches (memory ceiling)
    accum_axis: Optional[str] = None   # ACCO accumulation overlap: with
                                       # grad_accum > 1, unroll the
                                       # microbatch loop and reduce batch
                                       # k's grads over this named dp mesh
                                       # axis (chunked psum at site
                                       # acc.step{k}.rs_grads) while k+1's
                                       # compute runs; requires the step to
                                       # execute under shard_map/pmap with
                                       # the axis bound
    backend: Optional[str] = None   # kernel backend override
    sited_mesh: Optional[Any] = None   # plan-aware explicit collectives:
                                       # per-layer sites resolve against the
                                       # active TunedPlan (dense families)


def make_train_step(cfg, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch, step) ->
    (params, opt_state, metrics)."""
    sched = getattr(schedules, tcfg.schedule)

    def loss_fn(params, batch):
        loss, metrics = M.loss_and_metrics(cfg, params, batch,
                                           remat=tcfg.remat,
                                           backend=tcfg.backend,
                                           mesh=tcfg.sited_mesh)
        return loss, metrics

    def train_step(params, opt_state, batch, step):
        if tcfg.grad_accum > 1 and tcfg.accum_axis:
            # ACCO accumulation overlap: the microbatch loop is
            # Python-unrolled so each step k is static — its grad reduce
            # resolves the tuned knobs at site acc.step{k}.rs_grads at
            # trace time and is issued before microbatch k+1's compute,
            # letting XLA's latency-hiding scheduler pull the collective
            # under it (the paper's Pattern 2, lifted to the accumulation
            # loop).  Per-microbatch reduce (not accumulate-then-reduce)
            # is what creates the K overlap windows the acc.* sites tune.
            from repro.parallel import collectives

            n = tcfg.grad_accum
            mbs = [jax.tree.map(lambda a: a[i::n], batch) for i in range(n)]
            gsum = None
            tot_loss = jnp.zeros((), jnp.float32)
            metrics = None
            for k, b in enumerate(mbs):
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, b)
                g = collectives.psum_tree_chunked(
                    g, tcfg.accum_axis, site=f"acc.step{k}.rs_grads")
                g = jax.tree.map(lambda x: x.astype(jnp.float32), g)
                gsum = g if gsum is None else jax.tree.map(jnp.add, gsum, g)
                tot_loss = tot_loss + l
                metrics = m
            scale = n * collectives.axis_size(tcfg.accum_axis)
            grads = jax.tree.map(lambda a: a / scale, gsum)
            loss = tot_loss / n
        elif tcfg.grad_accum > 1:
            # sequential gradient accumulation via scan: bounds live
            # activations to one microbatch; grads accumulate in f32.
            n = tcfg.grad_accum
            mb = jax.tree.map(
                lambda a: a.reshape((n, a.shape[0] // n) + a.shape[1:]), batch)

            def accum(carry, b):
                gsum, lsum = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, b)
                gsum = jax.tree.map(
                    lambda s, x: s + x.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, tot_loss), metrics = jax.lax.scan(
                accum, (g0, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda a: a / n, grads)
            loss = tot_loss / n
            metrics = jax.tree.map(lambda a: a[-1], metrics)
        elif tcfg.microbatches > 1:
            # dual-batch interleave: split along batch; XLA's scheduler
            # overlaps microbatch i's collectives with i+1's compute.
            n = tcfg.microbatches
            parts = [jax.tree.map(lambda a: a[i::n], batch) for i in range(n)]
            grads = None
            tot_loss = 0.0
            metrics = None
            for p_ in parts:
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, p_)
                grads = g if grads is None else jax.tree.map(jnp.add, grads, g)
                tot_loss = tot_loss + l
                metrics = m
            grads = jax.tree.map(lambda a: a / n, grads)
            loss = tot_loss / n
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        lr_scale = sched(step, warmup=tcfg.warmup, total=tcfg.total_steps)
        params, opt_state, opt_metrics = adamw.apply_updates(
            params, grads, opt_state, tcfg.opt, lr_scale)
        metrics = dict(metrics, **opt_metrics, loss=loss)
        return params, opt_state, metrics

    return train_step


def train_loop(cfg, tcfg: TrainConfig, data_iter, *, steps: int,
               rng=None, params=None, log_every: int = 10,
               callback=None) -> Tuple[Any, Dict[str, list]]:
    """Single-host training driver (examples / smoke tests)."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    if params is None:
        params = M.init_params(cfg, rng)
    opt_state = adamw.init_state(params)
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    history: Dict[str, list] = {"loss": [], "step_time": [], "mfu": []}
    tracker = None
    t_prev = time.perf_counter()
    for step in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(data_iter).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch,
                                             jnp.asarray(step))
        loss = float(metrics["loss"])
        t_now = time.perf_counter()
        if tracker is None:
            tokens = int(batch["tokens"].shape[0] * batch["tokens"].shape[1])
            tracker = MET.Tracker(cfg, tokens)
        m = tracker.update(t_now - t_prev)
        history["loss"].append(loss)
        history["step_time"].append(t_now - t_prev)
        history["mfu"].append(m["mfu"])
        t_prev = t_now
        if callback:
            callback(step, metrics)
        if log_every and step % log_every == 0:
            print(f"step {step:5d}  loss {loss:.4f}  "
                  f"grad_norm {float(metrics['grad_norm']):.3f}  "
                  f"tok/s {m['tokens_per_s']:.0f}")
    return params, history
