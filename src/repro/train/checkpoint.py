"""Checkpointing: msgpack-free, numpy ``.npz`` + structure manifest.

Works on any pytree of arrays (params, optimizer state, data-pipeline
cursor).  Writes are atomic (tmp file + rename); a ``latest`` symlink tracks
the newest step, and ``keep`` bounds retention.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}, treedef


def save(path: str, tree, *, step: int, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    os.makedirs(path, exist_ok=True)
    arrays, treedef = _flatten(tree)
    ck = os.path.join(path, f"step_{step:08d}")
    tmp = ck + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "treedef": str(treedef),
                   "num_leaves": len(arrays), "extra": extra or {}}, f)
    if os.path.exists(ck):
        shutil.rmtree(ck)
    os.rename(tmp, ck)
    latest = os.path.join(path, "latest")
    with open(latest, "w") as f:
        f.write(os.path.basename(ck))
    _gc(path, keep)
    return ck


def restore(path: str, tree_like, *, step: Optional[int] = None):
    """Restores into the structure of ``tree_like``; returns (tree, step)."""
    if step is None:
        with open(os.path.join(path, "latest")) as f:
            ck = os.path.join(path, f.read().strip())
    else:
        ck = os.path.join(path, f"step_{step:08d}")
    with np.load(os.path.join(ck, "arrays.npz")) as z:
        arrays = [z[f"leaf_{i}"] for i in range(len(z.files))]
    with open(os.path.join(ck, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree.flatten(tree_like)
    assert len(leaves) == len(arrays), \
        f"checkpoint has {len(arrays)} leaves, model expects {len(leaves)}"
    restored = jax.tree.unflatten(treedef, arrays)
    return restored, manifest["step"]


def _gc(path: str, keep: int) -> None:
    cks = sorted(d for d in os.listdir(path) if d.startswith("step_"))
    for d in cks[:-keep]:
        shutil.rmtree(os.path.join(path, d), ignore_errors=True)
