"""Checkpointing: msgpack-free, numpy ``.npz`` + structure manifest.

Works on any pytree of arrays (params, optimizer state, data-pipeline
cursor).  Writes are atomic (tmp file + rename); a ``latest`` symlink tracks
the newest step, and ``keep`` bounds retention.

Restores are fault-tolerant: a corrupt checkpoint (truncated ``.npz``,
mangled manifest, wrong leaf count) warns and falls back to the newest
intact *earlier* step instead of crashing the relaunch — a torn write
should cost one checkpoint interval of progress, not the job.
"""
from __future__ import annotations

import json
import os
import shutil
import warnings
import zipfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

# what a torn/corrupt checkpoint actually raises when loaded: truncated
# zip container (BadZipFile), short reads / missing files (OSError covers
# FileNotFoundError, EOFError for pickled payload stubs), mangled .npy
# headers or manifest JSON (ValueError covers json.JSONDecodeError), and
# missing leaf_{i} keys (KeyError).
_LOAD_ERRORS = (zipfile.BadZipFile, OSError, EOFError, ValueError, KeyError)


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}, treedef


def save(path: str, tree, *, step: int, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    os.makedirs(path, exist_ok=True)
    arrays, treedef = _flatten(tree)
    ck = os.path.join(path, f"step_{step:08d}")
    tmp = ck + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "treedef": str(treedef),
                   "num_leaves": len(arrays), "extra": extra or {}}, f)
    if os.path.exists(ck):
        shutil.rmtree(ck)
    os.rename(tmp, ck)
    latest = os.path.join(path, "latest")
    with open(latest, "w") as f:
        f.write(os.path.basename(ck))
    _gc(path, keep)
    return ck


def _load_one(ck: str, tree_like):
    """Load one checkpoint dir into ``tree_like``'s structure (raises on
    any corruption; see ``_LOAD_ERRORS``)."""
    with np.load(os.path.join(ck, "arrays.npz")) as z:
        arrays = [z[f"leaf_{i}"] for i in range(len(z.files))]
    with open(os.path.join(ck, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree.flatten(tree_like)
    if len(leaves) != len(arrays):
        raise ValueError(
            f"checkpoint has {len(arrays)} leaves, model expects {len(leaves)}")
    return jax.tree.unflatten(treedef, arrays), manifest["step"]


def restore(path: str, tree_like, *, step: Optional[int] = None):
    """Restores into the structure of ``tree_like``; returns (tree, step).

    A corrupt requested checkpoint warns (``RuntimeWarning``) and falls
    back to the newest intact strictly-earlier step; only when every
    candidate is unreadable does a ``FileNotFoundError`` surface."""
    if step is None:
        with open(os.path.join(path, "latest")) as f:
            first = f.read().strip()
    else:
        first = f"step_{step:08d}"
    # fallback chain: the requested step, then every strictly-earlier one,
    # newest first (zero-padded names sort chronologically)
    earlier = sorted(
        (d for d in os.listdir(path)
         if d.startswith("step_") and not d.endswith(".tmp") and d < first),
        reverse=True)
    errors = []
    for name in [first] + earlier:
        ck = os.path.join(path, name)
        try:
            return _load_one(ck, tree_like)
        except _LOAD_ERRORS as e:
            errors.append(f"{name}: {type(e).__name__}: {e}")
            warnings.warn(
                f"checkpoint {ck} is unreadable ({type(e).__name__}: {e})"
                + (f" — falling back to {earlier[len(errors) - 1]}"
                   if len(errors) <= len(earlier) else ""),
                RuntimeWarning, stacklevel=2)
    raise FileNotFoundError(
        f"no intact checkpoint at or before {first} under {path}; tried: "
        + "; ".join(errors))


def _gc(path: str, keep: int) -> None:
    cks = sorted(d for d in os.listdir(path) if d.startswith("step_"))
    for d in cks[:-keep]:
        shutil.rmtree(os.path.join(path, d), ignore_errors=True)
