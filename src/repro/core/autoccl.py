"""AutoCCL baseline [NSDI'25] — the state-of-the-art communication tuner
Lagom compares against.

AutoCCL optimizes each communication's OWN latency via divide-and-conquer
(implementation-related subspaces) + online sampling of resource-related
parameters, oblivious to the computation it overlaps with.  In
communication-bound overlaps this is near-optimal; in computation-bound
overlaps it over-allocates resources (e.g. NC=61 in the paper's Fig. 8)
and can land below the NCCL default (0.87×).

ProfileTime goes through ``Simulator.profile_group`` and therefore the
batched engine's caches (core.profiling): coordinate descent revisits
configs when a shrink/grow cycle stalls, and structurally identical layers
repeat whole search trajectories, so AutoCCL never re-measures an
already-profiled point.  Its inner loop stays sequential by necessity —
each candidate's acceptance mutates the descent state (and the shared
budget) that the next candidate derives from.
"""
from __future__ import annotations

import math
from typing import Dict, List, Tuple

from repro.core.comm_params import CommConfig
from repro.core.simulator import Simulator
from repro.core.workload import ConfigSet, OverlapGroup, Workload

# pruned implementation-related subspaces (transport fixed to the cluster's
# native path, as AutoCCL's probe would select immediately)
_SUBSPACES: List[Tuple[str, str]] = [
    ("ring", "mixed"), ("ring", "bulk"), ("tree", "mixed"), ("bidir", "bulk"),
]


def _measure_x(sim: Simulator, group: OverlapGroup, cfgs: List[CommConfig],
               j: int) -> float:
    """Online sampling: measure comm j's latency in-situ (overlap running)."""
    return sim.profile_group(group, cfgs).comm_times[j]


def tune_group(sim: Simulator, group: OverlapGroup, *,
               max_steps_per_comm: int = 24) -> Tuple[List[CommConfig], int]:
    n = len(group.comms)
    start = sim.profile_count
    cfgs = [CommConfig() for _ in range(n)]
    for j in range(n):
        best_cfg, best_x = None, math.inf
        budget = max_steps_per_comm
        for algo, proto in _SUBSPACES:
            if budget <= 0:
                break
            # coordinate descent on (nc, chunk) inside the subspace:
            cur = CommConfig(algorithm=algo, protocol=proto, nc=4, chunk_kb=512)
            trial = list(cfgs)
            trial[j] = cur
            x_cur = _measure_x(sim, group, trial, j)
            budget -= 1
            improved = True
            while improved and budget > 0:
                improved = False
                for field_, vals in (("nc", (cur.nc * 2, max(1, cur.nc // 2))),
                                     ("chunk_kb", (cur.chunk_kb * 2, max(32, cur.chunk_kb // 2)))):
                    for v in vals:
                        if budget <= 0:
                            break
                        cand = cur.with_(**{field_: v})
                        if cand == cur:
                            continue
                        trial[j] = cand
                        x_c = _measure_x(sim, group, trial, j)
                        budget -= 1
                        if x_c < x_cur * 0.995:
                            cur, x_cur = cand, x_c
                            improved = True
            if x_cur < best_x:
                best_cfg, best_x = cur, x_cur
        cfgs[j] = best_cfg.with_(done=True)
    return cfgs, sim.profile_count - start


def tune_workload(sim: Simulator, wl: Workload) -> Tuple[ConfigSet, int]:
    configs: ConfigSet = {}
    iters = 0
    for gi, g in enumerate(wl.groups):
        res, it = tune_group(sim, g)
        for ci, cfg in enumerate(res):
            configs[(gi, ci)] = cfg
        iters += it
    return configs, iters
