"""AutoCCL baseline [NSDI'25] — the state-of-the-art communication tuner
Lagom compares against.

AutoCCL optimizes each communication's OWN latency via divide-and-conquer
(implementation-related subspaces) + online sampling of resource-related
parameters, oblivious to the computation it overlaps with.  In
communication-bound overlaps this is near-optimal; in computation-bound
overlaps it over-allocates resources (e.g. NC=61 in the paper's Fig. 8)
and can land below the NCCL default (0.87×).

ProfileTime goes through the batched engine's caches (core.profiling):
coordinate descent revisits configs when a shrink/grow cycle stalls, and
structurally identical layers repeat whole search trajectories, so AutoCCL
never re-measures an already-profiled point.  Its inner loop stays
sequential by necessity — each candidate's acceptance mutates the descent
state (and the shared budget) that the next candidate derives from — so
``AutoCCLSearch`` yields one-candidate batches; the cross-group scheduler
(core.scheduler) still interleaves the per-group descents, folding every
unfinished group's next sample into one engine call per step.
"""
from __future__ import annotations

import math
import warnings
from typing import List, Tuple

from repro.core.comm_params import CommConfig
from repro.core.scheduler import StepSearch, run_workload
from repro.core.simulator import Simulator
from repro.core.workload import ConfigSet, OverlapGroup, Workload

# pruned implementation-related subspaces (transport fixed to the cluster's
# native path, as AutoCCL's probe would select immediately)
_SUBSPACES: List[Tuple[str, str]] = [
    ("ring", "mixed"), ("ring", "bulk"), ("tree", "mixed"), ("bidir", "bulk"),
]


class AutoCCLSearch(StepSearch):
    """AutoCCL's per-group search as a resumable step machine.  The
    generator below is the former blocking coordinate descent with each
    in-situ sample (``sim.profile_group``) replaced by a one-candidate
    ``yield``; semantics and the per-comm budget are unchanged."""

    def __init__(self, group: OverlapGroup, *, max_steps_per_comm: int = 24):
        self.group = group
        self.max_steps_per_comm = max_steps_per_comm
        self.cfgs: List[CommConfig] = [CommConfig()
                                       for _ in range(len(group.comms))]
        super().__init__()

    def _search(self):
        group, cfgs = self.group, self.cfgs
        for j in range(len(group.comms)):
            best_cfg, best_x = None, math.inf
            budget = self.max_steps_per_comm
            for algo, proto in _SUBSPACES:
                if budget <= 0:
                    break
                # coordinate descent on (nc, chunk) inside the subspace:
                cur = CommConfig(algorithm=algo, protocol=proto,
                                 nc=4, chunk_kb=512)
                trial = list(cfgs)
                trial[j] = cur
                x_cur = (yield [trial])[0].comm_times[j]
                budget -= 1
                improved = True
                while improved and budget > 0:
                    improved = False
                    for field_, vals in (
                            ("nc", (cur.nc * 2, max(1, cur.nc // 2))),
                            ("chunk_kb", (cur.chunk_kb * 2,
                                          max(32, cur.chunk_kb // 2)))):
                        for v in vals:
                            if budget <= 0:
                                break
                            cand = cur.with_(**{field_: v})
                            if cand == cur:
                                continue
                            trial[j] = cand
                            x_c = (yield [trial])[0].comm_times[j]
                            budget -= 1
                            if x_c < x_cur * 0.995:
                                cur, x_cur = cand, x_c
                                improved = True
                if x_cur < best_x:
                    best_cfg, best_x = cur, x_cur
            cfgs[j] = best_cfg.with_(done=True)


def tune_group(sim: Simulator, group: OverlapGroup, *,
               max_steps_per_comm: int = 24) -> Tuple[List[CommConfig], int]:
    """Drive one ``AutoCCLSearch`` to completion (the serial walk)."""
    s = AutoCCLSearch(group, max_steps_per_comm=max_steps_per_comm)
    while not s.done:
        s.feed(sim.profile_many(group, s.pending))
    return s.cfgs, s.requests


def search_workload(sim: Simulator, wl: Workload, *,
                    mode: str = "interleaved") -> Tuple[ConfigSet, int]:
    """Tune every overlap group; ``mode="interleaved"`` (default) folds each
    unfinished group's next in-situ sample into one cross-group engine call
    per step, and whenever sharing is sound (deterministic or CRN noise —
    ``Simulator.can_share_trajectories``) structurally identical groups
    share one descent (scheduler.run_shared).  ``mode="serial"`` is the
    reference walk, ``mode="shared"`` requires sharing soundness up front;
    deterministic and CRN results are identical across all three."""
    from repro.core.profiling import group_fingerprint

    per_group = run_workload(sim, wl.groups, AutoCCLSearch,
                             group_fingerprint, mode)
    configs: ConfigSet = {}
    iters = 0
    for gi, s in enumerate(per_group):
        for ci, cfg in enumerate(s.cfgs):
            configs[(gi, ci)] = cfg
        iters += s.requests
    return configs, iters


def tune_workload(sim: Simulator, wl: Workload, *,
                  interleave: bool = True) -> Tuple[ConfigSet, int]:
    """Deprecated pre-session entry point (one release of grace): the
    legacy 2-tuple signature, bit-identical to ``search_workload`` with
    ``mode="interleaved" if interleave else "serial"``.  Use
    ``repro.core.session.tune(..., method="autoccl")`` instead."""
    warnings.warn(
        "autoccl.tune_workload is deprecated; use repro.core.session.tune("
        "wl, hw, method='autoccl', mode=...) — or autoccl.search_workload "
        "for an existing Simulator — and will be removed next release",
        DeprecationWarning, stacklevel=2)
    return search_workload(sim, wl,
                           mode="interleaved" if interleave else "serial")
