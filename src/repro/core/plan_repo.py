"""PlanRepository: a directory store of ``TunedPlan`` artifacts keyed on
(workload structural fingerprint × hardware name).

The paper's deployment story is "co-tune once, deploy the plan"; the
repository is the *once* made operational.  ``session.tune(..., repo=...)``
auto-``put``s every tuned plan, and the launchers' ``--plan-repo`` flag
``resolve``s the current (workload, hardware) pair at startup — a hit
installs the stored plan with zero tuning work, a miss launches untuned
with a warning.

Layout: one strict-RFC JSON file per key, named
``<fingerprint>__<hardware>.json`` (the fingerprint is the sha256 hex
``session.workload_fingerprint`` emits; hardware is ``Hardware.name``).
``get`` re-verifies the loaded plan's own provenance against the key and
refuses misfiled or tampered entries (``PlanRepoError``) rather than
installing configs tuned for a different structure.
"""
from __future__ import annotations

import os
from typing import Iterable, List, Optional, Tuple, Union

from repro.core.hardware import Hardware
from repro.core.session import TunedPlan, workload_fingerprint
from repro.core.workload import Workload


class PlanRepoError(ValueError):
    """A repository entry's content does not match its (fingerprint,
    hardware) key — misfiled, tampered, or hand-edited; refuse to apply."""


def _hw_name(hardware: Union[Hardware, str]) -> str:
    return hardware.name if isinstance(hardware, Hardware) else str(hardware)


class PlanRepository:
    """Directory-backed ``TunedPlan`` store keyed on (fingerprint, hardware)."""

    def __init__(self, root: Union[str, os.PathLike]):
        self.root = str(root)
        os.makedirs(self.root, exist_ok=True)

    # -- keys --------------------------------------------------------------
    def path_for(self, fingerprint: str, hardware: Union[Hardware, str]) -> str:
        return os.path.join(self.root, f"{fingerprint}__{_hw_name(hardware)}.json")

    def entries(self) -> List[Tuple[str, str, str]]:
        """Sorted ``(fingerprint, hardware, path)`` rows for every entry."""
        rows = []
        for fn in sorted(os.listdir(self.root)):
            if fn.endswith(".json") and "__" in fn:
                fp, hw = fn[: -len(".json")].split("__", 1)
                rows.append((fp, hw, os.path.join(self.root, fn)))
        return rows

    def __len__(self) -> int:
        return len(self.entries())

    def __contains__(self, key: Iterable[str]) -> bool:
        fp, hw = key
        return os.path.exists(self.path_for(fp, hw))

    # -- store / fetch -----------------------------------------------------
    def put(self, plan: TunedPlan, *, overwrite: bool = True) -> str:
        """Store ``plan`` under its own (fingerprint, hardware) provenance;
        returns the entry path."""
        path = self.path_for(plan.fingerprint, plan.hardware)
        if not overwrite and os.path.exists(path):
            raise FileExistsError(
                f"plan repository already holds an entry for "
                f"({plan.fingerprint[:12]}…, {plan.hardware}); pass "
                "overwrite=True to replace it"
            )
        # atomic publish: an interrupted tune must never leave a truncated
        # entry that later launches trip over
        tmp = f"{path}.tmp.{os.getpid()}"
        try:
            plan.save(tmp)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)
        return path

    def get(
        self, fingerprint: str, hardware: Union[Hardware, str]
    ) -> Optional[TunedPlan]:
        """The stored plan for the key, or ``None`` on a miss (including a
        stale-hardware miss: same fingerprint tuned for other hardware).
        Raises ``PlanRepoError`` when the entry's own provenance disagrees
        with the key it is filed under."""
        hw = _hw_name(hardware)
        path = self.path_for(fingerprint, hw)
        if not os.path.exists(path):
            return None
        try:
            plan = TunedPlan.load(path)
        except (ValueError, KeyError, TypeError) as e:
            raise PlanRepoError(
                f"repository entry {path} is not a readable TunedPlan "
                f"({type(e).__name__}: {e}) — truncated or corrupt; "
                "delete it or re-put"
            ) from e
        if plan.fingerprint != fingerprint or plan.hardware != hw:
            raise PlanRepoError(
                f"repository entry {path} is filed under "
                f"({fingerprint[:12]}…, {hw}) but carries provenance "
                f"({plan.fingerprint[:12]}…, {plan.hardware}) — refusing "
                "to apply a misfiled/tampered plan; re-tune or re-put"
            )
        return plan

    def resolve(
        self, wl: Workload, hardware: Union[Hardware, str]
    ) -> Optional[TunedPlan]:
        """The stored plan matching ``wl``'s structural fingerprint on
        ``hardware``, or ``None`` — the launch-time lookup."""
        return self.get(workload_fingerprint(wl), hardware)


def as_repository(repo: Union[str, os.PathLike, PlanRepository]) -> PlanRepository:
    """Coerce a directory path (or an existing repository) to a
    ``PlanRepository`` — what ``session.tune(repo=...)`` accepts."""
    return repo if isinstance(repo, PlanRepository) else PlanRepository(repo)
