"""PlanRepository: a directory store of ``TunedPlan`` artifacts keyed on
(workload structural fingerprint × hardware name).

The paper's deployment story is "co-tune once, deploy the plan"; the
repository is the *once* made operational.  ``session.tune(..., repo=...)``
auto-``put``s every tuned plan, and the launchers' ``--plan-repo`` flag
``resolve``s the current (workload, hardware) pair at startup — a hit
installs the stored plan with zero tuning work, a miss launches untuned
with a warning.

Layout: one strict-RFC JSON file per key, named
``<fingerprint>__<hardware>.json`` (the fingerprint is the sha256 hex
``session.workload_fingerprint`` emits; hardware is ``Hardware.name``).
``get`` re-verifies the loaded plan's own provenance against the key and
refuses misfiled or tampered entries (``PlanRepoError``) rather than
installing configs tuned for a different structure.

``resolve(band=...)`` extends the exact lookup to a *tolerance band*: a
serving fleet's decode batch drifts under traffic, so an exact-shape miss
that is a structural hit (same ``session.structure_fingerprint``) at a
nearby (seq, global_batch) resolves to the nearest tuned shape instead of
launching untuned.  Provenance is still verified entry by entry — but a
corrupt/misfiled *neighbor* found mid-scan is quarantined to
``<name>.corrupt`` and skipped with a ``RuntimeWarning`` instead of
aborting the lookup; only the direct ``get`` of an entry you explicitly
asked for stays strict.
"""
from __future__ import annotations

import math
import os
import warnings
from typing import Iterable, List, Optional, Tuple, Union

from repro.core.hardware import Hardware
from repro.core.session import (TunedPlan, structure_fingerprint,
                                workload_fingerprint, workload_shape)
from repro.core.workload import Workload


class PlanRepoError(ValueError):
    """A repository entry's content does not match its (fingerprint,
    hardware) key — misfiled, tampered, or hand-edited; refuse to apply."""


def _hw_name(hardware: Union[Hardware, str]) -> str:
    return hardware.name if isinstance(hardware, Hardware) else str(hardware)


class PlanRepository:
    """Directory-backed ``TunedPlan`` store keyed on (fingerprint, hardware)."""

    def __init__(self, root: Union[str, os.PathLike]):
        self.root = str(root)
        os.makedirs(self.root, exist_ok=True)

    # -- keys --------------------------------------------------------------
    def path_for(self, fingerprint: str, hardware: Union[Hardware, str]) -> str:
        return os.path.join(self.root, f"{fingerprint}__{_hw_name(hardware)}.json")

    def entries(self) -> List[Tuple[str, str, str]]:
        """Sorted ``(fingerprint, hardware, path)`` rows for every entry."""
        rows = []
        for fn in sorted(os.listdir(self.root)):
            if fn.endswith(".json") and "__" in fn:
                fp, hw = fn[: -len(".json")].split("__", 1)
                rows.append((fp, hw, os.path.join(self.root, fn)))
        return rows

    def __len__(self) -> int:
        return len(self.entries())

    def __contains__(self, key: Iterable[str]) -> bool:
        fp, hw = key
        return os.path.exists(self.path_for(fp, hw))

    # -- store / fetch -----------------------------------------------------
    def put(self, plan: TunedPlan, *, overwrite: bool = True,
            lint: Optional[str] = None) -> str:
        """Store ``plan`` under its own (fingerprint, hardware) provenance;
        returns the entry path.  ``lint="error"`` refuses to publish a
        plan with ERROR-severity deployment-lint findings
        (``repro.analysis.lint.PlanLintError``); ``lint="warn"`` surfaces
        findings as one ``RuntimeWarning`` but publishes anyway."""
        if lint not in (None, "off"):
            if lint not in ("warn", "error"):
                raise ValueError(f"lint= must be None, 'off', 'warn' or "
                                 f"'error', got {lint!r}")
            from repro.analysis.lint import (PlanLintError, errors,
                                             format_findings, lint_plan)

            findings = lint_plan(plan)
            if lint == "error" and errors(findings):
                raise PlanLintError(
                    findings,
                    label=f"repository entry ({plan.fingerprint[:12]}…, "
                          f"{plan.hardware})")
            if findings:
                import warnings

                warnings.warn(
                    format_findings(findings,
                                    label=f"put({plan.workload!r})"),
                    RuntimeWarning, stacklevel=2)
        path = self.path_for(plan.fingerprint, plan.hardware)
        if not overwrite and os.path.exists(path):
            raise FileExistsError(
                f"plan repository already holds an entry for "
                f"({plan.fingerprint[:12]}…, {plan.hardware}); pass "
                "overwrite=True to replace it"
            )
        # atomic publish: an interrupted tune must never leave a truncated
        # entry that later launches trip over
        tmp = f"{path}.tmp.{os.getpid()}"
        try:
            plan.save(tmp)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)
        return path

    def get(
        self, fingerprint: str, hardware: Union[Hardware, str]
    ) -> Optional[TunedPlan]:
        """The stored plan for the key, or ``None`` on a miss (including a
        stale-hardware miss: same fingerprint tuned for other hardware).
        Raises ``PlanRepoError`` when the entry's own provenance disagrees
        with the key it is filed under."""
        hw = _hw_name(hardware)
        path = self.path_for(fingerprint, hw)
        if not os.path.exists(path):
            return None
        try:
            plan = TunedPlan.load(path)
        except (ValueError, KeyError, TypeError) as e:
            raise PlanRepoError(
                f"repository entry {path} is not a readable TunedPlan "
                f"({type(e).__name__}: {e}) — truncated or corrupt; "
                "delete it or re-put"
            ) from e
        if plan.fingerprint != fingerprint or plan.hardware != hw:
            raise PlanRepoError(
                f"repository entry {path} is filed under "
                f"({fingerprint[:12]}…, {hw}) but carries provenance "
                f"({plan.fingerprint[:12]}…, {plan.hardware}) — refusing "
                "to apply a misfiled/tampered plan; re-tune or re-put"
            )
        return plan

    def resolve(
        self, wl: Workload, hardware: Union[Hardware, str], *,
        band: float = 0.0
    ) -> Optional[TunedPlan]:
        """The stored plan matching ``wl``'s structural fingerprint on
        ``hardware``, or ``None`` — the launch-time lookup.

        ``band`` > 0 widens an exact-fingerprint miss into a *tolerance
        band*: entries with the same shape-free ``structure_fingerprint``
        (same model, parallel degrees, SiteIds — only batch/seq differ)
        whose tuned (seq, global_batch) each sit within a relative
        deviation of ``band`` (e.g. 0.5 = up to 1.5× off) are candidates,
        nearest shape wins.  Every candidate is still provenance-verified
        through ``get`` — banding relaxes the shape, never the trust
        model.  ``band=0.0`` is the exact pre-band behavior.

        Args:
            wl: the live workload to resolve a plan for.
            hardware: profile (or name) keying the lookup.
            band: relative shape tolerance; 0 = exact fingerprint only.

        Returns:
            The stored ``TunedPlan``, or ``None`` on a miss.

        Raises:
            PlanRepoError: the *exact* entry for the key exists but its
                provenance disagrees with its filename (corrupt banded
                neighbors are quarantined, not raised).

        Example::

            >>> import tempfile
            >>> from repro.configs import get_smoke_config
            >>> from repro.core import (ParallelPlan,
            ...                         extract_decode_workload, tune)
            >>> wl = extract_decode_workload(
            ...     get_smoke_config("llama3-8b"),
            ...     ParallelPlan(kind="tp", tp=2), global_batch=8, seq=64)
            >>> repo = PlanRepository(tempfile.mkdtemp())
            >>> plan = tune(wl, "tpu-v5e", method="nccl", repo=repo)
            >>> repo.resolve(wl, "tpu-v5e").fingerprint == plan.fingerprint
            True
        """
        plan, _ = self.resolve_explain(wl, hardware, band=band)
        return plan

    def resolve_explain(
        self, wl: Workload, hardware: Union[Hardware, str], *,
        band: float = 0.0
    ) -> Tuple[Optional[TunedPlan], str]:
        """``resolve`` plus how the hit happened: ``(plan, "exact")``,
        ``(plan, "banded")`` or ``(None, "miss")`` — what serving engines
        record in their plan stats and the CI smoke asserts on."""
        hw = _hw_name(hardware)
        fp = workload_fingerprint(wl)
        plan = self.get(fp, hw)
        if plan is not None:
            return plan, "exact"
        if band <= 0.0:
            return None, "miss"
        want_struct = structure_fingerprint(wl)
        want_shape = workload_shape(wl)
        best: Optional[TunedPlan] = None
        best_d = math.inf
        for efp, ehw, path in self.entries():
            if ehw != hw or efp == fp:
                continue
            try:
                cand = self.get(efp, ehw)   # provenance re-verified
            except PlanRepoError as e:
                # one bad neighbor must not abort the whole banded scan:
                # quarantine it and keep looking.  Direct ``get`` stays
                # strict — only the opportunistic scan degrades gracefully.
                self._quarantine(path, f"during banded resolve: {e}")
                continue
            if cand is None:
                continue
            if not cand.structure or cand.structure != want_struct:
                continue
            d = _shape_distance(cand.shape, want_shape, band)
            if d is not None and d < best_d:
                best, best_d = cand, d
        return (best, "banded") if best is not None else (None, "miss")

    # -- lineage -----------------------------------------------------------
    def _quarantine(self, path: str, why: str) -> str:
        """Move a bad entry aside as ``<path>.corrupt`` (dropping it from
        ``entries()``) and warn — the graceful-degradation path shared by
        banded scans and lineage walks."""
        quarantined = f"{path}.corrupt"
        os.replace(path, quarantined)
        warnings.warn(
            f"skipping corrupt plan repository entry {why}; quarantined "
            f"to {quarantined}",
            RuntimeWarning,
            stacklevel=3,
        )
        return quarantined

    def retune_chain(
        self, fingerprint: str, hardware: Union[Hardware, str]
    ) -> List[str]:
        """The retune ancestry of the stored entry for the key, newest
        first: ``[entry_digest, parent_digest, grandparent_digest, ...]``.

        ``put`` overwrites one (fingerprint, hardware) key in place, so
        ancestors live only as the embedded ``lineage["chain"]`` digests
        — this walks them without needing the ancestor artifacts.  A
        cold-tuned entry returns a single-element chain; a missing key
        returns ``[]``.  A corrupt entry or malformed lineage is
        quarantined (same ``.corrupt`` path as banded scans) and returns
        ``[]`` instead of breaking the walk.

        Args:
            fingerprint: the workload fingerprint keying the entry.
            hardware: profile (or name) keying the entry.

        Returns:
            Artifact digests, newest (the stored entry itself) first.
        """
        hw = _hw_name(hardware)
        path = self.path_for(fingerprint, hw)
        try:
            plan = self.get(fingerprint, hw)
        except PlanRepoError as e:
            self._quarantine(path, f"during retune-chain walk: {e}")
            return []
        if plan is None:
            return []
        lineage = plan.lineage or {}
        chain = lineage.get("chain", [])
        parent = lineage.get("retuned_from")
        malformed = (
            not isinstance(chain, list)
            or not all(isinstance(d, str) for d in chain)
            or (parent is not None and not isinstance(parent, str))
            or (chain and parent != chain[0])
            or (parent is not None and not chain)
        )
        if malformed:
            self._quarantine(
                path,
                f"during retune-chain walk: lineage of "
                f"({fingerprint[:12]}…, {hw}) is malformed "
                f"(retuned_from={parent!r}, chain={chain!r})",
            )
            return []
        return [plan.artifact_digest()] + list(chain)


def _shape_distance(tuned: dict, want: dict, band: float) -> Optional[float]:
    """Log-scale distance between two banded shape records, or ``None``
    when any dimension is missing, non-positive, or deviates beyond
    ``band`` (relative: max/min − 1 ≤ band must hold per dimension)."""
    total = 0.0
    for key in ("seq", "global_batch"):
        a, b = tuned.get(key), want.get(key)
        if not a or not b or a <= 0 or b <= 0:
            return None
        ratio = max(a, b) / min(a, b)
        if ratio - 1.0 > band + 1e-12:
            return None
        total += abs(math.log(ratio))
    return total


def as_repository(repo: Union[str, os.PathLike, PlanRepository]) -> PlanRepository:
    """Coerce a directory path (or an existing repository) to a
    ``PlanRepository`` — what ``session.tune(repo=...)`` accepts."""
    return repo if isinstance(repo, PlanRepository) else PlanRepository(repo)
