"""One front door for the tuning product surface: ``tune`` -> ``TunedPlan``.

The engine stack underneath (batched profiling, cross-group scheduling,
counter-based noise) grew fast, but every caller still hand-wired a
``Simulator``, picked among ``tuner.tune_workload`` (3-tuple),
``autoccl.tune_workload`` (2-tuple) and ``baselines.nccl_defaults``, then
separately threaded configs through ``core.apply`` — the tune -> profile ->
compare -> apply loop was duplicated across every example, benchmark and
launcher.  This module is the paper's actual pitch ("co-tune once, deploy
the plan") as an API:

``tune(workload, hardware, *, method, mode, noise, noise_mode, seed)``
    One call, any registered search method, returning a ``TunedPlan``.

``TunedPlan``
    A first-class, persistable artifact: tuned configs plus provenance
    (method, hardware, workload structural fingerprint, seed, noise mode),
    per-step traces, ``profile_count`` and engine cache telemetry.  It
    round-trips through JSON (``save``/``load``/``to_json``/``from_json``),
    refuses to act on a structurally different workload
    (``PlanMismatchError``), lowers itself to JAX runtime knobs
    (``runtime_plan``, self-contained — the embedded site metadata means a
    deserialized plan needs no workload object), and produces the speedup
    rows the benchmarks print (``compare``).

``SearchBackend`` registry
    The built-in methods (``"lagom"``, ``"autoccl"``, ``"nccl"``) are
    plain registry entries; third-party tuners join with::

        @register_backend("mytuner")
        class MyBackend:
            def search(self, sim, wl, *, mode, **options):
                return SearchOutcome(configs, profile_count, traces)

    and are immediately addressable as ``tune(..., method="mytuner")``.

Scheduling ``mode`` (``scheduler.MODES``): ``"serial"`` is the reference
per-group walk, ``"interleaved"`` (default) the cross-group lock-step
pipeline with trajectory sharing whenever sound, ``"shared"`` requires
sharing soundness up front.  Deterministic and CRN-noise searches return
byte-identical configs under all three.
"""
from __future__ import annotations

import contextlib
import hashlib
import json
import math
from dataclasses import dataclass, field, fields
from typing import Callable, Dict, List, Optional, Protocol, Union, runtime_checkable

from repro.core.comm_params import CommConfig
from repro.core.faults import FaultSchedule, parse_fault_schedule
from repro.core.hardware import Hardware, by_name, profiles
from repro.core.scheduler import MODES, resolve_mode
from repro.core.simulator import Measurement, Simulator
from repro.core.topology import HierarchicalHardware, resolve_topology
from repro.core.workload import (ConfigSet, Workload, comm_site_meta,
                                 structure_components)

PLAN_VERSION = 1


def workload_fingerprint(wl: Workload) -> str:
    """Structural identity of a whole workload: the per-group fingerprints
    the profiling cache keys on (op shapes/bytes, names excluded), hashed
    so plans can carry it as a short provenance string.  Two workloads
    with equal fingerprints are indistinguishable to the contention model,
    which is exactly the condition under which re-applying a plan is
    sound."""
    from repro.core.profiling import group_fingerprint

    payload = repr(tuple(group_fingerprint(g) for g in wl.groups))
    return hashlib.sha256(payload.encode()).hexdigest()


def structure_fingerprint(wl: Workload) -> str:
    """Shape-free sibling of ``workload_fingerprint``: hashes
    ``workload.structure_components`` (names, comm kinds/group sizes,
    SiteIds — no payload magnitudes), so it is invariant under batch/seq
    drift.  This is the key tolerance-band repository resolution matches
    on: an exact-fingerprint miss may still be a structural hit at a
    nearby shape."""
    payload = repr(structure_components(wl))
    return hashlib.sha256(payload.encode()).hexdigest()


def workload_shape(wl: Workload) -> Dict[str, int]:
    """The banded shape coordinates a plan carries as provenance
    (``TunedPlan.shape``): seq/global_batch from the workload meta."""
    return {k: int(wl.meta[k]) for k in ("seq", "global_batch")
            if k in wl.meta}


class PlanMismatchError(ValueError):
    """Raised when a ``TunedPlan`` is applied to a workload whose
    structural fingerprint differs from the one it was tuned on."""


# ---------------------------------------------------------------------------
# search-backend registry
# ---------------------------------------------------------------------------

@dataclass
class SearchOutcome:
    """What a backend hands back: tuned configs for every comm site, the
    number of logical ProfileTime invocations spent, and optional per-step
    trace rows (dicts; ``cfg`` entries may be ``CommConfig``)."""
    configs: ConfigSet
    profile_count: int = 0
    traces: List[Dict] = field(default_factory=list)


@runtime_checkable
class SearchBackend(Protocol):
    """A tuning method: anything with
    ``search(sim, wl, *, mode, **options) -> SearchOutcome``."""

    def search(self, sim: Simulator, wl: Workload, *, mode: str,
               **options) -> SearchOutcome: ...


_BACKENDS: Dict[str, SearchBackend] = {}


def register_backend(name: str, *, overwrite: bool = False) -> Callable:
    """Class/instance decorator registering a ``SearchBackend`` under
    ``name`` (classes are instantiated with no arguments).  The method is
    immediately addressable as ``tune(..., method=name)``."""
    def deco(obj):
        if name in _BACKENDS and not overwrite:
            raise ValueError(f"search backend {name!r} already registered "
                             "(pass overwrite=True to replace it)")
        backend = obj() if isinstance(obj, type) else obj
        if not callable(getattr(backend, "search", None)):
            raise TypeError(f"backend {name!r} must expose a "
                            "search(sim, wl, *, mode, **options) method")
        _BACKENDS[name] = backend
        return obj
    return deco


def unregister_backend(name: str) -> None:
    _BACKENDS.pop(name, None)


def available_methods() -> List[str]:
    return sorted(_BACKENDS)


def get_backend(name: str) -> SearchBackend:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise KeyError(f"unknown tuning method {name!r}; registered: "
                       f"{available_methods()}") from None


@register_backend("lagom")
class LagomBackend:
    """Algorithms 1–2 (``core.tuner``); options: ``base``, ``warm_start``."""

    def search(self, sim, wl, *, mode, base=None, warm_start=False):
        from repro.core import tuner
        configs, iters, traces = tuner.search_workload(
            sim, wl, mode=mode, base=base, warm_start=warm_start)
        return SearchOutcome(configs, iters, traces)


@register_backend("autoccl")
class AutoCCLBackend:
    """AutoCCL [NSDI'25] coordinate descent (``core.autoccl``).  Takes no
    options — an unexpected one raises, same as the lagom backend."""

    def search(self, sim, wl, *, mode):
        from repro.core import autoccl
        configs, iters = autoccl.search_workload(sim, wl, mode=mode)
        return SearchOutcome(configs, iters, [])


@register_backend("nccl")
class NCCLBackend:
    """Vendor defaults (``core.baselines``) — zero profiles, the un-tuned
    baseline as a plan so it composes with ``compare``/``runtime_plan``."""

    def search(self, sim, wl, *, mode):
        from repro.core import baselines
        return SearchOutcome(baselines.nccl_defaults(wl, sim.hw), 0, [])


# ---------------------------------------------------------------------------
# the portable artifact
# ---------------------------------------------------------------------------

# derived, not hand-listed: a field added to CommConfig can never be
# silently dropped from saved plans
_CFG_FIELDS = tuple(f.name for f in fields(CommConfig))


def _cfg_to_dict(cfg: CommConfig) -> Dict:
    return {f: getattr(cfg, f) for f in _CFG_FIELDS}


def _cfg_from_dict(d: Dict) -> CommConfig:
    return CommConfig(**{f: d[f] for f in _CFG_FIELDS})


def _trace_val_to_json(v):
    """Trace values hold two non-JSON types: ``CommConfig`` rows and the
    non-finite floats of Algorithm 1's H metric (``inf`` marks a finished
    comm).  Both get *tagged* dict encodings — applied recursively and
    under any trace key, so third-party backend traces (nested lists/dicts
    included; tuples come back as lists, as in any JSON) round-trip too —
    and the emitted document is strict RFC JSON
    (``json.dumps(allow_nan=True)`` would write the bare ``Infinity``
    token, which jq/JS/most non-Python readers reject)."""
    if isinstance(v, CommConfig):
        return {"__commconfig__": _cfg_to_dict(v)}
    if isinstance(v, float) and not math.isfinite(v):
        return {"__nonfinite__": repr(v)}
    if isinstance(v, (list, tuple)):
        return [_trace_val_to_json(x) for x in v]
    if isinstance(v, dict):
        return {k: _trace_val_to_json(x) for k, x in v.items()}
    return v


def _trace_val_from_json(v):
    if isinstance(v, dict):
        if "__nonfinite__" in v:
            return float(v["__nonfinite__"])
        if "__commconfig__" in v:
            return _cfg_from_dict(v["__commconfig__"])
        return {k: _trace_val_from_json(x) for k, x in v.items()}
    if isinstance(v, list):
        return [_trace_val_from_json(x) for x in v]
    return v


@dataclass
class TunedPlan:
    """A tuned-configuration artifact with provenance — persist it, diff
    it, ship it to the runtime.  Produced by ``tune`` (cold) or ``retune``
    (warm, drift-scoped — provenance in ``.lineage``); self-contained: the
    embedded ``sites`` metadata (one row per comm site: name, kind, payload
    bytes) lets a deserialized plan lower itself to runtime knobs without
    the workload object, while ``fingerprint`` guards every
    workload-taking operation against structural mismatch.

    Example — tune, round-trip through JSON, check identity::

        >>> from repro.configs import get_smoke_config
        >>> from repro.core import ParallelPlan, extract_decode_workload
        >>> wl = extract_decode_workload(
        ...     get_smoke_config("llama3-8b"), ParallelPlan(kind="tp", tp=2),
        ...     global_batch=8, seq=64)
        >>> plan = tune(wl, "tpu-v5e", method="nccl")
        >>> again = TunedPlan.from_json(plan.to_json())
        >>> again.configs == plan.configs
        True
        >>> again.artifact_digest() == plan.artifact_digest()
        True
        >>> plan.matches(wl) and not plan.lineage
        True
    """
    method: str                    # registry name that produced the configs
    mode: str                      # scheduling mode it searched under
    hardware: str                  # Hardware.name it was tuned for
    workload: str                  # Workload.name (informational)
    fingerprint: str               # workload_fingerprint at tune time
    seed: int
    noise: float
    noise_mode: str
    configs: ConfigSet = field(default_factory=dict)
    sites: List[Dict] = field(default_factory=list)
    profile_count: int = 0
    traces: List[Dict] = field(default_factory=list)
    cache_stats: Optional[Dict] = None
    # banded provenance (defaults keep pre-band plan files loading): the
    # shape-free structure_fingerprint and the (seq, global_batch) the plan
    # was tuned at — what tolerance-band repository resolution matches on.
    structure: str = ""
    shape: Dict = field(default_factory=dict)
    # fault provenance (empty for nominal plans; default keeps pre-fault
    # plan files loading): the schedule a plan was tuned under, or — for
    # robust plans — the ensemble, per-candidate regrets and the winner.
    faults: Dict = field(default_factory=dict)
    # retune lineage (empty for cold-tuned plans; default keeps pre-retune
    # plan files loading): ``retuned_from`` (parent artifact digest),
    # ``sites``/``groups`` (the drift scope), ``calibration`` (per-site
    # observed/predicted/scale deltas), ``generation`` and ``chain`` (every
    # ancestor digest, newest first) — see ``core.retune``.
    lineage: Dict = field(default_factory=dict)
    # hierarchical-fabric provenance (empty for flat-tuned plans; default
    # keeps pre-topology plan files loading): ``fingerprint``/``name`` of
    # the ``core.topology.HierarchicalHardware`` the plan was tuned under
    # plus its full ``spec`` (``to_dict``), so ``evaluate`` can rebuild the
    # exact two-tier simulator and ``check_topology`` can refuse a
    # different fabric — a cross-pod plan applied to a flat cluster is as
    # unsound as one for the wrong model.
    topology: Dict = field(default_factory=dict)
    version: int = PLAN_VERSION

    # -- identity ----------------------------------------------------------
    def artifact_digest(self) -> str:
        """Content hash of the whole serialized artifact (sha256 hex of
        ``to_json()``) — the identity retune lineage records ancestors by.

        Returns:
            64-char hex string; equal plans (all fields, configs and
            traces included) digest equally, any edit moves it.
        """
        return hashlib.sha256(self.to_json(indent=None).encode()).hexdigest()

    # -- structural guard --------------------------------------------------
    def matches(self, wl: Workload) -> bool:
        return self.fingerprint == workload_fingerprint(wl)

    def matches_structure(self, wl: Workload) -> bool:
        """Shape-free match: same program at a possibly different
        batch/seq.  Pre-band plans (no recorded structure) never match."""
        return bool(self.structure) and self.structure == structure_fingerprint(wl)

    def check(self, wl: Workload) -> None:
        fp = workload_fingerprint(wl)
        if fp != self.fingerprint:
            raise PlanMismatchError(
                f"plan was tuned on {self.workload!r} "
                f"(fingerprint {self.fingerprint[:12]}…) but workload "
                f"{wl.name!r} fingerprints to {fp[:12]}… — structures "
                "differ, re-applying the configs is unsound; re-tune")

    def check_topology(self, topology=None) -> None:
        """Refuse a fabric mismatch: a plan tuned under one
        ``HierarchicalHardware`` (or under the flat single-fabric model —
        empty ``self.topology``) must only be applied under the same one.
        ``topology`` accepts anything ``core.topology.resolve_topology``
        does; ``None`` (or a flat topology) asserts the plan is
        flat-tuned."""
        topo = resolve_topology(topology)
        want = "" if topo is None or topo.is_flat else topo.fingerprint()
        have = self.topology.get("fingerprint", "")
        if have != want:
            def lbl(fp, name):
                return f"{name} ({fp[:12]}…)" if fp else "flat single-fabric"
            raise PlanMismatchError(
                "plan was tuned under the "
                f"{lbl(have, self.topology.get('name', '?'))} topology but "
                f"is being applied under {lbl(want, topo.name if topo else '')}"
                " — cross-tier configs are unsound there; re-tune with "
                "tune(..., topology=...)")

    # -- apply / evaluate / compare ---------------------------------------
    def runtime_plan(self, wl: Optional[Workload] = None) -> Dict:
        """Lower to per-site JAX runtime knobs (``core.apply``): one
        ``CollectiveRuntime`` per SiteId plus hierarchical prefix/class
        fallback entries, so two comm sites of one model can carry
        different chunk structure.  Self-contained via the embedded site
        metadata; pass the workload to assert it structurally matches
        before applying."""
        from repro.core import apply as apply_mod  # lazy: apply pulls in jax

        if wl is not None:
            self.check(wl)
        return apply_mod.site_runtime_plan(self.sites, self.configs)

    @contextlib.contextmanager
    def applied(self, wl: Optional[Workload] = None):
        """Scope this plan's runtime knobs to a ``with`` block::

            with plan.applied():
                y = ring_ag_matmul(...)     # sites resolve against plan

        Nested ``applied()`` scopes shadow (innermost wins) and every exit
        path — normal or exceptional — restores the prior state; the
        process-global install (``core.apply.activate`` / the launchers'
        ``--tuned-plan``) stays untouched underneath.  Yields the lowered
        runtime plan."""
        from repro.parallel import collectives   # lazy: pulls in jax

        rt = self.runtime_plan(wl)
        with collectives.use_runtime_plan(rt):
            yield rt

    # -- diffing -----------------------------------------------------------
    def diff(self, other: "TunedPlan") -> Dict:
        """Field-level config deltas vs ``other``, per site and only for
        changed fields::

            {"changed":    {site_id: {field: [self_val, other_val]}},
             "only_self":  [site_id, ...],   # sites other has no config for
             "only_other": [site_id, ...],
             "meta":       {field: [self_val, other_val]}}   # provenance

        Sites are labeled by SiteId (falling back to ``group:comm`` when a
        site is missing from the embedded metadata — e.g. diffing against
        a plan from a structurally different workload)."""
        def labels(plan):
            return {(s["group"], s["comm"]): s.get("site") or s["name"]
                    for s in plan.sites}

        lab = labels(self)
        lab.update({k: v for k, v in labels(other).items() if k not in lab})
        changed: Dict[str, Dict] = {}
        only_self: List[str] = []
        only_other: List[str] = []
        for key in sorted(set(self.configs) | set(other.configs)):
            sid = lab.get(key, f"{key[0]}:{key[1]}")
            a, b = self.configs.get(key), other.configs.get(key)
            if b is None:
                only_self.append(sid)
                continue
            if a is None:
                only_other.append(sid)
                continue
            delta = {f: [getattr(a, f), getattr(b, f)] for f in _CFG_FIELDS
                     if getattr(a, f) != getattr(b, f)}
            if delta:
                changed[sid] = delta
        meta = {f: [getattr(self, f), getattr(other, f)]
                for f in ("method", "mode", "hardware", "workload",
                          "fingerprint", "seed", "noise", "noise_mode")
                if getattr(self, f) != getattr(other, f)}
        return {"changed": changed, "only_self": only_self,
                "only_other": only_other, "meta": meta}

    def _hw(self):
        """The simulation target the plan was tuned for: the recorded
        ``HierarchicalHardware`` when topology provenance is present
        (hierarchical names are not registry profiles — the embedded spec
        is authoritative), else the named flat profile."""
        if self.topology.get("spec"):
            return HierarchicalHardware.from_dict(self.topology["spec"])
        try:
            return by_name(self.hardware)
        except KeyError:
            raise KeyError(
                f"plan hardware {self.hardware!r} is not a registered "
                f"profile ({profiles()}); pass an explicit sim= to "
                "evaluate/compare") from None

    def evaluate(self, wl: Workload, *, sim: Optional[Simulator] = None,
                 faults=None) -> Measurement:
        """Profile the plan's configs on its workload (fingerprint-checked).
        Defaults to a fresh deterministic simulator on the plan's hardware
        profile — or, for a topology-tuned plan, on the recorded
        ``HierarchicalHardware`` rebuilt from provenance — so evaluations
        are stable; pass ``sim=`` to evaluate under jitter or on shared RNG
        state, or ``faults=`` (a ``FaultSchedule``, inline spec, or
        schedule-file path) to evaluate under a scripted fault — the fresh
        simulator's fault clock starts at step 0."""
        if faults is not None:
            if sim is not None:
                raise ValueError("sim= carries its own fault schedule; "
                                 "pass faults= or sim=, not both")
            sim = Simulator(self._hw(), faults=parse_fault_schedule(faults))
        self.check(wl)
        sim = sim or Simulator(self._hw())
        return sim.profile(wl, self.configs)

    def compare(self, other: "TunedPlan", wl: Workload, *,
                sim: Optional[Simulator] = None) -> Dict:
        """The speedup row the benchmarks print; ``speedup`` = how much
        faster this plan's makespan is than ``other``'s.  Deterministic by
        default (a fresh noise-free simulator on the plan's hardware).
        For a *paired* noisy comparison, evaluate each plan on its own
        fresh ``noise_mode="crn"`` simulator with one seed — CRN draws are
        a pure function of (structure, trajectory position), so both
        evaluations then see identical jitter; a shared default-noise
        simulator gives independent draws, not pairing."""
        sim = sim or Simulator(self._hw())
        mine = self.evaluate(wl, sim=sim)
        theirs = other.evaluate(wl, sim=sim)
        return dict(workload=wl.name, method=self.method,
                    baseline=other.method,
                    z_ms=mine.Z * 1e3, baseline_z_ms=theirs.Z * 1e3,
                    speedup=theirs.Z / mine.Z,
                    profiles=self.profile_count,
                    baseline_profiles=other.profile_count)

    # -- serialization -----------------------------------------------------
    def to_json(self, *, indent: Optional[int] = 2) -> str:
        d = {f.name: getattr(self, f.name) for f in fields(self)}
        d["configs"] = [dict(group=gi, comm=ci, **_cfg_to_dict(cfg))
                        for (gi, ci), cfg in sorted(self.configs.items())]
        d["traces"] = [_trace_val_to_json(t) for t in self.traces]
        return json.dumps(d, indent=indent, allow_nan=False)

    @classmethod
    def from_json(cls, text: str) -> "TunedPlan":
        d = json.loads(text)
        version = d.pop("version", None)
        if version != PLAN_VERSION:
            raise ValueError(f"unsupported TunedPlan version {version!r} "
                             f"(this build reads version {PLAN_VERSION})")
        d["configs"] = {(c["group"], c["comm"]): _cfg_from_dict(c)
                        for c in d["configs"]}
        d["traces"] = [_trace_val_from_json(t) for t in d["traces"]]
        return cls(version=PLAN_VERSION, **d)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "TunedPlan":
        with open(path) as f:
            return cls.from_json(f.read())


def load_plan(path: str) -> TunedPlan:
    """Module-level alias for ``TunedPlan.load`` (launcher convenience)."""
    return TunedPlan.load(path)


def _lookup_hw(hardware: Union[Hardware, str]) -> Hardware:
    # names resolve through the core.hardware registry (its KeyError
    # already lists the registered profiles)
    return by_name(hardware) if isinstance(hardware, str) else hardware


# ---------------------------------------------------------------------------
# the front door
# ---------------------------------------------------------------------------

def _search_to_plan(backend, method: str, mode: str, sim: Simulator,
                    workload: Workload, options: Dict,
                    faults_meta: Optional[Dict] = None) -> TunedPlan:
    """One search on ``sim`` lowered to a ``TunedPlan`` (the shared tail of
    nominal, faulted and robust tuning)."""
    resolved = resolve_mode(sim, mode)
    outcome = backend.search(sim, workload, mode=resolved, **options)
    stats = (sim.engine.cache_stats()
             if sim.batched and sim._engine is not None else None)
    # provenance follows the simulator actually searched on: a hierarchical
    # one stamps its topology (and keys the plan on the topology's
    # repo-safe name); a flat one leaves topology empty — byte-identical
    # to pre-topology plans
    topo_meta, hw_name = {}, sim.hw.name
    if sim.topology is not None:
        topo_meta = {"fingerprint": sim.topology.fingerprint(),
                     "name": sim.topology.name,
                     "spec": sim.topology.to_dict()}
        hw_name = sim.topology.name
    return TunedPlan(
        method=method, mode=resolved, hardware=hw_name,
        workload=workload.name, fingerprint=workload_fingerprint(workload),
        seed=sim.seed, noise=sim.noise, noise_mode=sim.noise_mode,
        configs=dict(outcome.configs), sites=comm_site_meta(workload),
        profile_count=outcome.profile_count, traces=list(outcome.traces),
        cache_stats=stats, structure=structure_fingerprint(workload),
        shape=workload_shape(workload), faults=dict(faults_meta or {}),
        topology=topo_meta)


def _scenario_states(sched: Optional[FaultSchedule]) -> List:
    """The distinct fault windows a scenario can present — ``None`` (the
    healthy window) plus every unique active state over the schedule's
    horizon.  Worst-case scoring over these captures transient events
    (flaps, late-start degradations) that a single step-0 probe would
    miss."""
    states = [None]
    if sched is None:
        return states
    horizon = 1
    for ev in sched.events:
        horizon = max(horizon,
                      ev.stop if ev.stop is not None
                      else ev.start + max(1, ev.period))
    seen = set()
    for step in range(horizon):
        st = sched.state_at(step)
        if st is None:
            continue
        key = (st.comp_scale, st.sigma, st.comm_events)
        if key not in seen:
            seen.add(key)
            states.append(st)
    return states


def _robust_tune(backend, method: str, mode: str, workload: Workload,
                 hw: Hardware, sim_kw: Dict, ensemble: List[FaultSchedule],
                 options: Dict) -> TunedPlan:
    """Minimax-regret tuning over a fault ensemble: tune one candidate per
    scenario (nominal + each schedule), score every candidate's worst-case
    makespan under every scenario's fault windows, and keep the candidate
    whose worst regret vs the per-scenario best is smallest (ties break
    toward better nominal time).  The winner's ``faults`` provenance
    records the ensemble, the per-candidate regrets and the total search
    cost; its own ``profile_count`` stays its search cost."""
    scenarios: List[Optional[FaultSchedule]] = [None] + list(ensemble)
    labels = ["nominal"] + [f"robust[{i}]" for i in range(len(ensemble))]
    candidates: List[TunedPlan] = []
    for sched in scenarios:
        sim = Simulator(hw, faults=sched, **sim_kw)
        candidates.append(
            _search_to_plan(backend, method, mode, sim, workload, options))

    # score on the scalar reference path with an explicit fault window, so
    # every candidate sees each scenario's exact degraded physics
    eval_sim = Simulator(hw, batched=False)
    eval_profiles = 0

    def worst_z(plan: TunedPlan, sched: Optional[FaultSchedule]) -> float:
        nonlocal eval_profiles
        worst = 0.0
        for st in _scenario_states(sched):
            z = 0.0
            for gi, g in enumerate(workload.groups):
                cfgs = [plan.configs[(gi, ci)] for ci in range(len(g.comms))]
                z += eval_sim.run_group(g, cfgs, fstate=st).Z
            eval_profiles += 1
            worst = max(worst, z)
        return worst

    z_table = [[worst_z(c, sched) for sched in scenarios]
               for c in candidates]
    best = [min(z_table[c][s] for c in range(len(candidates)))
            for s in range(len(scenarios))]
    regrets = [max(z_table[c][s] - best[s] for s in range(len(scenarios)))
               for c in range(len(candidates))]
    win = min(range(len(candidates)),
              key=lambda c: (regrets[c], z_table[c][0]))

    plan = candidates[win]
    plan.faults = {
        "robust": True,
        "ensemble": [s.to_dict() for s in ensemble],
        "selected": labels[win],
        "worst_case_regret": regrets[win],
        "regrets": dict(zip(labels, regrets)),
        "nominal_z": z_table[win][0],
        "total_profiles": sum(c.profile_count for c in candidates)
        + eval_profiles,
    }
    return plan


def _lint_gate(plan: TunedPlan, workload: Workload, topology,
               lint: Optional[str]) -> None:
    """The ``tune(lint=...)`` hook: run the deployment linter
    (``repro.analysis.lint``) on a freshly tuned plan before it is
    returned or persisted.  ``None``/``"off"`` skip, ``"warn"`` emits one
    ``RuntimeWarning`` carrying the findings, ``"error"`` raises
    ``PlanLintError`` on ERROR-severity findings (warnings still warn)."""
    if lint in (None, "off"):
        return
    if lint not in ("warn", "error"):
        raise ValueError(f"lint= must be None, 'off', 'warn' or 'error', "
                         f"got {lint!r}")
    from repro.analysis.lint import (PlanLintError, errors,
                                     format_findings, lint_plan)

    findings = lint_plan(plan, workload=workload, topology=topology)
    if lint == "error" and errors(findings):
        raise PlanLintError(findings,
                            label=f"tuned plan for {workload.name!r}")
    if findings:
        import warnings

        warnings.warn(format_findings(findings, label=repr(workload.name)),
                      RuntimeWarning, stacklevel=3)


def tune(workload: Workload, hardware: Union[Hardware, str, None] = None, *,
         method: str = "lagom", mode: str = "interleaved",
         noise: float = 0.0, noise_mode: str = "default", seed: int = 0,
         batched: bool = True, simulator: Optional[Simulator] = None,
         repo=None, faults=None, fault_ensemble=None, topology=None,
         lint: Optional[str] = None, **options) -> TunedPlan:
    """Tune ``workload``'s collectives for ``hardware`` and return the
    result as a portable ``TunedPlan``.

    ``hardware`` is a ``Hardware`` profile or its registry name
    (``core.hardware.PROFILES``).  ``method`` selects a registered search
    backend (``available_methods()``); ``mode`` a schedule from
    ``scheduler.MODES``.  ``noise``/``noise_mode``/``seed``/``batched``
    configure the ProfileTime simulator exactly as ``Simulator(...)`` —
    configs are byte-identical to driving the per-method search by hand
    with the same simulator arguments.  Pass ``simulator=`` to reuse RNG
    state / engine caches instead (``hardware`` may then be omitted, and
    the simulator kwargs must stay unset — they would be silently shadowed
    otherwise, so that is rejected).  ``repo`` (a directory path or
    ``plan_repo.PlanRepository``) auto-``put``s the tuned plan under its
    (fingerprint, hardware) key so later launches with ``--plan-repo``
    resolve it with zero tuning work.

    Fault-aware tuning (``core.faults``): ``faults=`` (a ``FaultSchedule``,
    inline spec, or schedule-file path) injects scripted degradation into
    the search's ProfileTime draws and records the schedule as plan
    provenance — an empty schedule is a no-op and results stay
    byte-identical to the fault-free call.  ``fault_ensemble=`` (a list of
    schedules/specs) instead runs minimax-regret robust tuning: one
    candidate per scenario (nominal first), scored by worst-case makespan
    across all scenarios' fault windows; the returned plan carries the
    ensemble, regrets and total search cost in ``plan.faults``.  Both
    build their own simulators, so they reject ``simulator=``.

    Hierarchical tuning (``core.topology``): ``topology=`` (a
    ``HierarchicalHardware``, its ``to_dict()`` spec, or a saved-topology
    path) prices every comm against the fabric tier its site spans and
    stamps the topology fingerprint/spec into ``plan.topology`` (the plan
    then keys on the topology's name in repositories and refuses
    evaluation under a different fabric via ``check_topology``).  A flat
    topology (``pods == 1``) collapses to the bare island profile —
    results and provenance stay byte-identical to the single-fabric path.

    Static analysis (``repro.analysis``): ``lint=`` runs the deployment
    linter on the tuned plan before it is returned or auto-``put`` —
    ``"warn"`` surfaces findings as one ``RuntimeWarning``, ``"error"``
    additionally raises ``PlanLintError`` on ERROR-severity findings (the
    plan is then neither returned nor persisted).  Default ``None`` skips.

    Remaining keyword ``options`` go to the backend (e.g. Lagom's
    ``warm_start``).

    Args:
        workload: the overlap-group IR to tune (``core.extract``).
        hardware: a ``Hardware`` profile or registry name; optional only
            when ``simulator=`` is passed.
        method/mode/noise/noise_mode/seed/batched: search backend,
            schedule and ProfileTime simulator knobs (see above).
        simulator: reuse an existing ``Simulator`` (RNG state, caches).
        repo: directory or ``PlanRepository`` to auto-``put`` into.
        faults / fault_ensemble: scripted degradation for fault-aware or
            minimax-robust tuning (see above).
        lint: deployment-linter gate on the result — ``None``/``"off"``,
            ``"warn"``, or ``"error"`` (see above).

    Returns:
        A ``TunedPlan`` carrying the configs and full provenance.

    Raises:
        KeyError: unknown ``method`` or ``hardware`` name.
        ValueError: conflicting simulator/hardware/fault arguments.

    Example::

        >>> from repro.configs import get_smoke_config
        >>> from repro.core import ParallelPlan, extract_decode_workload
        >>> wl = extract_decode_workload(
        ...     get_smoke_config("llama3-8b"), ParallelPlan(kind="tp", tp=2),
        ...     global_batch=8, seq=64)
        >>> plan = tune(wl, "tpu-v5e", method="lagom")
        >>> plan.method, plan.profile_count > 0
        ('lagom', True)
    """
    backend = get_backend(method)
    topo = resolve_topology(topology)
    if topo is not None:
        if simulator is not None:
            raise ValueError(
                "topology= builds its own simulator; construct "
                "Simulator(topology) and pass simulator= alone (its "
                "topology lands in the plan provenance automatically)")
        if hardware is not None and _lookup_hw(hardware) != topo.island:
            raise ValueError(
                f"topology island {topo.island.name!r} conflicts with "
                "hardware=; pass one or the other")
        hardware = topo.island
        if topo.is_flat:
            topo = None   # degenerate single-pod case: plain flat tuning
    faults = parse_fault_schedule(faults)
    if not faults:
        faults = None            # empty schedule == fault-free tuning
    if faults is not None and fault_ensemble is not None:
        raise ValueError("pass faults= (tune under one schedule) or "
                         "fault_ensemble= (robust minimax tuning), not both")
    if simulator is not None:
        if faults is not None or fault_ensemble is not None:
            raise ValueError(
                "faults=/fault_ensemble= build their own simulators; drop "
                "simulator= (or construct Simulator(faults=...) yourself)")
        sim = simulator
        if hardware is not None:
            hw = _lookup_hw(hardware)
            if hw is not sim.hw:
                raise ValueError(
                    f"simulator hardware {sim.hw.name!r} conflicts with "
                    f"hardware={hw.name!r}; pass one or the other")
        if (noise, noise_mode, seed, batched) != (0.0, "default", 0, True):
            raise ValueError(
                "simulator= carries its own noise/noise_mode/seed/batched; "
                "configure the Simulator instead of passing them to tune()")
    else:
        if hardware is None:
            raise ValueError("pass hardware= (profile or name) or simulator=")
        hw = _lookup_hw(hardware)
        sim_kw = dict(noise=noise, seed=seed, noise_mode=noise_mode,
                      batched=batched)
        target = topo if topo is not None else hw
        if fault_ensemble is not None:
            ensemble = [parse_fault_schedule(f) for f in fault_ensemble]
            ensemble = [e for e in ensemble if e]
            if not ensemble:
                raise ValueError("fault_ensemble has no non-empty schedules")
            plan = _robust_tune(backend, method, mode, workload, target,
                                sim_kw, ensemble, options)
            _lint_gate(plan, workload, topo, lint)
            if repo is not None:
                from repro.core.plan_repo import as_repository
                as_repository(repo).put(plan)
            return plan
        sim = Simulator(target, faults=faults, **sim_kw)
    # validate here, not just in the built-in backends, so mode errors and
    # the shared-soundness rejection are uniform across every method
    # (nccl, third-party backends included)
    faults_meta = {"schedule": faults.to_dict()} if faults is not None else {}
    plan = _search_to_plan(backend, method, mode, sim, workload, options,
                           faults_meta)
    _lint_gate(plan, workload,
               topo if topo is not None else getattr(sim, "topology", None),
               lint)
    if repo is not None:
        from repro.core.plan_repo import as_repository
        as_repository(repo).put(plan)
    return plan


def retune(plan: TunedPlan, workload: Workload, *, sites=None,
           telemetry=None, hardware=None, repo=None,
           max_steps: Optional[int] = None) -> TunedPlan:
    """Drift-scoped warm re-tune of an installed plan (``core.retune``).

    Where ``tune`` searches every group from scratch, ``retune`` (1)
    calibrates the simulator's hardware model from observed per-site
    costs (``telemetry``), (2) re-searches only the comm groups owning
    the drifted ``sites`` — warm-started from ``plan``'s own configs,
    re-seeded at the calibrated cost model's balance point — and (3)
    returns a child ``TunedPlan`` whose ``lineage`` records the parent
    digest, drift scope and calibration deltas.  Untouched groups keep
    the parent's configs verbatim.

    Args:
        plan: the installed ``TunedPlan`` to warm-start from.
        workload: the live workload; must fingerprint-match ``plan``.
        sites: drifted SiteIds scoping the re-search (``None`` = every
            group, still warm-started).
        telemetry: observed per-site costs (seconds) — a ``{site: cost}``
            dict or a ``serving.telemetry.SiteTelemetry`` buffer (its
            most recent row is used).  ``None`` skips calibration.
        hardware: override profile (default: the plan's own).
        repo: directory or ``PlanRepository`` to auto-``put`` the child
            into (same key as the parent — the repo entry advances).
        max_steps: per-group search-step cap.

    Returns:
        A new ``TunedPlan`` with ``lineage["retuned_from"]`` set to
        ``plan.artifact_digest()``.

    Raises:
        PlanMismatchError: ``workload`` is structurally different from
            the one ``plan`` was tuned on.

    Example::

        >>> from repro.configs import get_smoke_config
        >>> from repro.core import ParallelPlan, extract_decode_workload
        >>> wl = extract_decode_workload(
        ...     get_smoke_config("llama3-8b"), ParallelPlan(kind="tp", tp=2),
        ...     global_batch=8, seq=64)
        >>> parent = tune(wl, "tpu-v5e", method="lagom")
        >>> child = retune(parent, wl, sites=["serve.layer0.attn.ar"])
        >>> child.lineage["retuned_from"] == parent.artifact_digest()
        True
        >>> child.lineage["generation"]
        1
    """
    from repro.core.retune import retune_plan  # lazy: retune imports session

    return retune_plan(plan, workload, sites=sites, telemetry=telemetry,
                       hardware=hardware, repo=repo, max_steps=max_steps)


__all__ = [
    "MODES", "PLAN_VERSION", "PlanMismatchError", "SearchBackend",
    "SearchOutcome", "TunedPlan", "available_methods", "get_backend",
    "load_plan", "register_backend", "retune", "structure_fingerprint",
    "tune", "unregister_backend", "workload_fingerprint", "workload_shape",
]


# ---------------------------------------------------------------------------
# CLI:  python -m repro.core.session diff a.json b.json
# ---------------------------------------------------------------------------

def _format_diff(a_path: str, b_path: str, d: Dict) -> str:
    lines = [f"plan diff: {a_path} vs {b_path}"]
    for f, (va, vb) in sorted(d["meta"].items()):
        lines.append(f"  meta {f}: {va!r} -> {vb!r}")
    if not d["changed"] and not d["only_self"] and not d["only_other"]:
        lines.append("  configs: identical")
        return "\n".join(lines)
    for sid, delta in d["changed"].items():
        fields_ = ", ".join(f"{f}: {va!r} -> {vb!r}"
                            for f, (va, vb) in sorted(delta.items()))
        lines.append(f"  {sid}: {fields_}")
    for sid in d["only_self"]:
        lines.append(f"  {sid}: only in {a_path}")
    for sid in d["only_other"]:
        lines.append(f"  {sid}: only in {b_path}")
    lines.append(f"  ({len(d['changed'])} site(s) changed, "
                 f"{len(d['only_self'])} only-left, "
                 f"{len(d['only_other'])} only-right)")
    return "\n".join(lines)


def _main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.core.session",
        description="TunedPlan artifact tooling")
    sub = ap.add_subparsers(dest="cmd", required=True)
    d = sub.add_parser("diff", help="field-level per-site config deltas "
                                    "between two saved plans")
    d.add_argument("a", help="baseline plan JSON")
    d.add_argument("b", help="comparison plan JSON")
    args = ap.parse_args(argv)
    if args.cmd == "diff":
        import sys

        plans = []
        for path in (args.a, args.b):
            # a missing file, non-JSON bytes, or JSON that is not a
            # TunedPlan artifact must exit with a clean diagnostic, not a
            # traceback — this CLI is wired into launch scripts
            try:
                plans.append(TunedPlan.load(path))
            except (OSError, ValueError, KeyError, TypeError) as e:
                print(f"error: {path}: not a readable TunedPlan artifact "
                      f"({e.__class__.__name__}: {e})", file=sys.stderr)
                return 2
        delta = plans[0].diff(plans[1])
        print(_format_diff(args.a, args.b, delta))
        return 0 if not (delta["changed"] or delta["only_self"]
                         or delta["only_other"] or delta["meta"]) else 1
    return 2


if __name__ == "__main__":
    raise SystemExit(_main())
