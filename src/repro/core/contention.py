"""Contention model — the paper's Eqs. (4)–(6) plus the communication-time
model that AutoCCL learns online.

Two contention dimensions (Sec. 3.2):
  * SM competition: NC channels occupy NC slots; computation waves become
      g_ij = ceil(μ_i / ((λ − NC_j) · TB_i))                      (Eq. 5)
  * Global-resource competition: communication draws V(NC, C) of the memory
    bandwidth; per-wave latency becomes
      f_ij = θ_ij + (λ − NC_j) · TB_i · D_i / (B̄ − V(NC_j, C_j)) (Eq. 6)
  and y_i = Σ_j f_ij · g_ij                                       (Eq. 4)
  (in the event-driven simulator the Σ over j emerges from time slicing).

NT (threads) is negligible by construction — multi-constraint occupancy and
coalesced transactions (Sec. 3.2); we give it a <0.5%% latency effect so the
tuner can verify the paper's negative result rather than assume it.
"""
from __future__ import annotations

import math
from typing import Optional

import numpy as np

from repro.core.comm_params import CommConfig
from repro.core.hardware import Hardware
from repro.core.workload import CommOp, CompOp

_PROTO = {
    # (bandwidth efficiency ceiling, per-chunk overhead multiplier)
    "latency": (0.70, 0.4),
    "mixed":   (0.92, 1.0),
    "bulk":    (1.00, 1.8),
}
_TRANSPORT = {"p2p": 1.0, "shm": 0.93, "net": 0.85}


def chunk_efficiency(chunk_kb: float, hw: Hardware, protocol: str) -> float:
    """Channel efficiency vs chunk size: small chunks pay per-chunk latency
    (diminishing returns curve of Fig. 3c)."""
    ceiling, _ = _PROTO[protocol]
    return ceiling * chunk_kb / (chunk_kb + hw.chunk_half_kb)


_NC_HALF = 3.0     # channels at which the bus reaches 50% of saturation


def wire_bandwidth(cfg: CommConfig, hw: Hardware) -> float:
    """Achieved bus bandwidth: rises with NC with diminishing returns and
    never quite saturates — the shape that makes a communication-only tuner
    (AutoCCL) keep over-allocating channels (paper Fig. 8: NC=61) while the
    marginal gain is tiny."""
    nc_curve = cfg.nc / (cfg.nc + _NC_HALF)
    bw = hw.link_bw * nc_curve * chunk_efficiency(cfg.chunk_kb, hw, cfg.protocol) \
        * _TRANSPORT[cfg.transport]
    return min(bw, hw.chan_bw * cfg.nc)      # few channels can't fill the bus


def comm_bandwidth_draw(cfg: CommConfig, hw: Hardware) -> float:
    """V(NC, C): global memory bandwidth consumed by the communication.
    HBM traffic ≈ 2× wire (read + write staging), plus per-channel staging
    pressure, capped below B̄."""
    wire = wire_bandwidth(cfg, hw)
    return min(2.0 * wire * (1.0 + 0.01 * cfg.nc), 0.85 * hw.hbm_bw)


def wire_bytes(op: CommOp, algo: str) -> float:
    """Per-chip wire traffic for the collective."""
    n = max(2, op.group_size)
    if op.kind == "allreduce":
        f = 2.0 * (n - 1) / n if algo != "tree" else 2.0 * math.log2(n) / n + 1.0
    elif op.kind in ("allgather", "reducescatter", "alltoall"):
        f = (n - 1) / n
    else:  # permute
        f = 1.0
    return op.bytes * f


def comm_time(op: CommOp, cfg: CommConfig, hw: Hardware, *,
              compute_active: bool = False) -> float:
    """x_j^{s_j} in seconds.  ``compute_active`` applies the reciprocal
    contention (computation stealing bandwidth from communication)."""
    bw = wire_bandwidth(cfg, hw)
    if compute_active:
        bw *= (1.0 - hw.comm_comp_beta)
    wb = wire_bytes(op, cfg.algorithm)
    n_chunks = max(1, math.ceil(op.bytes / (cfg.chunk_kb * 1024)))
    _, chunk_mult = _PROTO[cfg.protocol]
    nt_adj = 1.0 - 0.004 * (cfg.nt - 64) / 576.0          # negligible, by design
    n_steps = max(2, op.group_size) - 1 if cfg.algorithm == "ring" else \
        max(1, int(math.log2(max(2, op.group_size))))
    # per-step cost: the fixed 1µs algorithm-step overhead plus the fabric's
    # hop latency (0 pod-local; cross-pod RTT on core.topology inter tiers)
    latency = (hw.launch_us + 0.5 * cfg.nc                 # per-channel setup
               + n_chunks * hw.chunk_us * chunk_mult * nt_adj
               + n_steps * (1.0 + hw.hop_us)) * 1e-6
    return latency + wb / bw


def comp_time(op: CompOp, cfg: Optional[CommConfig], hw: Hardware) -> float:
    """y_i under an active communication with config ``cfg`` (None = alone).
    Implements Eqs. (4)–(6) for a single overlapped communication; the
    simulator time-slices across successive communications."""
    lam = hw.num_slots
    nc = min(cfg.nc, int(lam * 0.75)) if cfg is not None else 0
    V = comm_bandwidth_draw(cfg, hw) if cfg is not None else 0.0

    W = max(1, (lam - nc) * op.tb_per_slot)               # blocks per wave
    g = math.ceil(op.threadblocks / W)                    # Eq. 5
    # θ: pure-compute time per wave (a slot runs TB blocks concurrently),
    # inflated by staging-footprint interference: NC·C bytes of comm staging
    # evict the compute working set from L2/VMEM (the reason the paper's
    # Fig. 8 gains exceed the pure SM-wave effect).
    per_block_flops = op.flops / op.threadblocks
    theta = per_block_flops * op.tb_per_slot * lam / hw.achieved_flops
    if cfg is not None:
        footprint = cfg.nc * cfg.chunk_kb / hw.cache_kb
        theta *= 1.0 + hw.interference_gamma * min(1.0, footprint)
    mem = W * op.bytes_per_tb / max(hw.hbm_bw - V, 0.05 * hw.hbm_bw)  # Eq. 6
    return g * (theta + mem)


def comp_time_alone(op: CompOp, hw: Hardware) -> float:
    return comp_time(op, None, hw)


# ---------------------------------------------------------------------------
# Vectorized (batched) variants — the profiling engine's math kernel.
#
# These reproduce the scalar functions above BIT-FOR-BIT: every expression
# keeps the identical operator order/associativity on float64, so a batched
# profile equals the sequential event loop exactly (tests/test_profiling.py
# asserts `==`, not approx).  Array arguments broadcast; scalars come from
# the same Hardware dataclass.  Algorithm-dependent integer constants
# (wire-bytes factor, ring/tree step counts) are precomputed per-op with the
# scalar helpers and passed in, so no transcendental function is re-derived
# here.
# ---------------------------------------------------------------------------

PROTO_PARAMS = _PROTO            # public aliases for the batched engine
TRANSPORT_MULT = _TRANSPORT
NC_HALF = _NC_HALF


def comm_steps(op: CommOp, algorithm: str) -> int:
    """Step count of ``comm_time``'s latency term, factored out so the
    batched engine can precompute it with the identical expression."""
    if algorithm == "ring":
        return max(2, op.group_size) - 1
    return max(1, int(math.log2(max(2, op.group_size))))


def wire_bandwidth_v(nc, chunk_kb, proto_ceiling, transport_mult, hw: Hardware):
    """Vectorized ``wire_bandwidth`` (proto/transport constants pre-gathered)."""
    nc_curve = nc / (nc + _NC_HALF)
    eff = proto_ceiling * chunk_kb / (chunk_kb + hw.chunk_half_kb)
    bw = hw.link_bw * nc_curve * eff * transport_mult
    return np.minimum(bw, hw.chan_bw * nc)


def comm_bandwidth_draw_v(nc, chunk_kb, proto_ceiling, transport_mult,
                          hw: Hardware):
    """Vectorized ``comm_bandwidth_draw``; nc == 0 yields exactly 0.0 (the
    scalar ``cfg is None`` branch), which lets the engine pad a no-comm
    column instead of special-casing it."""
    wire = wire_bandwidth_v(nc, chunk_kb, proto_ceiling, transport_mult, hw)
    return np.minimum(2.0 * wire * (1.0 + 0.01 * nc), 0.85 * hw.hbm_bw)


def comm_time_v(op_bytes, wb, n_steps, nc, nt, chunk_kb, proto_ceiling,
                proto_chunk_mult, transport_mult, hw: Hardware, *,
                compute_active):
    """Vectorized ``comm_time``.  ``wb`` / ``n_steps`` are the per-(op, algo)
    constants from ``wire_bytes`` / ``comm_steps``; ``compute_active`` may be
    a bool or a boolean array."""
    bw = wire_bandwidth_v(nc, chunk_kb, proto_ceiling, transport_mult, hw)
    bw = np.where(compute_active, bw * (1.0 - hw.comm_comp_beta), bw)
    n_chunks = np.maximum(1, np.ceil(op_bytes / (chunk_kb * 1024)))
    nt_adj = 1.0 - 0.004 * (nt - 64) / 576.0
    latency = (hw.launch_us + 0.5 * nc
               + n_chunks * hw.chunk_us * proto_chunk_mult * nt_adj
               + n_steps * (1.0 + hw.hop_us)) * 1e-6
    return latency + wb / bw


def comp_time_v(theta_base, threadblocks, tb_per_slot, bytes_per_tb,
                nc, chunk_kb, V, hw: Hardware):
    """Vectorized ``comp_time``.  ``theta_base`` is the per-op pure-compute
    wave time ``(flops/μ)·TB·λ/achieved`` precomputed with scalar float
    arithmetic; nc == chunk_kb == V == 0 reproduces ``comp_time_alone``
    exactly (footprint multiplier collapses to 1.0, Eq. 6 denominator to B̄)."""
    lam = hw.num_slots
    nc_cl = np.minimum(nc, int(lam * 0.75))
    W = np.maximum(1, (lam - nc_cl) * tb_per_slot)
    g = np.ceil(threadblocks / W)
    footprint = nc * chunk_kb / hw.cache_kb
    theta = theta_base * (1.0 + hw.interference_gamma
                          * np.minimum(1.0, footprint))
    mem = W * bytes_per_tb / np.maximum(hw.hbm_bw - V, 0.05 * hw.hbm_bw)
    return g * (theta + mem)
