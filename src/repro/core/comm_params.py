"""Tunable collective-communication parameters (the paper's ``s_j``).

Six parameters per AutoCCL/Lagom: implementation-related (Algorithm,
Protocol, Transport — divide-and-conquer subspaces) and resource-related
(NC = channels, NT = threads, C = chunk size — the contention dials).
The per-communication space exceeds 10^6 configurations (Sec. 3.1).

TPU reinterpretation is documented per-knob in DESIGN.md §2; the dataclass
is hardware-neutral.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, replace
from typing import Iterator, Tuple

ALGORITHMS = ("ring", "tree", "bidir")       # TPU: decomposition strategy
PROTOCOLS = ("latency", "mixed", "bulk")     # NCCL LL / LL128 / Simple
TRANSPORTS = ("p2p", "shm", "net")           # TPU: ici / ici+dcn paths

NC_MIN, NC_MAX = 1, 64
NT_MIN, NT_MAX = 64, 640
C_MIN_KB, C_MAX_KB = 32, 8192


@dataclass(frozen=True)
class CommConfig:
    algorithm: str = "ring"
    protocol: str = "mixed"
    transport: str = "p2p"
    nc: int = 8          # number of channels
    nt: int = 256        # threads per channel (negligible — Sec. 3.2)
    chunk_kb: int = 2048 # C

    done: bool = False   # Algorithm 2 termination flag

    def clamp(self) -> "CommConfig":
        return self.with_()         # with_ applies the dial bounds

    def with_(self, **kw) -> "CommConfig":
        # fused replace+clamp: one construction instead of two (this runs
        # once per candidate dial in the tuner hot loop)
        d = dict(self.__dict__)
        d.update(kw)
        for f, lo, hi in (("nc", NC_MIN, NC_MAX), ("nt", NT_MIN, NT_MAX),
                          ("chunk_kb", C_MIN_KB, C_MAX_KB)):
            v = d[f]
            if type(v) is not int:
                v = int(round(v))
            d[f] = lo if v < lo else hi if v > hi else v
        return CommConfig(**d)


def min_config(base: "CommConfig | None" = None) -> CommConfig:
    """Algorithm 2 lines 1–3: start from minimal resource usage."""
    base = base or CommConfig()
    return base.with_(nc=NC_MIN, nt=NT_MIN, chunk_kb=C_MIN_KB, done=False)


def vendor_default(hw, kind: str = "allreduce") -> CommConfig:
    """NCCL-like defaults (what the un-tuned baseline runs)."""
    return CommConfig(nc=hw.default_nc, nt=256, chunk_kb=hw.default_chunk_kb)


def space_size() -> int:
    nc = NC_MAX - NC_MIN + 1
    nt = (NT_MAX - NT_MIN) // 32 + 1
    c = C_MAX_KB - C_MIN_KB + 1
    return len(ALGORITHMS) * len(PROTOCOLS) * len(TRANSPORTS) * nc * nt * c


def subspaces() -> Iterator[Tuple[str, str, str]]:
    """Implementation-related subspaces for divide-and-conquer (Sec. 2.2)."""
    return itertools.product(ALGORITHMS, PROTOCOLS, TRANSPORTS)
