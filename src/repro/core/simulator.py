"""Event-driven overlap simulator — the ProfileTime oracle.

Plays the role of the paper's online profiling step (DESIGN.md §2 deviation
1): two serialized streams (computation / communication) advance in
continuous time; whichever communication is active at an instant sets the
computation's instantaneous rate via the contention model, and vice versa
(reciprocal bandwidth steal).  The tuners treat this as a black box:
``profile(workload, configs) -> Measurement``.

Optional multiplicative lognormal noise emulates real measurement jitter so
the search algorithms cannot overfit exact model values.  The jitter comes
from counter-based Philox streams (``core.noise``): every noisy submission
holds a ticket ``(stream key, submission index)`` and its multipliers are a
pure function of that ticket, so the batched engine and the scalar
reference path below consume bit-identical values.  ``noise_mode``
selects the ticket policy — ``"default"`` (independent draws in flat
submission order) or ``"crn"`` (common random numbers keyed on the group's
structural fingerprint, which makes trajectory sharing sound under
jitter); see the ``core.noise`` module docstring for the full contract.
"""
from __future__ import annotations

import math
import numbers
from dataclasses import dataclass
from typing import List, Tuple

from repro.core import contention as C
from repro.core.comm_params import CommConfig
from repro.core.hardware import Hardware
from repro.core.noise import NOISE_MODES, NoiseModel
from repro.core.workload import ConfigSet, OverlapGroup, Workload


@dataclass
class GroupMeasurement:
    name: str
    Z: float                       # group makespan
    X: float                       # total communication busy time
    Y: float                       # total computation busy time
    comm_times: List[float]        # measured x_j (with contention)
    comp_times: List[float]        # measured y_i (with contention)


@dataclass
class Measurement:
    Z: float                       # iteration makespan (Σ group makespans)
    groups: List[GroupMeasurement]

    @property
    def X(self):
        return sum(g.X for g in self.groups)

    @property
    def Y(self):
        return sum(g.Y for g in self.groups)


class Simulator:
    """ProfileTime oracle.  ``batched=True`` (default) routes measurements
    through the vectorized + cached ``profiling.BatchSimulator`` engine;
    ``batched=False`` keeps every call on the pure-Python event loop below
    (the reference path, used by equivalence tests and the
    ``benchmarks/tuning_throughput.py`` baseline).  Both paths are
    numerically identical — including the noise RNG stream."""

    def __init__(self, hw: Hardware, *, noise: float = 0.0, seed: int = 0,
                 noise_mode: str = "default", batched: bool = True,
                 cache_size: int = 131072):
        # eager argument validation: a bad seed or noise level otherwise
        # only surfaces as an opaque Philox/Box-Muller failure (or silent
        # NaN measurements) deep inside the first noisy profile call
        if noise_mode not in NOISE_MODES:
            raise ValueError(
                f"noise_mode must be one of {NOISE_MODES}, got {noise_mode!r}")
        if isinstance(seed, bool) or not isinstance(seed, numbers.Integral):
            raise ValueError(
                f"seed must be an int, got {type(seed).__name__} ({seed!r})")
        if isinstance(noise, bool) or not isinstance(noise, numbers.Real) \
                or math.isnan(noise) or math.isinf(noise) or noise < 0:
            raise ValueError(
                "noise must be a finite non-negative lognormal sigma, got "
                f"{noise!r}")
        self.hw = hw
        self.noise = noise
        self.seed = seed
        self.noise_mode = noise_mode
        self._noise = NoiseModel(seed, noise, noise_mode) if noise else None
        self.profile_count = 0     # tuning-efficiency accounting (Fig. 8c)
        self.batched = batched
        self._cache_size = cache_size
        self._engine = None

    @property
    def can_share_trajectories(self) -> bool:
        """Whether structurally identical groups provably walk identical
        search trajectories, i.e. measurements are pure functions of
        (structure, configs, trajectory position): true noise-free and in
        CRN mode (fingerprint-keyed draws) — the soundness condition for
        ``scheduler.run_shared``."""
        return not self.noise or self.noise_mode == "crn"

    @property
    def engine(self):
        """The batched profiling engine (created lazily; import here avoids
        a simulator <-> profiling cycle)."""
        if self._engine is None:
            from repro.core.profiling import BatchSimulator
            self._engine = BatchSimulator(self, cache_size=self._cache_size)
        return self._engine

    # -- single overlap group (sequential reference path) ----------------
    def run_group(self, g: OverlapGroup, cfgs: List[CommConfig]) -> GroupMeasurement:
        assert len(cfgs) == len(g.comms)
        hw = self.hw
        if self.noise:
            # one ticket per submission; jitters are a pure function of it
            jit_comp, jit_comm = self._noise.group_jitters(
                g, len(g.comps), len(g.comms))
        else:
            jit_comp = [1.0] * len(g.comps)
            jit_comm = [1.0] * len(g.comms)

        # remaining work is tracked in fractions of each op
        comp_left = [1.0] * len(g.comps)
        comm_left = [1.0] * len(g.comms)
        comp_busy = comm_busy = 0.0
        comm_meas = [0.0] * len(g.comms)
        comp_meas = [0.0] * len(g.comps)
        ci = ki = 0                 # heads of comp / comm streams
        t = 0.0
        guard = 0
        while ci < len(g.comps) or ki < len(g.comms):
            guard += 1
            if guard > 100000:
                raise RuntimeError("simulator did not converge")
            active_cfg = cfgs[ki] if ki < len(g.comms) else None
            comp_active = ci < len(g.comps)

            comp_rate_dur = comm_rate_dur = math.inf
            if comp_active:
                comp_rate_dur = C.comp_time(g.comps[ci], active_cfg, hw) * jit_comp[ci]
            if ki < len(g.comms):
                comm_rate_dur = C.comm_time(g.comms[ki], cfgs[ki], hw,
                                            compute_active=comp_active) * jit_comm[ki]

            dt_options = []
            if comp_active:
                dt_options.append(comp_left[ci] * comp_rate_dur)
            if ki < len(g.comms):
                dt_options.append(comm_left[ki] * comm_rate_dur)
            dt = min(dt_options)
            t += dt
            if comp_active:
                comp_busy += dt
                comp_meas[ci] += dt
                comp_left[ci] -= dt / comp_rate_dur
                if comp_left[ci] <= 1e-12:
                    ci += 1
            if ki < len(g.comms):
                comm_busy += dt
                comm_meas[ki] += dt
                comm_left[ki] -= dt / comm_rate_dur
                if comm_left[ki] <= 1e-12:
                    ki += 1

        return GroupMeasurement(name=g.name, Z=t, X=comm_busy, Y=comp_busy,
                                comm_times=comm_meas, comp_times=comp_meas)

    # -- full workload ------------------------------------------------------
    def profile(self, wl: Workload, configs: ConfigSet) -> Measurement:
        self.profile_count += 1
        gms = []
        for gi, g in enumerate(wl.groups):
            cfgs = [configs[(gi, ci)] for ci in range(len(g.comms))]
            gms.append(self.engine.measure_one(g, cfgs) if self.batched
                       else self.run_group(g, cfgs))
        return Measurement(Z=sum(g.Z for g in gms), groups=gms)

    def profile_group(self, g: OverlapGroup, cfgs: List[CommConfig]) -> GroupMeasurement:
        self.profile_count += 1
        if self.batched:
            return self.engine.measure_one(g, cfgs)
        return self.run_group(g, cfgs)

    def profile_many(self, g: OverlapGroup,
                     cfg_lists: List[List[CommConfig]]) -> List[GroupMeasurement]:
        """Batched ProfileTime: one logical invocation per candidate (the
        Fig. 8c counter sees exactly what a loop of ``profile_group`` calls
        would), evaluated in a single vectorized pass.  An empty candidate
        list returns ``[]`` without touching the engine or the counter."""
        if not cfg_lists:
            return []
        self.profile_count += len(cfg_lists)
        if self.batched:
            return self.engine.measure_many(g, cfg_lists)
        return [self.run_group(g, cfgs) for cfgs in cfg_lists]

    def profile_many_grouped(
            self, requests: List[Tuple[OverlapGroup, List[List[CommConfig]]]],
    ) -> List[List[GroupMeasurement]]:
        """Cross-group batched ProfileTime for the tuning scheduler: every
        request is ``(group, cfg_lists)`` and the result lists align with
        the requests.  Accounting is unchanged — one logical invocation per
        candidate, summed across requests, so an interleaved schedule
        reports the same ``profile_count`` as the serial walk.  In noisy
        mode the reference path consumes the jitter RNG in flat submission
        order, matching the engine's draw contract (core.scheduler)."""
        total = sum(len(cfg_lists) for _, cfg_lists in requests)
        if not total:
            return [[] for _ in requests]
        self.profile_count += total
        if self.batched:
            return self.engine.measure_many_grouped(requests)
        return [[self.run_group(g, cfgs) for cfgs in cfg_lists]
                for g, cfg_lists in requests]
