"""Event-driven overlap simulator — the ProfileTime oracle.

Plays the role of the paper's online profiling step (DESIGN.md §2 deviation
1): two serialized streams (computation / communication) advance in
continuous time; whichever communication is active at an instant sets the
computation's instantaneous rate via the contention model, and vice versa
(reciprocal bandwidth steal).  The tuners treat this as a black box:
``profile(workload, configs) -> Measurement``.

Optional multiplicative lognormal noise emulates real measurement jitter so
the search algorithms cannot overfit exact model values.  The jitter comes
from counter-based Philox streams (``core.noise``): every noisy submission
holds a ticket ``(stream key, submission index)`` and its multipliers are a
pure function of that ticket, so the batched engine and the scalar
reference path below consume bit-identical values.  ``noise_mode``
selects the ticket policy — ``"default"`` (independent draws in flat
submission order) or ``"crn"`` (common random numbers keyed on the group's
structural fingerprint, which makes trajectory sharing sound under
jitter); see the ``core.noise`` module docstring for the full contract.

``faults=`` attaches a scripted :class:`~repro.core.faults.FaultSchedule`:
each logical ProfileTime invocation advances the fault clock by one step
(``profile_many`` counts one step per candidate, in flat submission order,
so the clock agrees with a loop of ``profile_group`` calls), and any
active fault window reshapes that step's draws — degraded link hardware
for matching comm sites, a duration multiplier on comps, and an extra
deterministic jitter burst.  Faulted steps run on the scalar reference
path (bypassing the engine's structural caches, which are keyed on
healthy hardware); an empty schedule is normalized away entirely, so the
fault-free path — and its results — are byte-identical to ``faults=None``.
"""
from __future__ import annotations

import math
import numbers
from dataclasses import dataclass
from typing import List, Tuple

from repro.core import contention as C
from repro.core.comm_params import CommConfig
from repro.core.faults import FaultSchedule, FaultState
from repro.core.hardware import Hardware
from repro.core.noise import NOISE_MODES, NoiseModel
from repro.core.topology import HierarchicalHardware
from repro.core.workload import ConfigSet, OverlapGroup, Workload


@dataclass
class GroupMeasurement:
    name: str
    Z: float                       # group makespan
    X: float                       # total communication busy time
    Y: float                       # total computation busy time
    comm_times: List[float]        # measured x_j (with contention)
    comp_times: List[float]        # measured y_i (with contention)


@dataclass
class Measurement:
    Z: float                       # iteration makespan (Σ group makespans)
    groups: List[GroupMeasurement]

    @property
    def X(self):
        return sum(g.X for g in self.groups)

    @property
    def Y(self):
        return sum(g.Y for g in self.groups)


class Simulator:
    """ProfileTime oracle.  ``batched=True`` (default) routes measurements
    through the vectorized + cached ``profiling.BatchSimulator`` engine;
    ``batched=False`` keeps every call on the pure-Python event loop below
    (the reference path, used by equivalence tests and the
    ``benchmarks/tuning_throughput.py`` baseline).  Both paths are
    numerically identical — including the noise RNG stream."""

    def __init__(self, hw, *, noise: float = 0.0, seed: int = 0,
                 noise_mode: str = "default", batched: bool = True,
                 cache_size: int = 131072, faults: FaultSchedule = None):
        # ``hw`` may be a flat Hardware profile or a
        # ``topology.HierarchicalHardware``.  Flat topologies (pods == 1)
        # collapse to their bare island profile, so their entire code path
        # — and results — are byte-identical to passing the Hardware
        # directly.  Hierarchical ones keep the topology for per-comm tier
        # pricing in ``run_group``.
        topology = None
        if isinstance(hw, HierarchicalHardware):
            topology = None if hw.is_flat else hw
            hw = hw.island
        elif not isinstance(hw, Hardware):
            raise ValueError(
                "hw must be a Hardware profile or a HierarchicalHardware "
                f"topology, got {type(hw).__name__}")
        # eager argument validation: a bad seed or noise level otherwise
        # only surfaces as an opaque Philox/Box-Muller failure (or silent
        # NaN measurements) deep inside the first noisy profile call
        if noise_mode not in NOISE_MODES:
            raise ValueError(
                f"noise_mode must be one of {NOISE_MODES}, got {noise_mode!r}")
        if isinstance(seed, bool) or not isinstance(seed, numbers.Integral):
            raise ValueError(
                f"seed must be an int, got {type(seed).__name__} ({seed!r})")
        if isinstance(noise, bool) or not isinstance(noise, numbers.Real) \
                or math.isnan(noise) or math.isinf(noise) or noise < 0:
            raise ValueError(
                "noise must be a finite non-negative lognormal sigma, got "
                f"{noise!r}")
        if faults is not None and not isinstance(faults, FaultSchedule):
            raise ValueError(
                f"faults must be a FaultSchedule, got {type(faults).__name__}")
        self.hw = hw
        self.topology = topology
        self.noise = noise
        self.seed = seed
        self.noise_mode = noise_mode
        self._noise = NoiseModel(seed, noise, noise_mode) if noise else None
        self.profile_count = 0     # tuning-efficiency accounting (Fig. 8c)
        # hierarchical measurements run on the scalar reference path: the
        # engine's structural caches are keyed on a single healthy hardware
        # (same reason faulted steps bypass it)
        self.batched = batched and topology is None
        self._cache_size = cache_size
        self._engine = None
        # empty schedule -> None: the fault-free path is left untouched
        self.faults = faults if faults else None

    @property
    def can_share_trajectories(self) -> bool:
        """Whether structurally identical groups provably walk identical
        search trajectories, i.e. measurements are pure functions of
        (structure, configs, trajectory position): true noise-free and in
        CRN mode (fingerprint-keyed draws) — the soundness condition for
        ``scheduler.run_shared``.  A fault schedule breaks purity a second
        way: measurements then also depend on the global fault clock."""
        return (not self.noise or self.noise_mode == "crn") \
            and self.faults is None

    @property
    def engine(self):
        """The batched profiling engine (created lazily; import here avoids
        a simulator <-> profiling cycle)."""
        if self._engine is None:
            from repro.core.profiling import BatchSimulator
            self._engine = BatchSimulator(self, cache_size=self._cache_size)
        return self._engine

    # -- single overlap group (sequential reference path) ----------------
    def run_group(self, g: OverlapGroup, cfgs: List[CommConfig], *,
                  fstate: FaultState = None) -> GroupMeasurement:
        assert len(cfgs) == len(g.comms)
        hw = self.hw
        if self.noise:
            # one ticket per submission; jitters are a pure function of it
            jit_comp, jit_comm = self._noise.group_jitters(
                g, len(g.comps), len(g.comms))
        else:
            jit_comp = [1.0] * len(g.comps)
            jit_comm = [1.0] * len(g.comms)

        comm_hw = None
        if self.topology is not None:
            # hierarchical topology: each comm prices on the fabric tier
            # its site spans — the pod-local island or the slow inter-pod
            # tier (which still carries the island's compute side, so
            # Eqs. 4-6 contention applies across tiers)
            comm_hw = [self.topology.comm_hardware(op) for op in g.comms]
        if fstate is not None:
            # active fault window: per-comm degraded link hardware (faults
            # degrade whichever tier the comm prices on), a global comp
            # slowdown, and this step's jitter burst folded into the
            # submission multipliers
            base_hw = comm_hw if comm_hw is not None else [hw] * len(g.comms)
            comm_hw = [
                fstate.hardware_for(op.site_id, op.name.split(".", 1)[0], bh)
                for op, bh in zip(g.comms, base_hw)]
            if fstate.comp_scale != 1.0:
                jit_comp = [j * fstate.comp_scale for j in jit_comp]
            if fstate.sigma:
                b_comp, b_comm = fstate.burst_jitters(
                    len(g.comps), len(g.comms))
                jit_comp = [j * b for j, b in zip(jit_comp, b_comp)]
                jit_comm = [j * b for j, b in zip(jit_comm, b_comm)]

        # remaining work is tracked in fractions of each op
        comp_left = [1.0] * len(g.comps)
        comm_left = [1.0] * len(g.comms)
        comp_busy = comm_busy = 0.0
        comm_meas = [0.0] * len(g.comms)
        comp_meas = [0.0] * len(g.comps)
        ci = ki = 0                 # heads of comp / comm streams
        t = 0.0
        guard = 0
        while ci < len(g.comps) or ki < len(g.comms):
            guard += 1
            if guard > 100000:
                raise RuntimeError("simulator did not converge")
            active_cfg = cfgs[ki] if ki < len(g.comms) else None
            comp_active = ci < len(g.comps)
            # the active comm's (possibly degraded) link sets the contention
            # terms for BOTH streams: a slower link shrinks the comm's
            # memory-bandwidth draw V, so overlapped compute responds too
            cur_hw = comm_hw[ki] if comm_hw is not None and ki < len(g.comms) \
                else hw

            comp_rate_dur = comm_rate_dur = math.inf
            if comp_active:
                comp_rate_dur = C.comp_time(g.comps[ci], active_cfg, cur_hw) * jit_comp[ci]
            if ki < len(g.comms):
                comm_rate_dur = C.comm_time(g.comms[ki], cfgs[ki], cur_hw,
                                            compute_active=comp_active) * jit_comm[ki]

            dt_options = []
            if comp_active:
                dt_options.append(comp_left[ci] * comp_rate_dur)
            if ki < len(g.comms):
                dt_options.append(comm_left[ki] * comm_rate_dur)
            dt = min(dt_options)
            t += dt
            if comp_active:
                comp_busy += dt
                comp_meas[ci] += dt
                comp_left[ci] -= dt / comp_rate_dur
                if comp_left[ci] <= 1e-12:
                    ci += 1
            if ki < len(g.comms):
                comm_busy += dt
                comm_meas[ki] += dt
                comm_left[ki] -= dt / comm_rate_dur
                if comm_left[ki] <= 1e-12:
                    ki += 1

        return GroupMeasurement(name=g.name, Z=t, X=comm_busy, Y=comp_busy,
                                comm_times=comm_meas, comp_times=comp_meas)

    def _fault_states(self, count: int):
        """The fault window for each of the next ``count`` logical
        invocations (fault clock = pre-increment ``profile_count``), or
        ``None`` when no window is active — the fault-free fast path."""
        if self.faults is None:
            return None
        states = [self.faults.state_at(self.profile_count + i)
                  for i in range(count)]
        return states if any(s is not None for s in states) else None

    # -- full workload ------------------------------------------------------
    def profile(self, wl: Workload, configs: ConfigSet) -> Measurement:
        states = self._fault_states(1)
        self.profile_count += 1
        gms = []
        for gi, g in enumerate(wl.groups):
            cfgs = [configs[(gi, ci)] for ci in range(len(g.comms))]
            if states is not None:
                gms.append(self.run_group(g, cfgs, fstate=states[0]))
            else:
                gms.append(self.engine.measure_one(g, cfgs) if self.batched
                           else self.run_group(g, cfgs))
        return Measurement(Z=sum(g.Z for g in gms), groups=gms)

    def profile_group(self, g: OverlapGroup, cfgs: List[CommConfig]) -> GroupMeasurement:
        states = self._fault_states(1)
        self.profile_count += 1
        if states is not None:
            return self.run_group(g, cfgs, fstate=states[0])
        if self.batched:
            return self.engine.measure_one(g, cfgs)
        return self.run_group(g, cfgs)

    def profile_many(self, g: OverlapGroup,
                     cfg_lists: List[List[CommConfig]]) -> List[GroupMeasurement]:
        """Batched ProfileTime: one logical invocation per candidate (the
        Fig. 8c counter sees exactly what a loop of ``profile_group`` calls
        would), evaluated in a single vectorized pass.  An empty candidate
        list returns ``[]`` without touching the engine or the counter.
        When a fault window covers any candidate's step, the whole call
        takes the scalar reference path (the two paths are bit-identical,
        so unfaulted candidates are unaffected) with per-candidate states."""
        if not cfg_lists:
            return []
        states = self._fault_states(len(cfg_lists))
        self.profile_count += len(cfg_lists)
        if states is not None:
            return [self.run_group(g, cfgs, fstate=s)
                    for cfgs, s in zip(cfg_lists, states)]
        if self.batched:
            return self.engine.measure_many(g, cfg_lists)
        return [self.run_group(g, cfgs) for cfgs in cfg_lists]

    def profile_many_grouped(
            self, requests: List[Tuple[OverlapGroup, List[List[CommConfig]]]],
    ) -> List[List[GroupMeasurement]]:
        """Cross-group batched ProfileTime for the tuning scheduler: every
        request is ``(group, cfg_lists)`` and the result lists align with
        the requests.  Accounting is unchanged — one logical invocation per
        candidate, summed across requests, so an interleaved schedule
        reports the same ``profile_count`` as the serial walk.  In noisy
        mode the reference path consumes the jitter RNG in flat submission
        order, matching the engine's draw contract (core.scheduler); the
        fault clock ticks in the same flat candidate order."""
        total = sum(len(cfg_lists) for _, cfg_lists in requests)
        if not total:
            return [[] for _ in requests]
        states = self._fault_states(total)
        self.profile_count += total
        if states is not None:
            out, k = [], 0
            for g, cfg_lists in requests:
                row = []
                for cfgs in cfg_lists:
                    row.append(self.run_group(g, cfgs, fstate=states[k]))
                    k += 1
                out.append(row)
            return out
        if self.batched:
            return self.engine.measure_many_grouped(requests)
        return [[self.run_group(g, cfgs) for cfgs in cfg_lists]
                for g, cfg_lists in requests]
