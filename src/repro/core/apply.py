"""Map tuned ``CommConfig``s onto JAX runtime knobs.

XLA collectives are compile-time constructs (DESIGN.md §2 deviation 2), so
"applying" a tuned config means choosing the chunked/ring implementations
in ``parallel.collectives`` and their chunk counts, then re-lowering.

  chunk_kb  -> num_chunks = ceil(payload / chunk)
  algorithm -> strategy: ring -> explicit ppermute ring, tree/bidir ->
               "chunked" scan of partial collectives, vendor default -> xla
  nc        -> no HLO footprint (DMA concurrency); consumed by the
               simulator and recorded for deployment (XLA flags).
"""
from __future__ import annotations

import math
from typing import Dict

from repro.core.comm_params import CommConfig
from repro.core.workload import ConfigSet, Workload
from repro.parallel.collectives import CollectiveRuntime

MAX_CHUNKS = 16      # scheduler-friendly cap: beyond this, per-chunk launch
                     # overhead dominates (same cliff as the paper's Fig. 3c)


def to_runtime(cfg: CommConfig, payload_bytes: float) -> CollectiveRuntime:
    chunks = max(1, math.ceil(payload_bytes / (cfg.chunk_kb * 1024.0)))
    chunks = min(MAX_CHUNKS, chunks)
    if cfg.algorithm == "ring":
        strategy = "ring"
    elif cfg.algorithm in ("tree", "bidir"):
        strategy = "chunked"
    else:
        strategy = "xla"
    return CollectiveRuntime(strategy=strategy, num_chunks=chunks)


def runtime_plan(wl: Workload, configs: ConfigSet) -> Dict[str, CollectiveRuntime]:
    """Per-site runtime plan keyed by the CommOp name prefix (site class)."""
    plan: Dict[str, CollectiveRuntime] = {}
    for (gi, ci), cfg in configs.items():
        op = wl.groups[gi].comms[ci]
        key = op.name.split(".")[0]        # ag / rs / ar / a2a site class
        plan.setdefault(key, to_runtime(cfg, op.bytes))
    return plan
