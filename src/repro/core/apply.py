"""Map tuned ``CommConfig``s onto JAX runtime knobs.

XLA collectives are compile-time constructs (DESIGN.md §2 deviation 2), so
"applying" a tuned config means choosing the chunked/ring implementations
in ``parallel.collectives`` and their chunk counts, then re-lowering.

  chunk_kb  -> num_chunks = ceil(payload / chunk)
  algorithm -> strategy: ring -> explicit ppermute ring, tree/bidir ->
               "chunked" scan of partial collectives, vendor default -> xla
  nc        -> no HLO footprint (DMA concurrency); consumed by the
               simulator and recorded for deployment (XLA flags).

The lowered plan is **per-site**: every tunable comm site's stable dotted
SiteId (``fsdp.layer3.ag_params``, ``tp.layer1.mlp.ar.fwd.mb0``, ...)
maps to its own ``CollectiveRuntime``, and every dotted *prefix* of a
SiteId is registered as a fallback entry (first site wins), down to the
legacy coarse class buckets (``"ag"``/``"rs"``/``"ar"``/``"a2a"``/
``"p2p"``).  Model-builder call sites address the plan at whatever
granularity they know (``tp.layer1.mlp`` covers both the layer's ag and
rs), and ``collectives.runtime_for`` walks the same hierarchy — so two
layers of one model can resolve to different chunk structure while legacy
class-keyed callers keep getting the exact knobs they always did.
"""
from __future__ import annotations

import math
import os
from typing import Dict, List

from repro.core.comm_params import CommConfig
from repro.core.workload import ConfigSet, Workload, comm_site_meta
from repro.parallel.collectives import CollectiveRuntime

MAX_CHUNKS = 16      # scheduler-friendly cap: beyond this, per-chunk launch
                     # overhead dominates (same cliff as the paper's Fig. 3c)


def to_runtime(cfg: CommConfig, payload_bytes: float) -> CollectiveRuntime:
    chunks = max(1, math.ceil(payload_bytes / (cfg.chunk_kb * 1024.0)))
    chunks = min(MAX_CHUNKS, chunks)
    if cfg.algorithm == "ring":
        strategy = "ring"
    elif cfg.algorithm in ("tree", "bidir"):
        strategy = "chunked"
    else:
        strategy = "xla"
    return CollectiveRuntime(strategy=strategy, num_chunks=chunks)


def site_runtime_plan(sites: List[Dict],
                      configs: ConfigSet) -> Dict[str, CollectiveRuntime]:
    """Per-site runtime plan keyed by SiteId, with hierarchical fallback
    entries at every dotted prefix plus the legacy class buckets;
    ``sites`` is ``workload.comm_site_meta`` metadata (live or deserialized
    from a ``TunedPlan``).  Sites without a tuned config are skipped.
    ``setdefault`` everywhere: the first site contributing to a prefix (or
    class) wins, which keeps the class-bucket knobs bit-identical to the
    pre-per-site three-knob plans."""
    plan: Dict[str, CollectiveRuntime] = {}
    for s in sites:
        cfg = configs.get((s["group"], s["comm"]))
        if cfg is None:
            continue
        rt = to_runtime(cfg, s["bytes"])
        sid = s.get("site") or s["name"]
        parts = sid.split(".")
        for k in range(len(parts), 0, -1):
            plan.setdefault(".".join(parts[:k]), rt)
        plan.setdefault(s["name"].split(".")[0], rt)   # ag / rs / ar / a2a / p2p
    return plan


def plan_digest(rt: Dict[str, CollectiveRuntime]) -> tuple:
    """Hashable identity of a lowered runtime plan.  Plans are consumed at
    *trace* time (``collectives.runtime_for`` inside the model builders),
    so a jitted step traced under one plan silently keeps that plan's
    chunk structure forever — plan-aware serving engines key their
    compiled-step caches on this digest to retrace per plan instead."""
    return tuple(sorted((sid, r.strategy, r.num_chunks)
                        for sid, r in rt.items()))


def runtime_plan(wl: Workload, configs: ConfigSet) -> Dict[str, CollectiveRuntime]:
    """Per-site runtime plan (see ``site_runtime_plan``) for a live workload."""
    return site_runtime_plan(comm_site_meta(wl), configs)


def activate(plan) -> Dict[str, CollectiveRuntime]:
    """Lower a ``session.TunedPlan`` (object or path to its JSON) to runtime
    knobs and install them as the process-wide base plan
    (``parallel.collectives.runtime_for``).  Returns the runtime plan —
    what the launchers' ``--tuned-plan`` flag applies at startup.  For a
    scoped install, use ``TunedPlan.applied()`` instead."""
    from repro.core.session import TunedPlan
    from repro.parallel import collectives

    if isinstance(plan, (str, os.PathLike)):
        plan = TunedPlan.load(plan)
    rt = plan.runtime_plan()
    collectives.install_runtime_plan(rt)
    return rt
