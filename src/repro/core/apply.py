"""Map tuned ``CommConfig``s onto JAX runtime knobs.

XLA collectives are compile-time constructs (DESIGN.md §2 deviation 2), so
"applying" a tuned config means choosing the chunked/ring implementations
in ``parallel.collectives`` and their chunk counts, then re-lowering.

  chunk_kb  -> num_chunks = ceil(payload / chunk)
  algorithm -> strategy: ring -> explicit ppermute ring, tree/bidir ->
               "chunked" scan of partial collectives, vendor default -> xla
  nc        -> no HLO footprint (DMA concurrency); consumed by the
               simulator and recorded for deployment (XLA flags).
"""
from __future__ import annotations

import math
import os
from typing import Dict, List

from repro.core.comm_params import CommConfig
from repro.core.workload import ConfigSet, Workload, comm_site_meta
from repro.parallel.collectives import CollectiveRuntime

MAX_CHUNKS = 16      # scheduler-friendly cap: beyond this, per-chunk launch
                     # overhead dominates (same cliff as the paper's Fig. 3c)


def to_runtime(cfg: CommConfig, payload_bytes: float) -> CollectiveRuntime:
    chunks = max(1, math.ceil(payload_bytes / (cfg.chunk_kb * 1024.0)))
    chunks = min(MAX_CHUNKS, chunks)
    if cfg.algorithm == "ring":
        strategy = "ring"
    elif cfg.algorithm in ("tree", "bidir"):
        strategy = "chunked"
    else:
        strategy = "xla"
    return CollectiveRuntime(strategy=strategy, num_chunks=chunks)


def site_runtime_plan(sites: List[Dict],
                      configs: ConfigSet) -> Dict[str, CollectiveRuntime]:
    """Per-site runtime plan keyed by the CommOp name prefix (site class);
    ``sites`` is ``workload.comm_site_meta`` metadata (live or deserialized
    from a ``TunedPlan``).  Sites without a tuned config are skipped."""
    plan: Dict[str, CollectiveRuntime] = {}
    for s in sites:
        cfg = configs.get((s["group"], s["comm"]))
        if cfg is None:
            continue
        key = s["name"].split(".")[0]      # ag / rs / ar / a2a site class
        plan.setdefault(key, to_runtime(cfg, s["bytes"]))
    return plan


def runtime_plan(wl: Workload, configs: ConfigSet) -> Dict[str, CollectiveRuntime]:
    """Per-site runtime plan keyed by the CommOp name prefix (site class)."""
    return site_runtime_plan(comm_site_meta(wl), configs)


def activate(plan) -> Dict[str, CollectiveRuntime]:
    """Lower a ``session.TunedPlan`` (object or path to its JSON) to runtime
    knobs and install them as the process-wide active plan
    (``parallel.collectives.runtime_for``).  Returns the runtime plan —
    what the launchers' ``--tuned-plan`` flag applies at startup."""
    from repro.core.session import TunedPlan
    from repro.parallel import collectives

    if isinstance(plan, (str, os.PathLike)):
        plan = TunedPlan.load(plan)
    rt = plan.runtime_plan()
    collectives.set_runtime_plan(rt)
    return rt
