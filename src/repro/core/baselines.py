"""Un-tuned baseline configurations (NCCL defaults / XLA defaults)."""
from __future__ import annotations

from repro.core.comm_params import vendor_default
from repro.core.workload import ConfigSet, Workload


def nccl_defaults(wl: Workload, hw) -> ConfigSet:
    cfg = vendor_default(hw)
    return {site: cfg for site in wl.comm_sites()}
