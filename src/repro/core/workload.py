"""Workload IR: what the tuner sees — overlap groups of computation and
communication operators (the M comps and N comms of Eq. 1).

The IR is framework-neutral: ``core.extract`` lowers a (model config ×
parallel plan × input shape) into this IR; the simulator executes it; the
tuners only ever see (Workload, configs) -> times.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from repro.core.comm_params import CommConfig

COMM_KINDS = ("allgather", "reducescatter", "allreduce", "alltoall", "permute")


@dataclass
class CompOp:
    """One computation operator (cuBLAS/cuDNN kernel; TPU fused region)."""
    name: str
    flops: float
    bytes_rw: float
    threadblocks: int          # μ_i — total blocks (tiles) to schedule
    tb_per_slot: int = 1       # TB_i — resident blocks per SM/slot
    bytes_per_tb: float = 0.0  # D_i — bytes moved per block

    def __post_init__(self):
        if not self.bytes_per_tb and self.threadblocks:
            self.bytes_per_tb = self.bytes_rw / self.threadblocks


@dataclass
class CommOp:
    """One collective in the serialized communication stream."""
    name: str
    kind: str                  # one of COMM_KINDS
    bytes: float               # payload per chip
    group_size: int = 8        # participating chips on its mesh axis
    site: str = ""             # stable dotted SiteId (runtime addressing);
                               # defaults to ``name`` when unset
    tier: str = ""             # fabric tier the site spans: "" = pod-local,
                               # "inter" = pod-joining (core.topology prices
                               # it on the slow fabric's Hardware)

    def __post_init__(self):
        assert self.kind in COMM_KINDS, self.kind

    @property
    def site_id(self) -> str:
        return self.site or self.name


@dataclass
class OverlapGroup:
    """One overlap window: comps run on the computation stream, comms on the
    (serialized) communication stream; makespan = max(X, Y) + unhidden."""
    name: str
    comps: List[CompOp] = field(default_factory=list)
    comms: List[CommOp] = field(default_factory=list)

    @property
    def total_flops(self) -> float:
        return sum(c.flops for c in self.comps)

    @property
    def total_comm_bytes(self) -> float:
        return sum(c.bytes for c in self.comms)


@dataclass
class Workload:
    """A training iteration (or serving step): sequence of overlap groups."""
    name: str
    groups: List[OverlapGroup]
    meta: Dict[str, float] = field(default_factory=dict)

    @property
    def num_comms(self) -> int:
        return sum(len(g.comms) for g in self.groups)

    def comm_sites(self) -> List[Tuple[int, int]]:
        """(group_idx, comm_idx) for every tunable communication."""
        return [(gi, ci) for gi, g in enumerate(self.groups)
                for ci in range(len(g.comms))]


ConfigSet = Dict[Tuple[int, int], CommConfig]


def comm_site_meta(wl: Workload) -> List[Dict]:
    """Portable per-site metadata — everything ``core.apply`` reads from
    the workload when lowering configs to runtime knobs, in a JSON-safe
    shape.  ``session.TunedPlan`` embeds this so a saved plan can be
    re-applied without rebuilding the workload it was tuned on.  ``site``
    is the stable dotted SiteId runtime call sites address
    (``collectives.runtime_for``)."""
    rows = []
    for gi, g in enumerate(wl.groups):
        for ci, op in enumerate(g.comms):
            row = dict(group=gi, comm=ci, name=op.name, kind=op.kind,
                       bytes=op.bytes, group_size=op.group_size,
                       site=op.site_id)
            if op.tier:           # append-only: flat workloads stay byte-stable
                row["tier"] = op.tier
            rows.append(row)
    return rows


def structure_components(wl: Workload) -> Tuple:
    """Shape-free structural identity of a workload: everything that stays
    fixed while batch/seq drift — the workload name (model × extraction
    kind), and per group its name, comp op names, and each comm's
    (kind, group_size, SiteId).  Two workloads with equal components are
    the same program at different shapes, which is the soundness condition
    for *tolerance-band* plan reuse (``PlanRepository.resolve(band=...)``):
    the sites line up one-to-one, only payload magnitudes differ.  Contrast
    ``session.workload_fingerprint``, which hashes op shapes/bytes and so
    changes with every batch/seq."""
    return (wl.name, tuple(
        (g.name,
         tuple(c.name for c in g.comps),
         # tier joins the identity only when set, so every pre-topology
         # fingerprint (and the plan repo keyed on it) stays stable
         tuple((c.kind, c.group_size, c.site_id) + ((c.tier,) if c.tier else ())
               for c in g.comms))
        for g in wl.groups))


def uniform_configs(wl: Workload, cfg: CommConfig) -> ConfigSet:
    return {site: cfg for site in wl.comm_sites()}


def matmul_comp(name: str, m: int, k: int, n: int, dsize: int = 2, *,
                tile: int = 128, tb_per_slot: int = 1) -> CompOp:
    """Helper: a GEMM's CompOp with tile-derived threadblock count."""
    flops = 2.0 * m * k * n
    bytes_rw = float(dsize) * (m * k + k * n + m * n)
    mu = max(1, math.ceil(m / tile) * math.ceil(n / tile))
    return CompOp(name=name, flops=flops, bytes_rw=bytes_rw,
                  threadblocks=mu, tb_per_slot=tb_per_slot)
