"""Cross-group tuning scheduler — one lock-step engine pipeline.

``tuner.tune_workload`` used to walk overlap groups one after another, so
every tuning step paid engine dispatch for a 3–5 candidate micro-batch
while independent groups sat idle — the same "keep both resources busy"
imbalance Lagom removes at the system level, reproduced inside the tuner.
This module turns the per-group searches into resumable step machines and
round-robins their pending candidate batches into a single cross-group
``Simulator.profile_many_grouped`` call per step, so the batched engine
(core.profiling) amortizes dispatch and vectorizes the replay across the
whole workload.

Protocol
========
A search is a ``StepSearch``: it exposes

  * ``pending`` — the candidate batch (list of config lists, all for one
    overlap group) it needs measured next; never empty while unfinished;
  * ``feed(measurements)`` — consume the measurements for ``pending`` (one
    ``GroupMeasurement`` per candidate, aligned) and advance to the next
    batch;
  * ``done`` / ``requests`` — completion flag and the number of logical
    ProfileTime invocations submitted so far.

Subclasses implement ``_search`` as a generator that *yields* candidate
batches and receives the measurement lists back — the natural way to keep
Algorithm 1/2 (and AutoCCL's coordinate descent) textually intact while
making every measurement point resumable.

Trajectory sharing
==================
In deterministic mode, measurements are pure functions of the group's
*structural* fingerprint and the configs, so two structurally identical
groups driven by the same search parameters provably walk the same
trajectory step for step.  ``run_shared`` exploits this: groups are
classed by a caller-supplied key (the tuner passes the structural
fingerprint), ONE search per class actually runs, and the duplicates'
logical ProfileTime invocations are accounted on top — a stack of
identical transformer layers tunes once, in lock-step, instead of
re-walking the cache layer after layer.

The same purity argument extends to CRN noise (``Simulator(noise_mode=
"crn")``): jitter is a pure function of ``(seed, structural fingerprint,
trajectory position)`` (core.noise), so identical groups see identical
noisy measurements at identical positions and their trajectories stay
byte-equal — sharing is sound under jitter.  ``Simulator.
can_share_trajectories`` is the authoritative predicate.  In default
noise mode each submission is an independent draw and trajectories of
identical groups legitimately diverge, so default-noisy callers schedule
one search per group.

Equivalence contract
====================
Deterministic mode: measurements are pure functions of ``(group, cfgs)``,
and each search only ever sees its own group's measurements, so the
interleaved schedule — with or without trajectory sharing — produces
configs, traces, and ``profile_count`` IDENTICAL to the serial walk
(tests/test_scheduler.py asserts equality on every multi-group model-zoo
workload).  ``profile_count`` keeps PR 1's meaning of *logical*
invocations: a shared trajectory increments it for every member group,
exactly as the serial walk's per-layer cache hits did.

Default noisy mode: noise tickets are issued per candidate in *flat
submission order* — requests in the order the scheduler submits them
(unfinished groups in group order, each group's batch in its internal
order), candidates within a request in list order.  That order differs
from the serial walk's, so noisy interleaved results may legitimately
differ from noisy serial ones, but they are seed-reproducible: same seed
+ same workload -> same configs, identical between the batched engine and
the ``batched=False`` reference path (which re-derives each submission's
ticket draws in the same flat order).

CRN noisy mode: tickets are keyed per structural fingerprint and indexed
per group trajectory, so results do NOT depend on the submission
interleaving at all — serial, interleaved, and shared schedules return
byte-identical configs, traces, and ``profile_count`` (asserted across
the model zoo in tests/test_noise.py), exactly like deterministic mode.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

from repro.core.workload import OverlapGroup

#: Scheduling modes for a whole-workload search (the session API's ``mode``):
#:   ``"serial"``      — finish each group before starting the next (the
#:                       reference walk; the exact pre-scheduler request
#:                       stream).
#:   ``"interleaved"`` — one cross-group engine call per lock-step round,
#:                       with trajectory sharing engaged automatically
#:                       whenever it is sound (``can_share_trajectories``).
#:   ``"shared"``      — interleaved with trajectory sharing *required*:
#:                       rejected up front when sharing is unsound
#:                       (default-mode noise) instead of silently degrading.
MODES = ("serial", "interleaved", "shared")


def resolve_mode(sim, mode: str) -> str:
    """Validate ``mode`` against ``MODES`` and the simulator's sharing
    soundness; returns the mode unchanged so call sites can inline it."""
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    if mode == "shared" and not sim.can_share_trajectories:
        raise ValueError(
            "mode='shared' requires trajectory sharing to be sound — a "
            "deterministic simulator or noise_mode='crn' (this one has "
            f"noise={sim.noise}, noise_mode={sim.noise_mode!r}); use "
            "mode='interleaved' to share opportunistically instead")
    return mode


class StepSearch:
    """Resumable search over one overlap group (see module docstring)."""

    def __init__(self):
        self._gen = self._search()
        self.done = False
        self.pending = None
        self.requests = 0           # logical ProfileTime invocations submitted
        self._advance(None)

    def _search(self):
        """Generator: yields candidate batches, receives measurement lists."""
        raise NotImplementedError
        yield  # pragma: no cover — marks this as a generator to subclasses

    def _advance(self, measurements) -> None:
        try:
            self.pending = self._gen.send(measurements)
        except StopIteration:
            self.done, self.pending = True, None
            return
        self.requests += len(self.pending)

    def feed(self, measurements: Sequence) -> None:
        """Consume measurements for ``pending`` and advance."""
        if self.done:
            raise RuntimeError("feed() on a finished search")
        self._advance(list(measurements))


Searches = List[Tuple[OverlapGroup, StepSearch]]


def run_serial(sim, searches: Searches) -> None:
    """Reference driver: finish each group before starting the next — the
    exact request stream of the pre-scheduler per-group loop."""
    for g, s in searches:
        while not s.done:
            s.feed(sim.profile_many(g, s.pending))


def run_interleaved(sim, searches: Searches) -> int:
    """Round-robin every unfinished group's pending batch into one
    cross-group engine call per step.  Returns the number of lock-step
    rounds (≈ the longest single group's step count, not the sum)."""
    rounds = 0
    while True:
        live = [(g, s) for g, s in searches if not s.done]
        if not live:
            return rounds
        requests = [(g, s.pending) for g, s in live]
        for (_, s), ms in zip(live, sim.profile_many_grouped(requests)):
            s.feed(ms)
        rounds += 1


def run_shared(sim, groups: Sequence[OverlapGroup], make_search,
               class_key) -> List[StepSearch]:
    """Interleave with trajectory sharing: groups with equal
    ``class_key(group)`` share one search (see module docstring — sound
    when ``sim.can_share_trajectories``: deterministic or CRN noise).
    Returns one search per group, aligned with ``groups``; duplicates
    reference their class's search.  Each duplicate's logical invocations
    are added to ``sim.profile_count`` so accounting matches a serial walk
    exactly."""
    classes: dict = {}
    reps: Searches = []
    order: List[StepSearch] = []
    for g in groups:
        key = class_key(g)
        s = classes.get(key)
        if s is None:
            s = make_search(g)
            classes[key] = s
            reps.append((g, s))
        order.append(s)
    run_interleaved(sim, reps)
    counted = set()
    for s in order:
        if id(s) in counted:
            sim.profile_count += s.requests     # logical accounting (Fig. 8c)
        else:
            counted.add(id(s))
    return order


def run_workload(sim, groups: Sequence[OverlapGroup], make_search,
                 class_key, mode: str) -> List[StepSearch]:
    """Mode dispatch shared by every whole-workload tuner
    (``tuner.search_workload`` / ``autoccl.search_workload``): validate
    ``mode``, pick the schedule — sharing whenever sound and not serial —
    and drive every group's search to completion.  Returns one finished
    search per group, aligned with ``groups``."""
    mode = resolve_mode(sim, mode)
    if mode != "serial" and sim.can_share_trajectories:
        return run_shared(sim, groups, make_search, class_key)
    searches = [(g, make_search(g)) for g in groups]
    if mode != "serial":
        run_interleaved(sim, searches)
    else:
        run_serial(sim, searches)
    return [s for _, s in searches]
