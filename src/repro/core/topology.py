"""Hierarchical (pod-aware) hardware model: fast islands × a slow fabric.

The paper evaluates on *flat* clusters — one interconnect tier, one
``Hardware`` profile.  Geo-distributed and bandwidth-starved training is
hierarchical: N pods, each a fast NVLink/ICI island described by an
existing :class:`~repro.core.hardware.Hardware` profile, joined by a much
slower pod-to-pod fabric (DCN, WAN, a PCIe switch complex) with its own
bandwidth, channel, launch and *latency* terms.  This module makes that
second tier a first-class cost-model citizen:

:class:`Fabric`
    The pod-joining interconnect tier: ``link_bw``/``chan_bw``/
    ``launch_us``/``chunk_us``/``chunk_half_kb`` exactly as on
    ``Hardware``, plus ``hop_us`` — a per-algorithm-step latency term
    (cross-pod RTT) the contention model adds on top of the fixed 1 µs
    step cost (``contention.comm_time``).  Built-ins live in ``FABRICS``
    (``"dcn"``, ``"wan"``, ``"pcie-switch"``).

:class:`HierarchicalHardware`
    ``pods`` copies of an ``island`` profile joined by a ``fabric``.
    Every :class:`~repro.core.workload.CommOp` carries a ``tier`` —
    ``""`` (pod-local, priced on the island) or ``"inter"`` (pod-spanning,
    priced on :meth:`inter_hardware`: the island's *compute* side with the
    fabric's link terms, so cross-pod communication still contends with
    island compute through Eqs. 4–6).  ``flat(hw)`` is the degenerate
    single-pod case — the simulator normalizes it away entirely, so flat
    tuning stays **bit-identical** to the single-fabric path.

Plans tuned under a topology record its :meth:`fingerprint` as provenance
(``TunedPlan.topology``) and refuse to evaluate under a different one —
a cross-pod plan applied to a flat fabric is exactly as unsound as a plan
for the wrong model.
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, fields, replace
from functools import cached_property
from typing import Dict, List, Optional, Union

from repro.core.hardware import Hardware, by_name

# CommOp.tier values: "" = pod-local (island), "inter" = pod-spanning.
TIERS = ("", "inter")


@dataclass(frozen=True)
class Fabric:
    """The pod-joining interconnect tier (see module docstring)."""

    name: str
    link_bw: float  # achieved pod-to-pod bus bandwidth (B/s)
    chan_bw: float  # per-channel bandwidth (B/s)
    launch_us: float  # per-collective launch overhead (µs)
    hop_us: float = 0.0  # per-algorithm-step latency (µs): ~RTT
    chunk_half_kb: float = 1024.0
    chunk_us: float = 2.0  # per-chunk processing overhead (µs)
    default_nc: int = 4
    default_chunk_kb: int = 8192

    def __post_init__(self):
        if self.link_bw <= 0 or self.chan_bw <= 0:
            raise ValueError(f"fabric {self.name!r} needs positive link_bw/chan_bw")
        if self.hop_us < 0 or self.launch_us < 0:
            raise ValueError(f"fabric {self.name!r} latency terms must be >= 0")

    def to_dict(self) -> Dict:
        return {f.name: getattr(self, f.name) for f in fields(self)}

    @classmethod
    def from_dict(cls, d: Dict) -> "Fabric":
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(
                f"unknown Fabric fields {sorted(unknown)}; "
                f"known: {sorted(known)}"
            )
        return cls(**d)


# Built-in pod-joining fabrics.  Bandwidths are achieved busbw per chip,
# not line rates — same convention as the Hardware profiles.
DCN_400G = Fabric(
    name="dcn",
    link_bw=6.25e9,  # 400 Gbps pod uplink, ~1/8 landing per chip
    chan_bw=3.125e9,
    launch_us=25.0,
    hop_us=12.0,  # same-campus pod-to-pod RTT per step
    chunk_half_kb=1024.0,
    chunk_us=2.0,
    default_nc=4,
    default_chunk_kb=8192,
)

WAN_10G = Fabric(
    name="wan",
    link_bw=1.0e9,  # cross-DC 10 Gbps effective
    chan_bw=0.5e9,
    launch_us=80.0,
    hop_us=500.0,  # cross-region RTT per step
    chunk_half_kb=4096.0,
    chunk_us=4.0,
    default_nc=2,
    default_chunk_kb=8192,
)

PCIE_SWITCH = Fabric(
    name="pcie-switch",
    link_bw=12e9,  # host PCIe complex joining NVLink islands
    chan_bw=3.0e9,
    launch_us=15.0,
    hop_us=3.0,
    chunk_half_kb=256.0,
    chunk_us=1.8,
    default_nc=8,
    default_chunk_kb=4096,
)

FABRICS: Dict[str, Fabric] = {f.name: f for f in (DCN_400G, WAN_10G, PCIE_SWITCH)}


def fabric_by_name(name: str) -> Fabric:
    """The registered fabric called ``name`` (``sorted(FABRICS)`` lists
    the built-ins); raises ``KeyError`` naming them otherwise."""
    try:
        return FABRICS[name]
    except KeyError:
        raise KeyError(
            f"unknown inter-pod fabric {name!r}; registered: "
            f"{sorted(FABRICS)}"
        ) from None


def _as_fabric(fabric: Union[Fabric, str, None]) -> Optional[Fabric]:
    if fabric is None or isinstance(fabric, Fabric):
        return fabric
    return fabric_by_name(fabric)


def _as_island(island: Union[Hardware, str]) -> Hardware:
    return by_name(island) if isinstance(island, str) else island


@dataclass(frozen=True)
class HierarchicalHardware:
    """``pods`` islands of ``island`` joined by ``fabric`` (see module
    docstring).  ``pods == 1`` is the flat degenerate case: no fabric is
    required, ``name`` collapses to the island's, and the simulator
    treats it exactly like the bare ``Hardware`` profile."""

    island: Hardware
    pods: int = 1
    fabric: Optional[Fabric] = None

    def __post_init__(self):
        if not isinstance(self.island, Hardware):
            raise TypeError(
                "island must be a Hardware profile, got "
                f"{type(self.island).__name__}"
            )
        if self.pods < 1:
            raise ValueError(f"pods must be >= 1, got {self.pods}")
        if self.pods > 1 and self.fabric is None:
            raise ValueError(
                f"{self.pods} pods need an inter-pod fabric; pass fabric= "
                f"(one of {sorted(FABRICS)} or a Fabric)"
            )

    # -- identity ----------------------------------------------------------
    @property
    def is_flat(self) -> bool:
        return self.pods == 1

    @property
    def name(self) -> str:
        """Repo-key-safe identity: the bare island name when flat (so flat
        plans key identically to single-fabric ones), else
        ``<island>-x<pods>-<fabric>``."""
        if self.is_flat:
            return self.island.name
        return f"{self.island.name}-x{self.pods}-{self.fabric.name}"

    def fingerprint(self) -> str:
        """Content hash of the full topology (island + pod count + fabric
        terms) — what ``TunedPlan.topology`` records and
        ``check_topology`` refuses mismatches on."""
        payload = json.dumps(self.to_dict(), sort_keys=True)
        return hashlib.sha256(payload.encode()).hexdigest()

    # -- tier pricing ------------------------------------------------------
    @cached_property
    def inter_hardware(self) -> Hardware:
        """The pod-spanning pricing profile: the island's compute side
        (FLOPs, HBM, slots, interference) with the fabric's link terms —
        a cross-pod collective still contends with island compute for
        memory bandwidth and SM slots, it just moves bytes over the slow
        tier and pays its per-step latency."""
        if self.is_flat:
            return self.island
        f = self.fabric
        return replace(
            self.island,
            name=f"{self.island.name}@{f.name}",
            link_bw=f.link_bw,
            chan_bw=f.chan_bw,
            launch_us=f.launch_us,
            chunk_us=f.chunk_us,
            chunk_half_kb=f.chunk_half_kb,
            hop_us=f.hop_us,
            default_nc=f.default_nc,
            default_chunk_kb=f.default_chunk_kb,
        )

    def tier_hardware(self, tier: str) -> Hardware:
        """The pricing profile for one ``CommOp.tier`` value."""
        if tier not in TIERS:
            raise ValueError(f"unknown fabric tier {tier!r}; known: {TIERS}")
        return self.inter_hardware if tier == "inter" else self.island

    def comm_hardware(self, op) -> Hardware:
        """The pricing profile for one ``CommOp`` — the fabric tier its
        site spans (the simulator's per-comm hook)."""
        return self.tier_hardware(op.tier)

    # -- serialization -----------------------------------------------------
    def to_dict(self) -> Dict:
        return {
            "island": self.island.to_dict(),
            "pods": self.pods,
            "fabric": None if self.fabric is None else self.fabric.to_dict(),
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "HierarchicalHardware":
        fab = d.get("fabric")
        return cls(
            island=Hardware.from_dict(d["island"]),
            pods=int(d.get("pods", 1)),
            fabric=None if fab is None else Fabric.from_dict(fab),
        )

    def to_json(self, *, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, allow_nan=False)

    @classmethod
    def from_json(cls, text: str) -> "HierarchicalHardware":
        return cls.from_dict(json.loads(text))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "HierarchicalHardware":
        with open(path) as f:
            return cls.from_json(f.read())


# ---------------------------------------------------------------------------
# constructors
# ---------------------------------------------------------------------------

def flat(island: Union[Hardware, str]) -> HierarchicalHardware:
    """The degenerate single-pod topology: bit-identical to tuning on the
    bare ``island`` profile (the simulator normalizes it away)."""
    return HierarchicalHardware(island=_as_island(island), pods=1)


def hierarchical(
    island: Union[Hardware, str],
    pods: int,
    fabric: Union[Fabric, str, None] = "dcn",
) -> HierarchicalHardware:
    """``pods`` islands of ``island`` joined by ``fabric`` (a ``Fabric``
    or a ``FABRICS`` name); ``pods == 1`` ignores the fabric and returns
    the flat topology."""
    island = _as_island(island)
    if pods == 1:
        return flat(island)
    return HierarchicalHardware(island=island, pods=pods, fabric=_as_fabric(fabric))


def two_pod(
    island: Union[Hardware, str] = "tpu-v5e",
    fabric: Union[Fabric, str] = "dcn",
) -> HierarchicalHardware:
    """The canonical hierarchical scenario: two islands over one slow
    fabric — the smallest topology where ``acc.*``/``outer.*`` cross-pod
    sites price differently from pod-local ones."""
    return hierarchical(island, 2, fabric)


def resolve_topology(
    topo: Union["HierarchicalHardware", Dict, str, None],
) -> Optional[HierarchicalHardware]:
    """Normalize a topology argument: ``None`` passes through, dicts are
    ``from_dict`` specs, strings are paths to saved topology JSON, and
    ``HierarchicalHardware`` instances are returned as-is."""
    if topo is None or isinstance(topo, HierarchicalHardware):
        return topo
    if isinstance(topo, dict):
        return HierarchicalHardware.from_dict(topo)
    if isinstance(topo, str):
        return HierarchicalHardware.load(topo)
    raise TypeError(
        "topology must be a HierarchicalHardware, a to_dict() spec, a "
        f"path to saved topology JSON, or None; got {type(topo).__name__}"
    )


def site_tier(site: str) -> str:
    """Fallback tier classification for sites whose ``CommOp`` predates
    the ``tier`` field (deserialized metadata): ``outer.*`` sync and
    ``acc.*.ar_grads`` span pods, everything else is pod-local."""
    if site.startswith("outer."):
        return "inter"
    if site.startswith("acc.") and site.endswith(".ar_grads"):
        return "inter"
    return ""
