"""Online re-tuning: live drift evidence -> calibrated, drift-scoped warm
re-search -> zero-downtime plan publish.

The offline pipeline tunes once and deploys the plan; this module is the
loop that keeps the plan true as the fabric changes underneath it.  Three
stages, each cheap by construction:

1. **Calibrate** (``calibrate_sites``): per drifted site, invert the
   contention model — find the bandwidth scale at which the site's tuned
   config would cost what telemetry actually observed — and express the
   result as an open-ended per-site ``degrade`` fault event.  A
   ``Simulator`` built on that schedule prices exactly the degraded
   fabric the engines are measuring, with zero profiling work.
2. **Warm re-search** (``retune_plan``): only the overlap groups owning
   drifted sites are re-searched.  Each drifted comm is re-seeded at the
   *calibrated* cost model's balance point (``tuner.warm_start_config``
   on the degraded hardware — the closed form does the big jump for
   free), non-drifted siblings seed from the installed plan verbatim,
   and the seeded ``GroupSearch`` refines with its Z-driven stop.  The
   result: an order-of-magnitude fewer ProfileTime calls than a cold
   full tune, with the same final makespan.
3. **Publish** (``RetuneService``): the child plan carries full lineage
   (parent digest, drift scope, calibration deltas, ancestor chain),
   lands in the ``PlanRepository`` under the same (fingerprint,
   hardware) key, and hot-swaps into the serving engine's
   ``PlanBinding`` between batches — compiled-step caches key on the
   plan digest, so the next batch retraces under the new configs and no
   token is ever dropped.

``RetuneService`` is the wiring: the engines hand it the sites their
``HealthMonitor`` flags (synchronous drive-by-tick — what
``launch/serve.py --retune`` and the tests use), or ``start()`` runs the
same ``tick`` on a background thread.
"""

from __future__ import annotations

import math
import threading
import time
import warnings
from typing import Dict, List, Optional, Tuple

from repro.core import contention
from repro.core.comm_params import vendor_default
from repro.core.faults import FaultEvent, FaultSchedule, degraded_hardware
from repro.core.session import (
    PlanMismatchError,
    TunedPlan,
    _lookup_hw,
    structure_fingerprint,
    workload_shape,
)
from repro.core.simulator import Simulator
from repro.core.tuner import tune_group, warm_start_config
from repro.core.workload import Workload, comm_site_meta

# a calibrated scale this close to 1.0 is measurement noise, not drift:
# no fault event is emitted and the site keeps its installed seed
_SCALE_NOISE_FLOOR = 0.999
_SCALE_MIN = 1e-3
DEFAULT_MAX_STEPS = 60


def _calibrate_scale(op, cfg, hw, observed: float) -> Tuple[float, float]:
    """Invert the contention model for one site: the bandwidth scale
    ``s`` at which ``comm_time(op, cfg, degraded_hardware(hw, s))``
    matches the observed cost.  Returns ``(scale, predicted_healthy)``;
    monotone geometric bisection (cost strictly rises as links slow), so
    ~40 iterations pin the scale to float precision with zero profiles."""
    predicted = contention.comm_time(op, cfg, hw, compute_active=False)
    if observed <= predicted * (1.0 + 1e-9):
        return 1.0, predicted  # at or below prediction: healthy
    worst = contention.comm_time(
        op, cfg, degraded_hardware(hw, _SCALE_MIN), compute_active=False
    )
    if worst < observed:
        return _SCALE_MIN, predicted  # beyond model range: clamp
    lo, hi = _SCALE_MIN, 1.0
    for _ in range(40):
        mid = math.sqrt(lo * hi)
        cost = contention.comm_time(
            op, cfg, degraded_hardware(hw, mid), compute_active=False
        )
        if cost > observed:
            lo = mid  # too slow a fabric -> raise the scale
        else:
            hi = mid
    return round(math.sqrt(lo * hi), 6), predicted


def calibrate_sites(
    plan: TunedPlan,
    workload: Workload,
    observed: Dict[str, float],
    sites: List[str],
    hw,
) -> Tuple[Dict, Optional[FaultSchedule]]:
    """Per-site hardware-model calibration from observed costs.

    Returns ``(calibration, schedule)``: one
    ``{site: {observed, predicted, scale}}`` row per calibrated site,
    plus a ``FaultSchedule`` of open-ended exact-site ``degrade`` events
    realizing those scales (``None`` when nothing drifted) — the
    schedule a re-tuning ``Simulator`` is built on."""
    by_site = {}
    for gi, g in enumerate(workload.groups):
        for ci, op in enumerate(g.comms):
            by_site[op.site_id] = (gi, ci, op)
    calibration: Dict[str, Dict] = {}
    events: List[FaultEvent] = []
    for sid in sorted(set(sites)):
        if sid not in by_site:
            raise ValueError(
                f"unknown drift site {sid!r}; workload sites: {sorted(by_site)}"
            )
        obs = observed.get(sid)
        if obs is None or obs <= 0:
            continue  # no evidence for this site: search uncalibrated
        gi, ci, op = by_site[sid]
        cfg = plan.configs.get((gi, ci)) or vendor_default(hw)
        scale, predicted = _calibrate_scale(op, cfg, hw, obs)
        calibration[sid] = {"observed": obs, "predicted": predicted, "scale": scale}
        if scale < _SCALE_NOISE_FLOOR:
            events.append(FaultEvent("degrade", site=sid, scale=scale, start=0))
    sched = FaultSchedule(events=tuple(events)) if events else None
    return calibration, sched


def retune_plan(
    plan: TunedPlan,
    workload: Workload,
    *,
    sites: Optional[List[str]] = None,
    telemetry=None,
    hardware=None,
    repo=None,
    max_steps: Optional[int] = None,
) -> TunedPlan:
    """Drift-scoped warm re-tune (the engine behind ``session.retune`` —
    see its docstring for the full argument contract).

    Only the overlap groups owning ``sites`` are re-searched; each
    drifted comm is re-seeded at the calibrated cost model's balance
    point, siblings and untouched groups keep the installed configs.
    The returned child plan's ``lineage`` records parentage
    (``retuned_from`` + ``chain``), the drift scope (``sites``,
    ``groups``) and the ``calibration`` deltas; ``faults["calibrated"]``
    carries the calibration schedule the search ran under."""
    plan.check(workload)
    hw = _lookup_hw(hardware if hardware is not None else plan.hardware)
    if hasattr(telemetry, "latest"):  # a SiteTelemetry ring buffer
        observed = telemetry.latest()
    else:
        observed = dict(telemetry or {})

    all_sites = {
        op.site_id: gi for gi, g in enumerate(workload.groups) for op in g.comms
    }
    if sites is None:
        scoped = sorted(range(len(workload.groups)))
        cal_sites = sorted(s for s in all_sites if s in observed)
    else:
        cal_sites = sorted(set(sites))
        unknown = [s for s in cal_sites if s not in all_sites]
        if unknown:
            raise ValueError(
                f"unknown drift site(s) {unknown}; workload sites: {sorted(all_sites)}"
            )
        scoped = sorted({all_sites[s] for s in cal_sites})

    calibration, sched = calibrate_sites(plan, workload, observed, cal_sites, hw)

    sim = Simulator(hw, faults=sched)
    configs = dict(plan.configs)
    profiles = 0
    traces: List[Dict] = []
    for gi in scoped:
        g = workload.groups[gi]
        seeds = []
        for ci, op in enumerate(g.comms):
            inst = plan.configs.get((gi, ci)) or vendor_default(hw)
            cal = calibration.get(op.site_id)
            if cal and cal["scale"] < _SCALE_NOISE_FLOOR:
                # the big jump is free: re-seed the drifted comm at the
                # calibrated model's balance point, keeping the searched
                # (algorithm, protocol) subspace choice
                ws = warm_start_config(g, ci, degraded_hardware(hw, cal["scale"]))
                seeds.append(
                    inst.with_(nc=ws.nc, nt=ws.nt, chunk_kb=ws.chunk_kb, done=False)
                )
            else:
                seeds.append(inst)
        res = tune_group(
            sim, g, seed_cfgs=seeds, max_steps=max_steps or DEFAULT_MAX_STEPS
        )
        for ci, cfg in enumerate(res.configs):
            configs[(gi, ci)] = cfg
        profiles += res.iterations
        traces.extend(dict(group=gi, **t) for t in res.trace)

    parent_digest = plan.artifact_digest()
    parent_lineage = plan.lineage or {}
    new = TunedPlan(
        method="lagom",
        mode="serial",
        hardware=hw.name,
        workload=workload.name,
        fingerprint=plan.fingerprint,
        seed=plan.seed,
        noise=0.0,
        noise_mode="default",
        configs=configs,
        sites=comm_site_meta(workload),
        profile_count=profiles,
        traces=traces,
        cache_stats=None,
        structure=plan.structure or structure_fingerprint(workload),
        shape=dict(plan.shape) or workload_shape(workload),
        faults={"calibrated": sched.to_dict()} if sched else {},
        lineage={
            "retuned_from": parent_digest,
            "generation": int(parent_lineage.get("generation", 0)) + 1,
            "sites": cal_sites,
            "groups": scoped,
            "calibration": calibration,
            "chain": [parent_digest] + list(parent_lineage.get("chain", [])),
        },
    )
    if repo is not None:
        from repro.core.plan_repo import as_repository

        as_repository(repo).put(new)
    return new


class RetuneService:
    """The online re-tuning loop around one serving ``PlanBinding``.

    ``handle(sites)`` is the synchronous drive-by-tick entry the engines
    call when their ``HealthMonitor`` flags sustained drift: it
    rate-limits (``interval`` batches between publishes, ``max_retunes``
    per run, optional ``drift_threshold`` floor), rebuilds the decode
    workload at the installed plan's shape, runs ``retune_plan`` on the
    binding's live telemetry, publishes to ``repo`` and hot-swaps via
    ``PlanBinding.set_plan`` — returning the new plan, or ``None`` when
    it declined (the engine then falls back to demotion).  ``tick()``
    polls the monitor for flagged-but-unhandled sites; ``start()`` runs
    ``tick`` on a daemon thread for true background operation."""

    def __init__(
        self,
        binding,
        *,
        repo=None,
        interval: int = 1,
        max_retunes: int = 4,
        drift_threshold: Optional[float] = None,
        max_steps: Optional[int] = None,
        poll_s: float = 0.05,
    ):
        if interval < 1:
            raise ValueError(f"interval must be >= 1, got {interval!r}")
        if max_retunes < 1:
            raise ValueError(f"max_retunes must be >= 1, got {max_retunes!r}")
        self.binding = binding
        self.repo = repo if repo is not None else binding.repo
        self.interval = interval
        self.max_retunes = max_retunes
        self.drift_threshold = drift_threshold
        self.max_steps = max_steps
        self.poll_s = poll_s
        self.history: List[Dict] = []
        self._last_publish: Optional[int] = None
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    @property
    def retunes(self) -> int:
        """Plans published so far this run."""
        return sum(1 for e in self.history if e["event"] == "retune")

    def handle(self, sites) -> Optional[TunedPlan]:
        """Re-tune for ``sites`` (drift-flagged SiteIds) now, or decline
        with ``None`` — rate limits and failures both decline, so the
        caller can fall back to demotion."""
        sites = sorted(set(sites))
        if not sites:
            return None
        with self._lock:
            return self._handle(sites)

    def _handle(self, sites: List[str]) -> Optional[TunedPlan]:
        b = self.binding
        old = b._plan
        if old is None:
            return None
        if self.retunes >= self.max_retunes:
            self._skip(sites, "max_retunes budget exhausted")
            return None
        if (
            self._last_publish is not None
            and b._batch - self._last_publish < self.interval
        ):
            self._skip(sites, f"within {self.interval}-batch interval")
            return None
        if self.drift_threshold is not None and b._health is not None:
            worst = max((b._health.last_drift.get(s, 0.0) for s in sites), default=0.0)
            if worst < self.drift_threshold:
                self._skip(
                    sites,
                    f"drift {worst:.3f} below threshold {self.drift_threshold:g}",
                )
                return None
        from repro.core.extract import extract_decode_workload

        shape = old.shape or {}
        gb = int(shape.get("global_batch") or b.last_batch or 1)
        seq = int(shape.get("seq") or b.max_seq or 0)
        wl = extract_decode_workload(b.cfg, b.parallel, global_batch=gb, seq=seq)
        try:
            new = retune_plan(
                old,
                wl,
                sites=sites,
                telemetry=b.telemetry.latest() or None,
                repo=self.repo,
                max_steps=self.max_steps,
            )
        except (PlanMismatchError, ValueError) as e:
            warnings.warn(
                f"online re-tune declined ({type(e).__name__}: {e}); "
                "falling back to demotion",
                RuntimeWarning,
                stacklevel=3,
            )
            self._skip(sites, f"{type(e).__name__}: {e}")
            return None
        b.set_plan(new)  # zero-downtime: picked up between batches
        event = {
            "event": "retune",
            "batch": b._batch,
            "sites": sites,
            "groups": list(new.lineage["groups"]),
            "profiles": new.profile_count,
            "retuned_from": new.lineage["retuned_from"][:12],
            "generation": new.lineage["generation"],
            "published": self.repo is not None,
        }
        b.events.append(event)
        self.history.append(event)
        self._last_publish = b._batch
        return new

    def _skip(self, sites: List[str], reason: str) -> None:
        event = {
            "event": "retune_skipped",
            "batch": self.binding._batch,
            "sites": sites,
            "reason": reason,
        }
        self.binding.events.append(event)
        self.history.append(event)

    # -- background mode ---------------------------------------------------
    def tick(self) -> Optional[TunedPlan]:
        """One poll: re-tune for any sites the binding's monitor has
        flagged and nothing has handled yet (a successful publish resets
        the monitor through ``set_plan``)."""
        mon = self.binding._health
        if mon is None:
            return None
        pending = sorted(set(mon.unhealthy) - set(self.binding.demoted))
        if not pending:
            return None
        return self.handle(pending)

    def start(self) -> None:
        """Run ``tick`` on a daemon thread every ``poll_s`` seconds until
        ``stop()``.  The synchronous ``handle`` path stays usable —
        publishes are serialized on one lock either way."""
        if self._thread is not None:
            return
        self._stop.clear()

        def _loop():
            while not self._stop.is_set():
                self.tick()
                time.sleep(self.poll_s)

        self._thread = threading.Thread(
            target=_loop, daemon=True, name="retune-service"
        )
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=5.0)
        self._thread = None

    def report(self) -> str:
        """One human-readable summary line (the launcher prints this
        after serving)."""
        n = self.retunes
        skipped = len(self.history) - n
        if not self.history:
            return (
                f"retune: armed, 0 re-tunes (budget {self.max_retunes}, "
                f"interval {self.interval} batch(es))"
            )
        parts = [f"retune: {n} re-tune(s)"]
        if n:
            last = next(e for e in reversed(self.history) if e["event"] == "retune")
            parts.append(
                f"last at batch {last['batch']} "
                f"({len(last['sites'])} site(s), "
                f"{last['profiles']} profiles, "
                f"generation {last['generation']})"
            )
        if skipped:
            parts.append(f"{skipped} declined")
        return ", ".join(parts)


__all__ = ["DEFAULT_MAX_STEPS", "RetuneService", "calibrate_sites", "retune_plan"]
