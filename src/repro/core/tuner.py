"""Lagom's search — Algorithm 1 (Cost-Effectiveness) + Algorithm 2
(Resource-Efficient Tuning).

Faithful to the paper with one documented interpretation: Alg. 2 line 8
writes ``lr = (x^{s'} − x^{s}) / x^{s'}`` which is ≤ 0 whenever the loop
continues (line 5 already terminated on positive), so we read it as the
relative improvement ``(x_prev − x_new) / x_new ≥ 0`` and apply it as a
multiplicative step on NC/NT/C (integer dials move by at least 1).  The
complexity remains linear in the number of communications: each comm takes
O(log(range)) growth steps and comms are tuned one-at-a-time by priority.

ProfileTime plumbing: the whole search is a resumable step machine
(``GroupSearch``, built on ``scheduler.StepSearch``) that *yields* its next
candidate batch — subspace probes, per-dial growth candidates, bisection
midpoints — and consumes the measurements fed back.  ``tune_group`` drives
one machine to completion through ``Simulator.profile_many`` (the serial
walk, bit-identical to the ``batched=False`` reference event loop
including the counter-based noise stream, core.noise); ``search_workload``
round-robins every group's pending batch into one cross-group
``profile_many_grouped`` call per step (``mode="interleaved"``, the
engine-aware default), which in deterministic and CRN-noise modes
produces configs, traces, and ``profile_count`` identical to the serial
walk.  ``profile_count`` still counts logical invocations.  The legacy
``tune_workload`` signature survives as a deprecation shim; the session
front door (``core.session``) is the supported public surface.
"""
from __future__ import annotations

import math
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core import priority
from repro.core.comm_params import (C_MAX_KB, C_MIN_KB, NC_MAX, NC_MIN,
                                    NT_MAX, CommConfig, min_config)
from repro.core.scheduler import StepSearch, run_workload
from repro.core.simulator import Simulator
from repro.core.workload import ConfigSet, OverlapGroup, Workload

LR_SEED = 0.5


@dataclass
class _CommState:
    cfg: CommConfig                  # current accepted config
    lr: float = LR_SEED
    h: float = priority.H_INIT
    done: bool = False
    initialized: bool = False
    last_x: float = math.inf         # measured comm time under accepted cfg
    history: List[Tuple[CommConfig, float]] = field(default_factory=list)


def _grow_candidates(cfg: CommConfig, lr: float, *, shrink: bool = False):
    """Per-dial growth candidates.  Lagom grows the dial whose step buys the
    most makespan — chunk size is contention-free (no slot steal) so it
    saturates first; NC only grows when chunks alone can't hide the comm.
    This is what lands on the paper's low-NC / moderate-C configs (Fig. 8:
    NC=2, C=684 KB where NCCL defaults NC=8, C=2 MB).

    ``shrink=True`` (warm-start mode, beyond-paper): also propose shrinking
    the contention dials, so a seed past the balance point can descend.

    Hot path: one positional ``CommConfig`` per stepped dial (``with_``'s
    dict merge costs ~3x as much and this runs for every tuning step)."""
    lr = max(0.25, min(1.0, lr))
    a, p, tr, done = cfg.algorithm, cfg.protocol, cfg.transport, cfg.done
    nc, nt, ck = cfg.nc, cfg.nt, cfg.chunk_kb
    cands = []
    c2 = min(C_MAX_KB, max(int(ck * 2), int(ck * (1 + lr))))
    if c2 != ck:
        cands.append(("chunk", CommConfig(a, p, tr, nc, nt, c2, done)))
    n2 = min(NC_MAX, max(nc + 1, int(round(nc * (1 + lr)))))
    if n2 != nc:
        cands.append(("nc", CommConfig(a, p, tr, n2, nt, ck, done)))
    t2 = min(NT_MAX, max(nt + 64, int(round(nt * (1 + lr)))))
    if t2 != nt:
        cands.append(("nt", CommConfig(a, p, tr, nc, t2, ck, done)))
    if shrink:
        n3 = max(NC_MIN, nc - max(1, nc // 3))
        if n3 != nc:
            cands.append(("nc-", CommConfig(a, p, tr, n3, nt, ck, done)))
        c3 = max(C_MIN_KB, ck // 2)
        if c3 != ck:
            cands.append(("chunk-", CommConfig(a, p, tr, nc, nt, c3, done)))
    return cands


def _midpoint(a: CommConfig, b: CommConfig) -> CommConfig:
    return a.with_(nc=(a.nc + b.nc) // 2, nt=(a.nt + b.nt) // 2,
                   chunk_kb=(a.chunk_kb + b.chunk_kb) // 2)


@dataclass
class TuneResult:
    configs: List[CommConfig]
    iterations: int                  # ProfileTime invocations
    trace: List[Dict]                # per-step log (benchmarks/Fig 8c)


def warm_start_config(group: OverlapGroup, j: int, hw) -> CommConfig:
    """Beyond-paper: instead of Algorithm 2's cold start from the minimum
    config, seed the search from the cost model's predicted balance point —
    the cheapest (NC, C) whose predicted communication time is below the
    group's un-contended computation time (§3.4 condition 3 says the optimum
    sits at X≈Y; the closed form gets us near it for free, and the online
    loop only has to correct model error)."""
    from repro.core import contention as _C
    y_est = sum(_C.comp_time_alone(c, hw) for c in group.comps)
    x_share = y_est / max(1, len(group.comms))
    op = group.comms[j]
    best = None
    for nc in (1, 2, 3, 4, 6, 8, 12, 16):
        for chunk in (256, 512, 1024, 2048, 4096):
            cfg = CommConfig(nc=nc, chunk_kb=chunk)
            x = _C.comm_time(op, cfg, hw, compute_active=True)
            cost = nc + chunk / 2048.0          # resource footprint order
            if x <= x_share and (best is None or cost < best[0]):
                best = (cost, cfg)
    if best is None:                            # comm-bound: start near max bw
        return CommConfig(nc=8, chunk_kb=2048)
    return best[1]


class GroupSearch(StepSearch):
    """Algorithm 1/2 over one overlap group as a resumable step machine:
    the generator body below is the former blocking loop with every
    ProfileTime call replaced by a ``yield`` of the candidate batch, so the
    search semantics are textually intact while a scheduler can interleave
    many groups' measurement points.  ``warm_start=True`` enables the
    beyond-paper cost-model seeding (see warm_start_config)."""

    def __init__(self, group: OverlapGroup, hw, *,
                 base: Optional[CommConfig] = None,
                 warm_start: bool = False,
                 seed_cfgs: Optional[List[CommConfig]] = None,
                 max_steps: int = 200):
        self.group = group
        self.hw = hw
        self.base = base
        self.warm_start = warm_start
        self.max_steps = max_steps
        n = len(group.comms)
        self.seed_cfgs = list(seed_cfgs) if seed_cfgs is not None else None
        if self.seed_cfgs is not None:
            # re-tune mode (beyond-paper): seed every comm from an installed
            # plan's configs and skip the subspace probes — the seed already
            # carries a searched (algorithm, protocol) choice.  Dynamics are
            # the warm Z-driven ones (shrink candidates, no paper stops), so
            # a seed past the balance point on changed hardware can descend.
            if len(self.seed_cfgs) != n:
                raise ValueError(
                    f"seed_cfgs must carry one config per comm "
                    f"({n} expected, got {len(self.seed_cfgs)})")
            self.states = [_CommState(cfg=c.with_(done=False),
                                      initialized=True)
                           for c in self.seed_cfgs]
        elif warm_start:
            self.states = [_CommState(cfg=warm_start_config(group, j, hw))
                           for j in range(n)]
        else:
            self.states = [_CommState(cfg=min_config(base)) for _ in range(n)]
        self.trace: List[Dict] = []
        super().__init__()

    def result(self) -> TuneResult:
        if not self.done:
            raise RuntimeError("search still has pending measurements")
        return TuneResult([s.cfg for s in self.states], self.requests,
                          self.trace)

    def _search(self):
        group, states, trace = self.group, self.states, self.trace
        warm_start = self.warm_start or self.seed_cfgs is not None
        n = len(group.comms)
        if n == 0:
            return

        # Alg 1 line 3: while ∃ s not done
        steps = 0
        prev_meas = None
        if self.seed_cfgs is not None:
            # one baseline measurement of the seed configs anchors the
            # Z-driven stop: a retune that cannot improve on the installed
            # plan terminates after a single candidate round.
            meas = (yield [[s.cfg for s in states]])[0]
            prev_meas = meas
            for i, s in enumerate(states):
                s.last_x = meas.comm_times[i]
            trace.append(dict(step=0, comm=-1, cfg=None, x=None, X=meas.X,
                              Y=meas.Y, Z=meas.Z, h=priority.H_INIT,
                              seeded=True))
        while any(not s.done for s in states) and steps < self.max_steps:
            steps += 1
            # line 4: argmin H among unfinished (first minimum wins, like min())
            j = -1
            for i in range(n):
                if not states[i].done and (j < 0 or states[i].h < states[j].h):
                    j = i
            st = states[j]

            # ---- Algorithm 2 for communication j -------------------------
            if not st.initialized:                  # lines 1–3: minimum config
                st.initialized = True
                # divide-and-conquer subspace pick (the AutoCCL framework
                # Lagom plugs into, Sec. 3.2): probe implementation-related
                # params at a mid-resource point, keep the best, then restart
                # from minimum.
                subs = (("ring", "mixed"), ("ring", "bulk"),
                        ("tree", "mixed"), ("bidir", "bulk"))
                probe_lists = []
                for algo, proto in subs:
                    probe = st.cfg.with_(algorithm=algo, protocol=proto,
                                         nc=4, chunk_kb=1024)
                    cfgs = [states[i].cfg for i in range(n)]
                    cfgs[j] = probe
                    probe_lists.append(cfgs)
                best_sub, best_x = None, math.inf
                for (algo, proto), m in zip(subs, (yield probe_lists)):
                    if m.comm_times[j] < best_x:
                        best_sub, best_x = (algo, proto), m.comm_times[j]
                if warm_start:  # keep the cost-model seed, adopt the subspace
                    st.cfg = st.cfg.with_(algorithm=best_sub[0],
                                          protocol=best_sub[1])
                else:           # paper-faithful: restart from the minimum
                    st.cfg = min_config(st.cfg).with_(algorithm=best_sub[0],
                                                      protocol=best_sub[1])
                cand = st.cfg
                cfgs = [states[i].cfg for i in range(n)]
                cfgs[j] = cand
                meas = (yield [cfgs])[0]
            else:
                cands = _grow_candidates(st.cfg, st.lr, shrink=warm_start)
                if not cands:                       # all dials saturated
                    st.done = True
                    st.cfg = st.cfg.with_(done=True)
                    continue
                cfgs = [states[i].cfg for i in range(n)]
                cand_lists = []
                for _, c in cands:
                    cl = list(cfgs)
                    cl[j] = c
                    cand_lists.append(cl)
                best = None                         # step the best dial
                for (_, c), m in zip(cands, (yield cand_lists)):
                    if best is None or m.Z < best[1].Z:
                        best = (c, m)
                cand, meas = best
                cfgs[j] = cand
                # warm mode is Z-driven: no candidate improves -> done.  A
                # cost-model warm start chases 0.2% gains (it must correct
                # model error); a plan-seeded re-tune already starts from a
                # searched optimum, so it only keeps moving for >=1% gains —
                # that is what keeps drift-scoped re-tunes far cheaper than
                # a cold tune.
                min_gain = 0.99 if self.seed_cfgs is not None else 0.998
                if warm_start and prev_meas is not None \
                        and meas.Z >= prev_meas.Z * min_gain:
                    st.done = True
                    st.cfg = st.cfg.with_(done=True)
                    st.h = math.inf
                    continue
            x_new = meas.comm_times[j]
            X_, Y_ = meas.X, meas.Y
            y_before = prev_meas.Y if prev_meas is not None else Y_
            x_before = st.last_x

            trace.append(dict(step=steps, comm=j, cfg=cand, x=x_new, X=X_,
                              Y=Y_, Z=meas.Z, h=st.h))

            # line 5: terminate if comm got slower, or comm fully hidden.
            # (2% guard band: profiles are noisy; the paper's real system
            # faces the same jitter on wall-clock measurements)
            # warm-start mode is purely Z-driven: skip the paper's x/X<Y stops.
            if warm_start:
                st.cfg = cand
                st.last_x = x_new
                prev_meas = meas
                continue
            if x_new - x_before > 0.02 * x_before \
                    and not math.isinf(st.last_x):
                st.done = True                      # revert: keep st.cfg
                st.cfg = st.cfg.with_(done=True)
                st.h = math.inf
                continue
            if X_ < Y_:
                # crossed the X=Y boundary (§3.4 condition 3): the optimum
                # sits between the previous config and this one — bisect
                # toward it.
                best_cfg, best_z = cand, meas.Z
                lo, hi = st.cfg, cand
                for _ in range(3):
                    mid = _midpoint(lo, hi)
                    if mid in (lo, hi):
                        break
                    cfgs[j] = mid
                    m2 = (yield [cfgs])[0]
                    trace.append(dict(step=steps, comm=j, cfg=mid,
                                      x=m2.comm_times[j], X=m2.X, Y=m2.Y,
                                      Z=m2.Z, h=st.h, bisect=True))
                    if m2.Z < best_z:
                        best_cfg, best_z = mid, m2.Z
                    if m2.X < m2.Y:
                        hi = mid    # still past the boundary — shrink down
                    else:
                        lo = mid
                st.cfg = best_cfg.with_(done=True)
                st.done = True
                st.last_x = x_new
                prev_meas = meas
                continue

            # accept; lines 8–11: grow by relative improvement
            if not math.isinf(st.last_x):
                st.lr = max(0.0, (x_before - x_new) / max(x_new, 1e-12))
                st.h = priority.metric_h(y_before, Y_, x_before, x_new)
            st.cfg = cand
            st.last_x = x_new
            st.history.append((cand, x_new))
            prev_meas = meas


def tune_group(sim: Simulator, group: OverlapGroup, *,
               base: Optional[CommConfig] = None,
               warm_start: bool = False,
               seed_cfgs: Optional[List[CommConfig]] = None,
               max_steps: int = 200) -> TuneResult:
    """Drive one ``GroupSearch`` to completion (the serial walk)."""
    gs = GroupSearch(group, sim.hw, base=base, warm_start=warm_start,
                     seed_cfgs=seed_cfgs, max_steps=max_steps)
    while not gs.done:
        gs.feed(sim.profile_many(group, gs.pending))
    return gs.result()


def search_workload(sim: Simulator, wl: Workload, *,
                    mode: str = "interleaved",
                    base: Optional[CommConfig] = None,
                    warm_start: bool = False,
                    ) -> Tuple[ConfigSet, int, List[Dict]]:
    """Tune every overlap group; groups are independent (their comms only
    contend within their own window), so their searches interleave into one
    cross-group engine call per step by default — and whenever trajectory
    sharing is sound (deterministic mode, or CRN noise: see
    ``Simulator.can_share_trajectories``) structurally identical groups
    share one trajectory outright (scheduler.run_shared).

    ``mode`` selects the schedule (``scheduler.MODES``): ``"serial"`` is
    the reference group walk, ``"interleaved"`` (default) the cross-group
    lock-step pipeline with opportunistic sharing, and ``"shared"``
    requires sharing soundness up front.  In deterministic and CRN modes
    all three return identical configs, traces, and ``profile_count``.

    This is the engine entry the session front door (``core.session``)
    drives; prefer ``session.tune`` unless you already hold a Simulator."""
    from repro.core.profiling import group_fingerprint

    def make(g):
        return GroupSearch(g, sim.hw, base=base, warm_start=warm_start)

    per_group = run_workload(sim, wl.groups, make, group_fingerprint, mode)
    configs: ConfigSet = {}
    iters = 0
    traces: List[Dict] = []
    for gi, gs in enumerate(per_group):
        res = gs.result()
        for ci, cfg in enumerate(res.configs):
            configs[(gi, ci)] = cfg
        iters += res.iterations
        traces.extend(dict(group=gi, **t) for t in res.trace)
    return configs, iters, traces


def tune_workload(sim: Simulator, wl: Workload, *,
                  base: Optional[CommConfig] = None,
                  warm_start: bool = False,
                  interleave: bool = True) -> Tuple[ConfigSet, int, List[Dict]]:
    """Deprecated pre-session entry point (one release of grace): the
    legacy 3-tuple signature, bit-identical to ``search_workload`` with
    ``mode="interleaved" if interleave else "serial"``.  Use
    ``repro.core.session.tune(..., method="lagom")`` instead."""
    warnings.warn(
        "tuner.tune_workload is deprecated; use repro.core.session.tune("
        "wl, hw, method='lagom', mode=...) — or tuner.search_workload for "
        "an existing Simulator — and will be removed next release",
        DeprecationWarning, stacklevel=2)
    return search_workload(sim, wl,
                           mode="interleaved" if interleave else "serial",
                           base=base, warm_start=warm_start)
