"""Lower (ModelConfig × ParallelPlan × InputShape) into the Workload IR.

Overlap structure per parallelism (paper Fig. 2):
  * FSDP: layer-i compute ‖ AllGather(layer i+1 params); backward:
    layer-i grads ‖ [AllGather(params i−1), ReduceScatter(grads i)]
    (the two-comm window of the paper's Pattern 2).
  * TP (Domino-style batch pipelining): attention compute of microbatch b
    ‖ AllReduce of microbatch b−1, same for the MLP half.
  * EP (dual-batch): expert FFN of one half-batch ‖ AlltoAll
    dispatch/combine of the other half.

Compute operators carry FLOPs / bytes / threadblock counts so the
contention model (Eqs. 4–6) can price them.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional

from repro.core.workload import CommOp, CompOp, OverlapGroup, Workload, matmul_comp


@dataclass(frozen=True)
class ParallelPlan:
    kind: str          # "fsdp" | "tp" | "ep" | "pp"
    dp: int = 1        # data-parallel degree (FSDP shard count for "fsdp")
    tp: int = 1
    ep: int = 1
    pp: int = 1        # pipeline stages
    microbatches: int = 2      # Domino / dual-batch pipelining depth
    dsize: int = 2             # bytes per element (bf16)
    # hierarchical-fabric axes (core.topology): ``pods`` replicas of the
    # plan's island joined by a slow inter-pod fabric.  ``accum_steps`` > 1
    # turns on ACCO-style gradient accumulation — per-layer groups shrink
    # to one microbatch and ``acc.step{k}`` groups hide microbatch k's grad
    # reduce under microbatch k+1's compute.  ``outer_frags`` > 0 (with
    # pods > 1) adds Streaming-DiLoCo ``outer.round{r}.sync.frag{f}``
    # groups: fragment-streamed cross-pod parameter sync hidden under the
    # next inner iteration's compute.
    pods: int = 1
    accum_steps: int = 1
    outer_frags: int = 0
    outer_rounds: int = 1

    @property
    def world(self) -> int:
        return max(self.dp, 1) * max(self.tp, 1) * max(self.ep, 1) \
            * max(self.pods, 1)


# ---------------------------------------------------------------------------
# per-layer compute ops
# ---------------------------------------------------------------------------

def _attn_ops(cfg, m: int, seq: int, batch_local: int, tp: int, dsize: int,
              tag: str) -> List[CompOp]:
    hd = cfg.head_dim
    hq = max(1, cfg.num_heads // tp)
    hkv = max(1, cfg.num_kv_heads // tp)
    ops = [
        matmul_comp(f"{tag}.qkv", m, cfg.d_model, (hq + 2 * hkv) * hd, dsize),
    ]
    ctx = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
    sdpa_flops = 2 * 2 * batch_local * hq * seq * ctx * hd / 2  # causal half
    sdpa_bytes = dsize * batch_local * seq * (hq + 2 * hkv + hq) * hd
    mu = max(1, batch_local * hq * math.ceil(seq / 128) * math.ceil(min(ctx, seq) / 512))
    ops.append(CompOp(f"{tag}.sdpa", sdpa_flops, sdpa_bytes, mu))
    ops.append(matmul_comp(f"{tag}.o", m, hq * hd, cfg.d_model, dsize))
    return ops


def _mlp_ops(cfg, m: int, tp: int, dsize: int, tag: str) -> List[CompOp]:
    f = max(1, cfg.d_ff // tp)
    n_in = 2 if cfg.mlp_kind == "swiglu" else 1
    ops = [matmul_comp(f"{tag}.up{i}", m, cfg.d_model, f, dsize) for i in range(n_in)]
    ops.append(matmul_comp(f"{tag}.down", m, f, cfg.d_model, dsize))
    return ops


def _expert_ops(cfg, tokens_local: int, ep: int, dsize: int, tag: str) -> List[CompOp]:
    # balanced routing: each device computes tokens_local·top_k expert-token
    # pairs across its num_experts/ep local experts
    m = max(1, tokens_local * cfg.top_k)
    f = cfg.moe_d_ff
    ops = [matmul_comp(f"{tag}.e_up{i}", m, cfg.d_model, f, dsize) for i in range(2)]
    ops.append(matmul_comp(f"{tag}.e_down", m, f, cfg.d_model, dsize))
    if cfg.num_shared_experts:
        sf = cfg.shared_d_ff or cfg.moe_d_ff * cfg.num_shared_experts
        ops += [matmul_comp(f"{tag}.s_up{i}", tokens_local, cfg.d_model, sf, dsize)
                for i in range(2)]
        ops.append(matmul_comp(f"{tag}.s_down", tokens_local, sf, cfg.d_model, dsize))
    return ops


def _layer_param_bytes(cfg, dsize: int) -> float:
    per_layer = cfg.param_count() - cfg.vocab_size * cfg.d_model * (
        1 if cfg.tie_embeddings else 2)
    return per_layer / max(1, cfg.num_layers) * dsize


def _scale(ops: List[CompOp], s: float, suffix: str) -> List[CompOp]:
    return [CompOp(o.name + suffix, o.flops * s, o.bytes_rw * s,
                   max(1, int(o.threadblocks * s)), o.tb_per_slot)
            for o in ops]


# ---------------------------------------------------------------------------
# workload builders
# ---------------------------------------------------------------------------

def extract_workload(cfg, plan: ParallelPlan, *, seq: int, global_batch: int,
                     decode: bool = False, layers: Optional[int] = None) -> Workload:
    L = layers or cfg.num_layers
    dsize = plan.dsize
    if decode:
        seq_q = 1
    else:
        seq_q = seq
    # under gradient accumulation the per-layer groups describe ONE
    # microbatch (1/accum_steps of the local batch); the other microbatches
    # live in the aggregated ``acc.step{k}`` groups appended below
    accum = max(1, plan.accum_steps) if not decode else 1
    batch_local = max(1, global_batch // max(1, plan.dp) // accum)
    m = batch_local * seq_q
    groups: List[OverlapGroup] = []

    if plan.kind == "fsdp":
        n = plan.dp
        pbytes = _layer_param_bytes(cfg, dsize)
        comp = (_attn_ops(cfg, m, seq, batch_local, 1, dsize, "attn")
                + _mlp_ops(cfg, m, 1, dsize, "mlp"))
        for i in range(L):
            groups.append(OverlapGroup(
                f"fwd.L{i}", comps=list(comp),
                comms=[CommOp(f"ag.L{i + 1}", "allgather", pbytes, n,
                              site=f"fsdp.layer{i + 1}.ag_params")]))
        if not decode:
            bcomp = _scale(comp, 2.0, ".bwd")
            for i in range(L):
                comms = [CommOp(f"ag.L{i - 1}", "allgather", pbytes, n,
                                site=f"fsdp.layer{i - 1}.ag_params.bwd")]
                if accum == 1:
                    # with accumulation, grads stay local per layer and the
                    # whole-model reduce moves to the acc.step{k} groups
                    comms.append(CommOp(f"rs.L{i}", "reducescatter", pbytes,
                                        n, site=f"fsdp.layer{i}.rs_grads"))
                groups.append(OverlapGroup(
                    f"bwd.L{i}", comps=list(bcomp), comms=comms))

    elif plan.kind == "tp":
        n = plan.tp
        mb = max(1, plan.microbatches)
        m_mb = max(1, m // mb)
        b_mb = max(1, batch_local // mb)
        ar_bytes = m_mb * cfg.d_model * dsize
        attn = _attn_ops(cfg, m_mb, seq, b_mb, n, dsize, "attn")
        mlp = _mlp_ops(cfg, m_mb, n, dsize, "mlp")
        passes = [("fwd", 1.0)] if decode else [("fwd", 1.0), ("bwd", 2.0)]
        for pname, s in passes:
            for i in range(L):
                groups.append(OverlapGroup(
                    f"{pname}.L{i}.attn",
                    comps=_scale(attn, s * mb, f".{pname}"),
                    comms=[CommOp(f"ar.attn.{pname}.L{i}.mb{b}", "allreduce",
                                  ar_bytes * s, n,
                                  site=f"tp.layer{i}.attn.ar.{pname}.mb{b}")
                           for b in range(mb)]))
                groups.append(OverlapGroup(
                    f"{pname}.L{i}.mlp",
                    comps=_scale(mlp, s * mb, f".{pname}"),
                    comms=[CommOp(f"ar.mlp.{pname}.L{i}.mb{b}", "allreduce",
                                  ar_bytes * s, n,
                                  site=f"tp.layer{i}.mlp.ar.{pname}.mb{b}")
                           for b in range(mb)]))

    elif plan.kind == "pp":
        # GPipe fill+drain: per tick, each stage's compute overlaps the
        # ppermute of the previous tick's activations to the next stage.
        n = max(2, plan.pp)
        layers_per_stage = max(1, L // n)
        mb = max(1, plan.microbatches)
        m_mb = max(1, m // mb)
        b_mb = max(1, batch_local // mb)
        stage_comp = (_attn_ops(cfg, m_mb, seq, b_mb, 1, dsize, "attn")
                      + _mlp_ops(cfg, m_mb, 1, dsize, "mlp"))
        stage_comp = _scale(stage_comp, float(layers_per_stage), ".stage")
        act_bytes = m_mb * cfg.d_model * dsize
        passes = [("fwd", 1.0)] if decode else [("fwd", 1.0), ("bwd", 2.0)]
        for pname, s in passes:
            for t in range(n + mb - 1):
                groups.append(OverlapGroup(
                    f"{pname}.tick{t}",
                    comps=_scale(stage_comp, s, f".{pname}"),
                    comms=[CommOp(f"p2p.{pname}.t{t}", "permute",
                                  act_bytes * s, n,
                                  site=f"pp.tick{t}.p2p.{pname}")]))

    elif plan.kind == "ep":
        n = plan.ep
        tokens_local = m
        halves = 2
        t_half = max(1, tokens_local // halves)
        a2a_bytes = t_half * cfg.top_k * cfg.d_model * dsize / n
        attn = _attn_ops(cfg, m, seq, batch_local, 1, dsize, "attn")
        experts = _expert_ops(cfg, t_half, n, dsize, "moe")
        moe_layers = max(1, L - cfg.first_dense_layers)
        passes = [("fwd", 1.0)] if decode else [("fwd", 1.0), ("bwd", 2.0)]
        for pname, s in passes:
            for i in range(moe_layers):
                groups.append(OverlapGroup(
                    f"{pname}.L{i}.attn", comps=_scale(attn, s, f".{pname}"), comms=[]))
                groups.append(OverlapGroup(
                    f"{pname}.L{i}.moe",
                    comps=_scale(experts, s * halves, f".{pname}"),
                    comms=[CommOp(f"a2a.{d}.{pname}.L{i}.h{h}", "alltoall",
                                  a2a_bytes * s, n,
                                  site=f"ep.layer{i}.moe.a2a_{d}.{pname}.h{h}")
                           for h in range(halves) for d in ("disp", "comb")]))
    else:
        raise ValueError(plan.kind)

    meta = {"seq": seq, "global_batch": global_batch}

    # -- ACCO gradient-accumulation overlap (acc.step{k} site class) -------
    # One microbatch's aggregate compute (the per-layer groups above are
    # exactly one microbatch when accum > 1), measured before acc/outer
    # groups are appended.
    mb_flops = sum(c.flops for g in groups for c in g.comps)
    mb_bytes = sum(c.bytes_rw for g in groups for c in g.comps)
    mb_tbs = sum(c.threadblocks for g in groups for c in g.comps)
    # a ``layers=`` trim scales the per-layer compute groups above, so the
    # whole-model reduce payloads scale with it too — otherwise a trimmed
    # workload's acc/outer groups price a 32-layer reduce against 4 layers
    # of compute
    param_bytes = cfg.param_count() * dsize * L / max(1, cfg.num_layers)
    shards = {"fsdp": plan.dp, "tp": plan.tp, "ep": plan.ep,
              "pp": plan.pp}[plan.kind]
    owned_bytes = param_bytes / max(1, shards)   # per-chip parameter shard

    if accum > 1:
        for k in range(accum):
            comms = []
            if plan.kind == "fsdp" and plan.dp > 1:
                # microbatch k's whole-model grad reduce across the pod-local
                # dp axis (replaces the per-layer rs_grads dropped above)
                comms.append(CommOp(
                    f"rs.grads.s{k}", "reducescatter", param_bytes, plan.dp,
                    site=f"acc.step{k}.rs_grads"))
            if plan.pods > 1:
                # the owned shard then reduces across pods on the slow tier
                comms.append(CommOp(
                    f"ar.grads.s{k}", "allreduce", owned_bytes, plan.pods,
                    site=f"acc.step{k}.ar_grads", tier="inter"))
            # hidden under microbatch k+1's compute; the last step has no
            # next microbatch — its reduce is the exposed tail
            comps = [] if k == accum - 1 else [
                CompOp(f"acc.mb{k + 1}.compute", mb_flops, mb_bytes,
                       max(1, mb_tbs))]
            groups.append(OverlapGroup(f"acc.step{k}", comps=comps,
                                       comms=comms))
        meta["accum_steps"] = float(accum)

    # -- Streaming-DiLoCo outer-loop sync (outer.round{r} site class) ------
    if plan.outer_frags > 0 and plan.pods > 1 and not decode:
        frags = plan.outer_frags
        frag_bytes = owned_bytes / frags
        iter_flops = mb_flops * accum            # one full inner iteration
        iter_bytes = mb_bytes * accum
        iter_tbs = mb_tbs * accum
        for r in range(max(1, plan.outer_rounds)):
            groups.append(OverlapGroup(
                f"outer.round{r}",
                comps=[CompOp(f"outer.r{r}.inner_iter", iter_flops,
                              iter_bytes, max(1, iter_tbs))],
                comms=[CommOp(f"outer.sync.r{r}.f{f}", "allreduce",
                              frag_bytes, plan.pods,
                              site=f"outer.round{r}.sync.frag{f}",
                              tier="inter")
                       for f in range(frags)]))
        meta["outer_frags"] = float(frags)
    if plan.pods > 1:
        meta["pods"] = float(plan.pods)

    total_flops = sum(g.total_flops for g in groups)
    meta["flops"] = total_flops
    return Workload(name=f"{cfg.name}:{plan.kind}", groups=groups, meta=meta)


def extract_decode_workload(cfg, plan: ParallelPlan, *, global_batch: int,
                            seq: int) -> Workload:
    """One *serving decode step* under ``plan``, with ``serve.*`` SiteIds.

    Unlike the per-kind training extractions above, serving deploys one
    combined topology: every layer contributes an attention group (TP
    AllReduce at ``serve.layer{i}.attn.ar``) plus either a dense MLP group
    (``serve.layer{i}.mlp.ag`` / ``.rs`` — the ``dense.tp_mlp`` pair) or a
    MoE group (``serve.layer{i}.moe.a2a_disp`` / ``.a2a_comb``), with
    ``i`` the *global* layer index — exactly the sites the sited decode
    path (``model.decode_step(mesh=...)``) resolves at trace time.  Comms
    appear only for degrees > 1, so a ``tp:1``/``ep:1`` plan yields a
    collective-free (but still fingerprintable) workload.

    ``global_batch`` is the number of sequences in flight (= tokens per
    decode step); ``seq`` the KV-cache context length.  Both land in
    ``meta`` as the banded shape coordinates tolerance-band repository
    resolution interpolates over.
    """
    dsize = plan.dsize
    tp = max(1, plan.tp)
    ep = max(1, plan.ep)
    m = max(1, global_batch)           # one token per in-flight sequence
    groups: List[OverlapGroup] = []
    attn = _attn_ops(cfg, m, seq, m, tp, dsize, "attn")
    mlp = _mlp_ops(cfg, m, tp, dsize, "mlp")
    act_bytes = m * cfg.d_model * dsize
    for i in range(cfg.num_layers):
        attn_comms = []
        if tp > 1:
            attn_comms.append(CommOp(f"ar.L{i}", "allreduce", act_bytes, tp,
                                     site=f"serve.layer{i}.attn.ar"))
        groups.append(OverlapGroup(f"decode.L{i}.attn", comps=list(attn),
                                   comms=attn_comms))
        if cfg.is_moe and i >= cfg.first_dense_layers:
            experts = _expert_ops(cfg, max(1, m // ep), ep, dsize, "moe")
            moe_comms = []
            if ep > 1:
                a2a_bytes = m * cfg.top_k * cfg.d_model * dsize / ep
                moe_comms = [CommOp(f"a2a.{d}.L{i}", "alltoall", a2a_bytes,
                                    ep, site=f"serve.layer{i}.moe.a2a_{d}")
                             for d in ("disp", "comb")]
            groups.append(OverlapGroup(f"decode.L{i}.moe", comps=experts,
                                       comms=moe_comms))
        else:
            mlp_comms = []
            if tp > 1:
                mlp_comms = [CommOp(f"ag.L{i}", "allgather", act_bytes, tp,
                                    site=f"serve.layer{i}.mlp.ag"),
                             CommOp(f"rs.L{i}", "reducescatter", act_bytes,
                                    tp, site=f"serve.layer{i}.mlp.rs")]
            groups.append(OverlapGroup(f"decode.L{i}.mlp", comps=list(mlp),
                                       comms=mlp_comms))
    total_flops = sum(g.total_flops for g in groups)
    return Workload(name=f"{cfg.name}:serve", groups=groups,
                    meta={"flops": total_flops, "seq": seq,
                          "global_batch": global_batch, "decode": 1.0})


def parse_parallel(spec: str) -> ParallelPlan:
    """``kind[:degree[:microbatches]]`` -> ``ParallelPlan`` — e.g.
    ``fsdp:8``, ``tp:4``, ``ep:16``, ``pp:4:8``.  The degree lands on the
    kind's own axis (dp for fsdp)."""
    parts = spec.split(":")
    kind = parts[0]
    deg = int(parts[1]) if len(parts) > 1 else 8
    mb = int(parts[2]) if len(parts) > 2 else 2
    axes = {"fsdp": "dp", "tp": "tp", "ep": "ep", "pp": "pp"}
    if kind not in axes:
        raise ValueError(f"unknown parallel kind {kind!r} in {spec!r} "
                         f"(expected one of {sorted(axes)})")
    return ParallelPlan(kind=kind, microbatches=mb, **{axes[kind]: deg})
