"""Lagom core: the paper's contribution.

  comm_params — the six tunable collective parameters (s_j)
  workload    — overlap-group IR (CompOp / CommOp / OverlapGroup)
  hardware    — cluster profiles (A40-PCIe, A40-NVLink, TPU v5e) + the
                named-profile registry (by_name / profiles)
  topology    — hierarchical fabric model: N pods of a Hardware island
                joined by a named inter-pod fabric (HierarchicalHardware)
  contention  — Eqs. 4–6 + communication-time model
  cost_model  — Eqs. 1–3 closed form
  simulator   — event-driven ProfileTime oracle
  faults      — scripted fault schedules (degraded links, stragglers,
                jitter bursts, flaps) injected into the oracle
  profiling   — batched/vectorized ProfileTime engine + caches
  scheduler   — cross-group interleaved tuning (resumable step machines)
  priority    — metric H (Eq. 7)
  tuner       — Algorithms 1–2 (Lagom)
  autoccl     — AutoCCL baseline tuner
  baselines   — NCCL/XLA default configs
  extract     — model × plan × shape -> Workload
  apply       — tuned configs -> JAX runtime knobs (chunked collectives)
  session     — the front door: tune(...) -> TunedPlan (portable artifact)
                + the SearchBackend registry
  plan_repo   — PlanRepository: (fingerprint × hardware) plan store for
                automatic reuse at launch (--plan-repo)
  retune      — online re-tuning: telemetry-calibrated, drift-scoped warm
                re-search + zero-downtime publish (RetuneService)
"""
from repro.core.comm_params import CommConfig, min_config, vendor_default
from repro.core.extract import (ParallelPlan, extract_decode_workload,
                                extract_workload, parse_parallel)
from repro.core.faults import (FaultEvent, FaultSchedule,
                               parse_fault_schedule)
from repro.core.hardware import (A40_NVLINK, A40_PCIE, PROFILES, TPU_V5E,
                                 Hardware, by_name, profiles,
                                 register_profile)
from repro.core.plan_repo import PlanRepoError, PlanRepository
from repro.core.topology import (FABRICS, Fabric, HierarchicalHardware,
                                 fabric_by_name, flat, hierarchical,
                                 resolve_topology, two_pod)
from repro.core.session import (PlanMismatchError, SearchBackend,
                                SearchOutcome, TunedPlan, available_methods,
                                register_backend,
                                structure_fingerprint, tune,
                                workload_fingerprint, workload_shape)

# ``retune`` names both the submodule and the session front door.  Import
# the submodule here (first import of ``repro.core.retune`` would
# otherwise re-bind the package attribute to the module mid-run), then
# deterministically re-bind the name to the function: ``from repro.core
# import retune`` always means the front door.
import repro.core.retune as _retune_module  # noqa: E402,F401
from repro.core.session import retune  # noqa: E402
from repro.core.simulator import Measurement, Simulator
from repro.core.workload import CommOp, CompOp, OverlapGroup, Workload

__all__ = [
    "CommConfig", "min_config", "vendor_default",
    "ParallelPlan", "extract_decode_workload", "extract_workload",
    "parse_parallel",
    "Hardware", "A40_PCIE", "A40_NVLINK", "TPU_V5E", "PROFILES",
    "by_name", "profiles", "register_profile",
    "Fabric", "FABRICS", "fabric_by_name", "HierarchicalHardware",
    "flat", "hierarchical", "two_pod", "resolve_topology",
    "Simulator", "Measurement",
    "FaultEvent", "FaultSchedule", "parse_fault_schedule",
    "CompOp", "CommOp", "OverlapGroup", "Workload",
    "tune", "retune", "TunedPlan", "PlanMismatchError", "SearchBackend",
    "SearchOutcome", "register_backend", "available_methods",
    "structure_fingerprint", "workload_fingerprint", "workload_shape",
    "PlanRepository", "PlanRepoError",
]
