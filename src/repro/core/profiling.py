"""Batched profiling engine — vectorized ProfileTime for the tuner hot path.

DESIGN
======
``Simulator.run_group`` is an event-driven loop: two serialized streams
(computation / communication) advance in continuous time, and between any
two head-completion events both heads progress *linearly* at rates fixed by
the pair ``(ci, ki)`` of current stream heads.  That piecewise-linear shape
admits a closed-form segment computation built from two small rate tables:

  * ``comp_dur[i, k]`` — duration of comp op i under comm config k, for
    k in ``0..N`` (column N = no active comm, i.e. ``comp_time_alone``);
  * ``comm_dur[k, active?]`` — duration of comm op k with/without an active
    computation stealing bandwidth.

The tables come from the vectorized ``contention.comp_time_v`` /
``comm_time_v`` kernels, which keep the scalar functions' exact float64
operation order — engine measurements equal the sequential event loop
BIT-FOR-BIT (tests/test_profiling.py asserts ``==``, never approx).

Two advance strategies share the tables:

  1. **Column-cached replay** (batches below ``_VECTOR_MIN``): each table
     column depends only on ``(group structure, comm slot, that slot's
     config)``, so columns are LRU-cached and a candidate's table is
     assembled by lookup; the remaining per-candidate replay is a handful
     of float ops per event.  This is what the tuner's 3–5-candidate
     batches hit, and it is valid in BOTH noise modes because jitter
     multiplies the cached rates after assembly.
  2. **Lock-step array advance** (large batches): all candidates' streams
     advance together with NumPy array ops — per iteration, gather every
     candidate's current-head durations, take the per-candidate ``min``
     segment, retire heads.  The Python-level loop runs at most ~M+N times
     regardless of batch size, so interpreter cost amortizes across the
     candidate set (benchmark sweeps, exhaustive probes).

Noise-mode semantics: jitter multipliers are drawn from the *simulator's*
RNG, one lognormal per comp then per comm, candidate-by-candidate in batch
order — the identical stream a sequence of ``run_group`` calls would
consume, so noisy refactored call sites reproduce seed measurements
exactly.

Cache-key semantics: the measurement-level LRU ``ProfileCache`` keys on a
*structural* fingerprint of the group (op shapes/bytes; names excluded —
a transformer stack of structurally identical layers shares one entry per
config) plus the tuple of configs with the ``done`` flag normalized away
(it never enters the math).  Hits return a shared measurement object whose
``name`` is the first structurally-identical group measured — measurements
are immutable value objects and nothing reads ``.name`` programmatically,
so structural sharing stays observable only as speed.  **Noisy mode
bypasses the measurement cache entirely** (both lookup and fill): jittered
measurements are draws, not values, and replaying one would both break
RNG-stream reproducibility and let a tuner overfit a lucky sample.  The
rate-column cache is deterministic pre-jitter math and is shared by both
modes.  ``Simulator.profile_count`` counts *logical* ProfileTime
invocations — cache hits increment it — so Fig. 8c tuning-efficiency
accounting is unchanged by the engine.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core import contention as C
from repro.core.comm_params import CommConfig
from repro.core.hardware import Hardware
from repro.core.workload import OverlapGroup

_TINY = 1e-12                       # head-completion epsilon (matches run_group)


def group_fingerprint(g: OverlapGroup) -> Tuple:
    """Structural identity of a group for caching: everything the contention
    model reads, nothing it doesn't (names excluded)."""
    return (
        tuple((c.flops, c.bytes_rw, c.threadblocks, c.tb_per_slot,
               c.bytes_per_tb) for c in g.comps),
        tuple((c.kind, c.bytes, c.group_size) for c in g.comms),
    )


def _cfg_key(cfg: CommConfig) -> Tuple:
    # ``done`` is a tuner bookkeeping flag with no effect on measurements.
    return (cfg.algorithm, cfg.protocol, cfg.transport,
            cfg.nc, cfg.nt, cfg.chunk_kb)


class ProfileCache:
    """Generic LRU keyed on hashable tuples (measurements / rate columns)."""

    def __init__(self, maxsize: int = 131072):
        self.maxsize = maxsize
        self._d: "OrderedDict[Tuple, Tuple]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._d)

    def get(self, key):
        v = self._d.get(key)
        if v is None:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return v

    def put(self, key, value) -> None:
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.maxsize:
            self._d.popitem(last=False)

    def clear(self) -> None:
        self._d.clear()


class _GroupKernel:
    """Per-(group structure, hardware) static arrays for the batched math."""

    def __init__(self, g: OverlapGroup, hw: Hardware):
        self.M = len(g.comps)
        self.N = len(g.comms)
        self.comms = list(g.comms)
        lam = hw.num_slots
        # theta_base keeps the scalar expression order of contention.comp_time
        self.threadblocks = np.array([c.threadblocks for c in g.comps],
                                     dtype=np.int64)
        self.tb_per_slot = np.array([c.tb_per_slot for c in g.comps],
                                    dtype=np.int64)
        self.bytes_per_tb = np.array([c.bytes_per_tb for c in g.comps],
                                     dtype=np.float64)
        self.theta_base = np.array(
            [c.flops / c.threadblocks * c.tb_per_slot * lam / hw.achieved_flops
             for c in g.comps], dtype=np.float64)

    def comp_column(self, cfg, V, hw: Hardware) -> Tuple[float, ...]:
        """Durations of every comp op under one comm config (nc=chunk=V=0
        reproduces ``comp_time_alone`` exactly)."""
        nc = cfg.nc if cfg is not None else 0
        chunk = cfg.chunk_kb if cfg is not None else 0
        col = C.comp_time_v(self.theta_base, self.threadblocks,
                            self.tb_per_slot, self.bytes_per_tb,
                            nc, chunk, V, hw)
        return tuple(col.tolist()) if self.M else ()


class BatchSimulator:
    """Vectorized + cached ProfileTime.  One engine per ``Simulator`` —
    it shares the simulator's hardware profile, noise setting, and RNG."""

    _VECTOR_MIN = 16     # batch size at which lock-step array advance wins

    def __init__(self, sim, cache_size: int = 131072):
        self.sim = sim
        self.cache = ProfileCache(cache_size)      # measurements (noise-free)
        self.columns = ProfileCache(cache_size)    # rate columns (both modes)
        self._kernels: Dict[int, _GroupKernel] = {}
        self._fp_ids: Dict[Tuple, int] = {}        # fingerprint -> intern id
        self._groups: Dict[int, Tuple] = {}        # id(group) -> (group, fpi)
        self._alone: Dict[int, Tuple] = {}         # fpi -> alone comp column

    # -- public API ------------------------------------------------------
    #
    # Cache hits return a SHARED GroupMeasurement object (constructed once
    # at fill time, ``name`` taken from the first structurally-identical
    # group measured).  Measurements are value objects — callers must not
    # mutate them; nothing in the tree reads ``.name`` programmatically.

    def measure_one(self, g: OverlapGroup, cfgs: Sequence[CommConfig]):
        """Single-candidate ProfileTime — the cache-hit fast path (most
        logical profiles of a structurally repeated workload are hits)."""
        from repro.core.simulator import GroupMeasurement

        fpi, kern = self._resolve(g)
        if self.sim.noise:
            p = self._measure_one(kern, fpi, cfgs, True)
            return GroupMeasurement(g.name, p[0], p[1], p[2],
                                    list(p[3]), list(p[4]))
        key = (fpi, tuple(map(_cfg_key, cfgs)))
        gm = self.cache.get(key)
        if gm is None:
            p = self._measure_one(kern, fpi, cfgs, False)
            gm = GroupMeasurement(g.name, p[0], p[1], p[2],
                                  list(p[3]), list(p[4]))
            self.cache.put(key, gm)
        return gm

    def measure_many(self, g: OverlapGroup,
                     cfg_lists: Sequence[Sequence[CommConfig]]) -> List:
        """Measure every candidate config list for one group.  Does NOT
        touch ``profile_count`` — the Simulator wrappers own accounting."""
        from repro.core.simulator import GroupMeasurement  # cycle-free late import

        if len(cfg_lists) == 1:
            return [self.measure_one(g, cfg_lists[0])]
        noisy = bool(self.sim.noise)
        fpi, kern = self._resolve(g)
        name = g.name
        cache = self.cache
        results: List = [None] * len(cfg_lists)
        todo: List[int] = []
        keys: List[Tuple] = [None] * len(cfg_lists)
        for i, cfgs in enumerate(cfg_lists):
            key = (fpi, tuple(map(_cfg_key, cfgs)))
            keys[i] = key
            gm = None if noisy else cache.get(key)
            if gm is None:
                todo.append(i)
            else:
                results[i] = gm
        if todo:
            batch = [cfg_lists[i] for i in todo]
            if len(todo) >= self._VECTOR_MIN:
                payloads = self._measure_lockstep(kern, fpi, batch, noisy)
            else:
                payloads = [self._measure_one(kern, fpi, cfgs, noisy)
                            for cfgs in batch]
            for i, p in zip(todo, payloads):
                gm = GroupMeasurement(name, p[0], p[1], p[2],
                                      list(p[3]), list(p[4]))
                if not noisy:
                    cache.put(keys[i], gm)
                results[i] = gm
        return results

    _GROUP_MEMO_MAX = 4096      # id-memo bound: ephemeral groups must not pin

    # -- group / column resolution ---------------------------------------
    def _resolve(self, g: OverlapGroup) -> Tuple[int, _GroupKernel]:
        ent = self._groups.get(id(g))
        if ent is not None and ent[0] is g:        # strong ref pins the id
            return ent[1], self._kernels[ent[1]]
        fp = group_fingerprint(g)
        fpi = self._fp_ids.setdefault(fp, len(self._fp_ids))
        if len(self._groups) >= self._GROUP_MEMO_MAX:
            self._groups.clear()    # drop pins; fingerprints just recompute
        self._groups[id(g)] = (g, fpi)
        if fpi not in self._kernels:
            self._kernels[fpi] = _GroupKernel(g, self.sim.hw)
        return fpi, self._kernels[fpi]

    def _alone_column(self, fpi: int, kern: _GroupKernel) -> Tuple:
        col = self._alone.get(fpi)
        if col is None:
            col = kern.comp_column(None, 0.0, self.sim.hw)
            self._alone[fpi] = col
        return col

    def _column(self, fpi: int, kern: _GroupKernel, k: int, cfg: CommConfig):
        """(comp durations under cfg, comm-op-k duration active/idle) —
        everything the replay needs about slot k running ``cfg``.  Computed
        with the vectorized contention kernels (bit-identical to the scalar
        model; tests assert ``==``)."""
        key = (fpi, k, _cfg_key(cfg))
        v = self.columns.get(key)
        if v is None:
            hw = self.sim.hw
            op = kern.comms[k]
            ceil_, cmult = C.PROTO_PARAMS[cfg.protocol]
            tmult = C.TRANSPORT_MULT[cfg.transport]
            wb = C.wire_bytes(op, cfg.algorithm)
            ns = C.comm_steps(op, cfg.algorithm)
            V = float(C.comm_bandwidth_draw_v(cfg.nc, cfg.chunk_kb,
                                              ceil_, tmult, hw))
            args = (op.bytes, wb, ns, cfg.nc, cfg.nt, cfg.chunk_kb,
                    ceil_, cmult, tmult)
            v = (kern.comp_column(cfg, V, hw),
                 float(C.comm_time_v(*args, hw, compute_active=True)),
                 float(C.comm_time_v(*args, hw, compute_active=False)))
            self.columns.put(key, v)
        return v

    # -- single-candidate replay over cached rate columns -----------------
    def _measure_one(self, kern: _GroupKernel, fpi: int,
                     cfgs: Sequence[CommConfig], noisy: bool) -> Tuple:
        M, N = kern.M, kern.N
        alone = self._alone_column(fpi, kern)
        cols = [self._column(fpi, kern, k, cfg) for k, cfg in enumerate(cfgs)]
        if noisy:
            rng, s = self.sim._rng, self.sim.noise
            jc = [float(rng.lognormal(0.0, s)) for _ in range(M)]
            jk = [float(rng.lognormal(0.0, s)) for _ in range(N)]
        else:
            jc = [1.0] * M
            jk = [1.0] * N

        ci = ki = 0
        cur_comp = cur_comm = 1.0
        t = comp_busy = comm_busy = 0.0
        comp_meas = [0.0] * M
        comm_meas = [0.0] * N
        d_comp = d_comm = math.inf
        guard = 0
        while ci < M or ki < N:
            guard += 1
            if guard > 100000:
                raise RuntimeError("simulator did not converge")
            comp_on = ci < M
            comm_on = ki < N
            if comp_on:
                base = cols[ki][0][ci] if comm_on else alone[ci]
                d_comp = base * jc[ci]
            if comm_on:
                d_comm = (cols[ki][1] if comp_on else cols[ki][2]) * jk[ki]
            rc = cur_comp * d_comp if comp_on else math.inf
            rk = cur_comm * d_comm if comm_on else math.inf
            dt = rc if rc <= rk else rk
            t += dt
            if comp_on:
                comp_busy += dt
                comp_meas[ci] += dt
                cur_comp -= dt / d_comp
                if cur_comp <= _TINY:
                    ci += 1
                    cur_comp = 1.0
            if comm_on:
                comm_busy += dt
                comm_meas[ki] += dt
                cur_comm -= dt / d_comm
                if cur_comm <= _TINY:
                    ki += 1
                    cur_comm = 1.0
        return (t, comm_busy, comp_busy, tuple(comm_meas), tuple(comp_meas))

    # -- lock-step array advance for large batches ------------------------
    def _tables(self, kern: _GroupKernel,
                cfg_lists: Sequence[Sequence[CommConfig]], fpi: int):
        """Assemble (C, M, N+1) comp and (C, N) comm duration tables from
        the column cache."""
        Cn, M, N = len(cfg_lists), kern.M, kern.N
        alone = self._alone_column(fpi, kern)
        comp_dur = np.empty((Cn, max(M, 1), N + 1))
        comm_act = np.empty((Cn, max(N, 1)))
        comm_idle = np.empty((Cn, max(N, 1)))
        for c, cfgs in enumerate(cfg_lists):
            for k, cfg in enumerate(cfgs):
                col = self._column(fpi, kern, k, cfg)
                if M:
                    comp_dur[c, :, k] = col[0]
                comm_act[c, k] = col[1]
                comm_idle[c, k] = col[2]
            if M:
                comp_dur[c, :, N] = alone
        return comp_dur, comm_act, comm_idle

    def _measure_lockstep(self, kern: _GroupKernel, fpi: int,
                          cfg_lists: Sequence[Sequence[CommConfig]],
                          noisy: bool) -> List[Tuple]:
        Cn, M, N = len(cfg_lists), kern.M, kern.N
        comp_dur, comm_act, comm_idle = self._tables(kern, cfg_lists, fpi)
        if noisy:
            rng, s = self.sim._rng, self.sim.noise
            jc = np.empty((Cn, max(M, 1)))
            jk = np.empty((Cn, max(N, 1)))
            for c in range(Cn):     # candidate-by-candidate: run_group's order
                jc[c, :M] = [float(rng.lognormal(0.0, s)) for _ in range(M)]
                jk[c, :N] = [float(rng.lognormal(0.0, s)) for _ in range(N)]
            comp_dur = comp_dur * jc[:, :, None]
            comm_act = comm_act * jk
            comm_idle = comm_idle * jk

        ar = np.arange(Cn)
        ci = np.zeros(Cn, dtype=np.int64)
        ki = np.zeros(Cn, dtype=np.int64)
        cur_comp = np.ones(Cn)
        cur_comm = np.ones(Cn)
        t = np.zeros(Cn)
        comp_busy = np.zeros(Cn)
        comm_busy = np.zeros(Cn)
        comp_meas = np.zeros((Cn, max(M, 1)))
        comm_meas = np.zeros((Cn, max(N, 1)))

        guard = 0
        while True:
            comp_on = ci < M
            comm_on = ki < N
            alive = comp_on | comm_on
            if not alive.any():
                break
            guard += 1
            if guard > 4 * (M + N) + 16:
                raise RuntimeError("batched simulator did not converge")

            ci_i = np.minimum(ci, max(M - 1, 0))
            ki_i = np.minimum(ki, max(N - 1, 0))
            d_comp = comp_dur[ar, ci_i, np.where(comm_on, ki_i, N)] if M \
                else np.ones(Cn)
            d_comm = np.where(comp_on, comm_act[ar, ki_i],
                              comm_idle[ar, ki_i]) if N \
                else np.ones(Cn)
            rem_comp = np.where(comp_on, cur_comp * d_comp, np.inf)
            rem_comm = np.where(comm_on, cur_comm * d_comm, np.inf)
            dt = np.where(alive, np.minimum(rem_comp, rem_comm), 0.0)
            t += dt

            if M:
                dtc = np.where(comp_on, dt, 0.0)
                comp_busy += dtc
                comp_meas[ar, ci_i] += dtc
                cur_comp = np.where(comp_on,
                                    cur_comp - dt / np.where(comp_on, d_comp,
                                                             1.0),
                                    cur_comp)
                fin = comp_on & (cur_comp <= _TINY)
                ci = ci + fin
                cur_comp = np.where(fin, 1.0, cur_comp)
            if N:
                dtk = np.where(comm_on, dt, 0.0)
                comm_busy += dtk
                comm_meas[ar, ki_i] += dtk
                cur_comm = np.where(comm_on,
                                    cur_comm - dt / np.where(comm_on, d_comm,
                                                             1.0),
                                    cur_comm)
                fin = comm_on & (cur_comm <= _TINY)
                ki = ki + fin
                cur_comm = np.where(fin, 1.0, cur_comm)

        return [(float(t[c]), float(comm_busy[c]), float(comp_busy[c]),
                 tuple(float(x) for x in comm_meas[c, :N]),
                 tuple(float(x) for x in comp_meas[c, :M]))
                for c in range(Cn)]
