"""Batched profiling engine — vectorized ProfileTime for the tuner hot path.

DESIGN
======
``Simulator.run_group`` is an event-driven loop: two serialized streams
(computation / communication) advance in continuous time, and between any
two head-completion events both heads progress *linearly* at rates fixed by
the pair ``(ci, ki)`` of current stream heads.  That piecewise-linear shape
admits a closed-form segment computation built from two small rate tables:

  * ``comp_dur[i, k]`` — duration of comp op i under comm config k, for
    k in ``0..N`` (column N = no active comm, i.e. ``comp_time_alone``);
  * ``comm_dur[k, active?]`` — duration of comm op k with/without an active
    computation stealing bandwidth.

The tables come from the vectorized ``contention.comp_time_v`` /
``comm_time_v`` kernels, which keep the scalar functions' exact float64
operation order — engine measurements equal the sequential event loop
BIT-FOR-BIT (tests/test_profiling.py asserts ``==``, never approx).

Two advance strategies share the tables:

  1. **Column-cached replay** (batches below ``_VECTOR_MIN``): each table
     column depends only on ``(group structure, comm slot, that slot's
     config)``, so columns are LRU-cached and a candidate's table is
     assembled by lookup; the remaining per-candidate replay is a handful
     of float ops per event.  This is what the tuner's 3–5-candidate
     batches hit, and it is valid in BOTH noise modes because jitter
     multiplies the cached rates after assembly.
  2. **Lock-step array advance** (batches of ``_VECTOR_MIN`` or more): all
     candidates' streams advance together with NumPy array ops — per
     iteration, gather every candidate's current-head durations, take the
     per-candidate ``min`` segment, retire heads.  The Python-level loop
     runs at most ~M+N times regardless of batch size, so interpreter cost
     amortizes across the candidate set.  The advance is HETEROGENEOUS:
     candidates may come from *different* overlap groups (the cross-group
     scheduler's round-robin batches) — each candidate carries its own
     (M, N) and its tables are padded to the batch maxima; padding entries
     are never selected by the masked gathers.  Table assembly is
     GATHER-BASED: every cached column also lives in append-only id-indexed
     stores (flat comm-duration arrays; one stacked comp matrix per group
     structure), so a batch's padded tables are built with a handful of
     fancy-index reads per distinct structure instead of per-candidate
     row copies — per-candidate assembly was a large share of the fixed
     cost that used to push the lock-step break-even near ~100 candidates
     (see ``_VECTOR_MIN``).  The stores are append-only while batches are
     in flight — gather ids must stay stable — and a key->id map that
     survives LRU eviction lets a column recomputed after eviction reuse
     its original rows (column values are deterministic functions of the
     key).  When eviction churn grows the stores past twice the cache
     bound they are compacted from the live cache at the next engine-call
     boundary (``_maybe_compact_stores``), so ``cache_size`` keeps its
     memory-cap contract.

``measure_many_grouped`` is the scheduler's entry point: a list of
``(group, cfg_lists)`` requests evaluated in one pass, sharing the
rate-column cache across requests and deduplicating identical
``(fingerprint, configs)`` candidates *within* the call — the engine
computes each unique point once and fans the shared measurement out.
(The scheduler's deterministic trajectory sharing already collapses
identical groups *before* submission, so in-tree the dedup mainly guards
duplicate candidate lists inside one ``profile_many`` batch and direct
``run_interleaved`` users that skip sharing.)

Noise-mode semantics: every noisy candidate is one *submission* holding a
counter-based ticket from the simulator's ``core.noise`` model (tickets
issued in flat submission order: requests in order, candidates within a
request in list order).  Jitter multipliers — one lognormal per comp then
per comm — are a pure function of the ticket, so the engine draws a whole
batch in one vectorized Philox read while the ``batched=False`` reference
path re-derives bit-identical values per ``run_group`` call.  In CRN mode
tickets are keyed per structural fingerprint and indexed per group
trajectory (``core.noise`` docstring), which the cross-group scheduler
exploits for trajectory sharing; the engine itself only forwards group
identity to the ticket issue.  Noisy mode never deduplicates: every
submitted candidate is its own submission.

Cache-key semantics: the measurement-level LRU ``ProfileCache`` keys on a
*structural* fingerprint of the group (op shapes/bytes; names excluded —
a transformer stack of structurally identical layers shares one entry per
config) plus the tuple of configs with the ``done`` flag normalized away
(it never enters the math).  Hits return a shared measurement object whose
``name`` is the first structurally-identical group measured — measurements
are immutable value objects and nothing reads ``.name`` programmatically,
so structural sharing stays observable only as speed.  **Noisy mode
bypasses the measurement cache entirely** (both lookup and fill): jittered
measurements are draws, not values, and replaying one would both break
RNG-stream reproducibility and let a tuner overfit a lucky sample.  The
rate-column cache is deterministic pre-jitter math and is shared by both
modes.  ``Simulator.profile_count`` counts *logical* ProfileTime
invocations — cache hits increment it — so Fig. 8c tuning-efficiency
accounting is unchanged by the engine.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import contention as C
from repro.core.comm_params import CommConfig
from repro.core.hardware import Hardware
from repro.core.workload import OverlapGroup

_TINY = 1e-12                       # head-completion epsilon (matches run_group)


def group_fingerprint(g: OverlapGroup) -> Tuple:
    """Structural identity of a group for caching: everything the contention
    model reads, nothing it doesn't (names excluded).  A comm's fabric tier
    joins the key only when set — it selects the pricing hardware under a
    hierarchical topology — so pre-topology fingerprints stay stable."""
    return (
        tuple((c.flops, c.bytes_rw, c.threadblocks, c.tb_per_slot,
               c.bytes_per_tb) for c in g.comps),
        tuple((c.kind, c.bytes, c.group_size) + ((c.tier,) if c.tier else ())
              for c in g.comms),
    )


def _cfg_key(cfg: CommConfig) -> Tuple:
    # ``done`` is a tuner bookkeeping flag with no effect on measurements.
    return (cfg.algorithm, cfg.protocol, cfg.transport,
            cfg.nc, cfg.nt, cfg.chunk_kb)


class ProfileCache:
    """Generic LRU keyed on hashable tuples (measurements / rate columns)."""

    def __init__(self, maxsize: int = 131072):
        self.maxsize = maxsize
        self._d: "OrderedDict[Tuple, Tuple]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._d)

    def get(self, key):
        v = self._d.get(key)
        if v is None:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return v

    def put(self, key, value) -> None:
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.maxsize:
            self._d.popitem(last=False)
            self.evictions += 1

    def clear(self) -> None:
        self._d.clear()

    def stats(self) -> Dict[str, int]:
        return dict(size=len(self._d), hits=self.hits, misses=self.misses,
                    evictions=self.evictions)


class _GrowStore:
    """Amortized-O(1) append + O(1) read view: a capacity-doubling ndarray
    (1-D for scalars, 2-D for fixed-width rows).  Backs the gather stores
    so registering a column never triggers a full-store rebuild — the
    reallocation cost is amortized across appends, and ``view()`` is a
    slice of the live buffer (taken fresh per batch; a view captured
    before a reallocating append still reads correct values for every id
    that existed when it was taken)."""

    def __init__(self, width: Optional[int] = None):
        self.n = 0
        shape = (16,) if width is None else (16, width)
        self._buf = np.empty(shape)

    def append(self, row) -> int:
        if self.n == len(self._buf):
            grown = np.empty((2 * len(self._buf),) + self._buf.shape[1:])
            grown[:self.n] = self._buf
            self._buf = grown
        self._buf[self.n] = row
        self.n += 1
        return self.n - 1

    def view(self) -> np.ndarray:
        return self._buf[:self.n]


class _GroupKernel:
    """Per-(group structure, hardware) static arrays for the batched math."""

    def __init__(self, g: OverlapGroup, hw: Hardware):
        self.M = len(g.comps)
        self.N = len(g.comms)
        self.comms = list(g.comms)
        lam = hw.num_slots
        # theta_base keeps the scalar expression order of contention.comp_time
        self.threadblocks = np.array([c.threadblocks for c in g.comps],
                                     dtype=np.int64)
        self.tb_per_slot = np.array([c.tb_per_slot for c in g.comps],
                                    dtype=np.int64)
        self.bytes_per_tb = np.array([c.bytes_per_tb for c in g.comps],
                                     dtype=np.float64)
        self.theta_base = np.array(
            [c.flops / c.threadblocks * c.tb_per_slot * lam / hw.achieved_flops
             for c in g.comps], dtype=np.float64)

    def comp_column(self, cfg, V, hw: Hardware) -> Tuple[float, ...]:
        """Durations of every comp op under one comm config (nc=chunk=V=0
        reproduces ``comp_time_alone`` exactly)."""
        nc = cfg.nc if cfg is not None else 0
        chunk = cfg.chunk_kb if cfg is not None else 0
        col = C.comp_time_v(self.theta_base, self.threadblocks,
                            self.tb_per_slot, self.bytes_per_tb,
                            nc, chunk, V, hw)
        return tuple(col.tolist()) if self.M else ()


class BatchSimulator:
    """Vectorized + cached ProfileTime.  One engine per ``Simulator`` —
    it shares the simulator's hardware profile, noise setting, and RNG."""

    # Batch size at which the lock-step array advance beats the scalar
    # column-cached replay.  The replay is a handful of float ops per event,
    # so NumPy's per-op dispatch only amortizes across a batch.  Gather-based
    # table assembly (id stores, no per-candidate row copies) plus the
    # saturating-head advance roughly halved the lock-step fixed cost, moving
    # the measured CPU break-even from ~96 candidates (PR 2) to the ~48-64
    # range across group shapes and load conditions; below it the flat
    # replay loop still wins on per-op overhead.
    _VECTOR_MIN = 48

    def __init__(self, sim, cache_size: int = 131072):
        self.sim = sim
        self.cache = ProfileCache(cache_size)      # measurements (noise-free)
        self.columns = ProfileCache(cache_size)    # rate columns (both modes)
        self._kernels: Dict[int, _GroupKernel] = {}
        self._fp_ids: Dict[Tuple, int] = {}        # fingerprint -> intern id
        self._groups: Dict[int, Tuple] = {}        # id(group) -> (group, fpi)
        self._alone: Dict[int, Tuple] = {}         # fpi -> alone comp column
        self.dedup_shared = 0   # within-call duplicate candidates fanned out
        # append-only gather stores backing the lock-step table assembly
        # (module docstring): kid indexes the flat comm-duration arrays,
        # rid the per-structure comp matrix.  kid 0 is a padding sentinel
        # (1.0 durations, never selected by the masked gathers).
        self._act = _GrowStore()
        self._idle = _GrowStore()
        self._act.append(1.0)
        self._idle.append(1.0)
        self._comp: Dict[int, _GrowStore] = {}          # fpi -> comp rows
        self._col_ids: Dict[Tuple, Tuple[int, int]] = {}    # permanent id map

    # -- public API ------------------------------------------------------
    #
    # Cache hits return a SHARED GroupMeasurement object (constructed once
    # at fill time, ``name`` taken from the first structurally-identical
    # group measured).  Measurements are value objects — callers must not
    # mutate them; nothing in the tree reads ``.name`` programmatically.

    def measure_one(self, g: OverlapGroup, cfgs: Sequence[CommConfig]):
        """Single-candidate ProfileTime — the cache-hit fast path (most
        logical profiles of a structurally repeated workload are hits)."""
        from repro.core.simulator import GroupMeasurement

        self._maybe_compact_stores()
        fpi, kern = self._resolve(g)
        if self.sim.noise:
            jit = self.sim._noise.draw(g, 1, kern.M + kern.N)[0]
            p = self._measure_one(kern, fpi, cfgs, True, jit=jit)
            return GroupMeasurement(g.name, p[0], p[1], p[2],
                                    list(p[3]), list(p[4]))
        key = (fpi, tuple(map(_cfg_key, cfgs)))
        gm = self.cache.get(key)
        if gm is None:
            p = self._measure_one(kern, fpi, cfgs, False)
            gm = GroupMeasurement(g.name, p[0], p[1], p[2],
                                  list(p[3]), list(p[4]))
            self.cache.put(key, gm)
        return gm

    def measure_many(self, g: OverlapGroup,
                     cfg_lists: Sequence[Sequence[CommConfig]]) -> List:
        """Measure every candidate config list for one group.  Does NOT
        touch ``profile_count`` — the Simulator wrappers own accounting."""
        if not cfg_lists:
            return []
        if len(cfg_lists) == 1:
            return [self.measure_one(g, cfg_lists[0])]
        return self.measure_many_grouped([(g, cfg_lists)])[0]

    def measure_many_grouped(
            self, requests: Sequence[Tuple[OverlapGroup,
                                           Sequence[Sequence[CommConfig]]]]
    ) -> List[List]:
        """Heterogeneous batched ProfileTime: each request is ``(group,
        cfg_lists)`` and the returned list of measurement lists aligns with
        the requests.  All requests' misses advance in ONE lock-step pass,
        sharing the per-group rate-column cache; identical noise-free
        candidates are computed once per call (within-call dedup).  Jitter
        draw order is the flat submission order (module docstring)."""
        from repro.core.simulator import GroupMeasurement  # cycle-free late import

        self._maybe_compact_stores()
        noisy = bool(self.sim.noise)
        cache = self.cache
        results: List[List] = [[None] * len(cfg_lists)
                               for _, cfg_lists in requests]
        todo: List[Tuple] = []      # (kern, fpi, cfgs) in submission order
        keys: List = []             # cache key per todo entry (None if noisy)
        sinks: List[List] = []      # (request, slot) fan-outs per todo entry
        names: List[str] = []       # group name of the first submitter
        specs: List[Tuple] = []     # noise ticket runs (key, first, n, M+N)
        spans: List[Tuple] = []     # per run: (todo start, n, M, N)
        first: Dict[Tuple, int] = {}
        for ri, (g, cfg_lists) in enumerate(requests):
            if not cfg_lists:
                continue
            fpi, kern = self._resolve(g)
            if noisy:                       # every candidate is a submission
                key, start = self.sim._noise.reserve(g, len(cfg_lists))
                specs.append((key, start, len(cfg_lists), kern.M + kern.N))
                spans.append((len(todo), len(cfg_lists), kern.M, kern.N))
                for li, cfgs in enumerate(cfg_lists):
                    todo.append((kern, fpi, cfgs))
                    keys.append(None)
                    sinks.append([(ri, li)])
                    names.append(g.name)
                continue
            for li, cfgs in enumerate(cfg_lists):
                key = (fpi, tuple(map(_cfg_key, cfgs)))
                gm = cache.get(key)
                if gm is not None:
                    results[ri][li] = gm
                    continue
                ti = first.get(key)
                if ti is not None:          # duplicate within this call
                    sinks[ti].append((ri, li))
                    self.dedup_shared += 1
                    continue
                first[key] = len(todo)
                todo.append((kern, fpi, cfgs))
                keys.append(key)
                sinks.append([(ri, li)])
                names.append(g.name)
        if todo:
            # all runs' jitters in one pass — contiguous tickets (the whole
            # batch, in default mode) come from a single vectorized draw
            jit_mats = self.sim._noise.draw_reserved(specs) if noisy else None
            cols_list = self._gather_columns(todo)
            if len(todo) >= self._VECTOR_MIN:
                payloads = self._measure_lockstep(
                    todo, noisy, cols_list,
                    noise_blocks=(spans, jit_mats) if noisy else None)
            else:
                jrows: List = [None] * len(todo)
                if noisy:
                    for (t0, cnt, _, _), mat in zip(spans, jit_mats):
                        for i in range(cnt):
                            jrows[t0 + i] = mat[i]
                payloads = [self._measure_one(kern, fpi, cfgs, noisy, cols,
                                              jit=jrow)
                            for (kern, fpi, cfgs), cols, jrow
                            in zip(todo, cols_list, jrows)]
            for p, key, outs, name in zip(payloads, keys, sinks, names):
                gm = GroupMeasurement(name, p[0], p[1], p[2],
                                      list(p[3]), list(p[4]))
                if key is not None:
                    cache.put(key, gm)
                for ri, li in outs:
                    results[ri][li] = gm
        return results

    def cache_stats(self) -> Dict:
        """Hit/miss/eviction counters for both LRUs plus the within-call
        dedup fan-out count (benchmark telemetry)."""
        return {"measurements": self.cache.stats(),
                "columns": self.columns.stats(),
                "dedup_shared": self.dedup_shared}

    _GROUP_MEMO_MAX = 4096      # id-memo bound: ephemeral groups must not pin

    # -- group / column resolution ---------------------------------------
    def _resolve(self, g: OverlapGroup) -> Tuple[int, _GroupKernel]:
        ent = self._groups.get(id(g))
        if ent is not None and ent[0] is g:        # strong ref pins the id
            return ent[1], self._kernels[ent[1]]
        fp = group_fingerprint(g)
        fpi = self._fp_ids.setdefault(fp, len(self._fp_ids))
        if len(self._groups) >= self._GROUP_MEMO_MAX:
            self._groups.clear()    # drop pins; fingerprints just recompute
        self._groups[id(g)] = (g, fpi)
        if fpi not in self._kernels:
            self._kernels[fpi] = _GroupKernel(g, self.sim.hw)
        return fpi, self._kernels[fpi]

    def _alone_column(self, fpi: int, kern: _GroupKernel) -> Tuple:
        col = self._alone.get(fpi)
        if col is None:
            col = (kern.comp_column(None, 0.0, self.sim.hw),)
            col = col + (np.array(col[0], dtype=np.float64),)
            self._alone[fpi] = col
        return col

    def _register_column(self, key: Tuple, fpi: int, act: float, idle: float,
                         col_arr: np.ndarray) -> Tuple[int, int]:
        """Append a freshly computed column to the gather stores; returns
        its ``(kid, rid)`` ids.  Stores are append-only within an engine
        call so ids stay valid for every in-flight batch (module
        docstring).  The id map outlives LRU eviction of the cache entry,
        so a column recomputed after eviction reuses its original rows
        (column values are deterministic functions of the key); the
        eviction-churn growth this implies is bounded by
        ``_maybe_compact_stores`` at call boundaries."""
        ids = self._col_ids.get(key)
        if ids is not None:
            return ids
        kid = self._act.append(act)
        self._idle.append(idle)
        store = self._comp.get(fpi)
        if store is None:
            store = self._comp[fpi] = _GrowStore(width=col_arr.shape[0])
        rid = store.append(col_arr)
        self._col_ids[key] = (kid, rid)
        return kid, rid

    def _maybe_compact_stores(self) -> None:
        """Rebuild the gather stores from the LIVE column cache once
        eviction churn has grown them past twice the cache bound, so
        ``cache_size`` keeps its memory-cap contract.  Ids are remapped,
        which is only safe BETWEEN engine calls (per-batch ``cols_list``
        snapshots hold ids) — the public measure paths call this before
        resolving any column."""
        if self._act.n <= 2 * self.columns.maxsize:
            return
        self._act = _GrowStore()
        self._idle = _GrowStore()
        self._act.append(1.0)
        self._idle.append(1.0)
        self._comp = {}
        self._col_ids = {}
        live = self.columns._d
        for key in list(live):
            col, act, idle, col_arr = live[key][:4]
            kid, rid = self._register_column(key, key[0], act, idle, col_arr)
            live[key] = (col, act, idle, col_arr, kid, rid)

    def _comm_arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        return self._act.view(), self._idle.view()

    def _comp_matrix(self, fpi: int) -> np.ndarray:
        return self._comp[fpi].view()

    def _column(self, fpi: int, kern: _GroupKernel, k: int, cfg: CommConfig):
        """(comp durations under cfg, comm-op-k duration active/idle, comp
        durations as ndarray, comm store id, comp store row id) —
        everything the replay needs about slot k running ``cfg``.  Computed
        with the vectorized contention kernels (bit-identical to the scalar
        model; tests assert ``==``).  The tuple form feeds the scalar
        replay (tuple indexing is cheap in Python); the ndarray twin and
        the ids feed gather-based lock-step table assembly."""
        key = (fpi, k, _cfg_key(cfg))
        v = self.columns.get(key)
        if v is None:
            hw = self.sim.hw
            op = kern.comms[k]
            ceil_, cmult = C.PROTO_PARAMS[cfg.protocol]
            tmult = C.TRANSPORT_MULT[cfg.transport]
            wb = C.wire_bytes(op, cfg.algorithm)
            ns = C.comm_steps(op, cfg.algorithm)
            V = float(C.comm_bandwidth_draw_v(cfg.nc, cfg.chunk_kb,
                                              ceil_, tmult, hw))
            args = (op.bytes, wb, ns, cfg.nc, cfg.nt, cfg.chunk_kb,
                    ceil_, cmult, tmult)
            col = kern.comp_column(cfg, V, hw)
            act = float(C.comm_time_v(*args, hw, compute_active=True))
            idle = float(C.comm_time_v(*args, hw, compute_active=False))
            col_arr = np.array(col, dtype=np.float64)
            kid, rid = self._register_column(key, fpi, act, idle,
                                              col_arr)
            v = (col, act, idle, col_arr, kid, rid)
            self.columns.put(key, v)
        return v

    def _gather_columns(self, todo: Sequence[Tuple]) -> List[List]:
        """Resolve every candidate's rate columns for a batch, computing all
        misses in one vectorized pass (``_compute_columns``).  Keys are
        built ONCE per (candidate, slot) — the returned per-candidate column
        lists feed both replay strategies, so no second cache walk
        happens."""
        out: List[List] = []
        need: Dict[Tuple, Tuple] = {}   # key -> (kern, k, cfg), deduped
        holes: List[Tuple] = []         # (cols, k, key) to patch post-compute
        get = self.columns.get
        for kern, fpi, cfgs in todo:
            cols: List = [None] * len(cfgs)
            for k, cfg in enumerate(cfgs):
                key = (fpi, k, _cfg_key(cfg))
                v = get(key)
                if v is None:
                    need.setdefault(key, (kern, k, cfg))
                    holes.append((cols, k, key))
                else:
                    cols[k] = v
            out.append(cols)
        if need:
            computed = self._compute_columns(need)
            for cols, k, key in holes:
                cols[k] = computed[key]
        return out

    def _compute_columns(self, need: Dict[Tuple, Tuple]) -> Dict[Tuple, Tuple]:
        """Batch-compute missing rate columns: ONE vectorized
        ``comm_time_v`` pass for all comm columns across all groups/slots,
        and one broadcast ``comp_time_v`` per distinct group structure —
        instead of per-column kernel calls from inside the replay.
        Elementwise float64 ops are identical whether batched or scalar, so
        the cached values are bit-equal to what ``_column`` would have
        computed lazily."""
        hw = self.sim.hw
        need_keys = list(need.keys())
        need_vals = list(need.values())
        need_fpi = [key[0] for key in need_keys]
        K = len(need_keys)
        cols = np.empty((9, K))
        for i, (kern, k, cfg) in enumerate(need_vals):
            op = kern.comms[k]
            pc, pm = C.PROTO_PARAMS[cfg.protocol]
            cols[:, i] = (op.bytes, C.wire_bytes(op, cfg.algorithm),
                          C.comm_steps(op, cfg.algorithm), cfg.nc, cfg.nt,
                          cfg.chunk_kb, pc, pm,
                          C.TRANSPORT_MULT[cfg.transport])
        ob, wb, ns, nc, nt, ck, ceil_, cmult, tmult = cols
        act = C.comm_time_v(ob, wb, ns, nc, nt, ck, ceil_, cmult, tmult,
                            hw, compute_active=True).tolist()
        idle = C.comm_time_v(ob, wb, ns, nc, nt, ck, ceil_, cmult, tmult,
                             hw, compute_active=False).tolist()
        V = C.comm_bandwidth_draw_v(nc, ck, ceil_, tmult, hw)
        by_fpi: Dict[int, List[int]] = {}
        for i, fpi in enumerate(need_fpi):
            by_fpi.setdefault(fpi, []).append(i)
        comp: List = [None] * K
        for fpi, idx in by_fpi.items():
            kern = self._kernels[fpi]
            if kern.M:
                ii = np.array(idx)
                mat = C.comp_time_v(kern.theta_base, kern.threadblocks,
                                    kern.tb_per_slot, kern.bytes_per_tb,
                                    nc[ii][:, None], ck[ii][:, None],
                                    V[ii][:, None], hw)
                for r, i in enumerate(idx):
                    comp[i] = np.ascontiguousarray(mat[r])
            else:
                empty = np.empty(0)
                for i in idx:
                    comp[i] = empty
        out: Dict[Tuple, Tuple] = {}
        for i, key in enumerate(need_keys):
            kid, rid = self._register_column(key, need_fpi[i], act[i],
                                              idle[i], comp[i])
            v = (tuple(comp[i].tolist()), act[i], idle[i], comp[i], kid, rid)
            self.columns.put(key, v)
            out[key] = v
        return out

    # -- single-candidate replay over cached rate columns -----------------
    def _measure_one(self, kern: _GroupKernel, fpi: int,
                     cfgs: Sequence[CommConfig], noisy: bool,
                     cols: Optional[List] = None,
                     jit: Optional[np.ndarray] = None) -> Tuple:
        M, N = kern.M, kern.N
        alone = self._alone_column(fpi, kern)[0]
        if cols is None:
            cols = [self._column(fpi, kern, k, cfg)
                    for k, cfg in enumerate(cfgs)]
        if noisy:
            # ``jit`` is this submission's ticket draw (M comp then N comm)
            row = jit.tolist()
            jc = row[:M]
            jk = row[M:]
        else:
            jc = [1.0] * M
            jk = [1.0] * N

        ci = ki = 0
        cur_comp = cur_comm = 1.0
        t = comp_busy = comm_busy = 0.0
        comp_meas = [0.0] * M
        comm_meas = [0.0] * N
        d_comp = d_comm = math.inf
        guard = 0
        while ci < M or ki < N:
            guard += 1
            if guard > 100000:
                raise RuntimeError("simulator did not converge")
            comp_on = ci < M
            comm_on = ki < N
            if comp_on:
                base = cols[ki][0][ci] if comm_on else alone[ci]
                d_comp = base * jc[ci]
            if comm_on:
                d_comm = (cols[ki][1] if comp_on else cols[ki][2]) * jk[ki]
            rc = cur_comp * d_comp if comp_on else math.inf
            rk = cur_comm * d_comm if comm_on else math.inf
            dt = rc if rc <= rk else rk
            t += dt
            if comp_on:
                comp_busy += dt
                comp_meas[ci] += dt
                cur_comp -= dt / d_comp
                if cur_comp <= _TINY:
                    ci += 1
                    cur_comp = 1.0
            if comm_on:
                comm_busy += dt
                comm_meas[ki] += dt
                cur_comm -= dt / d_comm
                if cur_comm <= _TINY:
                    ki += 1
                    cur_comm = 1.0
        return (t, comm_busy, comp_busy, tuple(comm_meas), tuple(comp_meas))

    # -- lock-step array advance for large batches ------------------------
    def _measure_lockstep(self, entries: Sequence[Tuple], noisy: bool,
                          cols_list: Optional[List[List]] = None,
                          noise_blocks: Optional[Tuple] = None) -> List[Tuple]:
        """Advance a heterogeneous candidate batch in lock step.  Each entry
        is ``(kern, fpi, cfgs)`` — candidates may belong to different groups.
        Per-candidate tables are padded to the batch-wide (max M, max N);
        padding cells hold 1.0 and are never selected: the gathers clip
        indices to each candidate's own (M, N) and the ``where`` masks zero
        any contribution from finished streams.  Tables are assembled by
        gathering from the append-only id stores — a few fancy-index reads
        per distinct group structure, no per-candidate row copies.  In
        noisy mode ``noise_blocks`` carries the batch's pre-drawn ticket
        jitters as ``(spans, matrices)`` with one ``(count, M + N)`` matrix
        per contiguous same-group run."""
        Cn = len(entries)
        if cols_list is None:
            cols_list = self._gather_columns(entries)
        Ms = np.array([e[0].M for e in entries], dtype=np.int64)
        Ns = np.array([e[0].N for e in entries], dtype=np.int64)
        maxM, maxN = int(Ms.max()), int(Ns.max())
        # Tables carry one SATURATION row/column past the batch maxima so
        # head indices never need clipping: a head that retires its last op
        # stops at its own (M, N) — a valid index whose cells hold 1.0 (the
        # kid-0 sentinel / the np.ones fill) and whose contributions are
        # zeroed by the masks, while comm column N doubles as the alone
        # column.  This removes per-iteration clip/where traffic and the
        # M==0 / N==0 special cases from the advance loop.
        pad = [0] * (maxN + 1)          # kid 0 = 1.0 sentinel
        kid = np.array([[col[4] for col in cols] + pad[len(cols):]
                        for cols in cols_list], dtype=np.intp)
        act_arr, idle_arr = self._comm_arrays()
        comm_act = act_arr[kid]
        comm_idle = idle_arr[kid]
        comp_dur = np.ones((Cn, maxM + 1, maxN + 1))
        by_fpi: Dict[int, List[int]] = {}
        for c, (kern, fpi, cfgs) in enumerate(entries):
            if kern.M:
                by_fpi.setdefault(fpi, []).append(c)
        for fpi, idx in by_fpi.items():
            kern = self._kernels[fpi]
            M, N = kern.M, kern.N
            ii = np.array(idx, dtype=np.intp)
            if N:
                rid = np.array([[col[5] for col in cols_list[c]]
                                for c in idx], dtype=np.intp)
                # (n, N, M) gather -> (n, M, N) table block
                comp_dur[ii, :M, :N] = \
                    self._comp_matrix(fpi)[rid].transpose(0, 2, 1)
            # column N = this structure's alone rates
            comp_dur[ii, :M, N] = self._alone_column(fpi, kern)[1]
        if noisy:
            spans, mats = noise_blocks
            jc = np.ones((Cn, maxM + 1))
            jk = np.ones((Cn, maxN + 1))
            for (t0, cnt, M, N), mat in zip(spans, mats):
                if M:
                    jc[t0:t0 + cnt, :M] = mat[:, :M]
                if N:
                    jk[t0:t0 + cnt, :N] = mat[:, M:]
            comp_dur = comp_dur * jc[:, :, None]
            comm_act = comm_act * jk
            comm_idle = comm_idle * jk

        ar = np.arange(Cn)
        ci = np.zeros(Cn, dtype=np.int64)
        ki = np.zeros(Cn, dtype=np.int64)
        cur_comp = np.ones(Cn)
        cur_comm = np.ones(Cn)
        t = np.zeros(Cn)
        comp_busy = np.zeros(Cn)
        comm_busy = np.zeros(Cn)
        comp_meas = np.zeros((Cn, maxM + 1))
        comm_meas = np.zeros((Cn, maxN + 1))

        guard = 0
        while True:
            comp_on = ci < Ms
            comm_on = ki < Ns
            alive = comp_on | comm_on
            if not alive.any():
                break
            guard += 1
            if guard > 4 * (maxM + maxN) + 16:
                raise RuntimeError("batched simulator did not converge")

            # ki == N selects the alone column / a 1.0 pad cell; retired
            # heads gather 1.0 durations so the masked updates divide by 1
            d_comp = comp_dur[ar, ci, ki]
            d_comm = np.where(comp_on, comm_act[ar, ki], comm_idle[ar, ki])
            rem_comp = np.where(comp_on, cur_comp * d_comp, np.inf)
            rem_comm = np.where(comm_on, cur_comm * d_comm, np.inf)
            dt = np.where(alive, np.minimum(rem_comp, rem_comm), 0.0)
            t += dt

            dtc = np.where(comp_on, dt, 0.0)
            comp_busy += dtc
            comp_meas[ar, ci] += dtc
            cur_comp = cur_comp - dtc / d_comp
            fin = comp_on & (cur_comp <= _TINY)
            ci = ci + fin
            cur_comp = np.where(fin, 1.0, cur_comp)

            dtk = np.where(comm_on, dt, 0.0)
            comm_busy += dtk
            comm_meas[ar, ki] += dtk
            cur_comm = cur_comm - dtk / d_comm
            fin = comm_on & (cur_comm <= _TINY)
            ki = ki + fin
            cur_comm = np.where(fin, 1.0, cur_comm)

        tl, xb, yb = t.tolist(), comm_busy.tolist(), comp_busy.tolist()
        km, cm = comm_meas.tolist(), comp_meas.tolist()
        return [(tl[c], xb[c], yb[c], tuple(km[c][:e[0].N]),
                 tuple(cm[c][:e[0].M]))
                for c, e in enumerate(entries)]
