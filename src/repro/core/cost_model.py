"""Unified overlap cost model (Eqs. 1–3) — the analytic counterpart of the
event-driven simulator, used for napkin math, search-space accounting, and
the benchmarks' sanity checks.

    Z = max(Y, X) = max(Σ_i y_i, Σ_j x_j^{s_j})          (Eq. 1)
    comm-bound:  min Z = Σ_j min_{s_j} x_j^{s_j}         (Eq. 2)
    comp-bound:  min Z = Σ_i y_i                         (Eq. 3)
"""
from __future__ import annotations

from typing import List

from repro.core import contention as C
from repro.core.comm_params import CommConfig
from repro.core.hardware import Hardware
from repro.core.workload import ConfigSet, OverlapGroup, Workload


def group_makespan(g: OverlapGroup, cfgs: List[CommConfig], hw: Hardware) -> float:
    """Closed-form Z = max(X, Y) with Y priced under the *sequence* of comm
    configs (each comm assumed to cover a Y-proportional window)."""
    if not g.comms:
        return sum(C.comp_time_alone(c, hw) for c in g.comps)
    X = sum(C.comm_time(op, s, hw, compute_active=bool(g.comps))
            for op, s in zip(g.comms, cfgs))
    # Eq. 4: computation is sliced across the j communications; weight each
    # config by its share of the communication stream.
    xs = [C.comm_time(op, s, hw, compute_active=bool(g.comps))
          for op, s in zip(g.comms, cfgs)]
    tot_x = sum(xs) or 1.0
    Y = 0.0
    for comp in g.comps:
        y = sum((xj / tot_x) * C.comp_time(comp, s, hw)
                for xj, s in zip(xs, cfgs))
        Y += y
    return max(X, Y)


def workload_makespan(wl: Workload, configs: ConfigSet, hw: Hardware) -> float:
    z = 0.0
    for gi, g in enumerate(wl.groups):
        cfgs = [configs[(gi, ci)] for ci in range(len(g.comms))]
        z += group_makespan(g, cfgs, hw)
    return z


def bottleneck(g: OverlapGroup, cfgs: List[CommConfig], hw: Hardware) -> str:
    if not g.comms:
        return "compute"
    X = sum(C.comm_time(op, s, hw) for op, s in zip(g.comms, cfgs))
    Y = sum(C.comp_time(c, cfgs[0], hw) for c in g.comps)
    return "compute" if Y >= X else "communication"
