"""Priority metric H (Eq. 7).

    H_j = (Y' − Y) / (x_j^{s_j} − x_j^{s_j'})

computation cost added per unit of communication improvement when growing
communication j's resources.  Smaller H = more profitable to tune next.
A non-positive denominator (communication got slower) means j is already
at its optimum (Sec. 3.3).
"""
from __future__ import annotations

import math

H_INIT = 0.01    # Algorithm 1 line 2


def metric_h(y_before: float, y_after: float,
             x_before: float, x_after: float) -> float:
    denom = x_before - x_after          # communication improvement
    if denom <= 0.0:
        return math.inf                 # already optimal — never re-selected
    return (y_after - y_before) / denom
