"""Fault injection for the cost model: scripted hardware-degradation
schedules the ProfileTime simulator replays deterministically.

Production fabrics degrade — links flap, chips straggle, thermal events
add jitter — and a plan tuned on healthy hardware silently becomes the
wrong plan.  A :class:`FaultSchedule` scripts such episodes as a list of
:class:`FaultEvent` windows over the simulator's *step clock* (one step
per logical ProfileTime invocation during tuning; one step per served
batch when the serving health monitor replays the same schedule):

``degrade``
    Link bandwidth degradation: every comm site matching ``site`` sees a
    hardware profile whose ``link_bw``/``chan_bw`` are multiplied by
    ``scale`` (< 1).  Composes *physically* with the contention model —
    ``comm_time`` slows down AND the communication's memory-bandwidth
    draw ``V`` shrinks, so overlapped computation speeds up slightly,
    exactly as on a real degraded link.

``straggler``
    Slowdown multiplier ``scale`` (> 1) on every computation operator's
    duration — a thermally throttled or contended chip.

``jitter``
    A jitter burst: extra lognormal measurement noise of width ``sigma``
    on top of the simulator's own noise model, drawn from a Philox
    stream keyed on ``(schedule seed, step)`` so bursts are bit-exactly
    reproducible and independent of the tuner's draw order.

``flap``
    A transient link fault with recovery: within the event window the
    link cycles every ``period`` steps, degraded (by ``scale``) for the
    first ``duty`` fraction of each cycle and healthy for the rest.

``site`` filters comm-affecting events by dotted SiteId prefix
(``"serve.layer0"`` covers ``serve.layer0.mlp.ag`` and siblings) or by
collective class (``"ag"``/``"rs"``/``"ar"``/``"a2a"``/``"p2p"``);
empty means every comm site.  An *empty* schedule is falsy and the
simulator treats it exactly like ``faults=None`` — the fault-free code
path is untouched, so results stay byte-identical to a fault-free run.

Schedules round-trip through JSON (``save``/``load``) and also parse
from a compact inline spec (``parse_fault_schedule``)::

    degrade,site=serve,scale=0.25,start=2;straggler,scale=1.5,start=6,stop=9
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from dataclasses import dataclass, field, fields
from typing import Dict, List, Optional, Tuple, Union

from repro.core.hardware import Hardware
from repro.core.noise import lognormal_rows, stream_key, uniform_rows

FAULT_KINDS = ("degrade", "straggler", "jitter", "flap")

_SCALED = ("degrade", "flap", "straggler")


@dataclass(frozen=True)
class FaultEvent:
    """One scripted fault window; see the module docstring for kinds."""

    kind: str
    start: int = 0
    stop: Optional[int] = None  # exclusive; None = open-ended
    site: str = ""  # dotted SiteId prefix or class ("" = all comm sites)
    scale: float = 1.0  # bw multiplier (degrade/flap) / comp slowdown (straggler)
    sigma: float = 0.0  # extra lognormal sigma (jitter)
    period: int = 0  # flap cycle length in steps
    duty: float = 0.5  # flap: fraction of each cycle spent degraded

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"fault kind must be one of {FAULT_KINDS}, got {self.kind!r}"
            )
        object.__setattr__(self, "site", self.site.rstrip("."))
        if self.start < 0 or (self.stop is not None and self.stop <= self.start):
            raise ValueError(
                f"fault window [{self.start}, {self.stop}) is empty or negative"
            )
        if self.kind in _SCALED and not (
            isinstance(self.scale, (int, float))
            and math.isfinite(self.scale)
            and self.scale > 0
        ):
            raise ValueError(
                f"{self.kind} scale must be a finite positive multiplier, "
                f"got {self.scale!r}"
            )
        if self.kind == "jitter" and not (
            math.isfinite(self.sigma) and self.sigma >= 0
        ):
            raise ValueError(f"jitter sigma must be finite >= 0, got {self.sigma!r}")
        if self.kind == "flap":
            if self.period <= 0:
                raise ValueError("flap needs period > 0 (steps per cycle)")
            if not 0.0 < self.duty <= 1.0:
                raise ValueError(f"flap duty must be in (0, 1], got {self.duty!r}")

    # -- activity ----------------------------------------------------------
    def active(self, step: int) -> bool:
        """Whether this event degrades anything at ``step`` (flaps are
        active only during the degraded fraction of their cycle)."""
        if step < self.start or (self.stop is not None and step >= self.stop):
            return False
        if self.kind == "flap":
            duty_steps = max(1, int(round(self.period * self.duty)))
            return (step - self.start) % self.period < duty_steps
        return True

    def matches(self, site: str, cls: str) -> bool:
        """Whether a comm site is covered by this event's ``site`` filter
        (exact id, dotted prefix, or collective class; empty = all)."""
        if not self.site:
            return True
        return (
            site == self.site
            or site.startswith(self.site + ".")
            or self.site == cls
        )


@dataclass(frozen=True)
class FaultState:
    """The active fault window at one step — what the simulator's scalar
    event loop consumes.  ``comp_scale`` multiplies every computation
    duration; ``comm_scale``/``hardware_for`` degrade the hardware seen
    by matching comm sites; ``burst_jitters`` adds the step's jitter
    burst (deterministic in ``(seed, step)``)."""

    step: int
    seed: int
    comp_scale: float = 1.0
    sigma: float = 0.0
    comm_events: Tuple[FaultEvent, ...] = ()

    def comm_scale(self, site: str, cls: str) -> float:
        s = 1.0
        for ev in self.comm_events:
            if ev.matches(site, cls):
                s *= ev.scale
        return s

    def hardware_for(self, site: str, cls: str, hw: Hardware) -> Hardware:
        """``hw`` with the link degraded by every matching active event
        (identity when none match)."""
        return degraded_hardware(hw, self.comm_scale(site, cls))

    def burst_jitters(self, m: int, n: int) -> Tuple[List[float], List[float]]:
        """Extra lognormal multipliers for this step's submission —
        ``(comp multipliers, comm multipliers)``, a pure function of
        ``(seed, step)`` via the counter-based Philox stream."""
        if not self.sigma:
            return [1.0] * m, [1.0] * n
        key = stream_key(self.seed, ("fault-burst", self.step))
        row = lognormal_rows(uniform_rows(key, 0, 1), self.sigma, m + n)[0].tolist()
        return row[:m], row[m:]


_HW_CACHE: Dict[Tuple[str, float], Hardware] = {}


def degraded_hardware(hw: Hardware, scale: float) -> Hardware:
    """``hw`` with ``link_bw`` and ``chan_bw`` multiplied by ``scale`` —
    the degraded-link variant the contention model prices (memoized;
    ``scale == 1`` returns ``hw`` itself)."""
    if scale == 1.0:
        return hw
    key = (hw.name, scale)
    got = _HW_CACHE.get(key)
    if got is None:
        got = dataclasses.replace(
            hw,
            name=f"{hw.name}~deg{scale:g}",
            link_bw=hw.link_bw * scale,
            chan_bw=hw.chan_bw * scale,
        )
        _HW_CACHE[key] = got
    return got


@dataclass(frozen=True)
class FaultSchedule:
    """An ordered script of :class:`FaultEvent` windows plus the seed
    keying its jitter-burst stream.  Falsy when empty — the simulator's
    fault-free path is then untouched."""

    events: Tuple[FaultEvent, ...] = ()
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))
        for ev in self.events:
            if not isinstance(ev, FaultEvent):
                raise TypeError(f"events must be FaultEvent, got {type(ev).__name__}")

    def __bool__(self) -> bool:
        return bool(self.events)

    def state_at(self, step: int) -> Optional[FaultState]:
        """The composed fault state at ``step``, or ``None`` when no event
        is active (the simulator's fast path)."""
        comp = 1.0
        sigma = 0.0
        comm: List[FaultEvent] = []
        for ev in self.events:
            if not ev.active(step):
                continue
            if ev.kind == "straggler":
                comp *= ev.scale
            elif ev.kind == "jitter":
                sigma = max(sigma, ev.sigma)
            else:  # degrade / flap
                comm.append(ev)
        if comp == 1.0 and sigma == 0.0 and not comm:
            return None
        return FaultState(
            step=step,
            seed=self.seed,
            comp_scale=comp,
            sigma=sigma,
            comm_events=tuple(comm),
        )

    # -- serialization -----------------------------------------------------
    def to_dict(self) -> Dict:
        return {
            "seed": self.seed,
            "events": [
                {f.name: getattr(ev, f.name) for f in fields(ev)}
                for ev in self.events
            ],
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "FaultSchedule":
        return cls(
            events=tuple(FaultEvent(**ev) for ev in d.get("events", ())),
            seed=int(d.get("seed", 0)),
        )

    def to_json(self, *, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, allow_nan=False)

    @classmethod
    def from_json(cls, text: str) -> "FaultSchedule":
        return cls.from_dict(json.loads(text))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "FaultSchedule":
        with open(path) as f:
            return cls.from_json(f.read())


# ---------------------------------------------------------------------------
# inline spec parsing (launcher --fault-schedule)
# ---------------------------------------------------------------------------

_EVENT_FIELDS = {f.name: f for f in fields(FaultEvent)}
_INT_FIELDS = ("start", "stop", "period")
_FLOAT_FIELDS = ("scale", "sigma", "duty")


def _parse_event(tokens: List[str]) -> FaultEvent:
    kw: Dict[str, object] = {}
    for i, tok in enumerate(tokens):
        if "=" not in tok:
            if i == 0:
                kw["kind"] = tok
                continue
            raise ValueError(
                f"fault event token {tok!r} is not key=value (only the "
                "leading kind may be bare)"
            )
        key, val = tok.split("=", 1)
        if key not in _EVENT_FIELDS:
            raise ValueError(
                f"unknown fault event field {key!r}; known: "
                f"{sorted(_EVENT_FIELDS)}"
            )
        if key in _INT_FIELDS:
            kw[key] = int(val)
        elif key in _FLOAT_FIELDS:
            kw[key] = float(val)
        else:
            kw[key] = val
    if "kind" not in kw:
        raise ValueError(f"fault event {';'.join(tokens)!r} names no kind")
    return FaultEvent(**kw)  # type: ignore[arg-type]


def parse_fault_schedule(
    spec: Union[str, os.PathLike, FaultSchedule, None],
) -> Optional[FaultSchedule]:
    """Coerce a ``--fault-schedule`` value to a :class:`FaultSchedule`:
    an existing schedule (or ``None``) passes through, a path to a JSON
    file loads it, anything else parses as an inline spec —
    ``;``-separated events of comma-separated ``key=value`` pairs whose
    first token is the kind, with an optional leading ``seed=N`` segment::

        seed=7;degrade,site=serve,scale=0.25,start=2;flap,period=4,duty=0.5
    """
    if spec is None or isinstance(spec, FaultSchedule):
        return spec
    spec = os.fspath(spec)
    if os.path.exists(spec):
        return FaultSchedule.load(spec)
    seed = 0
    events: List[FaultEvent] = []
    for seg in spec.split(";"):
        seg = seg.strip()
        if not seg:
            continue
        tokens = [t.strip() for t in seg.split(",") if t.strip()]
        if len(tokens) == 1 and tokens[0].startswith("seed="):
            seed = int(tokens[0].split("=", 1)[1])
            continue
        events.append(_parse_event(tokens))
    return FaultSchedule(events=tuple(events), seed=seed)


__all__ = [
    "FAULT_KINDS",
    "FaultEvent",
    "FaultSchedule",
    "FaultState",
    "degraded_hardware",
    "parse_fault_schedule",
]
