"""Counter-based measurement-noise streams — batched Philox + CRN sharing.

The simulator's jitter used to come from one sequential RNG: every draw
depended on global draw history, so a batched engine had to replay the
exact flat draw order of the scalar path, and two structurally identical
groups could never see the same noise (their draws interleaved).  This
module replaces that history-dependent stream with a *counter-based*
scheme built on NumPy's Philox bit generator:

  * Every noisy ProfileTime submission (one candidate measurement of one
    overlap group) is issued a **ticket** ``(stream key, submission
    index)``.
  * The jitter multipliers for a ticket are a **pure function of the
    ticket**: submission ``i`` owns the fixed counter block
    ``[i * WORDS_PER_SUBMISSION, (i + 1) * WORDS_PER_SUBMISSION)`` of the
    keyed Philox stream; its uniforms are turned into standard normals
    with the Box-Muller transform (fixed consumption: pair ``p`` of
    normals reads uniform words ``2p`` and ``2p + 1``) and exponentiated
    into lognormal(0, sigma) multipliers.

Because tickets are position-keyed rather than history-keyed, a batch of
submissions with contiguous indices is drawn in ONE vectorized
``Generator.random`` call (one ``advance`` to the first block, one read),
and the scalar reference path re-derives bit-identical values by reading
its single block through the same helpers — no draw-order bookkeeping.
NumPy's elementwise float64 ufuncs produce identical bits for identical
inputs regardless of array shape, so batched and per-submission
evaluation agree exactly (asserted in tests/test_noise.py).

Two ticket-issue policies (``Simulator(noise_mode=...)``):

``"default"``
    One stream key per (seed); indices are the global flat submission
    order — request order, candidates within a request in list order.
    Every submission is an independent draw, so structurally identical
    groups legitimately diverge under jitter and trajectory sharing
    stays unsound (matching real per-layer measurement noise).

``"crn"``
    Common random numbers: the stream key is derived from ``(seed,
    structural group fingerprint)`` and the index is the submitting
    group's OWN trajectory position (its running count of noisy
    submissions).  Structurally identical groups therefore see identical
    jitter at identical trajectory positions, which makes their search
    trajectories — and ``scheduler.run_shared`` trajectory sharing —
    provably identical, independent of how group submissions interleave.
    CRN is the standard variance-reduction device for *comparing*
    configurations under noise; it is sound for tuning (the search only
    compares measurements of the same group) but deliberately correlates
    noise across identical layers, so do not use it to study per-layer
    noise statistics.

Keys are 128-bit BLAKE2b digests of ``repr((seed, tag))`` — deterministic
across processes and platforms, unlike ``hash()``.
"""
from __future__ import annotations

import hashlib
import math
import weakref
from typing import Dict, List, Sequence, Tuple

import numpy as np

#: uniform float64 words reserved per submission ticket.  Must be a
#: multiple of 4 (Philox emits 4 words per counter increment); supports up
#: to ``WORDS_PER_SUBMISSION`` jitters per submission (Box-Muller pairs).
WORDS_PER_SUBMISSION = 64

NOISE_MODES = ("default", "crn")

_TWO_PI = 2.0 * math.pi

#: ticket spec issued by :meth:`NoiseModel.reserve` plus the jitter count:
#: ``(stream key, first submission index, submissions, jitters each)``.
RunSpec = Tuple[int, int, int, int]


def stream_key(seed: int, tag: object) -> int:
    """128-bit Philox key for ``(seed, tag)`` — a stable BLAKE2b digest of
    the repr, so streams are reproducible across processes (``hash()`` is
    salted) and distinct tags never collide in practice."""
    digest = hashlib.blake2b(repr((seed, tag)).encode(), digest_size=16).digest()
    return int.from_bytes(digest, "little")


def uniform_rows(key: int, first: int, count: int) -> np.ndarray:
    """The reserved uniform words of ``count`` contiguous submissions
    starting at index ``first``, shape ``(count, WORDS_PER_SUBMISSION)``.
    One ``advance`` + one ``random`` call; row ``i`` is bit-identical to
    ``uniform_rows(key, first + i, 1)[0]`` because Philox is counter-based
    and ``Generator.random`` consumes exactly one word per float64.

    This is the REFERENCE implementation of the stream; the hot path is
    :meth:`NoiseModel.uniforms`, which keeps one bit generator per key and
    re-seats its counter instead of paying ``Philox(key=...)`` key
    expansion (~tens of microseconds) on every draw.  The two are asserted
    bit-equal in tests/test_noise.py.
    """
    bg = np.random.Philox(key=key)
    bg.advance(first * (WORDS_PER_SUBMISSION // 4))  # advance() steps 4-word blocks
    u = np.random.Generator(bg).random(count * WORDS_PER_SUBMISSION)
    return u.reshape(count, WORDS_PER_SUBMISSION)


def lognormal_rows(u: np.ndarray, sigma: float, width: int) -> np.ndarray:
    """First ``width`` lognormal(0, sigma) jitters of each submission row.

    Box-Muller with fixed consumption: pair ``p`` reads words ``2p`` and
    ``2p + 1`` of the row, so jitter ``j`` depends only on its own pair —
    the value is independent of ``width`` and of the other rows, which is
    what lets heterogeneous batches share one uniform block.
    """
    if width > WORDS_PER_SUBMISSION:
        raise ValueError(
            f"group has {width} ops; raise noise.WORDS_PER_SUBMISSION "
            f"(currently {WORDS_PER_SUBMISSION}) to reserve more draws"
        )
    if width == 0:
        return np.empty((u.shape[0], 0))
    pairs = (width + 1) // 2
    u1 = 1.0 - u[:, 0 : 2 * pairs : 2]  # (0, 1] — log() stays finite
    u2 = u[:, 1 : 2 * pairs : 2]
    r = np.sqrt(-2.0 * np.log(u1))
    ang = _TWO_PI * u2
    z = np.empty((u.shape[0], 2 * pairs))
    z[:, 0::2] = r * np.cos(ang)
    z[:, 1::2] = r * np.sin(ang)
    return np.exp(sigma * z[:, :width])


class NoiseModel:
    """Per-simulator ticket issue + vectorized jitter draws.

    The model owns the mutable stream state: the global submission counter
    (default mode) or the per-fingerprint keys and per-group trajectory
    positions (CRN mode).  Jitter *values* never depend on this state
    beyond the issued ticket, so any consumer holding a ticket can
    re-derive its draws.
    """

    _TRAJ_MEMO_MAX = 65536  # CRN per-group position memo bound (see reserve)

    def __init__(self, seed: int, sigma: float, mode: str = "default"):
        if mode not in NOISE_MODES:
            raise ValueError(f"noise_mode must be one of {NOISE_MODES}, got {mode!r}")
        self.seed = seed
        self.sigma = float(sigma)
        self.mode = mode
        self._default_key = stream_key(seed, "default")
        self._next = 0  # default mode: global flat submission index
        self._fp_keys: Dict[Tuple, int] = {}  # crn: fingerprint -> stream key
        self._traj: Dict[int, List] = {}  # crn: id(group) -> [group, key, next]
        self._bgs: Dict[int, Tuple] = {}  # key -> (bitgen, Generator, state)

    # -- stream reads ----------------------------------------------------
    def uniforms(self, key: int, first: int, count: int) -> np.ndarray:
        """Hot-path twin of :func:`uniform_rows` (bit-identical): the bit
        generator for ``key`` is built once and its counter re-seated per
        read, skipping per-call Philox key expansion."""
        ent = self._bgs.get(key)
        if ent is None:
            bg = np.random.Philox(key=key)
            ent = (bg, np.random.Generator(bg), bg.state)
            self._bgs[key] = ent
        bg, gen, state = ent
        # block counter = submissions * blocks-per-submission; buffer_pos=4
        # marks the 4-word output buffer empty so the read starts at the
        # counter (the template state is pristine: pos 4, counter zeroed)
        state["state"]["counter"][0] = first * (WORDS_PER_SUBMISSION // 4)
        bg.state = state
        u = gen.random(count * WORDS_PER_SUBMISSION)
        return u.reshape(count, WORDS_PER_SUBMISSION)

    # -- ticket issue ----------------------------------------------------
    def reserve(self, g, n: int) -> Tuple[int, int]:
        """Issue ``n`` submission tickets for group ``g`` in flat
        submission order; returns ``(stream key, first index)`` — the
        tickets are the contiguous index range ``[first, first + n)``.

        CRN positions are tracked per group *instance* (weakly — a
        collected group's trajectory can never resume, so its entry is
        purged): a live group object re-entering the tuner continues its
        trajectory.  Trajectory position is semantic state, not a cache —
        dropping a LIVE group's entry would silently replay its draws and
        break the serial == interleaved == shared equality — so when the
        memo is full of live groups this raises instead of evicting; use a
        fresh ``Simulator`` per tuning session.
        """
        if self.mode == "default":
            first = self._next
            self._next += n
            return self._default_key, first
        ent = self._traj.get(id(g))
        if ent is None or ent[0]() is not g:  # dead/reused id -> fresh entry
            from repro.core.profiling import group_fingerprint

            fp = group_fingerprint(g)
            key = self._fp_keys.get(fp)
            if key is None:
                key = stream_key(self.seed, ("crn", fp))
                self._fp_keys[fp] = key
            if len(self._traj) >= self._TRAJ_MEMO_MAX:
                self._traj = {i: e for i, e in self._traj.items() if e[0]() is not None}
                if len(self._traj) >= self._TRAJ_MEMO_MAX:
                    raise RuntimeError(
                        f"more than {self._TRAJ_MEMO_MAX} live CRN group "
                        f"trajectories in one Simulator; tune with a fresh "
                        f"Simulator per session"
                    )
            ent = [weakref.ref(g), key, 0]
            self._traj[id(g)] = ent
        first = ent[2]
        ent[2] += n
        return ent[1], first

    # -- draws -----------------------------------------------------------
    def draw(self, g, n: int, width: int) -> np.ndarray:
        """Reserve ``n`` tickets for ``g`` and return their jitters,
        shape ``(n, width)`` (row layout: M comp jitters then N comm)."""
        key, first = self.reserve(g, n)
        return lognormal_rows(self.uniforms(key, first, n), self.sigma, width)

    def group_jitters(self, g, m: int, n: int) -> Tuple[List[float], List[float]]:
        """One submission's jitters for the scalar reference path:
        ``(comp multipliers, comm multipliers)`` as plain floats."""
        row = self.draw(g, 1, m + n)[0].tolist()
        return row[:m], row[m:]

    def draw_reserved(self, specs: Sequence[RunSpec]) -> List[np.ndarray]:
        """Jitter matrices for already-reserved ticket runs, one
        ``(count, width)`` array per spec.  Contiguous same-key spans
        (the whole batch, in default mode) share ONE uniform draw."""
        out: List[np.ndarray] = []
        i = 0
        while i < len(specs):
            key, first, total, _ = specs[i]
            j = i + 1
            while (
                j < len(specs)
                and specs[j][0] == key
                and specs[j][1] == first + total
            ):
                total += specs[j][2]
                j += 1
            u = self.uniforms(key, first, total)
            off = 0
            for k in range(i, j):
                _, _, cnt, width = specs[k]
                out.append(lognormal_rows(u[off : off + cnt], self.sigma, width))
                off += cnt
            i = j
        return out
