"""Hardware profiles for the contention model / overlap simulator.

The paper evaluates on two 16×A40 clusters (NVLink and PCIe variants);
those profiles drive the paper-faithful reproduction.  The TPU v5e profile
drives the deployment-target tuning (DESIGN.md §2): λ becomes the pool of
concurrent occupancy slots (VMEM-resident tile slots) and "channels" become
concurrent DMA streams that consume slots + HBM bandwidth.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, fields
from typing import Dict, List


@dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float          # per chip, bf16/fp16 (theoretical)
    gemm_eff: float            # achieved fraction of peak on real kernels
    hbm_bw: float              # B̄: peak global memory bandwidth (B/s)
    link_bw: float             # achieved interconnect bus bandwidth (B/s)
    num_slots: int             # λ: SMs (GPU) / occupancy slots (TPU)
    chan_bw: float             # per-channel link bandwidth (B/s)
    chunk_half_kb: float       # chunk size at which a channel hits 50% efficiency
    launch_us: float           # per-collective launch overhead (µs)
    chunk_us: float            # per-chunk processing overhead (µs)
    comm_comp_beta: float = 0.15   # comm slowdown fraction when compute is active
    default_nc: int = 8        # vendor-default channels (NCCL: 8; larger on NVLink)
    default_chunk_kb: int = 2048
    # staging-footprint interference: NC·C bytes of communication staging
    # buffers evict the compute working set from L2 (GPU) / VMEM (TPU),
    # stalling compute pipelines by up to ``interference_gamma``.
    cache_kb: int = 6144
    interference_gamma: float = 0.35
    # per-algorithm-step fabric latency (µs) on top of the fixed 1µs step
    # cost — 0 on pod-local fabrics; the pod-joining tiers of
    # ``core.topology`` carry their cross-pod RTT here.
    hop_us: float = 0.0

    @property
    def achieved_flops(self) -> float:
        return self.peak_flops * self.gemm_eff

    # -- serialization (named-profile registry round-trip) -----------------
    def to_dict(self) -> Dict:
        return {f.name: getattr(self, f.name) for f in fields(self)}

    @classmethod
    def from_dict(cls, d: Dict) -> "Hardware":
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown Hardware fields {sorted(unknown)}; "
                             f"known: {sorted(known)}")
        return cls(**d)

    def to_json(self, *, indent=2) -> str:
        return json.dumps(self.to_dict(), indent=indent, allow_nan=False)

    @classmethod
    def from_json(cls, text: str) -> "Hardware":
        return cls.from_dict(json.loads(text))


# Calibration anchors (paper Fig. 3, 8×A40): with λ=84 SMs and one resident
# block per SM, the wave model gives (84−16)/(84−32) = +30.8% FFN slowdown
# for NC 16→32 — the paper measures +30.2%.  Link numbers are achieved NCCL
# bus bandwidths, not line rates.
A40_PCIE = Hardware(
    name="a40-pcie",
    peak_flops=149.7e12 / 2,       # dense fp16 tensor
    gemm_eff=0.55,
    hbm_bw=696e9,
    link_bw=16e9,                  # PCIe 4.0 x16 achieved busbw
    num_slots=84,                  # GA102 SMs
    chan_bw=3.5e9,
    chunk_half_kb=128.0,
    launch_us=12.0,
    chunk_us=1.5,
    default_nc=8,
    default_chunk_kb=2048,
)

A40_NVLINK = Hardware(
    name="a40-nvlink",
    peak_flops=149.7e12 / 2,
    gemm_eff=0.55,
    hbm_bw=696e9,
    link_bw=20e9,                  # 400 Gbps NVLink achieved busbw
    num_slots=84,
    chan_bw=6e9,
    chunk_half_kb=96.0,
    launch_us=8.0,
    chunk_us=1.0,
    default_nc=16,                 # NCCL widens channels on NVLink (Sec. 4.2)
    default_chunk_kb=4096,
)

TPU_V5E = Hardware(
    name="tpu-v5e",
    peak_flops=197e12,             # bf16
    gemm_eff=0.55,
    hbm_bw=819e9,
    link_bw=42e9,                  # ICI achieved (~0.85 × 50 GB/s)
    num_slots=128,                 # VMEM-resident tile slots (occupancy pool)
    chan_bw=12.5e9,                # one ICI link direction
    chunk_half_kb=256.0,
    launch_us=2.0,
    chunk_us=0.6,
    default_nc=4,                  # XLA default: all links, bulk chunks
    default_chunk_kb=4096,
)

PROFILES = {h.name: h for h in (A40_PCIE, A40_NVLINK, TPU_V5E)}


# ---------------------------------------------------------------------------
# named-profile registry: launchers, fault specs and --plan-hardware resolve
# profiles by name instead of importing module constants
# ---------------------------------------------------------------------------

def by_name(name: str) -> Hardware:
    """The registered profile called ``name`` — the one lookup every
    by-name surface (``session.tune(workload, "tpu-v5e")``, the launchers'
    ``--plan-hardware``, benchmark hardware columns) goes through.

    Raises:
        KeyError: unknown name; the message lists ``profiles()``.
    """
    try:
        return PROFILES[name]
    except KeyError:
        raise KeyError(f"unknown hardware profile {name!r}; registered: "
                       f"{profiles()}") from None


def profiles() -> List[str]:
    """Sorted names of every registered profile."""
    return sorted(PROFILES)


def register_profile(hw: Hardware, *, overwrite: bool = False) -> Hardware:
    """Add ``hw`` to the registry under ``hw.name`` (refusing silent
    replacement unless ``overwrite=True``); returns ``hw`` so custom
    profiles register inline::

        hw = register_profile(Hardware(name="my-pod", ...))
    """
    if hw.name in PROFILES and not overwrite:
        raise ValueError(f"hardware profile {hw.name!r} already registered "
                         "(pass overwrite=True to replace it)")
    PROFILES[hw.name] = hw
    return hw
