"""AdamW + global-norm clipping, pure JAX (no optax in this environment).

State layout mirrors the param pytree: {"mu": ..., "nu": ..., "count": i32}.
Moments are kept in float32 regardless of param dtype (mixed-precision
training keeps a float32 master view implicitly via the update math).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def init_state(params) -> Dict[str, Any]:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)

    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, state, cfg: AdamWConfig,
                  lr_scale: jnp.ndarray | float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    count = state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        step = (mu / c1) / (jnp.sqrt(nu / c2) + cfg.eps)
        new_p = p.astype(jnp.float32) - lr * (step + cfg.weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, {"mu": new_mu, "nu": new_nu, "count": count}, \
        {"grad_norm": gnorm, "lr": lr}
