"""Chunked, overlap-friendly collective matmuls (shard_map + lax.ppermute).

These are the TPU-native runtime artifacts that Lagom's tuned parameters
select (DESIGN.md §2):

  * ``C`` (chunk size)      -> ``num_chunks`` of each decomposed collective
  * ``Algorithm``           -> ``strategy``: "xla" (one fused collective,
                               scheduling left to XLA's latency-hiding
                               scheduler) | "ring" (explicit ppermute ring)
                               | "chunked" (scan of partial collectives)
  * ``NC`` (channels)       -> modeled in the simulator (DMA concurrency);
                               on real HW it maps to
                               ``--xla_tpu_scoped_vmem_limit_kib`` style
                               staging limits, which have no HLO footprint.

Every function has a dense reference (``*_ref``) used by the tests, and the
explicit variants are HLO-visible: the dry-run roofline counts their
collective-permute / reduce-scatter bytes, so tuned chunk counts actually
move the measured collective term.

Per-site plan addressing
------------------------

Every tunable collective call site carries a stable dotted **SiteId**
(e.g. ``fsdp.layer3.ag_params``, ``tp.layer1.mlp.rs``) derived from the
Workload IR names that ``core.extract`` emits.  A runtime plan is a
``{site_id: CollectiveRuntime}`` map (what ``session.TunedPlan.
runtime_plan()`` lowers to); ``runtime_for(site, cls)`` resolves a site
against the *active* plan by walking from most- to least-specific:

  exact site id -> each dotted prefix (``tp.layer1.mlp`` -> ``tp.layer1``
  -> ``tp``) -> the site *class* (``"ag"`` / ``"rs"`` / ``"ar"`` /
  ``"a2a"`` / ``"p2p"``) -> XLA defaults.

so one plan can legitimately drive two layers of the same model to emit
different chunk structure.  Plans are scoped: ``use_runtime_plan`` pushes
a plan for a ``with`` block (what ``TunedPlan.applied()`` uses — nested
scopes shadow, exits restore, exception-safe), while
``install_runtime_plan`` sets the process-wide base plan (the launchers'
``--tuned-plan`` / ``--plan-repo`` startup path).  The legacy
``set_runtime_plan`` remains as a deprecation shim over the latter.
"""
from __future__ import annotations

import contextlib
import contextvars
import warnings
from dataclasses import dataclass
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

try:                                  # jax >= 0.5 exports it at top level
    from jax import shard_map
except ImportError:                   # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map


def axis_size(axis: str) -> int:
    """Concrete mesh-axis size inside a shard_map body (``lax.axis_size`` on
    new jax; on older jax ``psum(1, axis)`` folds to a static int)."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis)
    return lax.psum(1, axis)


@dataclass(frozen=True)
class CollectiveRuntime:
    """Runtime knobs for one collective site (what `core.apply` emits)."""
    strategy: str = "xla"        # xla | ring | chunked
    num_chunks: int = 1


@dataclass(frozen=True)
class SiteResolution:
    """One ``resolve_runtime`` consultation observed by
    ``record_site_resolutions`` — the ground truth the overlap verifier
    (``repro.analysis.overlap``) attributes emitted chunk structure with:
    plans are consumed at *trace* time, so the set of recorded rows is
    exactly the set of sites the traced program addressed, with the knobs
    and fallback tier each one actually received."""
    site: str
    cls: Optional[str]
    strategy: str
    num_chunks: int
    matched_key: str     # plan key that supplied the knobs ("" = default)
    tier: str            # "exact" | "prefix" | "class" | "default"


# Active runtime plans, each ``{site_id: CollectiveRuntime}``.  The base
# plan is process-wide (``install_runtime_plan`` — the launchers'
# ``--tuned-plan`` startup path); ``use_runtime_plan`` layers scoped plans
# over it (``TunedPlan.applied()``) in a ``ContextVar`` so concurrent
# threads/tasks cannot pop each other's scopes.  The *innermost* plan is
# the active one — scopes shadow rather than merge, so ``applied()`` means
# "exactly this plan", and exiting restores whatever was active before.
_BASE_PLAN: Dict[str, CollectiveRuntime] = {}
_SCOPED_PLANS: contextvars.ContextVar = contextvars.ContextVar(
    "repro_runtime_plans", default=())

_DEFAULT_RUNTIME = CollectiveRuntime()


def install_runtime_plan(plan: Optional[Dict[str, CollectiveRuntime]] = None,
                         ) -> None:
    """Install ``{site_id: CollectiveRuntime}`` as the process-wide base
    plan (replacing any previous one; ``None``/empty clears it).  Scoped
    plans pushed by ``use_runtime_plan`` shadow it while active."""
    global _BASE_PLAN
    _BASE_PLAN = dict(plan or {})


@contextlib.contextmanager
def use_runtime_plan(plan: Dict[str, CollectiveRuntime]):
    """Scope a runtime plan to a ``with`` block: inside, ``runtime_for``
    resolves against ``plan`` (shadowing any outer/base plan); on exit —
    normal or exceptional — the prior state is restored.  Nests, and is
    thread/async-safe (context-local, token-based restore)."""
    token = _SCOPED_PLANS.set(_SCOPED_PLANS.get() + (dict(plan),))
    try:
        yield
    finally:
        _SCOPED_PLANS.reset(token)


def set_runtime_plan(plan: Dict[str, CollectiveRuntime]) -> None:
    """Deprecated alias for ``install_runtime_plan`` (the pre-per-site
    process-global API).  Resolved knobs are bit-identical; prefer
    ``TunedPlan.applied()`` for scoped use."""
    warnings.warn(
        "set_runtime_plan is deprecated; use install_runtime_plan(plan) for "
        "a process-wide install or `with plan.applied(): ...` for a scoped "
        "one", DeprecationWarning, stacklevel=2)
    install_runtime_plan(plan)


def _active_plan() -> Dict[str, CollectiveRuntime]:
    scopes = _SCOPED_PLANS.get()
    return scopes[-1] if scopes else _BASE_PLAN


# Trace-time site-resolution recorder (context-local, like the scoped
# plans): while a ``record_site_resolutions`` block is active, every
# ``resolve_runtime`` call appends a ``SiteResolution`` row.  The overlap
# verifier traces a model builder inside this block to learn which sites
# the program consulted and what knobs each received — the sound way to
# attribute emitted scan/while chunk structure back to dotted SiteIds
# (builder call sites address plans at coarser granularity than the
# Workload IR site ids, so name matching alone is not enough).
_RESOLUTION_LOG: contextvars.ContextVar = contextvars.ContextVar(
    "repro_site_resolution_log", default=None)


@contextlib.contextmanager
def record_site_resolutions():
    """Record every ``resolve_runtime`` consultation in the ``with`` block.

    Yields the live list of ``SiteResolution`` rows (appended in call
    order, duplicates included — a builder may consult one site several
    times).  Nests: the innermost recorder captures the rows; outer
    recorders resume on exit.  Thread/async-safe (context-local)."""
    rows: list = []
    token = _RESOLUTION_LOG.set(rows)
    try:
        yield rows
    finally:
        _RESOLUTION_LOG.reset(token)


def active_runtime_plan() -> Dict[str, CollectiveRuntime]:
    """The innermost active plan (a copy)."""
    return dict(_active_plan())


def site_class(site: str) -> str:
    """First dotted component of a site id — the coarse bucket the legacy
    three-knob plans keyed on (``"ag"``/``"rs"``/``"ar"``/``"a2a"``/
    ``"p2p"`` for Workload IR comm names)."""
    return site.split(".", 1)[0]


def resolve_runtime(site: str, cls: Optional[str] = None,
                    ) -> Tuple[CollectiveRuntime, str, str]:
    """Resolve ``site`` against the active plan, reporting *how* it
    matched: ``(knobs, matched_key, tier)`` with ``tier`` one of
    ``"exact"`` (the full site id), ``"prefix"`` (a dotted prefix —
    ``acc.step3.rs_grads`` served by an ``acc`` entry), ``"class"`` (the
    ``cls`` fallback bucket), or ``"default"`` (XLA defaults,
    ``matched_key == ""``).  Resolution order: exact site id, then each
    dotted prefix (most to least specific), then ``cls``."""
    plan = _active_plan()
    rt, key, tier = _DEFAULT_RUNTIME, "", "default"
    if site:
        parts = site.split(".")
        for k in range(len(parts), 0, -1):
            pk = ".".join(parts[:k])
            if pk in plan:
                rt, key, tier = plan[pk], pk, ("exact" if k == len(parts)
                                               else "prefix")
                break
    if tier == "default" and cls is not None and cls in plan:
        rt, key, tier = plan[cls], cls, "class"
    log = _RESOLUTION_LOG.get()
    if log is not None:
        log.append(SiteResolution(site=site, cls=cls, strategy=rt.strategy,
                                  num_chunks=rt.num_chunks, matched_key=key,
                                  tier=tier))
    return rt, key, tier


def explain_runtime(site: str, cls: Optional[str] = None,
                    ) -> Tuple[CollectiveRuntime, str]:
    """Resolve ``site`` against the active plan; returns ``(knobs,
    matched_key)`` where ``matched_key`` is the plan key that supplied the
    knobs (``""`` = XLA defaults).  ``resolve_runtime`` additionally names
    the fallback tier that matched."""
    rt, key, _ = resolve_runtime(site, cls)
    return rt, key


def runtime_for(site: str, cls: Optional[str] = None) -> CollectiveRuntime:
    """The active knobs for a collective site.  ``site`` may be a full
    SiteId (``"fsdp.layer3.ag_params"``) or a bare site class (``"ag"``,
    ``"rs"``, ``"ar"``, ``"a2a"``, ``"p2p"``); ``cls`` is the fallback
    class a specific site degrades to when the plan has no entry at any
    of its prefixes.  XLA defaults when nothing matches."""
    return explain_runtime(site, cls)[0]


def _resolve_chunks(num_chunks, site: str, cls: Optional[str] = None) -> int:
    """Explicit ``num_chunks`` wins; ``None`` defers to the active plan."""
    return runtime_for(site, cls).num_chunks if num_chunks is None else num_chunks


class CollectiveDegradedWarning(RuntimeWarning):
    """A tuned site degrading to its monolithic/fallback collective at
    trace time.  Carries the same stable lint code as the static rule in
    ``repro.analysis.lint`` (``LAG010``: chunk count does not divide the
    payload) plus the resolved site id, so runtime warnings and static
    findings name the identical defect.  ``args[0]`` is the formatted
    message; ``site``/``code`` are machine-readable."""

    code = "LAG010"

    def __init__(self, message: str, *, site: str = ""):
        super().__init__(message)
        self.site = site


# Sites already warned about in this process: a degraded site warns once,
# not once per retrace (jit re-traces, vmap/grad passes and serving
# hot-swaps would otherwise repeat the identical message).  Tests reset
# via ``reset_degraded_warnings``.
_DEGRADED_WARNED: set = set()


def reset_degraded_warnings() -> None:
    """Clear the per-process ``CollectiveDegradedWarning`` dedupe state so
    the next degradation at any site warns again (test isolation)."""
    _DEGRADED_WARNED.clear()


def warn_degraded(site: str, detail: str, *, stacklevel: int = 3) -> None:
    """Emit the structured ``LAG010`` degradation warning for ``site``,
    once per (site, detail) per process.  ``detail`` finishes the sentence
    "collective site S: ..." — it should name what failed to divide and
    what the fallback emission is."""
    key = (site, detail)
    if key in _DEGRADED_WARNED:
        return
    _DEGRADED_WARNED.add(key)
    warnings.warn(
        CollectiveDegradedWarning(
            f"[{CollectiveDegradedWarning.code}] collective site {site!r}: "
            f"{detail}", site=site),
        stacklevel=stacklevel)


def _warn_unchunked(site: str, num_chunks: int, detail: str) -> None:
    """A tuned chunk count that does not divide the shard shape silently
    degrading to the monolithic collective is an audit hazard — name the
    site once at trace time instead."""
    warn_degraded(
        site,
        f"num_chunks={num_chunks} does not divide {detail}; emitting the "
        "unchunked collective for this site",
        stacklevel=4)


# ---------------------------------------------------------------------------
# all-gather ∘ matmul  (column-parallel matmul with sequence-sharded input)
#   x: (..., T, D) sharded on T over `axis`;  w: (D, F) sharded on F
#   y = allgather_T(x) @ w   -> (..., n*Tl, F_local)
# ---------------------------------------------------------------------------

def ag_matmul_ref(x, w):
    return x @ w


def _ring_ag_matmul_local(x, w, *, axis: str, num_chunks: int, site: str = "ag"):
    """Per-device body: hold one sequence shard, rotate shards around the
    ring; each step multiplies the currently-held shard so communication of
    the next shard overlaps with this step's matmul."""
    n = axis_size(axis)
    idx = lax.axis_index(axis)
    Tl = x.shape[-2]
    out_shape = x.shape[:-2] + (n * Tl, w.shape[-1])
    perm = [(j, (j - 1) % n) for j in range(n)]

    chunked = num_chunks > 1 and Tl % num_chunks == 0
    if num_chunks > 1 and not chunked:
        _warn_unchunked(site, num_chunks, f"the local sequence shard ({Tl})")

    def chunked_mm(xs):
        if not chunked:
            return xs @ w
        blocks = jnp.stack(jnp.split(xs, num_chunks, axis=-2))
        ys = lax.map(lambda b: b @ w, blocks)
        return jnp.concatenate(list(ys), axis=-2)

    def body(i, carry):
        x_cur, out = carry
        src = (idx + i) % n                 # whose shard we currently hold
        y = chunked_mm(x_cur)
        out = lax.dynamic_update_slice_in_dim(out, y, src * Tl, axis=-2)
        x_cur = lax.ppermute(x_cur, axis, perm)
        return (x_cur, out)

    out = jnp.zeros(out_shape, x.dtype)
    try:  # newer jax: align varying-manual-axes type with the inputs
        vma = tuple(set(jax.typeof(x).vma) | set(jax.typeof(w).vma))
        out = lax.pvary(out, vma)
    except AttributeError:
        pass
    _, out = lax.fori_loop(0, n, body, (x, out))
    return out


def ring_ag_matmul(x, w, mesh: Mesh, *, axis: str = "model",
                   x_spec: P, w_spec: P, out_spec: P,
                   num_chunks: int | None = None, site: str | None = None):
    site = site or "ag"
    num_chunks = _resolve_chunks(num_chunks, site, "ag")
    fn = shard_map(partial(_ring_ag_matmul_local, axis=axis,
                           num_chunks=num_chunks, site=site),
                   mesh=mesh, in_specs=(x_spec, w_spec), out_specs=out_spec)
    return fn(x, w)


# ---------------------------------------------------------------------------
# matmul ∘ reduce-scatter  (row-parallel matmul)
#   x: (..., T, Fl) F-sharded over `axis`; w: (Fl, D)
#   y = reduce_scatter_T( x @ w )  -> (..., T/n, D)
# ---------------------------------------------------------------------------

def mm_rs_ref(x, w):
    return x @ w


def _mm_rs_local(x, w, *, axis: str, num_chunks: int, site: str = "rs"):
    n = axis_size(axis)
    T = x.shape[-2]
    if num_chunks <= 1 or T % (num_chunks * n):
        if num_chunks > 1:
            _warn_unchunked(site, num_chunks,
                            f"the scatter tiling ({T} rows over {n} shards)")
        y = x @ w
        return lax.psum_scatter(y, axis, scatter_dimension=y.ndim - 2, tiled=True)
    # tile-aligned chunking: chunk i must contain rows {j·T/n + i·s ... } for
    # every destination shard j so the concatenated per-chunk scatters equal
    # the single full scatter.
    s = T // (n * num_chunks)
    lead = x.shape[:-2]
    xr = x.reshape(lead + (n, num_chunks, s, x.shape[-1]))
    blocks = jnp.moveaxis(xr, -3, 0)                     # (nc, ..., n, s, F)
    blocks = blocks.reshape((num_chunks,) + lead + (n * s, x.shape[-1]))

    def one(b):
        y = b @ w
        return lax.psum_scatter(y, axis, scatter_dimension=y.ndim - 2, tiled=True)

    ys = lax.map(one, blocks)        # chunked: scatter of chunk i overlaps mm of i+1
    return jnp.concatenate(list(ys), axis=-2)


def mm_reduce_scatter(x, w, mesh: Mesh, *, axis: str = "model",
                      x_spec: P, w_spec: P, out_spec: P,
                      num_chunks: int | None = None, site: str | None = None):
    site = site or "rs"
    num_chunks = _resolve_chunks(num_chunks, site, "rs")
    fn = shard_map(partial(_mm_rs_local, axis=axis, num_chunks=num_chunks,
                           site=site),
                   mesh=mesh, in_specs=(x_spec, w_spec), out_specs=out_spec)
    return fn(x, w)


# ---------------------------------------------------------------------------
# chunked all-to-all (MoE dispatch/combine)
#   x: (..., E, capl, D) with E sharded over `axis` on entry or exit
# ---------------------------------------------------------------------------

def _chunked_a2a_local(xl, *, axis: str, split_axis: int, concat_axis: int,
                       num_chunks: int, site: str = "a2a"):
    """Local body: one all_to_all, or ``num_chunks`` sequential a2a's over
    the trailing feature dim (reused by ``chunked_all_to_all`` and the
    explicit expert-parallel MoE FFN)."""
    if num_chunks <= 1 or xl.shape[-1] % num_chunks:
        if num_chunks > 1:
            _warn_unchunked(site, num_chunks,
                            f"the trailing feature dim ({xl.shape[-1]})")
        return lax.all_to_all(xl, axis, split_axis, concat_axis, tiled=True)
    blocks = jnp.stack(jnp.split(xl, num_chunks, axis=-1))
    ys = lax.map(lambda b: lax.all_to_all(b, axis, split_axis, concat_axis,
                                          tiled=True), blocks)
    return jnp.concatenate(list(ys), axis=-1)


def chunked_all_to_all(x, mesh: Mesh, *, axis: str = "model",
                       split_axis: int, concat_axis: int,
                       x_spec: P, out_spec: P, num_chunks: int | None = None,
                       site: str | None = None):
    """lax.all_to_all decomposed into ``num_chunks`` sequential a2a's over
    the trailing feature dim, so expert FFN compute on early chunks overlaps
    the transfer of later ones (the EP dual-batch pattern).  ``num_chunks=
    None`` (default) defers to the active tuned plan's knobs for ``site``
    (falling back to the ``a2a`` site class)."""
    site = site or "a2a"
    num_chunks = _resolve_chunks(num_chunks, site, "a2a")
    local = partial(_chunked_a2a_local, axis=axis, split_axis=split_axis,
                    concat_axis=concat_axis, num_chunks=num_chunks, site=site)
    fn = shard_map(local, mesh=mesh, in_specs=(x_spec,), out_specs=out_spec)
    return fn(x)


# ---------------------------------------------------------------------------
# plain helpers used by the trainer (gradient sync in explicit-DP mode)
# ---------------------------------------------------------------------------

def psum_tree(tree, axis: str):
    return jax.tree.map(lambda a: lax.psum(a, axis), tree)


def psum_tree_chunked(tree, axis: str, *, num_chunks: int | None = None,
                      site: str = "acc"):
    """``psum_tree`` decomposed into ``num_chunks`` sequential partial
    psums over each leaf's leading dim, so the reduce of early chunks
    overlaps whatever compute the scheduler has in flight — the ACCO
    accumulation-overlap gradient sync (``acc.step{k}.rs_grads`` sites)
    and the Streaming-DiLoCo outer sync (``outer.round{r}.sync.*``).
    ``num_chunks=None`` defers to the active tuned plan's knobs for
    ``site`` (falling back to the ``acc`` site class); leaves whose
    leading dim the chunk count does not divide (scalars included) reduce
    whole."""
    num_chunks = _resolve_chunks(num_chunks, site, site_class(site))

    def one(a):
        if num_chunks <= 1 or a.ndim == 0:
            return lax.psum(a, axis)
        if a.shape[0] % num_chunks:
            _warn_unchunked(site, num_chunks,
                            f"the leading dim ({a.shape[0]}) of a grad leaf")
            return lax.psum(a, axis)
        blocks = jnp.stack(jnp.split(a, num_chunks, axis=0))
        ys = lax.map(lambda b: lax.psum(b, axis), blocks)
        return jnp.concatenate(list(ys), axis=0)

    return jax.tree.map(one, tree)
