"""Chunked, overlap-friendly collective matmuls (shard_map + lax.ppermute).

These are the TPU-native runtime artifacts that Lagom's tuned parameters
select (DESIGN.md §2):

  * ``C`` (chunk size)      -> ``num_chunks`` of each decomposed collective
  * ``Algorithm``           -> ``strategy``: "xla" (one fused collective,
                               scheduling left to XLA's latency-hiding
                               scheduler) | "ring" (explicit ppermute ring)
                               | "chunked" (scan of partial collectives)
  * ``NC`` (channels)       -> modeled in the simulator (DMA concurrency);
                               on real HW it maps to
                               ``--xla_tpu_scoped_vmem_limit_kib`` style
                               staging limits, which have no HLO footprint.

Every function has a dense reference (``*_ref``) used by the tests, and the
explicit variants are HLO-visible: the dry-run roofline counts their
collective-permute / reduce-scatter bytes, so tuned chunk counts actually
move the measured collective term.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

try:                                  # jax >= 0.5 exports it at top level
    from jax import shard_map
except ImportError:                   # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map


def axis_size(axis: str) -> int:
    """Concrete mesh-axis size inside a shard_map body (``lax.axis_size`` on
    new jax; on older jax ``psum(1, axis)`` folds to a static int)."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis)
    return lax.psum(1, axis)


@dataclass(frozen=True)
class CollectiveRuntime:
    """Runtime knobs for one collective site (what `core.apply` emits)."""
    strategy: str = "xla"        # xla | ring | chunked
    num_chunks: int = 1


# Process-wide active runtime plan: per-site-class knobs (what a saved
# ``session.TunedPlan`` lowers to).  Launchers install it via
# ``core.apply.activate`` (the ``--tuned-plan`` flag); the chunked
# collectives below consume it whenever a call site leaves ``num_chunks``
# unset (``None``), so an installed plan changes the emitted collective
# structure without hand-plumbed chunk counts.
_ACTIVE_PLAN: dict = {}

_DEFAULT_RUNTIME = CollectiveRuntime()


def set_runtime_plan(plan: dict) -> None:
    """Install ``{site_class: CollectiveRuntime}`` as the active plan
    (replacing any previous one; empty dict clears it)."""
    global _ACTIVE_PLAN
    _ACTIVE_PLAN = dict(plan)


def active_runtime_plan() -> dict:
    return dict(_ACTIVE_PLAN)


def runtime_for(site: str) -> CollectiveRuntime:
    """The active knobs for a collective site class (``"ag"``, ``"rs"``,
    ``"ar"``, ``"a2a"``, ``"p2p"``); XLA defaults when no plan is active."""
    return _ACTIVE_PLAN.get(site, _DEFAULT_RUNTIME)


def _resolve_chunks(num_chunks, site: str) -> int:
    """Explicit ``num_chunks`` wins; ``None`` defers to the active plan."""
    return runtime_for(site).num_chunks if num_chunks is None else num_chunks


# ---------------------------------------------------------------------------
# all-gather ∘ matmul  (column-parallel matmul with sequence-sharded input)
#   x: (..., T, D) sharded on T over `axis`;  w: (D, F) sharded on F
#   y = allgather_T(x) @ w   -> (..., n*Tl, F_local)
# ---------------------------------------------------------------------------

def ag_matmul_ref(x, w):
    return x @ w


def _ring_ag_matmul_local(x, w, *, axis: str, num_chunks: int):
    """Per-device body: hold one sequence shard, rotate shards around the
    ring; each step multiplies the currently-held shard so communication of
    the next shard overlaps with this step's matmul."""
    n = axis_size(axis)
    idx = lax.axis_index(axis)
    Tl = x.shape[-2]
    out_shape = x.shape[:-2] + (n * Tl, w.shape[-1])
    perm = [(j, (j - 1) % n) for j in range(n)]

    def chunked_mm(xs):
        if num_chunks <= 1 or Tl % num_chunks:
            return xs @ w
        blocks = jnp.stack(jnp.split(xs, num_chunks, axis=-2))
        ys = lax.map(lambda b: b @ w, blocks)
        return jnp.concatenate(list(ys), axis=-2)

    def body(i, carry):
        x_cur, out = carry
        src = (idx + i) % n                 # whose shard we currently hold
        y = chunked_mm(x_cur)
        out = lax.dynamic_update_slice_in_dim(out, y, src * Tl, axis=-2)
        x_cur = lax.ppermute(x_cur, axis, perm)
        return (x_cur, out)

    out = jnp.zeros(out_shape, x.dtype)
    try:  # newer jax: align varying-manual-axes type with the inputs
        vma = tuple(set(jax.typeof(x).vma) | set(jax.typeof(w).vma))
        out = lax.pvary(out, vma)
    except AttributeError:
        pass
    _, out = lax.fori_loop(0, n, body, (x, out))
    return out


def ring_ag_matmul(x, w, mesh: Mesh, *, axis: str = "model",
                   x_spec: P, w_spec: P, out_spec: P,
                   num_chunks: int | None = None):
    num_chunks = _resolve_chunks(num_chunks, "ag")
    fn = shard_map(partial(_ring_ag_matmul_local, axis=axis, num_chunks=num_chunks),
                   mesh=mesh, in_specs=(x_spec, w_spec), out_specs=out_spec)
    return fn(x, w)


# ---------------------------------------------------------------------------
# matmul ∘ reduce-scatter  (row-parallel matmul)
#   x: (..., T, Fl) F-sharded over `axis`; w: (Fl, D)
#   y = reduce_scatter_T( x @ w )  -> (..., T/n, D)
# ---------------------------------------------------------------------------

def mm_rs_ref(x, w):
    return x @ w


def _mm_rs_local(x, w, *, axis: str, num_chunks: int):
    n = axis_size(axis)
    T = x.shape[-2]
    if num_chunks <= 1 or T % (num_chunks * n):
        y = x @ w
        return lax.psum_scatter(y, axis, scatter_dimension=y.ndim - 2, tiled=True)
    # tile-aligned chunking: chunk i must contain rows {j·T/n + i·s ... } for
    # every destination shard j so the concatenated per-chunk scatters equal
    # the single full scatter.
    s = T // (n * num_chunks)
    lead = x.shape[:-2]
    xr = x.reshape(lead + (n, num_chunks, s, x.shape[-1]))
    blocks = jnp.moveaxis(xr, -3, 0)                     # (nc, ..., n, s, F)
    blocks = blocks.reshape((num_chunks,) + lead + (n * s, x.shape[-1]))

    def one(b):
        y = b @ w
        return lax.psum_scatter(y, axis, scatter_dimension=y.ndim - 2, tiled=True)

    ys = lax.map(one, blocks)        # chunked: scatter of chunk i overlaps mm of i+1
    return jnp.concatenate(list(ys), axis=-2)


def mm_reduce_scatter(x, w, mesh: Mesh, *, axis: str = "model",
                      x_spec: P, w_spec: P, out_spec: P,
                      num_chunks: int | None = None):
    num_chunks = _resolve_chunks(num_chunks, "rs")
    fn = shard_map(partial(_mm_rs_local, axis=axis, num_chunks=num_chunks),
                   mesh=mesh, in_specs=(x_spec, w_spec), out_specs=out_spec)
    return fn(x, w)


# ---------------------------------------------------------------------------
# chunked all-to-all (MoE dispatch/combine)
#   x: (..., E, capl, D) with E sharded over `axis` on entry or exit
# ---------------------------------------------------------------------------

def chunked_all_to_all(x, mesh: Mesh, *, axis: str = "model",
                       split_axis: int, concat_axis: int,
                       x_spec: P, out_spec: P, num_chunks: int | None = None):
    """lax.all_to_all decomposed into ``num_chunks`` sequential a2a's over
    the trailing feature dim, so expert FFN compute on early chunks overlaps
    the transfer of later ones (the EP dual-batch pattern).  ``num_chunks=
    None`` (default) defers to the active tuned plan's ``a2a`` knobs."""
    num_chunks = _resolve_chunks(num_chunks, "a2a")
    def local(xl):
        if num_chunks <= 1 or xl.shape[-1] % num_chunks:
            return lax.all_to_all(xl, axis, split_axis, concat_axis, tiled=True)
        blocks = jnp.stack(jnp.split(xl, num_chunks, axis=-1))
        ys = lax.map(lambda b: lax.all_to_all(b, axis, split_axis, concat_axis,
                                              tiled=True), blocks)
        return jnp.concatenate(list(ys), axis=-1)

    fn = shard_map(local, mesh=mesh, in_specs=(x_spec,), out_specs=out_spec)
    return fn(x)


# ---------------------------------------------------------------------------
# plain helpers used by the trainer (gradient sync in explicit-DP mode)
# ---------------------------------------------------------------------------

def psum_tree(tree, axis: str):
    return jax.tree.map(lambda a: lax.psum(a, axis), tree)
