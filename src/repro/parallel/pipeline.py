"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

Each device holds one stage's parameters; microbatches flow through the
ring via ``lax.ppermute`` (TPU: neighbor ICI transfers).  Fill+drain
schedule: S + M − 1 ticks for S stages × M microbatches.  The inter-stage
permutes are exactly the "permute" CommOps the Lagom tuner prices
(core.extract kind="pp"), overlapping each tick's transfer with the next
tick's stage compute.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.collectives import (_warn_unchunked, axis_size,
                                        runtime_for, shard_map)


def _chunked_ppermute(x, axis: str, perm, *, num_chunks: int, site: str):
    """Inter-stage activation transfer, optionally decomposed into
    ``num_chunks`` feature-dim ppermutes (tuned ``p2p`` knobs) so the next
    tick's compute can start on early chunks."""
    if num_chunks <= 1 or x.shape[-1] % num_chunks:
        if num_chunks > 1:
            _warn_unchunked(site, num_chunks,
                            f"the trailing activation dim ({x.shape[-1]})")
        return lax.ppermute(x, axis, perm)
    blocks = jnp.stack(jnp.split(x, num_chunks, axis=-1))
    ys = lax.map(lambda b: lax.ppermute(b, axis, perm), blocks)
    return jnp.concatenate(list(ys), axis=-1)


def _pipeline_local(params, x_mb, *, fn: Callable, axis: str, microbatches: int,
                    num_chunks: int = 1, site: str = "p2p"):
    """Per-device body.  params: this stage's params (leading stage dim of 1
    squeezed by shard_map).  x_mb: (M, mb, ...) microbatched input
    (replicated).  Returns (M, mb, ...) outputs (only the last stage's
    contribution is non-zero; caller psums over the stage axis)."""
    n = axis_size(axis)
    stage = lax.axis_index(axis)
    M = microbatches
    params = jax.tree.map(lambda a: a[0], params)       # drop stage dim

    fwd = [(i, (i + 1) % n) for i in range(n)]          # stage i -> i+1

    def tick(t, carry):
        buf, ys = carry                                  # buf: (mb, ...) current input
        # stage 0 ingests microbatch t (when t < M); others use the permuted buf
        mb_idx = jnp.clip(t, 0, M - 1)
        inp = jnp.where(stage == 0,
                        x_mb[mb_idx].astype(buf.dtype), buf)
        out = fn(params, inp)
        # last stage emits microbatch t-(n-1) when valid
        emit_idx = jnp.clip(t - (n - 1), 0, M - 1)
        valid = (stage == n - 1) & (t >= n - 1) & (t - (n - 1) < M)
        ys = lax.dynamic_update_slice_in_dim(
            ys,
            jnp.where(valid, out, ys[emit_idx])[None],
            emit_idx, axis=0)
        buf = _chunked_ppermute(out, axis, fwd, num_chunks=num_chunks,
                                site=site)
        return (buf, ys)

    mb_shape = x_mb.shape[1:]
    buf0 = jnp.zeros(mb_shape, x_mb.dtype)
    out_shape = jax.eval_shape(fn, params, jax.ShapeDtypeStruct(mb_shape, x_mb.dtype))
    ys0 = jnp.zeros((M,) + out_shape.shape, out_shape.dtype)
    try:   # buffers become stage-varying inside the loop (params vary)
        buf0 = lax.pvary(buf0, (axis,))
        ys0 = lax.pvary(ys0, (axis,))
    except AttributeError:
        pass
    _, ys = lax.fori_loop(0, n + M - 1, tick, (buf0, ys0))
    # only the last stage's ys are real; zero elsewhere then psum outside
    ys = jnp.where(stage == n - 1, ys, jnp.zeros_like(ys))
    return lax.psum(ys, axis)


def pipeline_apply(fn: Callable, stage_params, x, *, mesh: Mesh,
                   axis: str = "stage", microbatches: int,
                   site: Optional[str] = None):
    """Run ``fn(stage_params_i, x)`` through an S-stage pipeline.

    stage_params: pytree with a leading stage dim (sharded over ``axis``).
    x: (M·mb, ...) global batch; reshaped to M microbatches.
    Returns (M·mb, ...) outputs, equivalent to applying the stages
    sequentially.  ``site`` addresses the inter-stage transfers in the
    active tuned plan (default the ``p2p`` site class): tuned chunk counts
    decompose each tick's ppermute into partial feature-dim transfers.
    """
    M = microbatches
    B = x.shape[0]
    assert B % M == 0
    x_mb = x.reshape((M, B // M) + x.shape[1:])
    p_specs = jax.tree.map(lambda a: P(axis, *([None] * (a.ndim - 1))),
                           stage_params)
    site = site or "p2p"
    rt = runtime_for(site, "p2p")
    local = partial(_pipeline_local, fn=fn, axis=axis, microbatches=M,
                    num_chunks=rt.num_chunks, site=site)
    out = shard_map(local, mesh=mesh,
                    in_specs=(p_specs, P()), out_specs=P())(stage_params, x_mb)
    return out.reshape((B,) + out.shape[2:])
