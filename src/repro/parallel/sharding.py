"""Sharding rules: parameter / batch / cache PartitionSpecs per architecture.

Scheme (the paper-faithful baseline; §Perf iterates on it):
  * 2-D FSDP × TP: every matrix shards its "feature-parallel" dim over the
    ``model`` axis (attention heads, FFN hidden, experts, vocab) and the
    other dim over the FSDP axes (``data``, plus ``pod`` when multi-pod).
  * MoE expert weights shard the expert dim over ``model`` (expert
    parallelism); non-divisible expert counts are padded (qwen2-moe 60→64).
  * 1-D params (norm scales, biases of FSDP'd outputs) are replicated.
  * Batch shards over (pod, data).  When the batch is too small
    (long_500k: B=1) decode caches shard their *sequence* dim over ``data``
    instead (GSPMD context parallelism).

Rules are path-regex → spec template; templates use placeholders
  F = fsdp axes, T = "model", E = expert dim over "model".
A rule's spec matches the *trailing* dims of the array; extra leading dims
(stacked layers / groups) are replicated (None).
"""
from __future__ import annotations

import re
from typing import Any, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

# (path regex, spec template applied to trailing dims)
# Templates: "F"->fsdp, "T"->model, None->replicated.
_RULES: Sequence[Tuple[str, Tuple[Any, ...]]] = (
    # embeddings / head
    (r"embed/table$",              ("T", "F")),
    (r"head/w$",                   ("F", "T")),
    (r"dec_pos$",                  ("F", None)),
    (r"enc_pos$",                  (None, None)),
    # attention (gqa)
    (r"attn/[qkv]/w$",             ("F", "T")),
    (r"attn/[qkv]/b$",             ("T",)),
    (r"attn/o/w$",                 ("T", "F")),
    (r"attn/o/b$",                 (None,)),
    (r"(self|cross)_attn/[qkv]/w$", ("F", "T")),
    (r"(self|cross)_attn/[qkv]/b$", ("T",)),
    (r"(self|cross)_attn/o/w$",    ("T", "F")),
    (r"(self|cross)_attn/o/b$",    (None,)),
    # attention (mla)
    (r"attn/q/w$",                 ("F", "T")),
    (r"attn/q_a/w$",               ("F", None)),
    (r"attn/q_b/w$",               (None, "T")),
    (r"attn/kv_a/w$",              ("F", None)),
    (r"attn/kv_b/w$",              (None, "T")),
    # mlps
    (r"(mlp|shared)/(gate|up)/w$", ("F", "T")),
    (r"(mlp|shared)/(gate|up)/b$", ("T",)),
    (r"(mlp|shared)/down/w$",      ("T", "F")),
    (r"(mlp|shared)/down/b$",      (None,)),
    # moe
    (r"moe/router/w$",             ("F", None)),
    (r"moe/(gate|up)$",            ("T", "F", None)),
    (r"moe/down$",                 ("T", None, "F")),
    (r"moe/shared_gate/w$",        (None, None)),
    # rwkv6 time-mix / channel-mix
    (r"tm/W[rkvg]$",               ("F", "T")),
    (r"tm/Wo$",                    ("T", "F")),
    (r"tm/maa_w1$",                ("F", None)),
    (r"tm/decay_w1$",              ("F", None)),
    (r"tm/decay_w2$",              (None, "F")),
    (r"tm/bonus$",                 ("T", None)),
    (r"cm/Wk$",                    ("F", "T")),
    (r"cm/Wv$",                    ("T", "F")),
    (r"cm/Wr$",                    ("F", "T")),
    # mamba2
    (r"mamba/(z_proj|xbc_proj)/w$", ("F", "T")),
    (r"mamba/dt_proj/w$",          ("F", None)),
    (r"mamba/out_proj/w$",         ("T", "F")),
    (r"mamba/conv_w$",             (None, "T")),
    (r"mamba/conv_b$",             ("T",)),
    # zamba2 per-application adapters
    (r"app_in/w$",                 ("F", "T")),
)


def _expand(template, fsdp, tp):
    out = []
    for t in template:
        if t == "F":
            out.append(fsdp if len(fsdp) > 1 else fsdp[0])
        elif t == "T":
            out.append(tp)
        else:
            out.append(None)
    return tuple(out)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return "/".join(parts)


def _divisible(shape, spec, mesh_shape) -> bool:
    for dim, ax in zip(shape, spec):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = int(np.prod([mesh_shape[a] for a in axes]))
        if dim % n != 0:
            return False
    return True


def param_specs(params_tree, mesh, *, fsdp_axes: Tuple[str, ...] = ("data",),
                tp_axis: "str | None" = "model"):
    """PartitionSpec pytree for a params (or shape) pytree."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(path, leaf):
        pstr = _path_str(path)
        shape = leaf.shape
        for rx, template in _RULES:
            if re.search(rx, pstr):
                spec = _expand(template, fsdp_axes, tp_axis)
                lead = len(shape) - len(spec)
                if lead < 0:
                    break
                full = (None,) * lead + spec
                # drop axes that don't divide evenly (fall back per-dim)
                full = tuple(ax if ax is not None and shape[i] % int(np.prod(
                    [mesh_shape[a] for a in (ax if isinstance(ax, tuple) else (ax,))])) == 0
                    else None for i, ax in enumerate(full))
                return P(*full)
        return P()  # replicate (norms, scalars, loras)

    return jax.tree_util.tree_map_with_path(one, params_tree)


def batch_specs(cfg, batch_tree, mesh, *, dp_axes: Tuple[str, ...] = ("data",)):
    """Batch dim over the data-parallel axes when divisible, else replicate."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = int(np.prod([mesh_shape[a] for a in dp_axes]))
    dp_spec = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def one(path, leaf):
        if leaf is None:
            return None
        B = leaf.shape[0] if leaf.ndim else 0
        lead = dp_spec if B and B % dp == 0 else None
        return P(lead, *([None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(one, batch_tree, is_leaf=lambda x: x is None)


def cache_specs(cfg, caches_tree, mesh, *, dp_axes: Tuple[str, ...] = ("data",),
                tp_axis: "str | None" = "model"):
    """Decode-cache sharding.  Layout per leaf (after any stacked leading
    dims): KV caches (B, S, N, h) — batch over data when divisible else
    sequence over data; heads over model.  States (B, H, K, V) — heads over
    model.  Conv/shift small leaves: batch over data if divisible."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = int(np.prod([mesh_shape[a] for a in dp_axes]))
    tp = mesh_shape[tp_axis] if tp_axis else 10**9   # None -> never divides
    dp_spec = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def one(path, leaf):
        pstr = _path_str(path)
        shape = leaf.shape
        nd = leaf.ndim
        if nd == 0 or pstr.endswith("pos") or "slot_pos" in pstr:
            return P()
        spec = [None] * nd
        # find the batch dim: first dim that is not a stacked-layer dim.
        # caches are built with stacked leading dims; identify the batch dim
        # as the dim whose size matches known batch... heuristic: use the
        # last 3-4 dims by leaf kind.
        if re.search(r"(^|/)(k|v|c_kv|k_rope)$", pstr):
            # (..., B, S, N, h) or (..., B, S, rank)
            b_ax = nd - (4 if pstr.endswith(("k", "v", "k_rope")) else 3)
            s_ax = b_ax + 1
            if shape[b_ax] % dp == 0:
                spec[b_ax] = dp_spec
            elif shape[s_ax] % dp == 0:
                spec[s_ax] = dp_spec           # context parallelism (B too small)
            if pstr.endswith(("k", "v")) and shape[nd - 2] % tp == 0:
                spec[nd - 2] = tp_axis          # kv heads over model
            elif spec[s_ax] is None and shape[s_ax] % tp == 0:
                spec[s_ax] = tp_axis            # kv heads don't divide tp:
                                                # shard the sequence instead
            elif not pstr.endswith(("k", "v")) and shape[nd - 1] % tp == 0:
                spec[nd - 1] = tp_axis          # MLA latent rank over model
        elif re.search(r"(wkv|state)$", pstr):
            # (..., B, H, K/P, V/N)
            b_ax = nd - 4
            if shape[b_ax] % dp == 0:
                spec[b_ax] = dp_spec
            if shape[nd - 3] % tp == 0:
                spec[nd - 3] = tp_axis
        elif re.search(r"(shift_tm|shift_cm|conv|memory)$", pstr):
            b_ax = max(0, nd - 3)
            if shape[b_ax] % dp == 0:
                spec[b_ax] = dp_spec
            if shape[nd - 1] % tp == 0 and pstr.endswith("conv"):
                spec[nd - 1] = tp_axis
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, caches_tree)
