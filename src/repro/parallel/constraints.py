"""Activation sharding constraints.

GSPMD propagates input shardings, but propagation through scans, gathers
and reshapes is best-effort — production frameworks pin activations at
layer boundaries.  The launcher installs the mesh axes via ``use_axes``;
when no context is installed every helper is a no-op (single-device smoke
tests never see a mesh).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Tuple

import jax
from jax import lax
from jax.sharding import PartitionSpec as P

_AXES: contextvars.ContextVar = contextvars.ContextVar("repro_mesh_axes",
                                                        default=None)


@contextlib.contextmanager
def use_axes(dp_axes: Tuple[str, ...], tp_axis: str, *, seq_shard: bool = False,
             tp_size: int = 16):
    """``seq_shard=True`` = sequence parallelism: (B,S,D) activations are
    additionally sharded over the model axis on S at layer boundaries, so
    per-layer saved residuals shrink by the TP degree (required for
    d_model≥8k training shapes; GSPMD inserts the AG/RS around attention)."""
    token = _AXES.set({"dp": tuple(dp_axes), "tp": tp_axis,
                       "seq_shard": seq_shard, "tp_size": tp_size})
    try:
        yield
    finally:
        _AXES.reset(token)


def axes():
    return _AXES.get()


def _dp(a):
    dp = a["dp"]
    return dp if len(dp) > 1 else dp[0]


def _constrain(x, spec: P):
    try:
        return lax.with_sharding_constraint(x, spec)
    except Exception:      # no ambient mesh (eager smoke test) — no-op
        return x


def btd(x):
    """(B, S, D) activations: batch over data axes (+ seq over model when
    sequence parallelism is on)."""
    a = axes()
    if a is None or x.ndim != 3:
        return x
    s_ax = (a["tp"] if a.get("seq_shard")
            and x.shape[1] % a.get("tp_size", 16) == 0 else None)
    return _constrain(x, P(_dp(a), s_ax, None))


def btf(x):
    """(B, S, F) ff activations: batch over data, features over model."""
    a = axes()
    if a is None or x.ndim != 3:
        return x
    return _constrain(x, P(_dp(a), None, a["tp"]))


def ecd(x):
    """(E, cap, D) MoE expert buffers: experts over model (the EP a2a) and
    capacity slots over the data axes (tokens arrive data-sharded, so this
    keeps the buffer footprint per chip constant as TP degree shrinks)."""
    a = axes()
    if a is None or x.ndim != 3:
        return x
    return _constrain(x, P(a["tp"], _dp(a), None))


def logits(x):
    """(B, c, V) loss logits chunk: batch over data, vocab over model."""
    a = axes()
    if a is None or x.ndim != 3:
        return x
    return _constrain(x, P(_dp(a), None, a["tp"]))
