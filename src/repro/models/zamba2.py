"""Zamba2 hybrid trunk — Mamba2 layers with a *shared* transformer block
(attention + MLP, one set of weights) applied every ``shared_attn_every``
layers [arXiv:2411.15242].

Faithful structure: the shared block consumes concat(hidden, original
embedding) (2·d_model) through a *per-application* input projection
(Zamba2's per-invocation LoRA adapters, here full-rank for simplicity —
documented in DESIGN.md), runs the shared attention+MLP at d_model, and is
added back to the residual stream.

Scan layout: the trunk is reshaped into ``n_groups`` groups of
``every`` mamba layers + one shared-block application, plus a tail of
remaining mamba layers — so the compiled graph is two nested scans.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models import mamba2
from repro.parallel import constraints as CT

Params = Dict[str, Any]


def _split(cfg) -> Tuple[int, int, int]:
    every = cfg.shared_attn_every
    n_groups = cfg.num_layers // every
    tail = cfg.num_layers - n_groups * every
    return every, n_groups, tail


def init_mamba_layer(key, cfg, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    return {"ln": L.init_norm(cfg.d_model, "rmsnorm", dtype),
            "mamba": mamba2.init_block(k2, cfg, dtype)}


def mamba_layer_fwd(p, cfg, x, cache, backend=None):
    x = CT.btd(x)
    h, nc = mamba2.block_fwd(p["mamba"], cfg, L.norm(p["ln"], x, "rmsnorm"),
                             cache, backend)
    return x + h, nc


def init_trunk(key, cfg, dtype=jnp.float32) -> Params:
    every, n_groups, tail = _split(cfg)
    D = cfg.d_model
    keys = jax.random.split(key, cfg.num_layers + n_groups + 3)
    lk = keys[:cfg.num_layers]
    init_m = partial(init_mamba_layer, cfg=cfg, dtype=dtype)
    p: Params = {}
    if n_groups:
        grouped = jax.vmap(jax.vmap(init_m))(
            lk[:n_groups * every].reshape(n_groups, every, 2))
        p["groups"] = grouped
        # per-application input projections (2D -> D)
        p["app_in"] = jax.vmap(lambda k_: L.init_linear(k_, 2 * D, D, dtype=dtype))(
            keys[cfg.num_layers:cfg.num_layers + n_groups])
        # shared transformer block (single weight set)
        p["shared"] = {
            "ln1": L.init_norm(D, "rmsnorm", dtype),
            "attn": L.init_attention(keys[-3], cfg, dtype=dtype),
            "ln2": L.init_norm(D, "rmsnorm", dtype),
            "mlp": L.init_mlp(keys[-2], D, cfg.d_ff, "swiglu", dtype),
        }
    if tail:
        p["tail"] = jax.vmap(init_m)(lk[n_groups * every:])
    return p


def _shared_block_fwd(shared: Params, app_in: Params, cfg, x, x0, positions, cache):
    x = CT.btd(x)
    h = L.linear(app_in, jnp.concatenate([x, x0], axis=-1))
    a = L.norm(shared["ln1"], h, "rmsnorm")
    attn_out, new_cache = L.attention(shared["attn"], cfg, a, positions, cache=cache)
    h = h + attn_out
    h = h + L.mlp(shared["mlp"], L.norm(shared["ln2"], h, "rmsnorm"), "swiglu")
    return x + h, new_cache


def trunk_fwd(p: Params, cfg, x, positions, caches=None, *,
              remat: bool = False, backend: Optional[str] = None):
    """caches: {"groups": stacked (G, every, ...), "attn": stacked (G, ...),
    "tail": stacked (tail, ...)} or None."""
    every, n_groups, tail = _split(cfg)
    x0 = x  # original embeddings, consumed by every shared-block application
    new_caches: Dict[str, Any] = {}

    def mamba_scan(x, stacked, stacked_cache):
        def fn(x, xs):
            if stacked_cache is None:
                def f(q, v):
                    return mamba_layer_fwd(q, cfg, v, None, backend)

                if remat:
                    f = jax.checkpoint(f)
                x2, _ = f(xs, x)
                return x2, None
            lp, lc = xs
            x2, nc = mamba_layer_fwd(lp, cfg, x, lc, backend)
            return x2, nc
        xs = stacked if stacked_cache is None else (stacked, stacked_cache)
        return lax.scan(fn, x, xs)

    if n_groups:
        def group_fn(x, xs):
            if caches is None:
                gp, ap = xs
                x, _ = mamba_scan(x, gp, None)
                x, _ = _shared_block_fwd(p["shared"], ap, cfg, x, x0, positions, None)
                return x, None
            gp, ap, gc, ac = xs
            x, ncm = mamba_scan(x, gp, gc)
            x, nca = _shared_block_fwd(p["shared"], ap, cfg, x, x0, positions, ac)
            return x, (ncm, nca)

        if caches is None:
            x, _ = lax.scan(group_fn, x, (p["groups"], p["app_in"]))
        else:
            x, (ncm, nca) = lax.scan(
                group_fn, x, (p["groups"], p["app_in"], caches["groups"], caches["attn"]))
            new_caches["groups"], new_caches["attn"] = ncm, nca

    if tail:
        x, nct = mamba_scan(x, p["tail"], caches["tail"] if caches else None)
        if caches is not None:
            new_caches["tail"] = nct

    return x, (new_caches or None), jnp.zeros((), jnp.float32)


def init_trunk_caches(cfg, batch: int, seq_len: int, dtype=jnp.float32) -> Params:
    every, n_groups, tail = _split(cfg)
    m = mamba2.init_cache(cfg, batch, dtype)
    caches: Params = {}
    if n_groups:
        caches["groups"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_groups, every) + a.shape).copy(), m)
        caches["attn"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape).copy(),
            L.init_kv_cache(cfg, batch, seq_len, dtype))
    if tail:
        caches["tail"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (tail,) + a.shape).copy(), m)
    return caches
