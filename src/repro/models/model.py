"""Unified model API over every family in the zoo.

    params = init_params(cfg, rng)
    loss, metrics = loss_and_metrics(cfg, params, batch)          # train
    x, caches, aux = forward_hidden(cfg, params, batch)           # prefill
    caches = init_caches(cfg, batch_size, seq_len)                # serving
    logits, caches = decode_step(cfg, params, tokens, caches)     # decode

``batch``: {"tokens": (B,S) i32, "targets": (B,S) i32, "mask": (B,S) f32}
plus "frames" (B,enc_seq,D) for audio and "patches" (B,n_patch,D) for vlm
(frontends are stubs: precomputed embeddings).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import dense, layers as L, rwkv6, whisper, zamba2
from repro.parallel import constraints as CT

Params = Dict[str, Any]

N_PATCHES = 256          # vlm stub: one 16x16 image at the sequence head
_PATCH_GRID = 16

_TRUNKS = {
    "dense": dense, "moe": dense, "vlm": dense,
    "ssm": rwkv6, "hybrid": zamba2, "audio": whisper,
}


def _trunk(cfg):
    return _TRUNKS[cfg.family]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg, rng, *, ep_pad: int = 1, dtype=None) -> Params:
    dtype = jnp.dtype(dtype or cfg.dtype)
    k_emb, k_trunk, k_head, k_pos = jax.random.split(rng, 4)
    p: Params = {"embed": L.init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype)}
    if cfg.family in ("dense", "moe", "vlm"):
        p["trunk"] = dense.init_trunk(k_trunk, cfg, ep_pad=ep_pad, dtype=dtype)
    elif cfg.family == "ssm":
        p["trunk"] = rwkv6.init_trunk(k_trunk, cfg, dtype)
    elif cfg.family == "hybrid":
        p["trunk"] = zamba2.init_trunk(k_trunk, cfg, dtype)
    elif cfg.family == "audio":
        p["trunk"] = whisper.init_trunk(k_trunk, cfg, dtype)
        p["dec_pos"] = (jax.random.normal(k_pos, (cfg.max_seq_len, cfg.d_model),
                                          jnp.float32) * 0.02).astype(dtype)
    else:
        raise ValueError(cfg.family)
    p["ln_f"] = L.init_norm(cfg.d_model, cfg.norm_kind, dtype)
    if not cfg.tie_embeddings:
        p["head"] = L.init_linear(k_head, cfg.d_model, cfg.vocab_size, dtype=dtype)
    return p


# ---------------------------------------------------------------------------
# positions
# ---------------------------------------------------------------------------

def _positions(cfg, batch, B: int, S: int, t0) -> jnp.ndarray:
    """(B,S) int32, or (3,B,S) for M-RoPE."""
    base = t0 + jnp.arange(S, dtype=jnp.int32)
    pos = jnp.broadcast_to(base[None], (B, S))
    if cfg.pos_kind != "mrope":
        return pos
    if batch.get("patches") is None:
        return jnp.broadcast_to(pos[None], (3, B, S))
    # image patches occupy the first N_PATCHES slots at (t=0, h, w) grid
    # positions; text then continues from grid_max + 1 on all three axes.
    n = N_PATCHES
    gh = jnp.arange(n, dtype=jnp.int32) // _PATCH_GRID
    gw = jnp.arange(n, dtype=jnp.int32) % _PATCH_GRID
    text = _PATCH_GRID + jnp.arange(S - n, dtype=jnp.int32)
    pt = jnp.concatenate([jnp.zeros((n,), jnp.int32), text])
    ph = jnp.concatenate([gh, text])
    pw = jnp.concatenate([gw, text])
    grid = jnp.stack([pt, ph, pw])                       # (3,S)
    return jnp.broadcast_to(grid[:, None], (3, B, S)) + t0


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _embed_inputs(cfg, p, batch) -> jnp.ndarray:
    x = L.embed(p["embed"], batch["tokens"])
    if cfg.family == "vlm" and batch.get("patches") is not None:
        n = batch["patches"].shape[1]
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x[:, n:]], axis=1)
    return x


def forward_hidden(cfg, p: Params, batch, caches: Optional[Params] = None, *,
                   remat: bool = False, backend: Optional[str] = None,
                   mesh=None
                   ) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
    """Runs the trunk over batch["tokens"].  If ``caches`` is given, this is a
    cached prefill (states/KV are filled; pass fresh caches).  ``mesh``
    opts dense-family trunks into the plan-aware explicit-collective path
    (``dense.trunk_fwd``); other families ignore it."""
    B, S = batch["tokens"].shape
    t0 = caches["pos"] if caches is not None else jnp.zeros((), jnp.int32)
    positions = _positions(cfg, batch, B, S, t0)
    x = _embed_inputs(cfg, p, batch)

    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "audio":
        memory = whisper.encode(p["trunk"], cfg, batch["frames"].astype(x.dtype))
        x = x + jnp.take(p["dec_pos"], positions, axis=0)
        tc = caches["trunk"] if caches is not None else None
        x, new_tc = whisper.decode_trunk(p["trunk"], cfg, x, memory, positions,
                                         tc, remat=remat)
        new_caches = None if caches is None else {
            "trunk": new_tc, "pos": t0 + S, "memory": memory}
    else:
        kw = dict(remat=remat)
        if cfg.family in ("ssm", "hybrid"):
            kw["backend"] = backend
        tc = caches["trunk"] if caches is not None else None
        if cfg.family == "ssm":
            x, new_tc, aux = rwkv6.trunk_fwd(p["trunk"], cfg, x, positions, tc, **kw)
        elif cfg.family == "hybrid":
            x, new_tc, aux = zamba2.trunk_fwd(p["trunk"], cfg, x, positions, tc, **kw)
        else:
            if mesh is not None:
                kw["mesh"] = mesh
            x, new_tc, aux = dense.trunk_fwd(p["trunk"], cfg, x, positions, tc, **kw)
        new_caches = None if caches is None else {"trunk": new_tc, "pos": t0 + S}

    x = L.norm(p["ln_f"], x, cfg.norm_kind)
    return x, new_caches, aux


def _unembed(cfg, p, x):
    if cfg.tie_embeddings:
        return L.unembed(p["embed"], x)
    return L.linear(p["head"], x)


# ---------------------------------------------------------------------------
# training loss (chunked cross-entropy: the full (B,S,V) logits tensor is
# never materialized — each chunk's logits are recomputed in the backward
# pass via jax.checkpoint)
# ---------------------------------------------------------------------------

def chunked_ce(cfg, p, x, targets, mask, *, chunk: int = 256):
    B, S, D = x.shape
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    n = x.shape[1] // chunk
    xc = jnp.moveaxis(x.reshape(B, n, chunk, D), 1, 0)
    tc = jnp.moveaxis(targets.reshape(B, n, chunk), 1, 0)
    mc = jnp.moveaxis(mask.reshape(B, n, chunk), 1, 0)

    @jax.checkpoint
    def body(tot, xs):
        xb, tb, mb = xs
        logits = CT.logits(_unembed(cfg, p, CT.btd(xb)).astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, tb[..., None], axis=-1)[..., 0]
        return tot + (((lse - tgt) * mb).sum()), None

    tot, _ = lax.scan(body, jnp.zeros((), jnp.float32), (xc, tc, mc))
    return tot / jnp.maximum(mask.sum(), 1.0)


def loss_and_metrics(cfg, p: Params, batch, *, remat: bool = True,
                     backend: Optional[str] = None, mesh=None):
    x, _, aux = forward_hidden(cfg, p, batch, remat=remat, backend=backend,
                               mesh=mesh)
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones(batch["targets"].shape, jnp.float32)
    if cfg.family == "vlm" and batch.get("patches") is not None:
        # patch positions carry no next-token target
        n = batch["patches"].shape[1]
        mask = mask.at[:, :n].set(0.0)
    ce = chunked_ce(cfg, p, x, batch["targets"], mask)
    loss = ce + cfg.router_aux_coef * aux
    return loss, {"ce": ce, "aux": aux, "loss": loss}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_caches(cfg, batch: int, seq_len: int, dtype=None) -> Params:
    dtype = jnp.dtype(dtype or cfg.dtype)
    t = _trunk(cfg)
    caches: Params = {"trunk": t.init_trunk_caches(cfg, batch, seq_len, dtype),
                      "pos": jnp.zeros((), jnp.int32)}
    if cfg.family == "audio":
        caches["memory"] = jnp.zeros((batch, cfg.encoder_seq, cfg.d_model), dtype)
    return caches


def decode_step(cfg, p: Params, tokens: jnp.ndarray, caches: Params, *,
                backend: Optional[str] = None, mesh=None,
                pos_offset: Optional[jnp.ndarray] = None,
                ) -> Tuple[jnp.ndarray, Params]:
    """One token per sequence: tokens (B,1) -> logits (B,1,vocab).

    ``mesh`` opts dense-family trunks into the plan-aware sited decode
    path (explicit collectives at ``serve.layer{i}.*`` SiteIds, resolved
    against the active tuned plan; other families ignore it).
    ``pos_offset`` (B,) int32 subtracts a per-sequence gap from the shared
    position counter — how the fixed-batch engine keeps right-padded
    ragged prompts on their true positions (the pad gap sits between
    prefill and decode slots, which the per-row ``slot_pos`` mask already
    excludes)."""
    B = tokens.shape[0]
    t0 = caches["pos"]
    positions = _positions(cfg, {"tokens": tokens}, B, 1, t0)
    if pos_offset is not None:
        off = jnp.asarray(pos_offset, jnp.int32)
        positions = positions - (off[None, :, None] if positions.ndim == 3
                                 else off[:, None])
    x = L.embed(p["embed"], tokens)

    if cfg.family == "audio":
        x = x + jnp.take(p["dec_pos"], positions, axis=0)
        x, new_tc = whisper.decode_trunk(p["trunk"], cfg, x, caches["memory"],
                                         positions, caches["trunk"])
        new_caches = {"trunk": new_tc, "pos": t0 + 1, "memory": caches["memory"]}
    else:
        kw: Dict[str, Any] = {}
        if cfg.family in ("ssm", "hybrid"):
            kw["backend"] = backend
        if cfg.family == "ssm":
            x, new_tc, _ = rwkv6.trunk_fwd(p["trunk"], cfg, x, positions, caches["trunk"], **kw)
        elif cfg.family == "hybrid":
            x, new_tc, _ = zamba2.trunk_fwd(p["trunk"], cfg, x, positions, caches["trunk"], **kw)
        else:
            if mesh is not None:
                kw["mesh"] = mesh
            x, new_tc, _ = dense.trunk_fwd(p["trunk"], cfg, x, positions, caches["trunk"], **kw)
        new_caches = {"trunk": new_tc, "pos": t0 + 1}

    x = L.norm(p["ln_f"], x, cfg.norm_kind)
    return _unembed(cfg, p, x), new_caches
