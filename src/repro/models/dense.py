"""Generic decoder trunk: dense GQA / SWA / MLA attention + SwiGLU/GELU or
MoE feed-forward.  Covers the dense, moe and vlm families (and is reused as
the transformer block by whisper and zamba2).

Layers are *stacked* (leading L axis) and executed with ``lax.scan`` so the
compiled graph contains one layer body regardless of depth — essential to
keep the 512-device GSPMD dry-run compiles tractable.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.parallel import constraints as CT

Params = Dict[str, Any]


def init_layer(key, cfg, *, use_moe: bool, ep_pad: int = 1, dtype=jnp.float32) -> Params:
    k_attn, k_mlp = jax.random.split(key)
    p: Params = {"ln1": L.init_norm(cfg.d_model, cfg.norm_kind, dtype)}
    if cfg.attn_kind == "mla":
        p["attn"] = L.init_mla(k_attn, cfg, dtype)
    else:
        p["attn"] = L.init_attention(k_attn, cfg, dtype=dtype)
    if not cfg.parallel_block:
        p["ln2"] = L.init_norm(cfg.d_model, cfg.norm_kind, dtype)
    if use_moe:
        p["moe"] = L.init_moe(k_mlp, cfg, ep_pad=ep_pad, dtype=dtype)
    else:
        p["mlp"] = L.init_mlp(k_mlp, cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype)
    return p


def layer_fwd(p: Params, cfg, x: jnp.ndarray, positions, cache: Optional[Params],
              *, use_moe: bool) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
    x = CT.btd(x)
    h = L.norm(p["ln1"], x, cfg.norm_kind)
    if cfg.attn_kind == "mla":
        attn_out, new_cache = L.mla_attention(p["attn"], cfg, h, positions, cache=cache)
    else:
        attn_out, new_cache = L.attention(p["attn"], cfg, h, positions, cache=cache)

    aux = jnp.zeros((), jnp.float32)
    if cfg.parallel_block:           # phi-2 style: mlp reads the same norm
        x = x + attn_out + L.mlp(p["mlp"], h, cfg.mlp_kind)
    else:
        x = x + attn_out
        h2 = L.norm(p["ln2"], x, cfg.norm_kind)
        if use_moe:
            ff, aux = L.moe_block(p["moe"], cfg, h2)
        else:
            ff = L.mlp(p["mlp"], h2, cfg.mlp_kind)
        x = x + ff
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# stacked trunk
# ---------------------------------------------------------------------------

def _stack_init(init_one, keys):
    return jax.vmap(init_one)(keys)


def init_trunk(key, cfg, *, ep_pad: int = 1, dtype=jnp.float32) -> Params:
    """Two stacked segments: leading dense layers (MoE archs may start dense),
    then the homogeneous tail."""
    n_dense_head = cfg.first_dense_layers if cfg.is_moe else cfg.num_layers
    n_tail = cfg.num_layers - n_dense_head
    keys = jax.random.split(key, cfg.num_layers)
    p: Params = {}
    if n_dense_head:
        p["dense_layers"] = _stack_init(
            partial(init_layer, cfg=cfg, use_moe=False, dtype=dtype), keys[:n_dense_head])
    if n_tail:
        p["moe_layers"] = _stack_init(
            partial(init_layer, cfg=cfg, use_moe=True, ep_pad=ep_pad, dtype=dtype),
            keys[n_dense_head:])
    return p


def _run_segment(stacked: Params, cfg, x, positions, caches, *, use_moe: bool,
                 remat: bool) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
    if caches is None:
        def scan_fn(carry, lp):
            x, aux = carry
            def fn(q, v):
                return layer_fwd(q, cfg, v, positions, None, use_moe=use_moe)

            if remat:
                fn = jax.checkpoint(fn)
            x, _, a = fn(lp, x)
            return (x, aux + a), None
        (x, aux), _ = jax.lax.scan(scan_fn, (x, jnp.zeros((), jnp.float32)), stacked)
        return x, None, aux

    def scan_fn(carry, xs):
        x, aux = carry
        lp, lc = xs
        x, nc, a = layer_fwd(lp, cfg, x, positions, lc, use_moe=use_moe)
        return (x, aux + a), nc
    (x, aux), new_caches = jax.lax.scan(scan_fn, (x, jnp.zeros((), jnp.float32)),
                                        (stacked, caches))
    return x, new_caches, aux


def trunk_fwd(p: Params, cfg, x, positions, caches=None, *, remat: bool = False):
    """caches: None | {"dense_layers": stacked_cache, "moe_layers": stacked_cache}."""
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {}
    for seg, use_moe in (("dense_layers", False), ("moe_layers", True)):
        if seg not in p:
            continue
        seg_cache = caches[seg] if caches is not None else None
        x, nc, aux = _run_segment(p[seg], cfg, x, positions, seg_cache,
                                  use_moe=use_moe, remat=remat)
        if nc is not None:
            new_caches[seg] = nc
        aux_total = aux_total + aux
    return x, (new_caches or None), aux_total


def init_trunk_caches(cfg, batch: int, seq_len: int, dtype=jnp.float32) -> Params:
    """Stacked per-segment decode caches (leading L axis, matching scan xs)."""
    def one(cfg):
        if cfg.attn_kind == "mla":
            return L.init_mla_cache(cfg, batch, seq_len, dtype)
        return L.init_kv_cache(cfg, batch, seq_len, dtype)

    n_dense_head = cfg.first_dense_layers if cfg.is_moe else cfg.num_layers
    n_tail = cfg.num_layers - n_dense_head
    caches: Params = {}
    if n_dense_head:
        caches["dense_layers"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_dense_head,) + a.shape).copy(), one(cfg))
    if n_tail:
        caches["moe_layers"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_tail,) + a.shape).copy(), one(cfg))
    return caches
