"""Generic decoder trunk: dense GQA / SWA / MLA attention + SwiGLU/GELU or
MoE feed-forward.  Covers the dense, moe and vlm families (and is reused as
the transformer block by whisper and zamba2).

Layers are *stacked* (leading L axis) and executed with ``lax.scan`` so the
compiled graph contains one layer body regardless of depth — essential to
keep the 512-device GSPMD dry-run compiles tractable.

Plan-aware (sited) path: passing ``mesh=`` to ``trunk_fwd`` unrolls the
stack into per-layer bodies whose feed-forward collectives are the
*explicit* chunked helpers (``ring_ag_matmul`` / ``mm_reduce_scatter`` /
the MoE all-to-alls), each addressed by a stable SiteId
(``tp.layer{i}.mlp``, ``ep.layer{j}.moe``; ``serve.layer{i}.mlp`` /
``serve.layer{i}.moe`` on the cached decode path).  Each site resolves its own
knobs against the active tuned plan (``collectives.runtime_for``), so one
``TunedPlan`` can legitimately drive two layers of the same model to emit
different chunk structure — the per-operator overlap decision flowing into
the emitted program, no hand-plumbed ``num_chunks`` anywhere.
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.parallel import constraints as CT
from repro.parallel.collectives import mm_reduce_scatter, ring_ag_matmul

Params = Dict[str, Any]


def init_layer(key, cfg, *, use_moe: bool, ep_pad: int = 1, dtype=jnp.float32) -> Params:
    k_attn, k_mlp = jax.random.split(key)
    p: Params = {"ln1": L.init_norm(cfg.d_model, cfg.norm_kind, dtype)}
    if cfg.attn_kind == "mla":
        p["attn"] = L.init_mla(k_attn, cfg, dtype)
    else:
        p["attn"] = L.init_attention(k_attn, cfg, dtype=dtype)
    if not cfg.parallel_block:
        p["ln2"] = L.init_norm(cfg.d_model, cfg.norm_kind, dtype)
    if use_moe:
        p["moe"] = L.init_moe(k_mlp, cfg, ep_pad=ep_pad, dtype=dtype)
    else:
        p["mlp"] = L.init_mlp(k_mlp, cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype)
    return p


def tp_mlp(p: Params, x: jnp.ndarray, kind: str, mesh, *, axis: str = "model",
           site: str = "tp.mlp") -> jnp.ndarray:
    """Explicit tensor-parallel MLP: the up projections are ring
    AllGather∘matmul over a sequence-sharded input (site ``{site}.ag``),
    the down projection matmul∘ReduceScatter (site ``{site}.rs``) — each
    site's chunk structure resolved independently against the active tuned
    plan.  Numerically identical to ``layers.mlp``."""
    ag = partial(ring_ag_matmul, mesh=mesh, axis=axis,
                 x_spec=P(None, axis, None), w_spec=P(None, axis),
                 out_spec=P(None, None, axis), site=f"{site}.ag")
    if kind == "swiglu":
        h = jax.nn.silu(ag(x, p["gate"]["w"])) * ag(x, p["up"]["w"])
    else:
        h = ag(x, p["up"]["w"])
        if "b" in p["up"]:
            h = h + p["up"]["b"]
        h = jax.nn.gelu(h)
    y = mm_reduce_scatter(h, p["down"]["w"], mesh, axis=axis,
                          x_spec=P(None, None, axis), w_spec=P(axis, None),
                          out_spec=P(None, axis, None), site=f"{site}.rs")
    if "b" in p["down"]:
        y = y + p["down"]["b"]
    return y


def serve_mlp(p: Params, x: jnp.ndarray, kind: str, mesh, *,
              axis: str = "model", site: str = "serve.mlp") -> jnp.ndarray:
    """Decode-shape plan-aware MLP.  ``tp_mlp`` chunks the sequence axis,
    which is length 1 at decode — so the in-flight batch is re-laid as
    that axis, (B, S, D) -> (1, B·S, D): the tuned chunk counts then
    decompose the collectives over the sequences in flight (serving's
    microbatch).  Position-wise MLP, so this is numerically the identity
    transform."""
    B, S, D = x.shape
    y = tp_mlp(p, x.reshape(1, B * S, D), kind, mesh, axis=axis, site=site)
    return y.reshape(B, S, D)


def layer_fwd(p: Params, cfg, x: jnp.ndarray, positions, cache: Optional[Params],
              *, use_moe: bool, mesh=None, axis: str = "model",
              site: str = "", serve: bool = False,
              ) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
    """One decoder layer.  ``mesh`` switches the feed-forward onto the
    explicit plan-aware collectives, with ``site`` the layer's SiteId
    prefix (``tp.layer{i}.mlp`` / ``ep.layer{j}.moe``, or
    ``serve.layer{i}.*`` when ``serve`` marks the decode-shape layout)."""
    def ff(q, v):
        if mesh is not None and not use_moe:
            if serve:
                return serve_mlp(q, v, cfg.mlp_kind, mesh, axis=axis,
                                 site=site or "serve.mlp")
            return tp_mlp(q, v, cfg.mlp_kind, mesh, axis=axis,
                          site=site or "tp.mlp")
        return L.mlp(q, v, cfg.mlp_kind)

    x = CT.btd(x)
    h = L.norm(p["ln1"], x, cfg.norm_kind)
    if cfg.attn_kind == "mla":
        attn_out, new_cache = L.mla_attention(p["attn"], cfg, h, positions, cache=cache)
    else:
        attn_out, new_cache = L.attention(p["attn"], cfg, h, positions, cache=cache)

    aux = jnp.zeros((), jnp.float32)
    if cfg.parallel_block:           # phi-2 style: mlp reads the same norm
        x = x + attn_out + ff(p["mlp"], h)
    else:
        x = x + attn_out
        h2 = L.norm(p["ln2"], x, cfg.norm_kind)
        if use_moe:
            ff_out, aux = L.moe_block(p["moe"], cfg, h2, mesh=mesh, axis=axis,
                                      site=site or "ep.moe")
        else:
            ff_out = ff(p["mlp"], h2)
        x = x + ff_out
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# stacked trunk
# ---------------------------------------------------------------------------

def _stack_init(init_one, keys):
    return jax.vmap(init_one)(keys)


def init_trunk(key, cfg, *, ep_pad: int = 1, dtype=jnp.float32) -> Params:
    """Two stacked segments: leading dense layers (MoE archs may start dense),
    then the homogeneous tail."""
    n_dense_head = cfg.first_dense_layers if cfg.is_moe else cfg.num_layers
    n_tail = cfg.num_layers - n_dense_head
    keys = jax.random.split(key, cfg.num_layers)
    p: Params = {}
    if n_dense_head:
        p["dense_layers"] = _stack_init(
            partial(init_layer, cfg=cfg, use_moe=False, dtype=dtype), keys[:n_dense_head])
    if n_tail:
        p["moe_layers"] = _stack_init(
            partial(init_layer, cfg=cfg, use_moe=True, ep_pad=ep_pad, dtype=dtype),
            keys[n_dense_head:])
    return p


def _run_segment(stacked: Params, cfg, x, positions, caches, *, use_moe: bool,
                 remat: bool) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
    if caches is None:
        def scan_fn(carry, lp):
            x, aux = carry
            def fn(q, v):
                return layer_fwd(q, cfg, v, positions, None, use_moe=use_moe)

            if remat:
                fn = jax.checkpoint(fn)
            x, _, a = fn(lp, x)
            return (x, aux + a), None
        (x, aux), _ = jax.lax.scan(scan_fn, (x, jnp.zeros((), jnp.float32)), stacked)
        return x, None, aux

    def scan_fn(carry, xs):
        x, aux = carry
        lp, lc = xs
        x, nc, a = layer_fwd(lp, cfg, x, positions, lc, use_moe=use_moe)
        return (x, aux + a), nc
    (x, aux), new_caches = jax.lax.scan(scan_fn, (x, jnp.zeros((), jnp.float32)),
                                        (stacked, caches))
    return x, new_caches, aux


def _sited_applicable(cfg, x, mesh, axis: str) -> Tuple[bool, str]:
    """Shape preconditions of the explicit collective helpers (shard_map
    needs exact divisibility; violations fall back to the scan path)."""
    if axis not in mesh.axis_names:
        return False, f"mesh has no {axis!r} axis"
    n = dict(mesh.shape)[axis]
    if x.shape[1] % n:
        return False, f"sequence length {x.shape[1]} not divisible by {n}"
    if cfg.d_ff and cfg.d_ff % n:
        return False, f"d_ff {cfg.d_ff} not divisible by {n}"
    return True, ""


def _sited_applicable_serve(cfg, x, mesh, axis: str) -> Tuple[bool, str]:
    """Decode-shape variant: ``serve_mlp`` re-lays (B, S, D) as
    (1, B·S, D), so the divisible axis is the whole in-flight token count,
    not the per-sequence length."""
    if axis not in mesh.axis_names:
        return False, f"mesh has no {axis!r} axis"
    n = dict(mesh.shape)[axis]
    if (x.shape[0] * x.shape[1]) % n:
        return False, (f"in-flight tokens {x.shape[0] * x.shape[1]} not "
                       f"divisible by {n}")
    if cfg.d_ff and cfg.d_ff % n:
        return False, f"d_ff {cfg.d_ff} not divisible by {n}"
    return True, ""


def _trunk_fwd_sited(p: Params, cfg, x, positions, mesh, *, axis: str,
                     remat: bool, caches=None):
    """Python-unrolled trunk: one body per layer so every layer's comm
    sites resolve independently against the active plan.  Without caches
    this is the train/prefill path (sites ``tp.layer{i}.mlp`` /
    ``ep.layer{j}.moe``, segment-local MoE indices — PR 5's convention);
    with caches it is the *serving* path, sites ``serve.layer{i}.mlp`` /
    ``serve.layer{i}.moe`` with global layer indices, matching
    ``core.extract.extract_decode_workload``.  Compile cost grows with
    depth, so this path is for tuned deployments, not the 512-device
    dry-run compiles."""
    aux_total = jnp.zeros((), jnp.float32)
    li = 0
    new_caches: Dict[str, Any] = {}
    for seg, use_moe in (("dense_layers", False), ("moe_layers", True)):
        if seg not in p:
            continue
        stacked = p[seg]
        n_seg = jax.tree.leaves(stacked)[0].shape[0]
        seg_cache = caches[seg] if caches is not None else None
        layer_caches = []
        for j in range(n_seg):
            lp = jax.tree.map(lambda a: a[j], stacked)
            if caches is None:
                site = f"ep.layer{j}.moe" if use_moe else f"tp.layer{li}.mlp"
                lc = None
            else:
                kind = "moe" if use_moe else "mlp"
                site = f"serve.layer{li}.{kind}"
                lc = jax.tree.map(lambda a: a[j], seg_cache)

            def fl(q, v, c):
                return layer_fwd(q, cfg, v, positions, c, use_moe=use_moe,
                                 mesh=mesh, axis=axis, site=site,
                                 serve=caches is not None)

            if remat and caches is None:
                fl = jax.checkpoint(fl)
            x, nc, a = fl(lp, x, lc)
            if nc is not None:
                layer_caches.append(nc)
            aux_total = aux_total + a
            li += 1
        if layer_caches:
            # restack to the scan layout (leading L axis) so sited and
            # scan decode caches are interchangeable pytrees
            new_caches[seg] = jax.tree.map(
                lambda *leaves: jnp.stack(leaves), *layer_caches)
    return x, (new_caches or None), aux_total


def trunk_fwd(p: Params, cfg, x, positions, caches=None, *, remat: bool = False,
              mesh=None, tp_axis: str = "model"):
    """caches: None | {"dense_layers": stacked_cache, "moe_layers": stacked_cache}.

    ``mesh``: opt into the plan-aware sited path (explicit per-layer
    collectives addressed as ``tp.layer{i}.mlp`` / ``ep.layer{j}.moe`` for
    train/prefill, ``serve.layer{i}.mlp`` / ``serve.layer{i}.moe`` for
    cached decode/prefill; see module docstring).  Shapes that violate the
    explicit helpers' divisibility fall back to the scan path with a
    ``RuntimeWarning``."""
    if mesh is not None:
        if caches is None:
            ok, why = _sited_applicable(cfg, x, mesh, tp_axis)
        else:
            ok, why = _sited_applicable_serve(cfg, x, mesh, tp_axis)
        if not ok:
            warnings.warn(f"plan-aware trunk disabled: {why}; using the "
                          "GSPMD scan path", RuntimeWarning, stacklevel=2)
        else:
            return _trunk_fwd_sited(p, cfg, x, positions, mesh, axis=tp_axis,
                                    remat=remat, caches=caches)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {}
    for seg, use_moe in (("dense_layers", False), ("moe_layers", True)):
        if seg not in p:
            continue
        seg_cache = caches[seg] if caches is not None else None
        x, nc, aux = _run_segment(p[seg], cfg, x, positions, seg_cache,
                                  use_moe=use_moe, remat=remat)
        if nc is not None:
            new_caches[seg] = nc
        aux_total = aux_total + aux
    return x, (new_caches or None), aux_total


def init_trunk_caches(cfg, batch: int, seq_len: int, dtype=jnp.float32) -> Params:
    """Stacked per-segment decode caches (leading L axis, matching scan xs)."""
    def one(cfg):
        if cfg.attn_kind == "mla":
            return L.init_mla_cache(cfg, batch, seq_len, dtype)
        return L.init_kv_cache(cfg, batch, seq_len, dtype)

    n_dense_head = cfg.first_dense_layers if cfg.is_moe else cfg.num_layers
    n_tail = cfg.num_layers - n_dense_head
    caches: Params = {}
    if n_dense_head:
        caches["dense_layers"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_dense_head,) + a.shape).copy(), one(cfg))
    if n_tail:
        caches["moe_layers"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_tail,) + a.shape).copy(), one(cfg))
    return caches
