"""Shared neural-net building blocks (pure JAX, params = nested dicts).

Covers: linear/norm primitives, RoPE (full / partial / M-RoPE), ALiBi,
learned positions, GQA attention with full-causal / sliding-window / cross
masks, memory-efficient blockwise (flash-style) attention, MLA
(DeepSeek-V2 latent attention) with compressed KV cache, SwiGLU / GELU
MLPs, and capacity-based mixture-of-experts with shared experts.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.parallel import constraints as CT

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def init_linear(key, d_in: int, d_out: int, bias: bool = False, *,
                scale: float | None = None, dtype=jnp.float32) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def init_norm(d: int, kind: str, dtype=jnp.float32) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm(p: Params, x: jnp.ndarray, kind: str, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + eps)
        y = y * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# positions
# ---------------------------------------------------------------------------

def rope_angles(positions: jnp.ndarray, rot_dim: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions (...,) int32 -> cos/sin (..., rot_dim//2)."""
    half = rot_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def _rotate(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x (..., rot_dim) with cos/sin (..., rot_dim//2); pair-split convention."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(q: jnp.ndarray, k: jnp.ndarray, positions: jnp.ndarray, *,
               head_dim: int, fraction: float = 1.0, theta: float = 10_000.0,
               mrope_sections: Tuple[int, ...] = ()) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """q (B,S,H,hd), k (B,S,KVH,hd); positions (B,S) int32 or (3,B,S) for M-RoPE."""
    rot = int(head_dim * fraction)
    rot -= rot % 2
    if mrope_sections:
        rot = 2 * sum(mrope_sections)
        cos_t, sin_t = rope_angles(positions, rot, theta)  # (3,B,S,rot/2)
        splits = [sum(mrope_sections[:i + 1]) for i in range(len(mrope_sections) - 1)]
        cos = jnp.concatenate([c[i] for i, c in enumerate(jnp.split(cos_t, splits, axis=-1))], axis=-1)
        sin = jnp.concatenate([s[i] for i, s in enumerate(jnp.split(sin_t, splits, axis=-1))], axis=-1)
    else:
        cos, sin = rope_angles(positions, rot, theta)      # (B,S,rot/2)
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]       # broadcast over heads

    def rope_one(x):
        xr, xp = x[..., :rot], x[..., rot:]
        xr = _rotate(xr.astype(jnp.float32), cos, sin).astype(x.dtype)
        return jnp.concatenate([xr, xp], axis=-1) if xp.shape[-1] else xr

    return rope_one(q), rope_one(k)


def alibi_slopes(num_heads: int) -> jnp.ndarray:
    exp = math.floor(math.log2(num_heads))
    base = 2.0 ** (-8.0 / (2 ** exp))
    slopes = [base ** (i + 1) for i in range(2 ** exp)]
    if len(slopes) < num_heads:  # non-power-of-two heads
        extra_base = 2.0 ** (-4.0 / (2 ** exp))
        slopes += [extra_base ** (2 * i + 1) for i in range(num_heads - len(slopes))]
    return jnp.array(slopes, jnp.float32)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

NEG_INF = -1e30
Q_BLOCK = 512      # query-axis chunk of the two-axis blockwise attention


def init_attention(key, cfg, d_in: int | None = None, dtype=jnp.float32) -> Params:
    d = d_in or cfg.d_model
    ks = jax.random.split(key, 6)
    p = {
        "q": init_linear(ks[0], d, cfg.q_dim, cfg.attn_bias, dtype=dtype),
        "k": init_linear(ks[1], d, cfg.kv_dim, cfg.attn_bias, dtype=dtype),
        "v": init_linear(ks[2], d, cfg.kv_dim, cfg.attn_bias, dtype=dtype),
        "o": init_linear(ks[3], cfg.q_dim, cfg.d_model, cfg.attn_bias, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_norm(cfg.head_dim, "rmsnorm", dtype)
        p["k_norm"] = init_norm(cfg.head_dim, "rmsnorm", dtype)
    return p


def _gqa_scores_to_out(q, k, v, bias, scale):
    """Dense attention.  q (B,Sq,N,G,h); k,v (B,Sk,N,h); bias broadcastable to
    (B,N,G,Sq,Sk) additive mask (float32)."""
    logits = jnp.einsum("bqngh,bsnh->bngqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    logits = logits + bias
    w = jax.nn.softmax(logits, axis=-1)
    # accumulate in f32, return the QUERY dtype (the cache may be narrower,
    # e.g. fp8 KV caches for memory-bound decode)
    out = jnp.einsum("bngqs,bsnh->bqngh", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def _kv_scan_attention(q, k, v, bias_fn, scale, kv_block: int, q0):
    """Online-softmax over KV blocks for one query chunk.

    q (B,Qb,N,G,h); k,v (B,Sk,N,h); bias_fn(q0, qlen, kv_start, kv_len) gives
    the additive mask block (broadcastable to (B,N,G,Qb,kv_len))."""
    B, Qb, N, G, h = q.shape
    Sk = k.shape[1]
    nblk = (Sk + kv_block - 1) // kv_block
    pad = nblk * kv_block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nblk, kv_block, N, h)
    vb = v.reshape(B, nblk, kv_block, N, h)
    qf = q.astype(jnp.float32)

    def step(carry, blk):
        m, l, acc = carry
        kblk, vblk, i = blk
        logits = jnp.einsum("bqngh,bsnh->bngqs", qf, kblk.astype(jnp.float32)) * scale
        mask = bias_fn(q0, Qb, i * kv_block, kv_block)
        if pad:  # mask out padded tail slots of the last block
            slot = i * kv_block + jnp.arange(kv_block)
            mask = mask + jnp.where(slot < Sk, 0.0, NEG_INF)
        logits = logits + mask
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bngqs,bsnh->bngqh", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, N, G, Qb), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, N, G, Qb), jnp.float32)
    a0 = jnp.zeros((B, N, G, Qb, h), jnp.float32)
    (m, l, acc), _ = lax.scan(
        step, (m0, l0, a0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nblk)))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return jnp.moveaxis(out, -2, 1).astype(v.dtype)  # (B,Qb,N,G,h)


def _blockwise_attention(q, k, v, bias_fn, scale, kv_block: int,
                         q_block: int = Q_BLOCK):
    """Flash-style attention chunked over BOTH axes: lax.map over query
    blocks (each rematted so backward recomputes per-chunk instead of
    stacking O(Sq·Sk) residuals) × online-softmax scan over KV blocks.
    Never materializes more than (q_block × kv_block) scores per head."""
    B, Sq, N, G, h = q.shape
    pad = (-Sq) % q_block
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    nq = q.shape[1] // q_block
    qb = jnp.moveaxis(q.reshape(B, nq, q_block, N, G, h), 1, 0)

    @jax.checkpoint
    def one_q(args):
        qc, qi = args
        return _kv_scan_attention(qc, k, v, bias_fn, scale, kv_block,
                                  qi * q_block)

    out = lax.map(one_q, (qb, jnp.arange(nq)))      # (nq,B,q_block,N,G,h)
    out = jnp.moveaxis(out, 0, 1).reshape(B, nq * q_block, N, G, h)
    return out[:, :Sq]


def attention(p: Params, cfg, x: jnp.ndarray, positions, *,
              cache: Optional[Params] = None, x_kv: Optional[jnp.ndarray] = None,
              causal: bool = True, kv_block: int = 1024,
              blockwise_threshold: int = 2048) -> Tuple[jnp.ndarray, Optional[Params]]:
    """GQA attention.  Returns (out, updated_cache).

    * ``cache`` None  -> train/prefill over the whole sequence.
    * ``cache`` given -> decode: x is (B,1,D); KV appended into the cache
      (ring buffer when cfg.sliding_window > 0).
    * ``x_kv`` given  -> cross attention (no cache update of x_kv side).
    """
    B, Sq, _ = x.shape
    N, G, h = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads, cfg.head_dim
    src = x if x_kv is None else x_kv

    q = linear(p["q"], x).reshape(B, Sq, N, G, h)
    k = linear(p["k"], src).reshape(B, src.shape[1], N, h)
    v = linear(p["v"], src).reshape(B, src.shape[1], N, h)
    if cfg.qk_norm:
        q = norm(p["q_norm"], q, "rmsnorm")
        k = norm(p["k_norm"], k, "rmsnorm")

    scale = 1.0 / math.sqrt(h)
    is_cross = x_kv is not None
    new_cache = None

    if cfg.pos_kind == "rope" or cfg.pos_kind == "mrope":
        if not is_cross:
            qr = q.reshape(B, Sq, N * G, h)
            qr, k = apply_rope(qr, k, positions, head_dim=h,
                               fraction=cfg.rope_fraction, theta=cfg.rope_theta,
                               mrope_sections=cfg.mrope_sections if cfg.pos_kind == "mrope" else ())
            q = qr.reshape(B, Sq, N, G, h)

    if cache is not None and not is_cross:
        # ---- decode / cached prefill: append this step's K/V --------------
        # Sq == 1 is the decode step; Sq > 1 is prefill-into-cache (only
        # valid for SWA when the whole segment fits the ring without wrap).
        W = cache["k"].shape[1]
        t = cache["pos"]                       # scalar int32: tokens so far
        slot = t % W if cfg.sliding_window else t
        ck = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        cv = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        # slot_pos is per-sequence (B, W): serving engines invalidate each
        # row's right-padded prefill slots independently (slot_pos = -1)
        row = jnp.broadcast_to(t + jnp.arange(Sq, dtype=jnp.int32)[None, :], (B, Sq))
        spos = lax.dynamic_update_slice(cache["slot_pos"], row, (0, slot))
        new_cache = {"k": ck, "v": cv, "pos": t + Sq, "slot_pos": spos}
        k, v = ck, cv
        q_pos = t + jnp.arange(Sq)                                # (Sq,)
        valid = (spos[:, None, :] >= 0) & (spos[:, None, :] <= q_pos[None, :, None])
        if cfg.sliding_window:
            valid &= spos[:, None, :] > q_pos[None, :, None] - cfg.sliding_window
        bias = jnp.where(valid, 0.0, NEG_INF)[:, None, None, :, :]
        out = _gqa_scores_to_out(q, k, v, bias, scale)
    else:
        Sk = k.shape[1]
        if is_cross or not causal:
            def bias_fn(q0, qlen, s0, slen):
                return jnp.zeros((1, 1, 1, 1, slen), jnp.float32)
        else:
            q_pos_full = positions if positions.ndim == 2 else positions[0]
            padq = (-Sq) % Q_BLOCK
            if padq:        # bias_fn may be sliced from padded query blocks
                q_pos_full = jnp.pad(q_pos_full, ((0, 0), (0, padq)))

            def bias_fn(q0, qlen, s0, slen):
                q_pos = lax.dynamic_slice_in_dim(q_pos_full, q0, qlen, axis=1)
                kpos = s0 + jnp.arange(slen)
                m = q_pos[:, :, None] >= kpos[None, None, :]
                if cfg.sliding_window:
                    m &= q_pos[:, :, None] - kpos[None, None, :] < cfg.sliding_window
                b = jnp.where(m, 0.0, NEG_INF)            # (B,qlen,slen)
                b = b[:, None, None, :, :]
                if cfg.pos_kind == "alibi":
                    slopes = alibi_slopes(cfg.num_heads).reshape(1, N, G, 1, 1)
                    dist = (kpos[None, None, :] - q_pos[:, :, None]).astype(jnp.float32)
                    b = b + slopes * dist[:, None, None, :, :]
                return b

        if Sk > blockwise_threshold or Sq * Sk > blockwise_threshold ** 2:
            out = _blockwise_attention(q, k, v, bias_fn, scale, kv_block)
        else:
            out = _gqa_scores_to_out(q, k, v, bias_fn(0, Sq, 0, Sk), scale)

    out = out.reshape(B, Sq, N * G * h)
    return linear(p["o"], out), new_cache


def init_kv_cache(cfg, batch: int, seq_len: int, dtype=jnp.float32) -> Params:
    """Pre-allocated decode cache.  SWA archs allocate only the window (that
    is the sub-quadratic memory story for long_500k)."""
    W = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    return {
        "k": jnp.zeros((batch, W, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, W, cfg.num_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
        "slot_pos": jnp.full((batch, W), -1, jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLA — DeepSeek-V2 multi-head latent attention
# ---------------------------------------------------------------------------

def init_mla(key, cfg, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    qk_hd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    p = {}
    if cfg.q_lora_rank:
        p["q_a"] = init_linear(ks[0], d, cfg.q_lora_rank, dtype=dtype)
        p["q_a_norm"] = init_norm(cfg.q_lora_rank, "rmsnorm", dtype)
        p["q_b"] = init_linear(ks[1], cfg.q_lora_rank, cfg.num_heads * qk_hd, dtype=dtype)
    else:
        p["q"] = init_linear(ks[0], d, cfg.num_heads * qk_hd, dtype=dtype)
    p["kv_a"] = init_linear(ks[2], d, cfg.kv_lora_rank + cfg.qk_rope_head_dim, dtype=dtype)
    p["kv_a_norm"] = init_norm(cfg.kv_lora_rank, "rmsnorm", dtype)
    p["kv_b"] = init_linear(ks[3], cfg.kv_lora_rank,
                            cfg.num_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim), dtype=dtype)
    p["o"] = init_linear(ks[4], cfg.num_heads * cfg.v_head_dim, d, dtype=dtype)
    return p


def mla_attention(p: Params, cfg, x: jnp.ndarray, positions, *,
                  cache: Optional[Params] = None, kv_block: int = 1024,
                  blockwise_threshold: int = 2048) -> Tuple[jnp.ndarray, Optional[Params]]:
    """MLA with the compressed (c_kv, k_rope) cache — the cache is rank-512
    per token, not per-head, which is the technique's point."""
    B, Sq, _ = x.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    if cfg.q_lora_rank:
        q = linear(p["q_b"], norm(p["q_a_norm"], linear(p["q_a"], x), "rmsnorm"))
    else:
        q = linear(p["q"], x)
    q = q.reshape(B, Sq, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]

    kv_a = linear(p["kv_a"], x)                              # (B,S,rank+dr)
    c_kv = norm(p["kv_a_norm"], kv_a[..., :cfg.kv_lora_rank], "rmsnorm")
    k_rope = kv_a[..., cfg.kv_lora_rank:][:, :, None, :]     # (B,S,1,dr)

    q_rope, k_rope = apply_rope(q_rope, k_rope, positions, head_dim=dr,
                                fraction=1.0, theta=cfg.rope_theta)

    new_cache = None
    if cache is not None:
        t = cache["pos"]
        c_kv = lax.dynamic_update_slice(cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, t, 0))
        k_rope = lax.dynamic_update_slice(cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, t, 0, 0))
        # per-sequence slot validity, same contract as the GQA cache: the
        # serving engines invalidate right-padded prefill slots per row
        row = jnp.broadcast_to(t + jnp.arange(Sq, dtype=jnp.int32)[None, :], (B, Sq))
        spos = lax.dynamic_update_slice(cache["slot_pos"], row, (0, t))
        new_cache = {"c_kv": c_kv, "k_rope": k_rope, "pos": t + Sq,
                     "slot_pos": spos}
        Sk = c_kv.shape[1]
        kmask = (spos[:, None, :] >= 0) & (
            spos[:, None, :] <= (t + jnp.arange(Sq))[None, :, None])  # (B,Sq,Sk)
    else:
        Sk = Sq
        kmask = None

    kv = linear(p["kv_b"], c_kv).reshape(B, Sk, H, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]

    scale = 1.0 / math.sqrt(dn + dr)
    if Sk > blockwise_threshold and cache is None:
        # prefill at long context: online-softmax over KV chunks, never
        # materializing the (Sq, Sk) score matrix.
        out = _mla_blockwise(q_nope, q_rope, k_nope, k_rope, v, scale, kv_block)
    else:
        logits = (jnp.einsum("bqhd,bshd->bhqs", q_nope.astype(jnp.float32), k_nope.astype(jnp.float32))
                  + jnp.einsum("bqhd,bsxd->bhqs", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32))) * scale
        if cache is not None:
            bias = jnp.where(kmask, 0.0, NEG_INF)[:, None, :, :]
        else:
            q_pos = jnp.arange(Sq)
            bias = jnp.where(q_pos[:, None] >= jnp.arange(Sk)[None, :], 0.0, NEG_INF)[None, None]
        w = jax.nn.softmax(logits + bias, axis=-1)
        out = jnp.einsum("bhqs,bshd->bqhd", w, v.astype(jnp.float32)).astype(x.dtype)
    out = out.reshape(B, Sq, H * dv)
    return linear(p["o"], out), new_cache


def _mla_blockwise(q_nope, q_rope, k_nope, k_rope, v, scale, kv_block,
                   q_block: int = 512):
    """MLA prefill attention, chunked over query AND key blocks (same
    two-axis structure as _blockwise_attention)."""
    B, Sq, H, dn = q_nope.shape
    Sk = k_nope.shape[1]
    dv = v.shape[-1]
    nblk = (Sk + kv_block - 1) // kv_block
    pad = nblk * kv_block - Sk
    if pad:
        k_nope = jnp.pad(k_nope, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kn = jnp.moveaxis(k_nope.reshape(B, nblk, kv_block, H, dn), 1, 0)
    kr = jnp.moveaxis(k_rope.reshape(B, nblk, kv_block, 1, -1), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nblk, kv_block, H, dv), 1, 0)

    qpad = (-Sq) % q_block
    if qpad:
        q_nope = jnp.pad(q_nope, ((0, 0), (0, qpad), (0, 0), (0, 0)))
        q_rope = jnp.pad(q_rope, ((0, 0), (0, qpad), (0, 0), (0, 0)))
    nq = q_nope.shape[1] // q_block
    qn_b = jnp.moveaxis(q_nope.reshape(B, nq, q_block, H, dn), 1, 0)
    qr_b = jnp.moveaxis(q_rope.reshape(B, nq, q_block, H, -1), 1, 0)

    @jax.checkpoint
    def one_q(args):
        qn, qr, qi = args
        qn = qn.astype(jnp.float32)
        qr = qr.astype(jnp.float32)
        q_pos = qi * q_block + jnp.arange(q_block)

        def step(carry, blk):
            m, l, acc = carry
            knb, krb, vbb, i = blk
            logits = (jnp.einsum("bqhd,bshd->bhqs", qn, knb.astype(jnp.float32))
                      + jnp.einsum("bqhd,bsxd->bhqs", qr, krb.astype(jnp.float32))) * scale
            kpos = i * kv_block + jnp.arange(kv_block)
            mask = (q_pos[:, None] >= kpos[None, :]) & (kpos[None, :] < Sk)
            logits = logits + jnp.where(mask, 0.0, NEG_INF)[None, None]
            m_new = jnp.maximum(m, logits.max(axis=-1))
            pw = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + pw.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqs,bshd->bhqd", pw, vbb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_block), jnp.float32)
        a0 = jnp.zeros((B, H, q_block, dv), jnp.float32)
        (m, l, acc), _ = lax.scan(step, (m0, l0, a0), (kn, kr, vb, jnp.arange(nblk)))
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        return jnp.moveaxis(out, 1, 2).astype(v.dtype)   # (B,q_block,H,dv)

    out = lax.map(one_q, (qn_b, qr_b, jnp.arange(nq)))   # (nq,B,q_block,H,dv)
    out = jnp.moveaxis(out, 0, 1).reshape(B, nq * q_block, H, dv)
    return out[:, :Sq]


def init_mla_cache(cfg, batch: int, seq_len: int, dtype=jnp.float32) -> Params:
    return {
        "c_kv": jnp.zeros((batch, seq_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, seq_len, 1, cfg.qk_rope_head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
        "slot_pos": jnp.full((batch, seq_len), -1, jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, kind: str, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {"gate": init_linear(ks[0], d_model, d_ff, dtype=dtype),
                "up": init_linear(ks[1], d_model, d_ff, dtype=dtype),
                "down": init_linear(ks[2], d_ff, d_model, dtype=dtype)}
    return {"up": init_linear(ks[0], d_model, d_ff, True, dtype=dtype),
            "down": init_linear(ks[1], d_ff, d_model, True, dtype=dtype)}


def mlp(p: Params, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "swiglu":
        return linear(p["down"], jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x))
    return linear(p["down"], jax.nn.gelu(linear(p["up"], x)))


# ---------------------------------------------------------------------------
# Mixture of Experts (capacity-based, sort-free scatter dispatch)
# ---------------------------------------------------------------------------

def moe_pad_experts(num_experts: int, ep_size: int) -> int:
    """Experts padded up to a multiple of the expert-parallel axis (e.g.
    qwen2-moe's 60 -> 64 on a 16-way axis).  Padded experts get -inf router
    logits and never receive tokens; documented in DESIGN.md."""
    return ((num_experts + ep_size - 1) // ep_size) * ep_size


def init_moe(key, cfg, *, ep_pad: int = 1, dtype=jnp.float32) -> Params:
    E = moe_pad_experts(cfg.num_experts, ep_pad)
    d, f = cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    p = {
        "router": init_linear(ks[0], d, cfg.num_experts, dtype=jnp.float32),
        "gate": (jax.random.normal(ks[1], (E, d, f), jnp.float32) * s).astype(dtype),
        "up": (jax.random.normal(ks[2], (E, d, f), jnp.float32) * s).astype(dtype),
        "down": (jax.random.normal(ks[3], (E, f, d), jnp.float32) / math.sqrt(f)).astype(dtype),
    }
    if cfg.num_shared_experts:
        sf = cfg.shared_d_ff or cfg.moe_d_ff * cfg.num_shared_experts
        p["shared"] = init_mlp(ks[4], d, sf, "swiglu", dtype)
        if cfg.shared_expert_gate:
            p["shared_gate"] = init_linear(ks[5], d, 1, dtype=dtype)
    return p


def _moe_ffn_explicit(p: Params, buf: jnp.ndarray, mesh, *, axis: str,
                      site: str) -> jnp.ndarray:
    """Expert FFN with the dispatch/combine all-to-alls made explicit: one
    shard_map over the expert axis — chunked a2a in (``{site}.a2a_disp``),
    per-device expert einsums on the local expert shard, chunked a2a out
    (``{site}.a2a_comb``).  Chunk counts resolve per-site against the
    active tuned plan, so two MoE layers can emit different a2a structure
    from one plan (the paper's per-site co-tuning made HLO-visible)."""
    from repro.parallel.collectives import (_chunked_a2a_local, runtime_for,
                                            shard_map)

    nc_disp = runtime_for(f"{site}.a2a_disp", "a2a").num_chunks
    nc_comb = runtime_for(f"{site}.a2a_comb", "a2a").num_chunks

    def local(b, gate, up, down):
        # (E, cap/n, D) token-sharded -> (E/n, cap, D) expert-sharded
        b = _chunked_a2a_local(b, axis=axis, split_axis=0, concat_axis=1,
                               num_chunks=nc_disp, site=f"{site}.a2a_disp")
        h = jnp.einsum("ecd,edf->ecf", b, gate)
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", b, up)
        y = jnp.einsum("ecf,efd->ecd", h, down)
        # back to the token-sharded capacity layout for the combine gather
        return _chunked_a2a_local(y, axis=axis, split_axis=1, concat_axis=0,
                                  num_chunks=nc_comb, site=f"{site}.a2a_comb")

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(None, axis, None), P(axis, None, None),
                             P(axis, None, None), P(axis, None, None)),
                   out_specs=P(None, axis, None))
    return fn(buf, p["gate"], p["up"], p["down"])


def moe_block(p: Params, cfg, x: jnp.ndarray, *, capacity_factor: float | None = None,
              mesh=None, axis: str = "model", site: str = "moe",
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k routed experts with capacity-bounded scatter dispatch + optional
    shared experts.  Returns (out, aux_loss).

    Dispatch: tokens are scattered into per-expert capacity buffers
    (E, cap, D) by position-within-expert (cumsum over the flat token axis);
    overflow tokens are dropped (their combine weight is zero).  Under EP
    sharding the (T,D)->(E,cap,D) scatter lowers to all-to-all.

    With ``mesh`` given, the expert FFN runs the *explicit* expert-parallel
    path instead of leaving the layout change to GSPMD: the dispatch and
    combine are real chunked all-to-alls whose chunk counts resolve against
    the active tuned plan at ``{site}.a2a_disp`` / ``{site}.a2a_comb``
    (numerically identical to the GSPMD path).
    """
    B, S, D = x.shape
    T = B * S
    E_real = cfg.num_experts
    E = p["gate"].shape[0]
    k = cfg.top_k
    cf = capacity_factor or cfg.capacity_factor
    cap = max(1, int(T * k * cf / E_real))
    xt = x.reshape(T, D)

    logits = linear(p["router"], xt.astype(jnp.float32))       # (T,E_real)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, k)                          # (T,k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch-style)
    me = probs.mean(axis=0)                                     # (E_real,)
    ce = jnp.zeros((E_real,)).at[top_e.reshape(-1)].add(1.0) / (T * k)
    aux = E_real * jnp.sum(me * ce)

    # position of each (token, slot) within its expert
    flat_e = top_e.reshape(-1)                                  # (T*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)         # (T*k,E)
    pos_in_e = jnp.cumsum(onehot, axis=0) * onehot              # 1-based
    pos = pos_in_e.sum(-1) - 1                                  # (T*k,); >=cap -> overflow

    # scatter into capacity buffers via FLAT row indices + scatter-add:
    # overflow rows are clipped onto the last slot with zeroed updates, so
    # they contribute nothing (their combine weight is also zeroed below).
    # 1-D indices keep the XLA scatter compact — 2-D advanced indexing with
    # mode="drop"/"fill" materializes (T·k, D)-sized index tensors.
    # Under EP sharding the (T,D)->(E,cap,D) layout change is the all-to-all.
    keep = pos < cap
    row = jnp.clip(flat_e * cap + pos, 0, E * cap - 1)          # (T*k,)
    vals = jnp.repeat(xt, k, axis=0) * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((E * cap, D), x.dtype).at[row].add(vals).reshape(E, cap, D)

    if mesh is not None:
        n = dict(mesh.shape).get(axis, 1)
        if E % n or cap % n:
            from repro.parallel.collectives import warn_degraded

            warn_degraded(
                site,
                f"expert buffer (E={E}, cap={cap}) is not divisible by the "
                f"{axis!r} axis ({n}); using the GSPMD expert layout "
                "instead of explicit all-to-alls",
                stacklevel=3)
            mesh = None
    if mesh is not None:
        y = _moe_ffn_explicit(p, buf, mesh, axis=axis, site=site)
    else:
        buf = CT.ecd(buf)      # expert-parallel layout: this IS the all-to-all
        h = jnp.einsum("ecd,edf->ecf", buf, p["gate"])
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, p["up"])
        y = CT.ecd(jnp.einsum("ecf,efd->ecd", h, p["down"]))    # (E,cap,D)

    gathered = jnp.take(y.reshape(E * cap, D), row, axis=0)     # (T*k,D)
    w = (top_p.reshape(-1) * keep).astype(x.dtype)
    out = (gathered * w[:, None]).reshape(T, k, D).sum(axis=1)

    if "shared" in p:
        sh = mlp(p["shared"], xt, "swiglu")
        if "shared_gate" in p:
            sh = sh * jax.nn.sigmoid(linear(p["shared_gate"], xt))
        out = out + sh
    return out.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# embeddings / positions tables
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)}


def embed(p: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return p["table"][tokens]


def unembed(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["table"].T
