"""Mamba2 (SSD) block — scalar-identity state space with chunked scan.

Used by the zamba2 hybrid trunk.  The inner recurrence runs through
``kernels.ops.ssd`` (chunked matmul form / Pallas kernel / naive ref).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as K
from repro.models import layers as L

Params = Dict[str, Any]


def d_inner(cfg) -> int:
    return cfg.ssm_expand * cfg.d_model


def head_p(cfg) -> int:
    return d_inner(cfg) // cfg.ssm_heads


def conv_channels(cfg) -> int:
    return d_inner(cfg) + 2 * cfg.ssm_groups * cfg.ssm_state


def init_block(key, cfg, dtype=jnp.float32) -> Params:
    D = cfg.d_model
    din = d_inner(cfg)
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 6)
    return {
        # separate projections (z / conv-input / dt) so each has a clean
        # TP sharding axis (a packed in_proj would shard across segment
        # boundaries and force GSPMD reshards at every split)
        "z_proj": L.init_linear(ks[0], D, din, dtype=dtype),
        "xbc_proj": L.init_linear(ks[3], D, din + 2 * G * N, dtype=dtype),
        "dt_proj": L.init_linear(ks[4], D, H, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_kernel, conv_channels(cfg)), jnp.float32)
                   / math.sqrt(cfg.conv_kernel)).astype(dtype),
        "conv_b": jnp.zeros((conv_channels(cfg),), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": L.init_norm(din, "rmsnorm", dtype),
        "out_proj": L.init_linear(ks[2], din, D, dtype=dtype),
    }


def _causal_conv(xBC: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 conv_state: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv1d.  xBC (B,S,C), w (K,C).  conv_state (B,K-1,C)
    carries the previous K-1 inputs (decode)."""
    Kk = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((xBC.shape[0], Kk - 1, xBC.shape[2]), xBC.dtype)
    xp = jnp.concatenate([conv_state, xBC], axis=1)             # (B,S+K-1,C)
    out = sum(xp[:, i:i + xBC.shape[1]] * w[i] for i in range(Kk)) + b
    new_state = xp[:, -(Kk - 1):]
    return jax.nn.silu(out), new_state


def block_fwd(p: Params, cfg, x: jnp.ndarray, cache: Optional[Params],
              backend: Optional[str] = None):
    """cache: {"conv": (B,K-1,C), "state": (B,H,P,N)} or None (train)."""
    B, S, _ = x.shape
    din = d_inner(cfg)
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    P = head_p(cfg)

    z = L.linear(p["z_proj"], x)
    xBC = L.linear(p["xbc_proj"], x)
    dt = L.linear(p["dt_proj"], x)
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"],
                                   cache["conv"] if cache else None)
    xs, Bm, Cm = jnp.split(xBC, [din, din + G * N], axis=-1)
    xs = xs.reshape(B, S, H, P)
    rep = H // G
    Bm = jnp.repeat(Bm.reshape(B, S, G, N), rep, axis=2)
    Cm = jnp.repeat(Cm.reshape(B, S, G, N), rep, axis=2)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    y, new_state = K.ssd(xs, dt, A, Bm, Cm, p["D"],
                         cache["state"] if cache else None, backend=backend)
    y = y.reshape(B, S, din)
    y = L.norm(p["norm"], y * jax.nn.silu(z), "rmsnorm")
    out = L.linear(p["out_proj"], y)
    new_cache = {"conv": conv_state, "state": new_state} if cache is not None else None
    return out, new_cache


def init_cache(cfg, batch: int, dtype=jnp.float32) -> Params:
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_channels(cfg)), dtype),
        "state": jnp.zeros((batch, cfg.ssm_heads, head_p(cfg), cfg.ssm_state), jnp.float32),
    }
