"""RWKV6 "Finch" — attention-free, data-dependent decay [arXiv:2404.05892].

Block = time-mix (WKV6 linear recurrence over a per-head (K,V) state, with
data-dependent per-channel decay produced by a LoRA on the token-shifted
input) + channel-mix (squared-ReLU FFN with receptance gate).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops as K
from repro.models import layers as L
from repro.parallel import constraints as CT

Params = Dict[str, Any]

MIX_LORA = 32     # rank of the 5-way token-mix LoRA
DECAY_LORA = 64   # rank of the decay LoRA


def init_layer(key, cfg, dtype=jnp.float32) -> Params:
    D, F = cfg.d_model, cfg.d_ff
    H, Kd = cfg.num_heads, cfg.head_dim
    ks = jax.random.split(key, 12)
    s = 1.0 / math.sqrt(D)

    def mat(k_, m, n, sc=None):
        return (jax.random.normal(k_, (m, n), jnp.float32) * (sc or 1.0 / math.sqrt(m))).astype(dtype)

    return {
        "ln1": L.init_norm(D, "layernorm", dtype),
        "ln2": L.init_norm(D, "layernorm", dtype),
        "tm": {
            "maa_x": jnp.zeros((D,), dtype),
            "maa": jnp.zeros((5, D), dtype),                       # w,k,v,r,g bases
            "maa_w1": mat(ks[0], D, 5 * MIX_LORA, 0.01),
            "maa_w2": (jax.random.normal(ks[1], (5, MIX_LORA, D), jnp.float32) * 0.01).astype(dtype),
            "decay": jnp.full((D,), -6.0, dtype),                  # w = exp(-exp(decay+lora))
            "decay_w1": mat(ks[2], D, DECAY_LORA, 0.01),
            "decay_w2": mat(ks[3], DECAY_LORA, D, 0.01),
            "bonus": (jax.random.normal(ks[4], (H, Kd), jnp.float32) * 0.1).astype(dtype),  # u
            "Wr": mat(ks[5], D, D, s), "Wk": mat(ks[6], D, D, s),
            "Wv": mat(ks[7], D, D, s), "Wg": mat(ks[8], D, D, s),
            "Wo": mat(ks[9], D, D, s),
            "ln_x": L.init_norm(D, "layernorm", dtype),            # per-head groupnorm
        },
        "cm": {
            "maa_k": jnp.zeros((D,), dtype),
            "maa_r": jnp.zeros((D,), dtype),
            "Wk": mat(ks[10], D, F),
            "Wv": mat(ks[11], F, D),
            "Wr": mat(jax.random.fold_in(key, 99), D, D),
        },
    }


def _token_shift(x: jnp.ndarray, last: Optional[jnp.ndarray]) -> jnp.ndarray:
    """x (B,S,D) -> previous token's activations; ``last`` (B,1,D) is the
    carry from the previous segment (zeros at sequence start)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _group_norm_heads(p, x, H):
    """LayerNorm per head (RWKV's GroupNorm(heads))."""
    B, S, D = x.shape
    xh = x.reshape(B, S, H, D // H).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mu) * lax.rsqrt(var + 64e-5)
    xh = xh.reshape(B, S, D) * p["scale"] + p["bias"]
    return xh


def time_mix(p: Params, cfg, x: jnp.ndarray, state, shift_last,
             backend: Optional[str] = None):
    B, S, D = x.shape
    H, Kd = cfg.num_heads, cfg.head_dim
    xprev = _token_shift(x, shift_last)
    dx = xprev - x
    xxx = x + dx * p["maa_x"]
    m = jnp.tanh(xxx @ p["maa_w1"]).reshape(B, S, 5, MIX_LORA)
    m = jnp.einsum("bsfr,frd->bsfd", m, p["maa_w2"])               # (B,S,5,D)
    mu = p["maa"][None, None] + m
    xw, xk, xv, xr, xg = (x + dx * mu[:, :, i] for i in range(5))

    r = (xr @ p["Wr"]).reshape(B, S, H, Kd)
    k = (xk @ p["Wk"]).reshape(B, S, H, Kd)
    v = (xv @ p["Wv"]).reshape(B, S, H, Kd)
    g = jax.nn.silu(xg @ p["Wg"])
    w_log = -jnp.exp(p["decay"].astype(jnp.float32)
                     + jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"])
    w_log = w_log.reshape(B, S, H, Kd)

    y, new_state = K.wkv6(r, k, v, w_log, p["bonus"], state, backend=backend)
    y = _group_norm_heads(p["ln_x"], y.reshape(B, S, D), H).astype(x.dtype)
    out = (y * g) @ p["Wo"]
    return out, new_state, x[:, -1:]


def channel_mix(p: Params, x: jnp.ndarray, shift_last):
    xprev = _token_shift(x, shift_last)
    dx = xprev - x
    xk = x + dx * p["maa_k"]
    xr = x + dx * p["maa_r"]
    h = jnp.square(jax.nn.relu(xk @ p["Wk"]))
    return jax.nn.sigmoid(xr @ p["Wr"]) * (h @ p["Wv"]), x[:, -1:]


def layer_fwd(p: Params, cfg, x: jnp.ndarray, cache: Optional[Params],
              backend: Optional[str] = None):
    x = CT.btd(x)
    st = cache or {}
    tm_out, wkv, tm_last = time_mix(p["tm"], cfg, L.norm(p["ln1"], x, "layernorm"),
                                    st.get("wkv"), st.get("shift_tm"), backend)
    x = x + tm_out
    cm_out, cm_last = channel_mix(p["cm"], L.norm(p["ln2"], x, "layernorm"),
                                  st.get("shift_cm"))
    x = x + cm_out
    new_cache = {"wkv": wkv, "shift_tm": tm_last, "shift_cm": cm_last} \
        if cache is not None else None
    return x, new_cache


def init_trunk(key, cfg, dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, cfg.num_layers)
    return {"layers": jax.vmap(partial(init_layer, cfg=cfg, dtype=dtype))(keys)}


def trunk_fwd(p: Params, cfg, x, positions=None, caches=None, *,
              remat: bool = False, backend: Optional[str] = None):
    def scan_fn(x, xs):
        if caches is None:
            def fn(q, v):
                return layer_fwd(q, cfg, v, None, backend)

            if remat:
                fn = jax.checkpoint(fn)
            x, _ = fn(xs, x)
            return x, None
        lp, lc = xs
        x, nc = layer_fwd(lp, cfg, x, lc, backend)
        return x, nc

    xs = p["layers"] if caches is None else (p["layers"], caches["layers"])
    x, new_caches = lax.scan(scan_fn, x, xs)
    return x, ({"layers": new_caches} if caches is not None else None), jnp.zeros((), jnp.float32)


def init_trunk_caches(cfg, batch: int, seq_len: int, dtype=jnp.float32) -> Params:
    one = {
        "wkv": jnp.zeros((batch, cfg.num_heads, cfg.head_dim, cfg.head_dim), jnp.float32),
        "shift_tm": jnp.zeros((batch, 1, cfg.d_model), dtype),
        "shift_cm": jnp.zeros((batch, 1, cfg.d_model), dtype),
    }
    return {"layers": jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape).copy(), one)}
