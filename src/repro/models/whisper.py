"""Whisper-style encoder-decoder backbone [arXiv:2212.04356].

The mel-spectrogram + conv frontend is a STUB per the assignment: the
encoder consumes precomputed frame embeddings (B, encoder_seq, d_model).
Encoder = bidirectional attention stack; decoder = causal self-attention +
cross-attention to the encoder memory.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.parallel import constraints as CT

Params = Dict[str, Any]


def init_enc_layer(key, cfg, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_norm(cfg.d_model, "layernorm", dtype),
        "attn": L.init_attention(k1, cfg, dtype=dtype),
        "ln2": L.init_norm(cfg.d_model, "layernorm", dtype),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype),
    }


def init_dec_layer(key, cfg, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.init_norm(cfg.d_model, "layernorm", dtype),
        "self_attn": L.init_attention(k1, cfg, dtype=dtype),
        "ln_x": L.init_norm(cfg.d_model, "layernorm", dtype),
        "cross_attn": L.init_attention(k2, cfg, dtype=dtype),
        "ln2": L.init_norm(cfg.d_model, "layernorm", dtype),
        "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype),
    }


def init_trunk(key, cfg, dtype=jnp.float32) -> Params:
    ke, kd, kp = jax.random.split(key, 3)
    enc_keys = jax.random.split(ke, cfg.encoder_layers)
    dec_keys = jax.random.split(kd, cfg.num_layers)
    return {
        "enc_pos": (jax.random.normal(kp, (cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.02).astype(dtype),
        "enc_layers": jax.vmap(partial(init_enc_layer, cfg=cfg, dtype=dtype))(enc_keys),
        "enc_ln": L.init_norm(cfg.d_model, "layernorm", dtype),
        "dec_layers": jax.vmap(partial(init_dec_layer, cfg=cfg, dtype=dtype))(dec_keys),
    }


def encode(p: Params, cfg, frames: jnp.ndarray, *, remat: bool = True) -> jnp.ndarray:
    """frames: (B, encoder_seq, d_model) stub embeddings -> memory."""
    x = CT.btd(frames + p["enc_pos"][None, :frames.shape[1]])
    pos = jnp.broadcast_to(jnp.arange(frames.shape[1])[None], frames.shape[:2])

    def body(lp, x):
        x = CT.btd(x)
        h = L.norm(lp["ln1"], x, "layernorm")
        a, _ = L.attention(lp["attn"], cfg, h, pos, causal=False)
        x = x + a
        x = x + L.mlp(lp["mlp"], L.norm(lp["ln2"], x, "layernorm"), cfg.mlp_kind)
        return x

    def fn(x, lp):
        f = jax.checkpoint(body) if remat else body
        return f(lp, x), None

    x, _ = lax.scan(fn, x, p["enc_layers"])
    return L.norm(p["enc_ln"], x, "layernorm")


def dec_layer_fwd(lp: Params, cfg, x, memory, positions, cache):
    x = CT.btd(x)
    h = L.norm(lp["ln1"], x, "layernorm")
    a, new_cache = L.attention(lp["self_attn"], cfg, h, positions, cache=cache)
    x = x + a
    h = L.norm(lp["ln_x"], x, "layernorm")
    a, _ = L.attention(lp["cross_attn"], cfg, h, positions, x_kv=memory)
    x = x + a
    x = x + L.mlp(lp["mlp"], L.norm(lp["ln2"], x, "layernorm"), cfg.mlp_kind)
    return x, new_cache


def decode_trunk(p: Params, cfg, x, memory, positions, caches=None, *,
                 remat: bool = False):
    def fn(x, xs):
        if caches is None:
            def f(q, v):
                return dec_layer_fwd(q, cfg, v, memory, positions, None)

            if remat:
                f = jax.checkpoint(f)
            x2, _ = f(xs, x)
            return x2, None
        lp, lc = xs
        x2, nc = dec_layer_fwd(lp, cfg, x, memory, positions, lc)
        return x2, nc

    xs = p["dec_layers"] if caches is None else (p["dec_layers"], caches["dec"])
    x, new = lax.scan(fn, x, xs)
    return x, ({"dec": new} if caches is not None else None)


def init_trunk_caches(cfg, batch: int, seq_len: int, dtype=jnp.float32) -> Params:
    one = L.init_kv_cache(cfg, batch, seq_len, dtype)
    return {"dec": jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape).copy(), one)}
