"""Model configuration schema shared by the whole framework.

One ``ModelConfig`` describes any architecture in the zoo: dense GQA
decoders, sliding-window variants, MoE (shared + routed experts), MLA,
RWKV6 (attention-free), Mamba2/Zamba2 hybrids, Whisper-style
encoder-decoder, and VLM backbones with M-RoPE.  The fields are a
superset; each family reads the subset it needs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class ModelConfig:
    # --- identity -------------------------------------------------------
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    source: str = ""            # citation (arXiv id / model card)

    # --- trunk ----------------------------------------------------------
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0           # 0 -> d_model // num_heads
    d_ff: int = 0               # dense-MLP hidden size
    vocab_size: int = 0
    max_seq_len: int = 1 << 19

    # --- attention ------------------------------------------------------
    attn_kind: str = "gqa"      # gqa | mla | none
    pos_kind: str = "rope"      # rope | mrope | alibi | learned | none
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0  # partial rotary (stablelm / phi style)
    mrope_sections: Tuple[int, ...] = ()   # M-RoPE dims per (t, h, w) section
    sliding_window: int = 0     # 0 -> full causal attention
    attn_bias: bool = False
    qk_norm: bool = False

    # --- MLA (deepseek-v2) ------------------------------------------------
    kv_lora_rank: int = 0
    q_lora_rank: int = 0        # 0 -> full-rank q projection
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- block / mlp ------------------------------------------------------
    norm_kind: str = "rmsnorm"  # rmsnorm | layernorm
    mlp_kind: str = "swiglu"    # swiglu | gelu
    parallel_block: bool = False  # attn and mlp read the same norm (phi-2)
    tie_embeddings: bool = False

    # --- MoE --------------------------------------------------------------
    num_experts: int = 0        # routed experts (0 -> dense MLP)
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0           # per-expert ffn hidden
    shared_d_ff: int = 0        # shared-expert ffn hidden (0 -> moe_d_ff * n_shared)
    first_dense_layers: int = 0  # leading dense layers before MoE starts
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    shared_expert_gate: bool = False  # qwen2-moe gates its shared expert

    # --- SSM / RWKV ---------------------------------------------------------
    ssm_state: int = 0          # state dim per head (mamba2) / head size (rwkv)
    ssm_heads: int = 0
    ssm_expand: int = 2
    ssm_groups: int = 1         # B/C groups for mamba2
    conv_kernel: int = 4

    # --- hybrid (zamba2) ----------------------------------------------------
    shared_attn_every: int = 0  # apply the shared attention block every k layers

    # --- encoder-decoder (whisper) -------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 0        # stub frontend output length (audio frames)

    # --- modality stub (audio / vlm) ------------------------------------------
    frontend_stub: bool = False  # inputs are precomputed embeddings

    # --- numerics --------------------------------------------------------------
    dtype: str = "float32"

    # ---------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_kv_heads == 0:
            object.__setattr__(self, "num_kv_heads", self.num_heads)
        if self.attn_kind == "mla" and self.v_head_dim == 0:
            object.__setattr__(self, "v_head_dim", self.head_dim)

    # --- derived ------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for long_500k decode (sub-quadratic / windowed attention)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def has_decode(self) -> bool:
        """Encoder-only archs have no decode step; all ours decode."""
        return True

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # --- parameter count (analytic, for roofline MODEL_FLOPS) ----------------
    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count.  ``active_only`` counts MoE experts at
        top_k (+ shared) instead of all routed experts — the 6·N_active·D
        convention for MoE roofline."""
        d = self.d_model
        p = 0
        # embeddings (+ untied head)
        p += self.vocab_size * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            if self.attn_kind == "mla":
                q_in = self.q_lora_rank or d
                qhd = self.qk_nope_head_dim + self.qk_rope_head_dim
                a = 0
                if self.q_lora_rank:
                    a += d * self.q_lora_rank
                a += q_in * self.num_heads * qhd
                a += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                a += self.kv_lora_rank * self.num_heads * (self.qk_nope_head_dim + self.v_head_dim)
                a += self.num_heads * self.v_head_dim * d
                return a
            return d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d

        def mlp_params(hidden: int) -> int:
            mult = 3 if self.mlp_kind == "swiglu" else 2
            return mult * d * hidden

        if self.family == "ssm":       # rwkv6
            # time-mix: r,k,v,g,o projections + decay loras; channel-mix 2 mats
            p += self.num_layers * (5 * d * d + 2 * d * self.d_ff)
        elif self.family == "hybrid":  # zamba2: mamba2 layers + one shared attn block
            d_in = self.ssm_expand * d
            per_mamba = d * (2 * d_in + 2 * self.ssm_groups * self.ssm_state) + d_in * d
            p += self.num_layers * per_mamba
            p += attn_params() * 2 + mlp_params(self.d_ff)  # shared block (concat input ~2x)
        else:
            layers = self.num_layers + self.encoder_layers
            p += layers * attn_params()
            if self.is_encoder_decoder:
                p += self.num_layers * attn_params()  # cross attention
            moe_layers = max(0, self.num_layers - self.first_dense_layers) if self.is_moe else 0
            dense_layers = layers - moe_layers
            p += dense_layers * mlp_params(self.d_ff)
            if moe_layers:
                n_routed = self.top_k if active_only else self.num_experts
                p += moe_layers * (n_routed * mlp_params(self.moe_d_ff)
                                   + mlp_params(self.shared_d_ff or self.moe_d_ff * self.num_shared_experts)
                                   + d * self.num_experts)
        return p


def smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: ≤2 layers, d_model ≤ 256, ≤4 experts."""
    heads = min(cfg.num_heads, 4) or 4
    kv = max(1, min(cfg.num_kv_heads, heads))
    if cfg.num_kv_heads < cfg.num_heads:  # preserve GQA grouping
        kv = max(1, heads // max(1, cfg.num_heads // cfg.num_kv_heads))
    d_model = min(256, cfg.d_model)
    head_dim = d_model // heads
    kw = dict(
        num_layers=min(2, cfg.num_layers) or 2,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=head_dim,
        d_ff=min(512, cfg.d_ff) if cfg.d_ff else 0,
        vocab_size=min(512, cfg.vocab_size),
        max_seq_len=4096,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
    )
    if cfg.is_moe:
        kw.update(num_experts=min(4, cfg.num_experts),
                  top_k=min(2, cfg.top_k),
                  moe_d_ff=min(128, cfg.moe_d_ff),
                  shared_d_ff=min(128, cfg.shared_d_ff) if cfg.shared_d_ff else 0,
                  first_dense_layers=min(1, cfg.first_dense_layers))
    if cfg.attn_kind == "mla":
        kw.update(kv_lora_rank=64, q_lora_rank=min(cfg.q_lora_rank, 64) if cfg.q_lora_rank else 0,
                  qk_nope_head_dim=head_dim, qk_rope_head_dim=max(8, head_dim // 2),
                  v_head_dim=head_dim)
    if cfg.mrope_sections:
        h = head_dim // 2
        kw.update(mrope_sections=(h - 2 * (h // 3), h // 3, h // 3))
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=min(cfg.ssm_state, 16) or 16,
                  ssm_heads=min(cfg.ssm_heads, 4) if cfg.ssm_heads else 0,
                  shared_attn_every=2 if cfg.shared_attn_every else 0)
    if cfg.is_encoder_decoder:
        kw.update(encoder_layers=2, encoder_seq=64)
    return cfg.replace(**kw)
