"""Whisper-small — enc-dec; conv/mel frontend is a stub (precomputed frames) [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    source="arXiv:2212.04356",
    num_layers=12,          # decoder layers
    encoder_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    attn_kind="gqa",
    pos_kind="learned",
    norm_kind="layernorm",
    mlp_kind="gelu",
    attn_bias=True,
    is_encoder_decoder=True,
    encoder_seq=1500,       # stub frontend: precomputed frame embeddings
    frontend_stub=True,
    tie_embeddings=True,
    max_seq_len=32768,      # decode_32k stress shape bounds the learned-pos table
)
