"""Qwen2-VL-72B backbone — M-RoPE, dynamic resolution; vision encoder stubbed [arXiv:2409.12191]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    source="arXiv:2409.12191",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    attn_kind="gqa",
    pos_kind="mrope",
    mrope_sections=(16, 24, 24),   # (temporal, height, width) rotary dims
    rope_theta=1_000_000.0,
    attn_bias=True,
    frontend_stub=True,            # ViT + projector stubbed: patch embeddings in
)
