"""Config registry: ``get_config("<arch-id>")`` / ``--arch <id>``.

Ten assigned architectures + the five models from Lagom's own Table 2.
"""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, smoke
from repro.configs.shapes import INPUT_SHAPES, InputShape, shape_applicable

# arch-id -> module name
_REGISTRY = {
    # assigned pool (10)
    "rwkv6-1.6b":           "rwkv6_1p6b",
    "zamba2-7b":            "zamba2_7b",
    "h2o-danube-1.8b":      "h2o_danube_1p8b",
    "qwen2-moe-a2.7b":      "qwen2_moe_a2p7b",
    "stablelm-3b":          "stablelm_3b",
    "whisper-small":        "whisper_small",
    "phi4-mini-3.8b":       "phi4_mini_3p8b",
    "qwen2-vl-72b":         "qwen2_vl_72b",
    "yi-34b":               "yi_34b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    # Lagom Table 2 workloads (5)
    "phi2-2b":              "phi2_2b",
    "llama3-8b":            "llama3_8b",
    "mpt-7b":               "mpt_7b",
    "deepseek-moe-16b":     "deepseek_moe_16b",
    "olmoe-1b-7b":          "olmoe_1b_7b",
}

ASSIGNED_ARCHS = list(_REGISTRY)[:10]
PAPER_ARCHS = list(_REGISTRY)[10:]
ALL_ARCHS = list(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    mod = importlib.import_module(f"repro.configs.{_REGISTRY[name]}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return smoke(get_config(name))


__all__ = [
    "ModelConfig", "InputShape", "INPUT_SHAPES", "shape_applicable",
    "get_config", "get_smoke_config", "smoke",
    "ASSIGNED_ARCHS", "PAPER_ARCHS", "ALL_ARCHS",
]
