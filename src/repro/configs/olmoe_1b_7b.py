"""OLMoE-1B-7B — 64 experts top-8, qk-norm (Lagom Table 2 workload)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    source="arXiv:2409.02060 (Lagom Table 2)",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    attn_kind="gqa",
    pos_kind="rope",
    qk_norm=True,
    num_experts=64,
    num_shared_experts=0,
    top_k=8,
    moe_d_ff=1024,
)
