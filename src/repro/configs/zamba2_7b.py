"""Zamba2-7B — Mamba2 trunk + shared attention blocks [arXiv:2411.15242]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=81,          # mamba2 layers
    d_model=3584,
    num_heads=32,           # shared attention block heads
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,             # shared block MLP
    vocab_size=32000,
    attn_kind="gqa",
    pos_kind="rope",
    ssm_state=64,           # mamba2 N (state per head)
    ssm_heads=112,          # d_inner=7168, P=64
    ssm_expand=2,
    ssm_groups=1,
    shared_attn_every=6,    # shared transformer block applied every 6 layers
)
