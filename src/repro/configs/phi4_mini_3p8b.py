"""Phi-4-mini 3.8B — RoPE (partial), SwiGLU, GQA kv=8 [arXiv:2412.08905]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    source="arXiv:2412.08905",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    attn_kind="gqa",
    pos_kind="rope",
    rope_fraction=0.75,     # phi-4-mini partial rotary factor
    tie_embeddings=True,
)
