"""Phi-2 2.7B — parallel block, partial rotary, layernorm (Lagom Table 2 workload)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi2-2b",
    family="dense",
    source="microsoft/phi-2 (Lagom Table 2)",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=51200,
    attn_kind="gqa",
    pos_kind="rope",
    rope_fraction=0.4,
    norm_kind="layernorm",
    mlp_kind="gelu",
    parallel_block=True,
    attn_bias=True,
)
