"""MPT-7B — ALiBi positions, layernorm (Lagom Table 2 workload)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mpt-7b",
    family="dense",
    source="mosaicml/mpt-7b (Lagom Table 2)",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=16384,
    vocab_size=50432,
    attn_kind="gqa",
    pos_kind="alibi",
    norm_kind="layernorm",
    mlp_kind="gelu",
    tie_embeddings=True,
)
