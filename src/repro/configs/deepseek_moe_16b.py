"""DeepSeek-MoE-16B — 64 routed + 2 shared, top-6 (Lagom Table 2 workload)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    source="arXiv:2401.06066 (Lagom Table 2)",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=10944,
    vocab_size=102400,
    attn_kind="gqa",
    pos_kind="rope",
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    shared_d_ff=2816,
    first_dense_layers=1,
)
