"""RWKV6 "Finch" 1.6B — attention-free, data-dependent decay [arXiv:2404.05892]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    source="arXiv:2404.05892",
    num_layers=24,
    d_model=2048,
    num_heads=32,          # WKV heads (head size 64)
    num_kv_heads=32,
    head_dim=64,
    d_ff=7168,             # channel-mix hidden
    vocab_size=65536,
    attn_kind="none",
    pos_kind="none",
    norm_kind="layernorm",
    ssm_state=64,          # per-head state width == head size
    ssm_heads=32,
)
