"""Llama-3-8B (Lagom Table 2 workload)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    source="meta-llama/Meta-Llama-3-8B (Lagom Table 2)",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    attn_kind="gqa",
    pos_kind="rope",
    rope_theta=500_000.0,
)
