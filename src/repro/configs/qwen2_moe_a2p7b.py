"""Qwen1.5/2-MoE-A2.7B — 4 shared + 60 routed experts top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=5632,              # (dense fallback; all layers are MoE)
    vocab_size=151936,
    attn_kind="gqa",
    pos_kind="rope",
    rope_theta=1_000_000.0,
    attn_bias=True,
    num_experts=60,
    num_shared_experts=4,
    top_k=4,
    moe_d_ff=1408,
    shared_d_ff=5632,
    shared_expert_gate=True,
)
