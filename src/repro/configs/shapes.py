"""Assigned input shapes.  Decode shapes lower ``serve_step`` (one new token
against a ``seq_len`` KV/state cache); the others lower ``train_step`` /
prefill."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    seq_len=4_096,   global_batch=256, kind="train"),
    "prefill_32k": InputShape("prefill_32k", seq_len=32_768,  global_batch=32,  kind="prefill"),
    "decode_32k":  InputShape("decode_32k",  seq_len=32_768,  global_batch=128, kind="decode"),
    "long_500k":   InputShape("long_500k",   seq_len=524_288, global_batch=1,   kind="decode"),
}


def shape_applicable(cfg, shape: InputShape) -> tuple[bool, str]:
    """long_500k requires sub-quadratic attention (see DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, (f"{cfg.name} is pure full-attention; long_500k decode "
                       "requires sub-quadratic attention (SSM/hybrid/SWA)")
    return True, ""
