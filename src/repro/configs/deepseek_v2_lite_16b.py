"""DeepSeek-V2-Lite 16B — MLA (kv_lora=512) + MoE [arXiv:2405.04434].

Assignment note: the pool row says both "MoE 64e top-6" and "2 shared+160
routed"; 160 routed belongs to full V2.  V2-Lite's model card is 64 routed
+ 2 shared, top-6 — we follow the card and the "64e top-6" half of the row.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    source="arXiv:2405.04434",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,        # MLA: all heads share the compressed kv latent
    head_dim=128,
    d_ff=10944,             # first dense layer
    vocab_size=102400,
    attn_kind="mla",
    pos_kind="rope",
    kv_lora_rank=512,
    q_lora_rank=0,          # V2-Lite has no q compression
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    shared_d_ff=2816,
    first_dense_layers=1,
)
