"""H2O-Danube 1.8B — llama/mistral mix with sliding-window attention [arXiv:2401.16818]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    source="arXiv:2401.16818",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,         # GQA
    head_dim=80,
    d_ff=6912,
    vocab_size=32000,
    attn_kind="gqa",
    pos_kind="rope",
    sliding_window=4096,    # mistral-style SWA -> long_500k eligible
)
