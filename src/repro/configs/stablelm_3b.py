"""StableLM family config (assigned dims) — partial rotary, layernorm [hf:stabilityai/stablelm-2-1_6b]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab_size=50304,
    attn_kind="gqa",
    pos_kind="rope",
    rope_fraction=0.25,     # stablelm partial rotary
    norm_kind="layernorm",
)
