"""Causal flash-attention Pallas TPU kernel (GQA-aware), forward pass.

Grid: (B·Hq, Sq/QB, Sk/KB) with the KV axis sequential ("arbitrary") —
running max / denominator / accumulator live in VMEM scratch across KV
block iterations; (batch·head, q-block) axes are parallel.  Used for
inference prefill (the training path keeps the pure-JAX two-axis blockwise
attention in models/layers.py, which autodiffs); validated in interpret
mode against that reference.

VMEM per step (QB=KB=256, h=128, fp32): q/k/v blocks 3·256·128·4 = 384 KB,
acc 128 KB, m/l 2 KB — MXU-aligned (q·kᵀ is 256×128·128ᵀ).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
                  scale: float, qb: int, kb: int, causal: bool):
    ki = pl.program_id(2)
    qi = pl.program_id(1)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    # skip fully-masked blocks (k start beyond q end)
    run = (not causal) or (ki * kb <= qi * qb + qb - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0, :, 0, :].astype(jnp.float32)        # (qb,h)
        k = k_ref[0, :, 0, :].astype(jnp.float32)        # (kb,h)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = (q @ k.T) * scale                            # (qb,kb)
        if causal:
            qpos = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
            kpos = ki * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev, l_prev = m_s[...], l_s[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_prev * corr + p.sum(axis=-1)
        acc_s[...] = acc_s[...] * corr[:, None] + p @ v
        m_s[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, :, 0, :] = (acc_s[...] /
                             jnp.maximum(l_s[...], 1e-20)[:, None]
                             ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, q_block: int = 256,
                    kv_block: int = 256, interpret: bool = True):
    """q (B,Sq,Hq,h); k,v (B,Sk,Hkv,h) with Hq % Hkv == 0 (GQA)."""
    B, Sq, Hq, h = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qb = min(q_block, Sq)
    kb = min(kv_block, Sk)
    assert Sq % qb == 0 and Sk % kb == 0, "pad sequences to block multiples"
    scale = 1.0 / math.sqrt(h)

    q_spec = pl.BlockSpec((1, qb, 1, h), lambda b, qi, ki: (b // Hq, qi, b % Hq, 0))
    kv_spec = pl.BlockSpec((1, kb, 1, h),
                           lambda b, qi, ki: (b // Hq, ki, (b % Hq) // G, 0))
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, qb=qb, kb=kb,
                          causal=causal),
        grid=(B * Hq, Sq // qb, Sk // kb),
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((B, Sq, Hq, h), q.dtype),
        scratch_shapes=[_vmem((qb,), jnp.float32), _vmem((qb,), jnp.float32),
                        _vmem((qb, h), jnp.float32)],
        interpret=interpret,
        compiler_params=None if interpret else _tpu_params(),
    )(q, k, v)
    return out


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _tpu_params():
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.CompilerParams(
        dimension_semantics=("parallel", "parallel", "arbitrary"))
