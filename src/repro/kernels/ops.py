"""Public jit'd entry points for the kernel layer.

``backend``:
  * "ref"      — naive per-step jnp scan (exact oracle)
  * "chunked"  — chunked matmul-form jnp (same algorithm as the Pallas kernel;
                 the default: MXU-friendly, sub-quadratic activation memory)
  * "pallas"   — the Pallas TPU kernel (interpret=True on CPU)

The model code always calls these wrappers; the dry-run path uses "chunked"
(pure jnp lowers on any backend), tests sweep all three against "ref".
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref

_DEFAULT = "chunked"


def set_default_backend(name: str) -> None:
    global _DEFAULT
    assert name in ("ref", "chunked", "pallas")
    _DEFAULT = name


def default_backend() -> str:
    return _DEFAULT


def _pad_seq(a, mult):
    S = a.shape[1]
    pad = (-S) % mult
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
    return a, S


def wkv6(r, k, v, w_log, u, state=None, *, backend: str | None = None, chunk: int = 32):
    """RWKV6 WKV. r,k,v,w_log (B,S,H,K); u (H,K) -> y (B,S,H,V), state (B,H,K,V)."""
    backend = backend or _DEFAULT
    if backend == "ref" or r.shape[1] == 1:
        return _ref.wkv6_ref(r, k, v, w_log, u, state)
    if backend == "chunked":
        (r, S0), (k, _), (v, _), (w_log, _) = (_pad_seq(a, chunk) for a in (r, k, v, w_log))
        y, st = _ref.wkv6_chunked_ref(r, k, v, w_log, u, state, chunk=chunk)
        return y[:, :S0], st
    from repro.kernels import wkv6 as _pk
    (r, S0), (k, _), (v, _), (w_log, _) = (_pad_seq(a, chunk) for a in (r, k, v, w_log))
    y, st = _pk.wkv6_pallas(r, k, v, w_log, u, state, chunk=chunk)
    return y[:, :S0], st


def ssd(x, dt, A, Bm, Cm, D, state=None, *, backend: str | None = None, chunk: int = 64):
    """Mamba2 SSD. x (B,S,H,P); dt (B,S,H); A,D (H,); Bm,Cm (B,S,H,N)."""
    backend = backend or _DEFAULT
    if backend == "ref" or x.shape[1] == 1:
        return _ref.ssd_ref(x, dt, A, Bm, Cm, D, state)
    if backend == "chunked":
        (x, S0), (dt, _), (Bm, _), (Cm, _) = (_pad_seq(a, chunk) for a in (x, dt, Bm, Cm))
        y, st = _ref.ssd_chunked_ref(x, dt, A, Bm, Cm, D, state, chunk=chunk)
        return y[:, :S0], st
    from repro.kernels import ssd as _pk
    (x, S0), (dt, _), (Bm, _), (Cm, _) = (_pad_seq(a, chunk) for a in (x, dt, Bm, Cm))
    y, st = _pk.ssd_pallas(x, dt, A, Bm, Cm, D, state, chunk=chunk)
    return y[:, :S0], st


def rmsnorm(x, scale, *, backend: str | None = None, eps: float = 1e-5):
    backend = backend or _DEFAULT
    if backend in ("ref", "chunked"):
        return _ref.rmsnorm_ref(x, scale, eps)
    from repro.kernels import rmsnorm as _pk
    return _pk.rmsnorm_pallas(x, scale, eps=eps)
