"""Fused RMSNorm Pallas kernel (rows × features tiling).

Trivial but ubiquitous: every block norms through this on TPU.  Blocks of
(ROWS, D) stream through VMEM; the mean-square reduction and scale fuse
into one pass (vs. separate reduce + mul HLOs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 256


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(x, scale, *, eps: float = 1e-5, interpret: bool = True):
    orig_shape = x.shape
    D = x.shape[-1]
    xf = x.reshape(-1, D)
    T = xf.shape[0]
    rows = min(ROWS, T)
    pad = (-T) % rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(xf.shape[0] // rows,),
        in_specs=[pl.BlockSpec((rows, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, scale)
    return out[:T].reshape(orig_shape)
