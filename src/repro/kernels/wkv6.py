"""RWKV6 WKV Pallas TPU kernel — chunked matmul-form linear recurrence.

Grid: (B·H, S/Q).  The chunk axis is sequential ("arbitrary") so the (K,V)
state lives in a VMEM scratch carried across chunk iterations; the B·H axis
is parallel.  Within a chunk the recurrence is evaluated in matmul form
(MXU-friendly): intra-chunk attention-like matrix A[t,s] plus an
inter-chunk state term — identical math to ``ref.wkv6_chunked_ref``, whose
tests gate this kernel (interpret mode on CPU).

VMEM budget per grid step (Q=32, K=V=64, fp32):
  blocks r/k/v/w 4·Q·K = 32 KB, state K·V = 16 KB, decay tensor Q·Q·K
  = 256 KB, out Q·V = 8 KB — comfortably under the ~16 MB/core budget,
  with dims aligned to the 8×128 / MXU 128 tiling where it matters (K=V=64
  uses half-tiles; acceptable for head_dim-64 models).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                 y_ref, sf_ref, state, *, nq: int):
    qi = pl.program_id(1)

    @pl.when(qi == 0)
    def _init():
        state[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0, :].astype(jnp.float32)      # (Q,K)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    w = w_ref[0, :, 0, :].astype(jnp.float32)      # log decay ≤ 0
    u = u_ref[0].astype(jnp.float32)               # (K,)
    Q = r.shape[0]

    cw = jnp.cumsum(w, axis=0) - w                 # exclusive cumsum (Q,K)
    cw_end = jnp.sum(w, axis=0)                    # (K,)
    S0 = state[...]                                # (K,V)

    # inter-chunk: y_t += (r_t ⊙ e^{cw_t}) · S0
    y = (r * jnp.exp(cw)) @ S0                     # (Q,V)

    # intra-chunk: A[t,s] = Σ_K r_t k_s e^{cw_t − cw_s − w_s}  (s<t), diag u
    dmat = cw[:, None, :] - cw[None, :, :] - w[None, :, :]     # (Q,Q,K)
    mask = (jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
            > jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1))
    P = jnp.where(mask[:, :, None], jnp.exp(dmat), 0.0)
    A = jnp.einsum("qk,sk,qsk->qs", r, k, P,
                   preferred_element_type=jnp.float32)
    diag = jnp.sum(r * u[None, :] * k, axis=-1)                # (Q,)
    y = y + A @ v + diag[:, None] * v

    # state update: S = diag(e^{cw_end}) S0 + Σ_s e^{cw_end − cw_s − w_s} k_s v_sᵀ
    carry_k = k * jnp.exp(cw_end[None, :] - cw - w)            # (Q,K)
    state[...] = jnp.exp(cw_end)[:, None] * S0 + carry_k.T @ v

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    sf_ref[0, 0] = state[...].astype(sf_ref.dtype)


def wkv6_pallas(r, k, v, w_log, u, state=None, *, chunk: int = 32,
                interpret: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """r,k,v,w_log: (B,S,H,K); u: (H,K); state: (B,H,K,V) fp32 or None."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    assert S % chunk == 0
    nq = S // chunk
    if state is None:
        state = jnp.zeros((B, H, K, V), jnp.float32)

    seq_spec = pl.BlockSpec((1, chunk, 1, K),
                            lambda bh, qi: (bh // H, qi, bh % H, 0))
    u_spec = pl.BlockSpec((1, K), lambda bh, qi: (bh % H, 0))
    st_spec = pl.BlockSpec((1, 1, K, V), lambda bh, qi: (bh // H, bh % H, 0, 0))

    y, sf = pl.pallas_call(
        functools.partial(_wkv6_kernel, nq=nq),
        grid=(B * H, nq),
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec, u_spec, st_spec],
        out_specs=[pl.BlockSpec((1, chunk, 1, V),
                                lambda bh, qi: (bh // H, qi, bh % H, 0)),
                   st_spec],
        out_shape=[jax.ShapeDtypeStruct((B, S, H, V), v.dtype),
                   jax.ShapeDtypeStruct((B, H, K, V), jnp.float32)],
        scratch_shapes=[_vmem((K, V), jnp.float32)],
        interpret=interpret,
        compiler_params=None if interpret else _tpu_params(),
    )(r, k, v, w_log, u, state)
    return y, sf


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _tpu_params():
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.CompilerParams(
        dimension_semantics=("parallel", "arbitrary"))
