"""Pure-jnp oracles for every Pallas kernel.

These are the correctness references (`tests/test_kernels.py` sweeps shapes
and dtypes against them) and the default CPU execution path selected by
``kernels.ops``.  Naive per-timestep scans — O(S) sequential steps — written
for clarity, not speed.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# RWKV6 "Finch" WKV — data-dependent per-channel decay
#   S_t = diag(w_t) S_{t-1} + k_t v_t^T
#   y_t = r_t · (S_{t-1} + diag(u) k_t v_t^T)
# ---------------------------------------------------------------------------

def wkv6_ref(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w_log: jnp.ndarray,
             u: jnp.ndarray, state: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """r,k,v,w_log: (B,S,H,K); u: (H,K); state: (B,H,K,V) or None.

    w_log is log-decay (≤ 0, i.e. w = exp(w_log) ∈ (0,1]).
    Returns y (B,S,H,V) and the final state (B,H,K,V).  fp32 internally.
    """
    B, S, H, K = r.shape
    V = v.shape[-1]
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    wf = w_log.astype(jnp.float32)
    uf = u.astype(jnp.float32)
    if state is None:
        state = jnp.zeros((B, H, K, V), jnp.float32)
    else:
        state = state.astype(jnp.float32)

    def step(S_, inp):
        r_t, k_t, v_t, wl_t = inp                      # (B,H,K) each
        kv = k_t[..., :, None] * v_t[..., None, :]     # (B,H,K,V)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S_ + uf[None, :, :, None] * kv)
        S_ = jnp.exp(wl_t)[..., :, None] * S_ + kv
        return S_, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf))
    state, ys = lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1).astype(v.dtype), state


def wkv6_chunked_ref(r, k, v, w_log, u, state=None, *, chunk: int = 64):
    """Chunked (matmul-form) WKV — the algorithm the Pallas kernel implements.
    Mathematically identical to wkv6_ref; used to validate the chunking."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    assert S % chunk == 0, "pad sequence to a chunk multiple"
    Q = chunk
    n = S // Q
    def rs(a):
        return jnp.moveaxis(a.reshape(B, n, Q, H, K), 1, 0).astype(jnp.float32)

    rf, kf, vf, wf = rs(r), rs(k), rs(v), rs(w_log)
    uf = u.astype(jnp.float32)
    if state is None:
        state = jnp.zeros((B, H, K, V), jnp.float32)
    else:
        state = state.astype(jnp.float32)

    def chunk_step(S0, inp):
        rq, kq, vq, wq = inp                      # (B,Q,H,K)
        cw = jnp.cumsum(wq, axis=1) - wq          # exclusive cumsum: Σ_{τ<t} w
        cw_end = jnp.sum(wq, axis=1)              # (B,H,K)
        # inter-chunk: y_t += (r_t ⊙ exp(cw_t)) · S0   (cw_t ≤ 0: safe)
        y_inter = jnp.einsum("bqhk,bhkv->bqhv", rq * jnp.exp(cw), S0)
        # intra-chunk: A[t,s] = Σ_K r_t exp(cw_t − cw_s − w_s) k_s  (s < t)
        #              A[t,t] = Σ_K r_t u k_t
        # exponent formed as a difference BEFORE exp so it is ≤ 0 for s < t
        # (factoring into exp(cw_t)·exp(−cw_s−w_s) overflows for long chunks).
        dmat = cw[:, :, None] - cw[:, None] - wq[:, None]        # (B,Q,Q,H,K)
        mask = jnp.tril(jnp.ones((Q, Q), bool), k=-1)[None, :, :, None, None]
        # mask the EXPONENT (not the exp) — exp of the masked-out s>t branch
        # is inf and poisons the where-gradient (inf · 0 = NaN in backward)
        P = jnp.where(mask, jnp.exp(jnp.where(mask, dmat, 0.0)), 0.0)
        A = jnp.einsum("bqhk,bshk,bqshk->bhqs", rq, kq, P)
        A_diag = jnp.einsum("bqhk,hk,bqhk->bqh", rq, uf, kq)
        y = y_inter + jnp.einsum("bhqs,bshv->bqhv", A, vq) \
            + A_diag[..., None] * vq
        # state update: S = diag(e^{cw_end}) S0 + Σ_s e^{cw_end − cw_s − w_s} k_s v_s^T
        carry_k = kq * jnp.exp(cw_end[:, None] - cw - wq)
        S_new = jnp.exp(cw_end)[..., None] * S0 \
            + jnp.einsum("bshk,bshv->bhkv", carry_k, vq)
        return S_new, y

    state, ys = lax.scan(chunk_step, state, (rf, kf, vf, wf))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, V)
    return y.astype(v.dtype), state


# ---------------------------------------------------------------------------
# Mamba2 SSD — scalar-identity state space
#   h_t = exp(dt_t·A) h_{t-1} + (dt_t x_t) ⊗ B_t ;  y_t = h_t · C_t + D x_t
# ---------------------------------------------------------------------------

def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray, Bm: jnp.ndarray,
            Cm: jnp.ndarray, D: jnp.ndarray, state: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,H,P); dt: (B,S,H) (post-softplus, >0); A: (H,) (<0);
    Bm, Cm: (B,S,H,N) (already expanded from groups to heads); D: (H,).
    Returns y (B,S,H,P), final state (B,H,P,N)."""
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = Bm.astype(jnp.float32), Cm.astype(jnp.float32)
    Af, Df = A.astype(jnp.float32), D.astype(jnp.float32)
    if state is None:
        state = jnp.zeros((B_, H, P, N), jnp.float32)
    else:
        state = state.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp                  # (B,H,P),(B,H),(B,H,N),(B,H,N)
        decay = jnp.exp(dt_t * Af)                 # (B,H)
        h = decay[..., None, None] * h \
            + (dt_t[..., None] * x_t)[..., None] * B_t[..., None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h, C_t) + Df[None, :, None] * x_t
        return h, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    state, ys = lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state


def ssd_chunked_ref(x, dt, A, Bm, Cm, D, state=None, *, chunk: int = 64):
    """Chunked SSD (Mamba-2 paper block decomposition) — what the Pallas
    kernel implements."""
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0
    Q, n = chunk, S // chunk
    def mv(a):
        return jnp.moveaxis(a.reshape((B_, n, Q) + a.shape[2:]), 1, 0).astype(jnp.float32)

    xc, dtc, Bc, Cc = mv(x), mv(dt), mv(Bm), mv(Cm)
    Af, Df = A.astype(jnp.float32), D.astype(jnp.float32)
    if state is None:
        state = jnp.zeros((B_, H, P, N), jnp.float32)
    else:
        state = state.astype(jnp.float32)

    def chunk_step(h0, inp):
        xq, dtq, Bq, Cq = inp                       # (B,Q,H,*)
        a = dtq * Af                                # (B,Q,H) log decay
        cum = jnp.cumsum(a, axis=1)                 # inclusive
        # inter: y_t += C_t · (e^{cum_t} h0)
        y_inter = jnp.einsum("bqhn,bhpn->bqhp", Cq * jnp.exp(cum)[..., None], h0)
        # intra: L[t,s] = e^{cum_t − cum_s} (s ≤ t)
        Ldiff = cum[:, :, None] - cum[:, None]      # (B,Q,Q,H)
        mask = jnp.tril(jnp.ones((Q, Q), bool))[None, :, :, None]
        # exponent masked BEFORE exp: see wkv6 note (NaN-safe backward)
        Lmat = jnp.where(mask, jnp.exp(jnp.where(mask, Ldiff, 0.0)), 0.0)
        G = jnp.einsum("bqhn,bshn->bqsh", Cq, Bq) * Lmat
        y = y_inter + jnp.einsum("bqsh,bsh,bshp->bqhp", G, dtq, xq) \
            + Df[None, None, :, None] * xq
        # state: h = e^{cum_end} h0 + Σ_s e^{cum_end − cum_s} (dt_s x_s) ⊗ B_s
        cum_end = cum[:, -1]                        # (B,H)
        w = jnp.exp(cum_end[:, None] - cum) * dtq   # (B,Q,H)
        h = jnp.exp(cum_end)[..., None, None] * h0 \
            + jnp.einsum("bqh,bqhp,bqhn->bhpn", w, xq, Bq)
        return h, y

    state, ys = lax.scan(chunk_step, state, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B_, S, H, P)
    return y.astype(x.dtype), state


# ---------------------------------------------------------------------------
# fused RMSNorm (oracle for kernels/rmsnorm.py)
# ---------------------------------------------------------------------------

def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)
