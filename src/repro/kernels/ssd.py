"""Mamba2 SSD Pallas TPU kernel — chunked scalar-identity state space.

Grid: (B·H, S/Q); chunk axis sequential with the (P,N) state in VMEM
scratch; B·H parallel.  Matmul-form block decomposition (Mamba-2 paper):
intra-chunk C·Bᵀ ⊙ decay-mask GEMM + inter-chunk state term — identical
math to ``ref.ssd_chunked_ref``.

VMEM per grid step (Q=64, P=64, N=64 fp32): x/B/C blocks 3·Q·max(P,N)
= 48 KB, state P·N = 16 KB, L-mask Q·Q = 16 KB — minimal; the two GEMMs
(Q×N·Nᵀ and Q×Q @ Q×P) land on the MXU.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, s0_ref,
                y_ref, sf_ref, state):
    qi = pl.program_id(1)

    @pl.when(qi == 0)
    def _init():
        state[...] = s0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (Q,P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (Q,)
    A = a_ref[0]                                     # scalar (per head)
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)       # (Q,N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)       # (Q,N)
    D = d_ref[0]
    Q = x.shape[0]

    a = dt * A                                       # (Q,) log decay ≤ 0
    cum = jnp.cumsum(a)                              # inclusive
    h0 = state[...]                                  # (P,N)

    # inter-chunk: y_t += (C_t e^{cum_t}) · h0ᵀ
    y = (Cm * jnp.exp(cum)[:, None]) @ h0.T          # (Q,P)

    # intra-chunk: G[t,s] = (C_t·B_s) e^{cum_t − cum_s} dt_s   (s ≤ t)
    Ldiff = cum[:, None] - cum[None, :]
    mask = (jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1))
    L = jnp.where(mask, jnp.exp(Ldiff), 0.0)
    G = (Cm @ Bm.T) * L * dt[None, :]
    y = y + G @ x + D * x

    # state: h = e^{cum_end} h0 + Σ_s e^{cum_end − cum_s} dt_s x_s ⊗ B_s
    cum_end = cum[-1]
    wgt = jnp.exp(cum_end - cum) * dt                # (Q,)
    state[...] = jnp.exp(cum_end) * h0 + (x * wgt[:, None]).T @ Bm

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    sf_ref[0, 0] = state[...].astype(sf_ref.dtype)


def ssd_pallas(x, dt, A, Bm, Cm, D, state=None, *, chunk: int = 64,
               interpret: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,H,P); dt (B,S,H); A,D (H,); Bm,Cm (B,S,H,N) head-expanded."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0
    nq = S // chunk
    if state is None:
        state = jnp.zeros((B, H, P, N), jnp.float32)

    x_spec = pl.BlockSpec((1, chunk, 1, P), lambda bh, qi: (bh // H, qi, bh % H, 0))
    bc_spec = pl.BlockSpec((1, chunk, 1, N), lambda bh, qi: (bh // H, qi, bh % H, 0))
    dt_spec = pl.BlockSpec((1, chunk, 1), lambda bh, qi: (bh // H, qi, bh % H))
    h_spec = pl.BlockSpec((1,), lambda bh, qi: (bh % H,))
    st_spec = pl.BlockSpec((1, 1, P, N), lambda bh, qi: (bh // H, bh % H, 0, 0))

    y, sf = pl.pallas_call(
        _ssd_kernel,
        grid=(B * H, nq),
        in_specs=[x_spec, dt_spec, h_spec, bc_spec, bc_spec, h_spec, st_spec],
        out_specs=[x_spec, st_spec],
        out_shape=[jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
                   jax.ShapeDtypeStruct((B, H, P, N), jnp.float32)],
        scratch_shapes=[_vmem((P, N), jnp.float32)],
        interpret=interpret,
        compiler_params=None if interpret else _tpu_params(),
    )(x, dt, A.astype(jnp.float32), Bm, Cm, D.astype(jnp.float32), state)
    return y, sf


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _tpu_params():
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.CompilerParams(dimension_semantics=("parallel", "arbitrary"))
