"""CLI front door for the plan static-analysis subsystem.

    PYTHONPATH=src python -m repro.analysis lint PLAN.json [...]
    PYTHONPATH=src python -m repro.analysis verify-overlap PLAN.json [...]

``lint`` runs the deployment linter (jax-free).  Exit codes: 0 = no
ERROR-severity findings, 1 = at least one ERROR, 2 = unreadable plan.
``--expect CODES`` inverts the contract for seeded-broken CI fixtures:
exit 0 iff the set of finding codes equals the comma-separated list.

``verify-overlap`` traces every tuned site's production chunked builder
under the plan (``analysis.exercise``) and judges materialization.  Exit
codes: 0 = every site MATERIALIZED (``--allow-degraded`` tolerates
DEGRADED), 1 = a site is ABSENT/DEGRADED, 2 = unreadable plan.
"""

import argparse
import sys

_LOAD_ERRORS = (OSError, ValueError, KeyError, TypeError)


def _load(path: str):
    from repro.core.session import TunedPlan

    try:
        return TunedPlan.load(path)
    except _LOAD_ERRORS as e:
        print(f"error: {path}: not a readable TunedPlan artifact "
              f"({e.__class__.__name__}: {e})", file=sys.stderr)
        return None


def _cmd_lint(args) -> int:
    from repro.analysis.lint import errors, format_findings, lint_plan

    worst = 0
    for path in args.plans:
        plan = _load(path)
        if plan is None:
            return 2
        findings = lint_plan(plan)
        print(format_findings(findings, label=path))
        if args.expect is not None:
            want = {c for c in args.expect.split(",") if c}
            got = {f.code for f in findings}
            if got != want:
                print(f"expected codes {sorted(want)} but found "
                      f"{sorted(got)}", file=sys.stderr)
                worst = max(worst, 1)
        elif errors(findings):
            worst = max(worst, 1)
    return worst


def _cmd_verify(args) -> int:
    from repro.analysis.exercise import exercise_and_report

    worst = 0
    for path in args.plans:
        plan = _load(path)
        if plan is None:
            return 2
        ok, text = exercise_and_report(
            plan, allow_degraded=args.allow_degraded, label=path)
        print(text)
        if not ok:
            worst = max(worst, 1)
    return worst


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="TunedPlan static analysis: deployment linter and "
                    "overlap-materialization verifier")
    sub = ap.add_subparsers(dest="cmd", required=True)

    lp = sub.add_parser("lint", help="run the LAG0xx rule catalog over "
                                     "saved plans")
    lp.add_argument("plans", nargs="+", help="TunedPlan JSON path(s)")
    lp.add_argument("--expect", default=None,
                    help="comma-separated finding codes this plan must "
                         "produce exactly (CI fixture contract)")
    lp.set_defaults(fn=_cmd_lint)

    vp = sub.add_parser("verify-overlap",
                        help="trace each tuned site's chunked builder "
                             "under the plan and judge materialization")
    vp.add_argument("plans", nargs="+", help="TunedPlan JSON path(s)")
    vp.add_argument("--allow-degraded", action="store_true",
                    help="tolerate DEGRADED (monolithic-fallback) sites")
    vp.set_defaults(fn=_cmd_verify)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    # before any jax import: verify-overlap traces 8-way shard_map
    # programs.  Guarded so importing this module (tests call ``main``
    # in-process) never mutates the host process's device topology.
    import os

    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    raise SystemExit(main())
