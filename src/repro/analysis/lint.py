"""Deployment linter: pure static checks on ``TunedPlan × Workload ×
Hardware/Topology``.

A broken plan should be caught before it is bound to a serving engine or
installed into a trainer — not discovered as a ``RuntimeWarning``
mid-serve.  Every check is a registered rule with a stable code
(``LAG0xx``) and a fixed severity; rules run on the plan artifact alone
(the embedded ``sites`` metadata makes it self-contained), with optional
``workload=``/``topology=`` arguments unlocking the cross-artifact
provenance rules.

Rule catalog (see ``docs/analysis.md`` for rationale + examples):

========  ========  =====================================================
code      severity  what it catches
========  ========  =====================================================
LAG001    error     dead plan entry: a tuned config resolving to no site
LAG002    warning   untuned site: a comm site the plan has no config for
LAG003    error     shadowed entry: a site's tuned knobs can never win
                    their own resolution (captured by an earlier entry)
LAG004    error     duplicate SiteId rows lowering to conflicting knobs
LAG010    warning   chunk count that cannot divide the site's payload
                    (the runtime ``CollectiveDegradedWarning`` twin)
LAG020    error     inter-pod site in a flat-tuned plan (tier mismatch)
LAG021    warning   hierarchical topology recorded but no inter-tier site
LAG030    error     provenance drift: fingerprint/structure/topology
                    disagree with the artifact or given workload/topology
LAG031    warning   banded-repo entry whose structure/shape can never
                    match a tolerance-band lookup
LAG040    error     malformed retune lineage (repo walks would quarantine)
========  ========  =====================================================

``lint_plan`` returns findings sorted most severe first; front doors:
``python -m repro.analysis lint``, ``launch/dryrun.py --lint``,
``session.tune(lint=...)``, ``PlanRepository.put(lint=...)`` and the
``PlanBinding`` ERROR-refusal gate in ``serving.plans``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

SEVERITIES = ("error", "warning", "info")


@dataclass(frozen=True)
class Finding:
    """One lint finding: a stable rule code, its severity, the SiteId it
    anchors to (``""`` for plan-level findings) and a message."""

    code: str
    severity: str
    site: str
    message: str

    def format(self) -> str:
        where = f" site={self.site}" if self.site else ""
        return f"{self.code} {self.severity}{where}: {self.message}"


@dataclass(frozen=True)
class Rule:
    code: str
    severity: str
    doc: str
    fn: Callable


_RULES: Dict[str, Rule] = {}


def rule(code: str, *, severity: str = "warning"):
    """Register a lint rule.  The decorated function receives a
    ``_LintContext`` and yields/returns ``(site, message)`` pairs; the
    registry stamps the code and severity::

        @rule("LAG0xx", severity="error")
        def _my_rule(ctx):
            yield "", "something is statically wrong"
    """
    if severity not in SEVERITIES:
        raise ValueError(
            f"rule severity must be one of {SEVERITIES}, got {severity!r}")

    def deco(fn):
        if code in _RULES:
            raise ValueError(f"lint rule {code!r} already registered")
        _RULES[code] = Rule(code=code, severity=severity,
                            doc=(fn.__doc__ or "").strip(), fn=fn)
        return fn

    return deco


def rules() -> Dict[str, Rule]:
    """The registered rule catalog (code -> Rule), insertion-ordered."""
    return dict(_RULES)


class _LintContext:
    """Everything a rule may inspect, computed once per lint run."""

    def __init__(self, plan, workload=None, topology=None):
        from repro.core.apply import site_runtime_plan, to_runtime

        self.plan = plan
        self.workload = workload
        self.topology = topology
        self.sites: List[Dict] = list(plan.sites)
        self.configs = dict(plan.configs)
        # canonical lowering of this artifact (what activate() installs)
        self.runtime = site_runtime_plan(self.sites, self.configs)
        self._to_runtime = to_runtime

    def site_id(self, row: Dict) -> str:
        return row.get("site") or row["name"]

    def row_runtime(self, row: Dict):
        """The knobs ``row``'s own tuned config lowers to (``None`` when
        the site has no config)."""
        cfg = self.configs.get((row["group"], row["comm"]))
        if cfg is None:
            return None
        return self._to_runtime(cfg, row["bytes"])

    def site_tier(self, row: Dict) -> str:
        from repro.core.topology import site_tier

        tier = row.get("tier")
        return tier if tier is not None else site_tier(self.site_id(row))


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------

@rule("LAG001", severity="error")
def _dead_entry(ctx):
    """A tuned config keyed to a (group, comm) coordinate with no site
    row: the config can never lower into the runtime plan — it is dead
    weight, usually a merge of plans from different workloads."""
    coords = {(s["group"], s["comm"]) for s in ctx.sites}
    for key in sorted(ctx.configs, key=str):
        if key not in coords:
            yield "", (f"config for (group={key[0]}, comm={key[1]}) "
                       "matches no site row; it will never lower to "
                       "runtime knobs")


@rule("LAG002", severity="warning")
def _untuned_site(ctx):
    """A comm site with no tuned config: it silently falls back to a
    prefix/class entry or XLA defaults at runtime."""
    for row in ctx.sites:
        if (row["group"], row["comm"]) not in ctx.configs:
            yield ctx.site_id(row), (
                "site has no tuned config; it will resolve through "
                "fallback entries or XLA defaults")


@rule("LAG003", severity="error")
def _shadowed_entry(ctx):
    """A site whose tuned knobs never win its own resolution: an earlier
    row's prefix fallback captured this site's exact key (``setdefault``
    lowering is first-wins), so the tuned config is silently dropped."""
    from repro.parallel import collectives as C

    with C.use_runtime_plan(ctx.runtime):
        for row in ctx.sites:
            own = ctx.row_runtime(row)
            if own is None:
                continue
            sid = ctx.site_id(row)
            got, key, _tier = C.resolve_runtime(sid, C.site_class(sid))
            if got != own:
                yield sid, (
                    f"tuned knobs {own.strategy}/x{own.num_chunks} are "
                    f"shadowed: resolution lands on entry {key!r} with "
                    f"{got.strategy}/x{got.num_chunks}")


@rule("LAG004", severity="error")
def _duplicate_site(ctx):
    """Two site rows sharing one SiteId but lowering to different knobs:
    only the first row's knobs survive the first-wins lowering."""
    seen: Dict[str, object] = {}
    for row in ctx.sites:
        sid = ctx.site_id(row)
        own = ctx.row_runtime(row)
        if own is None:
            continue
        if sid in seen and seen[sid] != own:
            yield sid, (
                f"duplicate SiteId with conflicting knobs "
                f"({seen[sid].strategy}/x{seen[sid].num_chunks} vs "
                f"{own.strategy}/x{own.num_chunks}); the first row wins")
        seen.setdefault(sid, own)


@rule("LAG010", severity="warning")
def _indivisible_chunk(ctx):
    """A lowered chunk count that cannot evenly divide the site's payload:
    the runtime will degrade to the monolithic collective and emit the
    matching ``CollectiveDegradedWarning`` at trace time — same rule,
    caught statically."""
    for row in ctx.sites:
        rt = ctx.row_runtime(row)
        if rt is None or rt.num_chunks <= 1:
            continue
        payload = int(row.get("bytes") or 0)
        gs = int(row.get("group_size") or 1)
        quantum = rt.num_chunks * (gs if row.get("kind") == "reducescatter"
                                   else 1)
        if payload and payload % quantum:
            yield ctx.site_id(row), (
                f"num_chunks={rt.num_chunks} cannot evenly divide the "
                f"{payload}-byte payload"
                + (f" across {gs} shards" if quantum != rt.num_chunks else "")
                + "; the runtime will fall back to the monolithic "
                "collective")


@rule("LAG020", severity="error")
def _tier_mismatch(ctx):
    """An inter-pod site (``outer.*``, ``acc.*.ar_grads``, or an explicit
    ``tier="inter"`` row) in a plan with no topology provenance: its knobs
    were priced on the flat intra-pod fabric, which mis-provisions the
    much slower cross-pod tier."""
    if ctx.plan.topology.get("fingerprint"):
        return
    for row in ctx.sites:
        if ctx.site_tier(row) == "inter":
            yield ctx.site_id(row), (
                "inter-pod site in a flat-tuned plan (no topology "
                "provenance); cross-pod knobs priced on the island "
                "fabric are unsound — re-tune with tune(..., topology=)")


@rule("LAG021", severity="warning")
def _hierarchical_without_inter(ctx):
    """Topology provenance records multiple pods, yet no site spans the
    inter-pod tier — the slow fabric never carried a tuned collective, so
    the hierarchical tune bought nothing (or the workload lost its
    ``acc.*``/``outer.*`` sites)."""
    spec = ctx.plan.topology.get("spec") or {}
    if int(spec.get("pods") or 1) <= 1:
        return
    if not any(ctx.site_tier(row) == "inter" for row in ctx.sites):
        yield "", (
            f"topology provenance records {spec.get('pods')} pods but no "
            "site spans the inter-pod tier; the fabric-aware tune is "
            "unused")


@rule("LAG030", severity="error")
def _provenance_drift(ctx):
    """Provenance fields that disagree — internally (topology spec vs its
    recorded fingerprint/name) or with a given workload/topology: applying
    the plan would raise ``PlanMismatchError`` at runtime, or worse,
    silently tune the wrong program."""
    topo_meta = ctx.plan.topology
    if topo_meta.get("spec"):
        from repro.core.topology import HierarchicalHardware

        try:
            rebuilt = HierarchicalHardware.from_dict(topo_meta["spec"])
        except (KeyError, TypeError, ValueError) as e:
            yield "", f"topology spec does not rebuild: {e}"
        else:
            if rebuilt.fingerprint() != topo_meta.get("fingerprint"):
                yield "", (
                    "recorded topology fingerprint does not match the "
                    "embedded spec — the artifact was hand-edited")
            elif ctx.plan.hardware != rebuilt.name:
                yield "", (
                    f"plan hardware {ctx.plan.hardware!r} disagrees with "
                    f"its topology name {rebuilt.name!r}")
    if ctx.workload is not None:
        from repro.core.session import (structure_fingerprint,
                                        workload_fingerprint)

        if ctx.plan.fingerprint != workload_fingerprint(ctx.workload):
            yield "", (
                f"plan fingerprint {ctx.plan.fingerprint[:12]}… does not "
                f"match workload {ctx.workload.name!r} — structures "
                "differ; re-applying is unsound")
        elif (ctx.plan.structure
              and ctx.plan.structure != structure_fingerprint(ctx.workload)):
            yield "", (
                "plan structure fingerprint drifted from the workload "
                "(same payload hash, different site structure) — the "
                "artifact was hand-edited")
    if ctx.topology is not None:
        from repro.core.session import PlanMismatchError

        try:
            ctx.plan.check_topology(ctx.topology)
        except PlanMismatchError as e:
            yield "", str(e)


@rule("LAG031", severity="warning")
def _band_unservable(ctx):
    """An entry tolerance-band resolution can never serve: banded lookups
    require a structure fingerprint and positive shape coordinates
    (``_shape_distance`` returns ``None`` otherwise), so this plan only
    ever resolves on an exact fingerprint hit."""
    if not ctx.plan.structure:
        yield "", ("no structure fingerprint recorded; tolerance-band "
                   "repository resolution will never consider this plan")
        return
    shape = ctx.plan.shape or {}
    bad = [k for k in ("seq", "global_batch")
           if not shape.get(k) or shape[k] <= 0]
    if bad:
        yield "", (
            f"shape coordinates {bad} missing or non-positive; banded "
            "shape distance is undefined for this plan")


@rule("LAG040", severity="error")
def _malformed_lineage(ctx):
    """Retune lineage a repository chain walk would quarantine: the
    ``retuned_from`` digest and ``chain`` list must agree (chain head ==
    parent, both present or both absent)."""
    lineage = ctx.plan.lineage or {}
    chain = lineage.get("chain", [])
    parent = lineage.get("retuned_from")
    malformed = (
        not isinstance(chain, list)
        or not all(isinstance(d, str) for d in chain)
        or (parent is not None and not isinstance(parent, str))
        or (chain and parent != chain[0])
        or (parent is not None and not chain)
    )
    if malformed:
        yield "", (f"lineage is malformed (retuned_from={parent!r}, "
                   f"chain={chain!r}); repository chain walks would "
                   "quarantine this entry")


# ---------------------------------------------------------------------------
# front door
# ---------------------------------------------------------------------------

_SEV_RANK = {s: i for i, s in enumerate(SEVERITIES)}


def lint_plan(plan, *, workload=None, topology=None,
              select: Optional[List[str]] = None) -> List[Finding]:
    """Run every registered rule (or the ``select`` subset of codes) on
    ``plan`` — a ``TunedPlan`` or a path to its JSON.  ``workload=`` and
    ``topology=`` unlock the cross-artifact provenance checks.  Returns
    findings sorted most severe first (then by code, then site)."""
    import os

    from repro.core.session import TunedPlan

    if isinstance(plan, (str, os.PathLike)):
        plan = TunedPlan.load(plan)
    ctx = _LintContext(plan, workload=workload, topology=topology)
    findings: List[Finding] = []
    for code, r in _RULES.items():
        if select is not None and code not in select:
            continue
        for site, message in r.fn(ctx) or ():
            findings.append(Finding(code=code, severity=r.severity,
                                    site=site, message=message))
    findings.sort(key=lambda f: (_SEV_RANK[f.severity], f.code, f.site))
    return findings


def errors(findings: List[Finding]) -> List[Finding]:
    """The ERROR-severity subset (what refusal gates act on)."""
    return [f for f in findings if f.severity == "error"]


def format_findings(findings: List[Finding], *, label: str = "") -> str:
    """The ``analysis:`` output line plus one line per finding."""
    n_err = len(errors(findings))
    n_warn = sum(1 for f in findings if f.severity == "warning")
    head = (f"analysis: {len(findings)} finding(s) "
            f"({n_err} error(s), {n_warn} warning(s))")
    if label:
        head += f" in {label}"
    return "\n".join([head] + [f"  {f.format()}" for f in findings])


class PlanLintError(ValueError):
    """A plan refused because lint found ERROR-level defects (the
    ``PlanBinding``/``tune``/``put`` refusal gates)."""

    def __init__(self, findings: List[Finding], *, label: str = "plan"):
        self.findings = findings
        bad = errors(findings)
        super().__init__(
            f"{label} has {len(bad)} ERROR-level lint finding(s): "
            + "; ".join(f.format() for f in bad)
            + " — fix the plan or override the lint gate (lint='off')")


def check_plan(plan, *, workload=None, topology=None,
               label: str = "plan") -> List[Finding]:
    """Lint and raise ``PlanLintError`` on any ERROR finding; returns the
    findings (warnings included) otherwise."""
    findings = lint_plan(plan, workload=workload, topology=topology)
    if errors(findings):
        raise PlanLintError(findings, label=label)
    return findings
