"""Static analysis of plan artifacts: the overlap-materialization
verifier and the deployment linter.

A ``TunedPlan`` only earns its speedup if the compiler actually emits the
chunk structure it promises, and only deploys safely if its entries,
provenance and lineage are coherent.  This package checks both without
running a training step:

``analysis.ir``
    Collective/compute op-graph extraction from closed jaxprs and
    post-SPMD HLO text (the shared op table; ``collective_bytes`` is the
    dryrun roofline parser, async ``-start``/``-done`` aware).

``analysis.overlap``
    The verifier: trace under the plan with the trace-time resolution
    recorder armed, then judge every consulted tuned site
    ``MATERIALIZED | DEGRADED | ABSENT``.

``analysis.lint``
    The linter: registered ``LAG0xx`` rules over ``TunedPlan × Workload ×
    Topology`` (dead entries, shadowed rules, indivisible chunks, tier
    mismatches, provenance drift, band-unservable shapes, malformed
    lineage).

``analysis.exercise``
    Model-free verification: synthetic per-site builder programs sized so
    the plan's chunking divides (the ``verify-overlap`` CLI body).

Front doors: ``python -m repro.analysis lint|verify-overlap``,
``launch/dryrun.py --lint``, ``session.tune(lint=...)``,
``PlanRepository.put(lint=...)`` and the ``serving.plans.PlanBinding``
ERROR-refusal gate.

Importing this package (and running ``lint``) stays jax-free; the
verifier modules import jax lazily on first attribute access.
"""

from repro.analysis.ir import (COLLECTIVE_OPS, ChunkLoop, CollectiveOp,
                               OpGraph, collective_bytes, graph_from_hlo,
                               graph_from_jaxpr)
from repro.analysis.lint import (Finding, PlanLintError, check_plan, errors,
                                 format_findings, lint_plan, rule, rules)

_LAZY = {
    # jax-importing modules: resolved on first access
    "OverlapReport": "repro.analysis.overlap",
    "SiteVerdict": "repro.analysis.overlap",
    "trace_and_verify": "repro.analysis.overlap",
    "verify": "repro.analysis.overlap",
    "verify_hlo": "repro.analysis.overlap",
    "exercise_plan": "repro.analysis.exercise",
    "exercise_and_report": "repro.analysis.exercise",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib

        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "COLLECTIVE_OPS", "ChunkLoop", "CollectiveOp", "Finding", "OpGraph",
    "OverlapReport", "PlanLintError", "SiteVerdict", "check_plan",
    "collective_bytes", "errors", "exercise_and_report", "exercise_plan",
    "format_findings", "graph_from_hlo", "graph_from_jaxpr", "lint_plan",
    "rule", "rules", "trace_and_verify", "verify", "verify_hlo",
]
