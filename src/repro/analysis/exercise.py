"""Synthetic per-site exercisers: verify a plan with no model required.

``overlap.trace_and_verify`` needs a traced program that consults the
plan's sites.  The real programs (trainer, serving engines) are heavy and
shape-constrained; this module instead builds, for every tuned site in a
plan, a minimal ``shard_map`` program that calls the *production chunked
builder* for the site's collective kind at the site's exact SiteId —
``ring_ag_matmul`` for allgather sites, ``mm_reduce_scatter`` for
reducescatter, ``chunked_all_to_all`` for alltoall, ``psum_tree_chunked``
for allreduce, the pipeline's chunked ppermute for permute — with payload
shapes sized so the plan's resolved chunk count divides evenly.  Tracing
that program under the plan and judging it answers "does this artifact
materialize when its sites are exercised?" for any plan, which is what
``python -m repro.analysis verify-overlap`` and the CI gate run over the
zoo's tuned plans.

A DEGRADED/ABSENT verdict here is therefore a property of the *plan and
resolution machinery* (shadowed entries, nc > MAX payload, plan not
installed), never of payload divisibility — the exerciser removes that
variable by construction.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

from repro.analysis.overlap import OverlapReport, trace_and_verify
from repro.parallel import collectives as C

# Workload IR comm kind -> the site-class string its production builder
# resolves with (collectives.runtime_for's cls argument)
KIND_CLS = {"allgather": "ag", "reducescatter": "rs", "allreduce": None,
            "alltoall": "a2a", "permute": "p2p"}


def _site_specs(plan) -> List[Tuple[str, str, int]]:
    """(site, kind, resolved nc) per unique tuned site, resolved exactly
    as the exercisers will resolve at trace time."""
    rt = plan.runtime_plan()
    specs, seen = [], set()
    with C.use_runtime_plan(rt):
        for row in plan.sites:
            sid = row.get("site") or row["name"]
            if sid in seen or row["kind"] not in KIND_CLS:
                continue
            seen.add(sid)
            cls = KIND_CLS[row["kind"]] or C.site_class(sid)
            knobs, _key, tier = C.resolve_runtime(sid, cls)
            if tier == "default":
                continue       # untuned site: nothing to materialize
            specs.append((sid, row["kind"], knobs.num_chunks))
    return specs


def _exercise_one(mesh, sid: str, kind: str, nc: int, n: int):
    """One builder call at ``sid`` with shapes the resolved ``nc``
    divides.  Runs inside the traced function."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    nc = max(1, nc)
    if kind == "allgather":
        # x (n*nc, 4) T-sharded, w (4, n*2) F-sharded: local shard nc rows
        x = jnp.ones((n * nc, 4), jnp.float32)
        w = jnp.ones((4, n * 2), jnp.float32)
        return C.ring_ag_matmul(x, w, mesh, axis="x",
                                x_spec=P("x", None), w_spec=P(None, "x"),
                                out_spec=P(None, "x"), site=sid)
    if kind == "reducescatter":
        # x (n*nc, n*4) F-sharded: scatter tiling n*nc rows over n shards
        x = jnp.ones((n * nc, n * 4), jnp.float32)
        w = jnp.ones((n * 4, 8), jnp.float32)
        return C.mm_reduce_scatter(x, w, mesh, axis="x",
                                   x_spec=P(None, "x"), w_spec=P("x", None),
                                   out_spec=P("x", None), site=sid)
    if kind == "alltoall":
        # local (n, 2, nc): split axis 0 divisible by n, trailing by nc
        x = jnp.ones((n * n, 2, nc), jnp.float32)
        return C.chunked_all_to_all(x, mesh, axis="x", split_axis=0,
                                    concat_axis=1,
                                    x_spec=P("x", None, None),
                                    out_spec=P("x", None, None), site=sid)
    if kind == "allreduce":
        # leaf leading dim nc per device: every chunk divides
        g = jnp.ones((n * nc, 4), jnp.float32)

        def body(gl):
            return C.psum_tree_chunked({"g": gl}, "x", site=sid)["g"]

        return C.shard_map(body, mesh=mesh, in_specs=(P("x", None),),
                           out_specs=P())(g)
    if kind == "permute":
        from repro.parallel.pipeline import _chunked_ppermute

        perm = [(j, (j + 1) % n) for j in range(n)]
        x = jnp.ones((n * 2, nc), jnp.float32)

        def body(xl):
            rt = C.runtime_for(sid, "p2p")
            return _chunked_ppermute(xl, "x", perm,
                                     num_chunks=rt.num_chunks, site=sid)

        return C.shard_map(body, mesh=mesh, in_specs=(P("x", None),),
                           out_specs=P("x", None))(x)
    raise ValueError(f"no exerciser for comm kind {kind!r}")


def exercise_plan(plan, *, install: bool = True,
                  mesh=None) -> OverlapReport:
    """Trace one synthetic program exercising every tuned site of ``plan``
    (each through its production chunked builder, divisible payloads) and
    return the overlap verdicts.  ``install=False`` traces without the
    plan — the deliberate-ABSENT control.  ``mesh`` defaults to every
    local device on one ``"x"`` axis."""
    import jax
    import numpy as np
    from jax.sharding import Mesh

    if mesh is None:
        mesh = Mesh(np.asarray(jax.devices()), ("x",))
    (n,) = mesh.devices.shape
    specs = _site_specs(plan)

    def program():
        return [_exercise_one(mesh, sid, kind, nc, n)
                for sid, kind, nc in specs]

    return trace_and_verify(plan, program, install=install)


def exercise_and_report(plan, *, allow_degraded: bool = False,
                        label: str = "plan") -> Tuple[bool, str]:
    """(ok, printable report) — the verify-overlap CLI/CI-gate body."""
    report = exercise_plan(plan)
    ok = report.ok(allow_degraded=allow_degraded)
    text = report.format().replace("overlap[jaxpr]", f"overlap[{label}]", 1)
    return ok, text


__all__ = ["KIND_CLS", "exercise_and_report", "exercise_plan"]
