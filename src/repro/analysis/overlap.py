"""Overlap-materialization verifier: does the emitted program structure
actually carry the plan's tuned chunk knobs?

The repo's strongest implicit invariant is that a ``TunedPlan`` *changes
what the compiler emits* — a tuned chunk count ``nc`` must show up as a
scan/while trip count interleaving partial collectives with compute, not
just as a number in a JSON file.  This module makes that invariant
checkable: trace a plan-aware builder under the plan with the trace-time
resolution recorder armed (``collectives.record_site_resolutions``),
extract the op graph (``analysis.ir``), and judge every consulted tuned
site:

``MATERIALIZED``
    The site resolved to the plan's knobs at trace time AND the artifact
    contains the chunk structure those knobs promise (a chunk loop with
    trip == ``nc`` of the site class's collective shape; trivially
    satisfied when the plan leaves the site unchunked).
``DEGRADED``
    The knobs reached the site but the chunk structure is missing or
    wrong — the runtime fell back to the monolithic collective (e.g. an
    indivisible payload, the ``LAG010`` runtime warning) or the loop
    serializes instead of interleaving.
``ABSENT``
    The site never received the plan's knobs (traced with the plan not
    installed / shadowed by another scope) or its collective class is
    missing from the artifact entirely.

Per-class expected chunk shapes (validated against the live builders):

* ``ag`` — ``ring_ag_matmul``: a compute-only scan of ``nc`` matmul
  chunks inside the ppermute ring (the ring itself carries the permute).
* ``rs`` — ``mm_reduce_scatter``: a scan of trip ``nc`` whose body
  interleaves a dot with a ``reduce-scatter``.
* ``a2a`` — ``chunked_all_to_all``: a scan of trip ``nc`` of partial
  ``all-to-all``s.
* ``p2p`` — pipeline ``_chunked_ppermute``: a scan of trip ``nc`` of
  ``collective-permute``s.
* ``ar`` / ``acc`` / ``outer`` — ``psum_tree_chunked``: a scan of trip
  ``nc`` of partial ``psum``s (all-reduce).
"""

from __future__ import annotations

import contextlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.analysis.ir import OpGraph, graph_from_hlo, graph_from_jaxpr
from repro.parallel import collectives as C

VERDICTS = ("MATERIALIZED", "DEGRADED", "ABSENT")

# site class -> (loop collective kind [None = compute-only chunk loop],
#                companion kind that must exist at all for the class,
#                body must interleave compute)
_CLASS_EXPECT: Dict[str, Tuple[Optional[str], str, bool]] = {
    "ag": (None, "permute", False),
    "rs": ("reducescatter", "reducescatter", True),
    "a2a": ("alltoall", "alltoall", False),
    "p2p": ("permute", "permute", False),
    "ar": ("allreduce", "allreduce", False),
    "acc": ("allreduce", "allreduce", False),
    "outer": ("allreduce", "allreduce", False),
}


@dataclass(frozen=True)
class SiteVerdict:
    """One tuned site's materialization verdict."""

    site: str
    cls: str                 # site class the expectation was drawn from
    strategy: str            # plan-intended knobs
    num_chunks: int
    verdict: str             # MATERIALIZED | DEGRADED | ABSENT
    detail: str
    resolution_tier: str     # how the trace resolved it (exact/prefix/...)


@dataclass
class OverlapReport:
    """Per-site verdicts for one traced artifact against one plan.

    ``unobserved`` lists plan-tuned SiteIds the trace never consulted
    (e.g. an fsdp plan verified against a tp builder) — excluded from
    verdicts rather than reported ABSENT, so verification over partial
    surfaces stays false-positive-free.  ``untuned`` lists consulted
    sites the plan carries no entry for."""

    source: str
    verdicts: List[SiteVerdict] = field(default_factory=list)
    unobserved: List[str] = field(default_factory=list)
    untuned: List[str] = field(default_factory=list)

    def by_verdict(self, verdict: str) -> List[SiteVerdict]:
        return [v for v in self.verdicts if v.verdict == verdict]

    @property
    def materialized(self) -> List[SiteVerdict]:
        return self.by_verdict("MATERIALIZED")

    @property
    def degraded(self) -> List[SiteVerdict]:
        return self.by_verdict("DEGRADED")

    @property
    def absent(self) -> List[SiteVerdict]:
        return self.by_verdict("ABSENT")

    def ok(self, *, allow_degraded: bool = False) -> bool:
        """Every consulted tuned site materialized (``allow_degraded``
        tolerates indivisible-payload fallbacks)."""
        if self.absent:
            return False
        return allow_degraded or not self.degraded

    def verdict_for(self, site: str) -> Optional[str]:
        for v in self.verdicts:
            if v.site == site:
                return v.verdict
        return None

    def format(self) -> str:
        n = len(self.verdicts)
        counts = ", ".join(
            f"{len(self.by_verdict(v))} {v}" for v in VERDICTS
            if self.by_verdict(v))
        lines = [f"overlap[{self.source}]: {n} tuned site(s) verified"
                 + (f" — {counts}" if counts else "")]
        for v in self.verdicts:
            lines.append(
                f"  {v.verdict:12s} {v.site}  {v.strategy}/x{v.num_chunks}"
                f"  ({v.detail})")
        if self.unobserved:
            lines.append(
                f"  ({len(self.unobserved)} plan site(s) not exercised by "
                "this trace)")
        return "\n".join(lines)


def _as_runtime_plan(plan) -> Dict[str, C.CollectiveRuntime]:
    """A ``TunedPlan`` (lowered) or an already-lowered runtime dict."""
    if hasattr(plan, "runtime_plan"):
        return plan.runtime_plan()
    return dict(plan)


def _plan_site_ids(plan) -> List[str]:
    if hasattr(plan, "sites"):
        return [s.get("site") or s["name"] for s in plan.sites]
    return []


def _dedupe_rows(rows: Sequence[C.SiteResolution]) -> List[C.SiteResolution]:
    seen, out = set(), []
    for r in rows:
        if r.site not in seen:
            seen.add(r.site)
            out.append(r)
    return out


def verify(plan, graph: OpGraph,
           resolutions: Sequence[C.SiteResolution]) -> OverlapReport:
    """Judge every consulted tuned site against ``graph`` (see module
    docstring).  ``plan`` is a ``TunedPlan`` or a lowered runtime dict;
    ``resolutions`` is the trace-time log recorded while the artifact was
    traced (``collectives.record_site_resolutions``)."""
    rt = _as_runtime_plan(plan)
    report = OverlapReport(source=graph.source)
    rows = _dedupe_rows(resolutions)

    judged: List[Tuple[C.SiteResolution, C.CollectiveRuntime, str]] = []
    with C.use_runtime_plan(rt):
        for row in rows:
            expect, _key, tier = C.resolve_runtime(row.site, row.cls)
            if tier == "default":
                report.untuned.append(row.site)
                continue
            judged.append((row, expect, tier))

    observed = {row.site for row in rows}
    report.unobserved = sorted(
        {s for s in _plan_site_ids(plan) if s not in observed})

    # multiset supply of chunk loops per (loop_kind, trip): two sites tuned
    # to the same signature must find two distinct loops
    supply: Dict[Tuple[Optional[str], int], int] = {}

    def take(loop_kind: Optional[str], nc: int,
             need_compute: bool) -> Optional[str]:
        """Consume one matching loop; returns a description or ``None``."""
        key = (loop_kind, nc)
        if key not in supply:
            exact = graph.chunk_loops(loop_kind, trip=nc)
            if need_compute:
                exact = [lp for lp in exact if lp.has_compute]
            supply[key] = len(exact)
        if supply[key] > 0:
            supply[key] -= 1
            return f"chunk loop of trip {nc}"
        # HLO whiles whose bound XLA hid: kind matches, trip unknown
        wild = (loop_kind, 0)
        if wild not in supply:
            loops = [lp for lp in graph.chunk_loops(loop_kind)
                     if lp.trip == 0]
            if need_compute:
                loops = [lp for lp in loops if lp.has_compute]
            supply[wild] = len(loops)
        if supply[wild] > 0:
            supply[wild] -= 1
            return "chunk loop (trip not statically visible)"
        return None

    for row, expect, _tier in sorted(judged, key=lambda j: j[0].site):
        site, nc = row.site, expect.num_chunks
        cls = row.cls if row.cls in _CLASS_EXPECT else C.site_class(site)
        loop_kind, companion, need_compute = _CLASS_EXPECT.get(
            cls, (None, "", False))
        recorded = (row.strategy, row.num_chunks)
        intended = (expect.strategy, expect.num_chunks)

        if recorded != intended:
            verdict, detail = "ABSENT", (
                f"traced under {row.strategy}/x{row.num_chunks} "
                f"(resolution tier {row.tier!r}) but the plan intends "
                f"{expect.strategy}/x{nc} — plan not installed at trace "
                "time?")
        elif nc <= 1:
            verdict, detail = "MATERIALIZED", (
                "plan leaves this site unchunked (nc=1); nothing to "
                "materialize")
        elif cls not in _CLASS_EXPECT:
            # unknown class: accept any loop of the right trip
            hit = take(None, nc, False)
            for k in ("allreduce", "reducescatter", "alltoall", "permute"):
                if hit is not None:
                    break
                hit = take(k, nc, False)
            verdict = "MATERIALIZED" if hit else "DEGRADED"
            detail = hit or (f"no chunk loop of trip {nc} for "
                             f"unrecognized site class {cls!r}")
        else:
            hit = take(loop_kind, nc, need_compute)
            if hit is not None:
                extra = ""
                if cls == "ag":
                    if graph.count("permute") == 0:
                        hit, extra = None, ""
                    else:
                        extra = " inside the ppermute ring"
                if hit is not None:
                    verdict, detail = "MATERIALIZED", hit + extra
            if hit is None:
                present = graph.count(companion) if companion else 0
                if present:
                    verdict, detail = "DEGRADED", (
                        f"{companion} collective emitted but no trip-{nc} "
                        "chunk loop — monolithic fallback (indivisible "
                        "payload, LAG010) or serialized body")
                else:
                    verdict, detail = "ABSENT", (
                        f"no {companion or 'matching'} collective in the "
                        "artifact for this site's class")
        report.verdicts.append(SiteVerdict(
            site=site, cls=cls, strategy=expect.strategy, num_chunks=nc,
            verdict=verdict, detail=detail, resolution_tier=row.tier))
    return report


def trace_and_verify(plan, fn, *args, install: bool = True,
                     hlo: Optional[str] = None,
                     ) -> Union[OverlapReport, Tuple[OverlapReport,
                                                     OverlapReport]]:
    """Trace ``fn(*args)`` with the resolution recorder armed and verify
    the jaxpr against ``plan``.  ``install=True`` (default) scopes the
    plan over the trace — the normal "does my plan materialize" question;
    ``install=False`` traces under the ambient plan instead, which is how
    a deliberately-uninstalled plan flips every tuned chunked site to
    ``ABSENT``.  Pass post-SPMD ``hlo`` text to also judge the compiled
    artifact with the same resolution log; returns ``(jaxpr_report,
    hlo_report)`` then."""
    import jax  # deferred: lint-only callers never pay the import

    rt = _as_runtime_plan(plan)
    scope = C.use_runtime_plan(rt) if install else contextlib.nullcontext()
    with scope, C.record_site_resolutions() as rows:
        # fresh wrapper: jax caches traces by function identity, so an
        # ``fn`` that was already jitted/traced would replay its cached
        # jaxpr and never consult resolve_runtime — the recorder must see
        # a genuine re-trace
        closed = jax.make_jaxpr(lambda *a: fn(*a))(*args)
    report = verify(plan, graph_from_jaxpr(closed), rows)
    if hlo is None:
        return report
    return report, verify(plan, graph_from_hlo(hlo), rows)


def verify_hlo(plan, hlo_text: str,
               resolutions: Sequence[C.SiteResolution]) -> OverlapReport:
    """Judge post-SPMD HLO text against ``plan`` using a resolution log
    recorded when the program was traced."""
    return verify(plan, graph_from_hlo(hlo_text), resolutions)
