"""Collective/compute op-graph extraction from traced jaxprs and
post-SPMD HLO text.

This is the mechanical layer of ``repro.analysis``: it does not know
about plans or sites, it only answers "what collective ops does this
artifact contain, inside which loops, with which trip counts, next to
which compute".  The overlap verifier (``analysis.overlap``) attributes
that structure back to dotted SiteIds via the active runtime plan's
trace-time resolution log; the dry-run roofline
(``launch.dryrun.parse_collective_bytes``) delegates its byte accounting
to :func:`collective_bytes` so both front ends share one op table.

The op table (:data:`COLLECTIVE_OPS`) maps the canonical Workload IR
comm kinds (``workload.COMM_KINDS``) to their spellings in each artifact:

====================  ============================  =======================
kind                  post-SPMD HLO opcode(s)       jaxpr primitive(s)
====================  ============================  =======================
``allgather``         ``all-gather``                ``all_gather``
``allreduce``         ``all-reduce``                ``psum`` / ``psum2``
``reducescatter``     ``reduce-scatter``            ``reduce_scatter``
``alltoall``          ``all-to-all``                ``all_to_all``
``permute``           ``collective-permute``        ``ppermute``
====================  ============================  =======================

Every HLO opcode also appears in async form as ``<op>-start`` /
``<op>-done`` pairs; the walkers count the ``-start`` (or the bare op)
and skip the ``-done`` so async pairs are never double-counted.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# canonical kind -> artifact spellings.  ``psum2`` is the shard_map-body
# psum on current jax; older traces bind ``psum``.
COLLECTIVE_OPS: Dict[str, Dict[str, Tuple[str, ...]]] = {
    "allgather": {"hlo": ("all-gather",), "jaxpr": ("all_gather",)},
    "allreduce": {"hlo": ("all-reduce",), "jaxpr": ("psum", "psum2")},
    "reducescatter": {
        "hlo": ("reduce-scatter",),
        "jaxpr": ("reduce_scatter", "psum_scatter"),
    },
    "alltoall": {"hlo": ("all-to-all",), "jaxpr": ("all_to_all",)},
    "permute": {"hlo": ("collective-permute",), "jaxpr": ("ppermute",)},
}

# flat reverse lookups
HLO_COLLECTIVE_KIND: Dict[str, str] = {
    op: kind for kind, spec in COLLECTIVE_OPS.items() for op in spec["hlo"]
}
JAXPR_COLLECTIVE_KIND: Dict[str, str] = {
    p: kind for kind, spec in COLLECTIVE_OPS.items() for p in spec["jaxpr"]
}

# the overlap-eligible compute ops (what a chunk loop interleaves with)
JAXPR_COMPUTE_PRIMS = ("dot_general", "conv_general_dilated")
HLO_COMPUTE_OPS = ("dot", "convolution", "fusion")

ASYNC_SUFFIXES = ("-start", "-done")

_HLO_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f64": 8,
    "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f8e4m3": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2,
}

# one collective instruction: optional tuple-open paren before the result
# shape (async starts return tuples), then the opcode with an optional
# async suffix, immediately followed by its operand list
_HLO_COLLECTIVE_RE = re.compile(
    r"=\s*\(?\s*([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"\b(" + "|".join(sorted(HLO_COLLECTIVE_KIND, key=len, reverse=True))
    + r")(-start|-done)?\(")


@dataclass(frozen=True)
class CollectiveOp:
    """One collective instruction in the artifact."""

    kind: str        # canonical kind (COLLECTIVE_OPS key)
    raw: str         # primitive/opcode as spelled in the artifact
    bytes: float = 0.0   # result bytes (HLO only; 0.0 for jaxpr ops)
    trip: int = 1    # innermost enclosing loop trip (1 = not in a loop)
    depth: int = 0   # loop nesting depth


@dataclass(frozen=True)
class ChunkLoop:
    """One loop (jaxpr ``scan``/``while``, HLO ``while``) summarized by
    what one iteration of its body contains — the shape the overlap
    verifier matches tuned chunk counts against."""

    trip: int                    # trip count; 0 = not statically known
    kinds: Tuple[str, ...]       # collective kinds in the body (sorted)
    n_collectives: int           # collective ops per iteration
    has_compute: bool            # dot/conv (HLO: fusion) in the body
    depth: int                   # nesting depth of the loop itself
    source: str = "scan"         # "scan" | "while"


@dataclass
class OpGraph:
    """The extracted collective/compute structure of one artifact."""

    source: str                          # "jaxpr" | "hlo"
    collectives: List[CollectiveOp] = field(default_factory=list)
    loops: List[ChunkLoop] = field(default_factory=list)
    compute_ops: int = 0

    def count(self, kind: str) -> int:
        """Number of collective ops of ``kind`` (loop bodies count once —
        multiply by ``trip`` for dynamic instances)."""
        return sum(1 for c in self.collectives if c.kind == kind)

    def chunk_loops(self, kind: Optional[str], *, trip: Optional[int] = None,
                    has_compute: Optional[bool] = None) -> List[ChunkLoop]:
        """Loops whose body contains a ``kind`` collective (``kind=None``:
        compute-only loops with no collective at all), optionally filtered
        by exact ``trip`` and by whether the body also computes."""
        out = []
        for lp in self.loops:
            if kind is None:
                if lp.kinds or not lp.has_compute:
                    continue
            elif kind not in lp.kinds:
                continue
            if trip is not None and lp.trip != trip:
                continue
            if has_compute is not None and lp.has_compute != has_compute:
                continue
            out.append(lp)
        return out


# ---------------------------------------------------------------------------
# jaxpr walker
# ---------------------------------------------------------------------------

@dataclass
class _BodyStats:
    kinds: set = field(default_factory=set)
    n_collectives: int = 0
    compute: int = 0

    def merge(self, other: "_BodyStats") -> None:
        self.kinds |= other.kinds
        self.n_collectives += other.n_collectives
        self.compute += other.compute


def _sub_jaxprs(params: Dict):
    """Every sub-jaxpr reachable from one equation's params (pjit bodies,
    shard_map bodies, cond branches, custom-derivative calls, ...)."""
    for v in params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for w in vs:
            if hasattr(w, "eqns"):            # raw Jaxpr
                yield w
            elif hasattr(w, "jaxpr"):         # ClosedJaxpr
                yield w.jaxpr

def _walk_jaxpr(jaxpr, depth: int, trip: int, g: OpGraph) -> _BodyStats:
    stats = _BodyStats()
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim in ("scan", "while"):
            body = eqn.params["jaxpr"] if prim == "scan" else (
                eqn.params["body_jaxpr"])
            body = body.jaxpr if hasattr(body, "jaxpr") else body
            length = int(eqn.params.get("length") or 0) if prim == "scan" else 0
            inner = _walk_jaxpr(body, depth + 1, length or trip, g)
            g.loops.append(ChunkLoop(
                trip=length, kinds=tuple(sorted(inner.kinds)),
                n_collectives=inner.n_collectives,
                has_compute=inner.compute > 0, depth=depth, source=prim))
            stats.merge(inner)
        elif prim in JAXPR_COLLECTIVE_KIND:
            kind = JAXPR_COLLECTIVE_KIND[prim]
            g.collectives.append(CollectiveOp(
                kind=kind, raw=prim, trip=trip or 1, depth=depth))
            stats.kinds.add(kind)
            stats.n_collectives += 1
        elif prim in JAXPR_COMPUTE_PRIMS:
            stats.compute += 1
        else:
            for sub in _sub_jaxprs(eqn.params):
                stats.merge(_walk_jaxpr(sub, depth, trip, g))
    return stats


def graph_from_jaxpr(jaxpr) -> OpGraph:
    """Extract the op graph from a (closed) jaxpr — typically
    ``jax.make_jaxpr(fn)(*args)`` of a plan-aware model builder.  Loop
    bodies are walked recursively through every higher-order primitive
    (``pjit``, ``shard_map``, ``scan``, ``while``, ``cond``, custom
    derivative calls); ``lax.map``/``lax.fori_loop`` appear as ``scan``
    with a static ``length``, which is exactly where tuned chunk counts
    materialize."""
    inner = jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr
    g = OpGraph(source="jaxpr")
    top = _walk_jaxpr(inner, 0, 0, g)
    g.compute_ops = top.compute
    return g


# ---------------------------------------------------------------------------
# HLO text walker
# ---------------------------------------------------------------------------

# header = name + parameter list + "->" + result type + "{".  The parameter
# list may itself contain parenthesized tuple types (while bodies take the
# loop carry as one tuple param), so only the prefix is matched and the
# "->"/"{" tail is checked separately.
_HLO_COMP_HEAD = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_HLO_WHILE = re.compile(
    r"\bwhile\(.*?\bcondition=%?([\w.\-]+).*?\bbody=%?([\w.\-]+)"
    r"|\bwhile\(.*?\bbody=%?([\w.\-]+).*?\bcondition=%?([\w.\-]+)")
_HLO_CALL_REFS = re.compile(
    r"\b(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_HLO_BRANCHES = re.compile(r"\bbranch_computations=\{([^}]*)\}")
_HLO_CONST_INT = re.compile(r"\bconstant\((\d+)\)")


def _hlo_computations(hlo_text: str) -> Dict[str, List[str]]:
    """Split HLO text into ``{computation_name: [instruction lines]}``."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[List[str]] = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _HLO_COMP_HEAD.match(line)
            if m and "->" in line and stripped.endswith("{"):
                cur = comps.setdefault(m.group(1), [])
        elif stripped.startswith("}"):
            cur = None
        elif stripped:
            cur.append(stripped)
    return comps


def _shape_bytes(dtype: str, shape: str) -> float:
    if dtype not in _HLO_DTYPE_BYTES:
        return 0.0
    n = 1
    for d in shape.split(","):
        if d.strip().isdigit():
            n *= int(d)
    return float(n * _HLO_DTYPE_BYTES[dtype])


def _line_collectives(line: str):
    """(kind, raw, bytes) for each counted collective on one instruction
    line — async ``-done`` halves are skipped (their ``-start`` counted)."""
    for m in _HLO_COLLECTIVE_RE.finditer(line):
        dtype, shape, base, suffix = m.groups()
        if suffix == "-done":
            continue
        yield (HLO_COLLECTIVE_KIND[base], base + (suffix or ""),
               _shape_bytes(dtype, shape))


def _line_has_compute(line: str) -> bool:
    return any(f" {op}(" in line or f"= {op}(" in line
               for op in HLO_COMPUTE_OPS)


def _while_refs(line: str):
    m = _HLO_WHILE.search(line)
    if not m:
        return None
    cond, body, body2, cond2 = m.groups()
    return (cond or cond2), (body or body2)


def _comp_closure(name: str, comps: Dict[str, List[str]],
                  seen: Optional[set] = None) -> List[str]:
    """Instruction lines of ``name`` plus every computation it references
    (nested whiles, fusions, reducers), cycle-safe."""
    seen = set() if seen is None else seen
    if name in seen or name not in comps:
        return []
    seen.add(name)
    lines = list(comps[name])
    for line in comps[name]:
        for ref in _HLO_CALL_REFS.findall(line):
            lines += _comp_closure(ref, comps, seen)
        bm = _HLO_BRANCHES.search(line)
        if bm:
            for ref in bm.group(1).split(","):
                lines += _comp_closure(ref.strip().lstrip("%"), comps, seen)
    return lines


def _while_trip(cond_lines: List[str]) -> int:
    """Best-effort trip count of a counted HLO while loop: the largest
    integer constant in its condition computation (a scan-lowered loop
    compares the induction variable against the trip count there).
    0 when the bound is not statically visible."""
    consts = [int(x) for line in cond_lines
              for x in _HLO_CONST_INT.findall(line)]
    return max(consts) if consts else 0


def graph_from_hlo(hlo_text: str) -> OpGraph:
    """Extract the op graph from post-SPMD HLO text
    (``compiled.as_text()``).  Every ``while`` instruction becomes a
    :class:`ChunkLoop` summarizing its body's transitive collective and
    compute content, with the trip count recovered from the loop
    condition when XLA kept it statically visible; collectives inside
    loop bodies carry that trip, top-level ones ``trip=1``."""
    comps = _hlo_computations(hlo_text)
    g = OpGraph(source="hlo")

    # while nesting: body computations reachable from other whiles' bodies
    whiles = []           # (cond_name, body_name)
    for lines in comps.values():
        for line in lines:
            refs = _while_refs(line)
            if refs:
                whiles.append(refs)
    body_names = {b for _, b in whiles}
    depth_of: Dict[str, int] = {}

    def depth_for(body: str, seen=()) -> int:
        if body in depth_of:
            return depth_of[body]
        if body in seen:
            return 0
        d = 0
        for cond2, body2 in whiles:
            if body2 == body:
                continue
            closure = set()
            _comp_closure(body2, comps, closure)
            if body in closure:
                d = max(d, depth_for(body2, seen + (body,)) + 1)
        depth_of[body] = d
        return d

    for cond_name, body_name in whiles:
        body_lines = _comp_closure(body_name, comps)
        kinds: set = set()
        n_coll = 0
        compute = False
        for line in body_lines:
            for kind, _raw, _b in _line_collectives(line):
                kinds.add(kind)
                n_coll += 1
            compute = compute or _line_has_compute(line)
        g.loops.append(ChunkLoop(
            trip=_while_trip(comps.get(cond_name, [])),
            kinds=tuple(sorted(kinds)), n_collectives=n_coll,
            has_compute=compute, depth=depth_for(body_name), source="while"))

    # collectives: entry + every computation, annotated with the loop they
    # live in (if any)
    trip_of_body = {b: _while_trip(comps.get(c, [])) for c, b in whiles}
    for name, lines in comps.items():
        in_loop = name in body_names
        trip = trip_of_body.get(name, 0) if in_loop else 1
        dep = (depth_of.get(name, 0) + 1) if in_loop else 0
        for line in lines:
            if _line_has_compute(line):
                g.compute_ops += 1
            for kind, raw, nbytes in _line_collectives(line):
                g.collectives.append(CollectiveOp(
                    kind=kind, raw=raw, bytes=nbytes,
                    trip=trip or 1, depth=dep))
    return g


# ---------------------------------------------------------------------------
# dry-run byte accounting (shared with launch.dryrun)
# ---------------------------------------------------------------------------

def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result bytes of every collective in (post-SPMD) HLO text, keyed
    by base opcode plus a total ``"count"``.  Recognizes the full family
    including async ``-start``/``-done`` pairs, counting each async pair
    once (on its ``-start``) — the dry-run roofline's collective term."""
    out: Dict[str, float] = {op: 0.0 for op in HLO_COLLECTIVE_KIND}
    out["count"] = 0
    for line in hlo_text.splitlines():
        for _kind, raw, nbytes in _line_collectives(line):
            base = raw
            for suf in ASYNC_SUFFIXES:
                if base.endswith(suf):
                    base = base[: -len(suf)]
            out[base] += nbytes
            out["count"] += 1
    return out
