"""Batched serving engine: continuous-batching-lite decode loop with
prefill-into-cache and greedy/temperature sampling.

``serve_step`` (one token against a seq_len cache) is the function the
decode-shape dry-runs lower; the Engine wraps it for the runnable examples.

Plan-aware serving: pass ``plan=`` (a ``TunedPlan``) or ``repo=`` (a
``PlanRepository``) and the engine decodes under that plan's per-site
collective runtimes at the ``serve.layer{i}.*`` SiteIds — applied through
the scoped plan stack per batch, with compiled steps cached per plan
digest so ``set_plan`` hot-swaps between batches retrace instead of
reusing stale chunk structure.

Fault-aware serving: ``fault_schedule=`` arms per-site drift detection
(``serving.health``) — each decoded token advances the batch clock, and a
site whose observed cost drifts past ``health_tolerance`` for
``health_window`` consecutive batches is demoted mid-generate to its
fallback knobs via a transactional plan swap (the demoted plan's step is
retraced before commit; failure rolls back).  ``health_events`` /
``health_report()`` expose the structured degradation log.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.serving.plans import DEFAULT_BAND, PlanBinding
from repro.serving.types import Request

__all__ = ["Engine", "Request", "make_serve_step"]


def _make_retune(binding, retune):
    """Lower the engines' ``retune=`` kwarg to a ``core.retune``
    ``RetuneService``: ``None``/``False`` off, ``True`` defaults, a dict
    of service kwargs, or an already-built service."""
    if not retune:
        return None
    from repro.core.retune import RetuneService

    if isinstance(retune, RetuneService):
        return retune
    opts = {} if retune is True else dict(retune)
    return RetuneService(binding, **opts)


def make_serve_step(cfg, *, backend: Optional[str] = None, mesh=None):
    """serve_step(params, tokens (B,1), caches[, pos_offset (B,)]) ->
    (next (B,1), caches).  ``mesh`` opts dense families into the sited
    explicit-collective decode path (``serve.layer{i}.*``)."""
    def serve_step(params, tokens, caches, pos_offset=None):
        logits, caches = M.decode_step(cfg, params, tokens, caches,
                                       backend=backend, mesh=mesh,
                                       pos_offset=pos_offset)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, caches
    return serve_step


def _invalidate_pad_slots(caches, lens: jnp.ndarray):
    """Mark right-pad KV slots dead per row: ``slot_pos`` leaves are
    (..., B, W); slots at index >= the row's true length get -1 so decode
    never attends to them."""
    def fix(path, leaf):
        if str(getattr(path[-1], "key", "")) != "slot_pos":
            return leaf
        idx = jnp.arange(leaf.shape[-1])
        keep = idx[None, :] < lens[:, None]          # (B, W)
        return jnp.where(keep, leaf, -1)
    return jax.tree_util.tree_map_with_path(fix, caches)


class Engine:
    """Fixed-batch decode engine (the examples' serving driver)."""

    def __init__(self, cfg, params, *, batch_size: int, max_seq: int,
                 backend: Optional[str] = None, plan=None, repo=None,
                 plan_hardware: str = "tpu-v5e", plan_parallel=None,
                 plan_band: float = DEFAULT_BAND, mesh=None,
                 fault_schedule=None, health_window: int = 3,
                 health_tolerance: float = 0.25, retune=None,
                 plan_lint: str = "error"):
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_seq = max_seq
        self.backend = backend
        self._binding = PlanBinding(cfg, plan=plan, repo=repo,
                                    hardware=plan_hardware,
                                    parallel=plan_parallel, band=plan_band,
                                    max_seq=max_seq, lint=plan_lint)
        if fault_schedule is not None:
            self._binding.attach_faults(fault_schedule,
                                        tolerance=health_tolerance,
                                        window=health_window)
        self.retune_service = _make_retune(self._binding, retune)
        if mesh is None and self._binding.bound and cfg.family in (
                "dense", "moe", "vlm"):
            from repro.launch.mesh import make_mesh
            mesh = make_mesh((jax.device_count(),), ("model",))
        self.mesh = mesh
        self._fns: Dict[tuple, Tuple] = {}     # plan digest -> (step, prefill)

    # ------------------------------------------------------------------
    def set_plan(self, plan) -> None:
        """Hot-swap the tuned plan between batches (TunedPlan, path to its
        JSON, runtime dict, or None to unpin)."""
        self._binding.set_plan(plan)

    @property
    def plan_stats(self) -> Dict[str, int]:
        return dict(self._binding.stats)

    @property
    def health_events(self) -> List[Dict]:
        """Structured degradation log: drift detections, demotions (with
        rollback status) and band-widening events, in order."""
        return list(self._binding.events)

    def health_report(self) -> str:
        return self._binding.health_report()

    @property
    def telemetry(self):
        """The binding's live ``SiteTelemetry`` ring buffer (one row of
        observed per-site costs per served batch)."""
        return self._binding.telemetry

    def _compiled(self, rt) -> Tuple:
        """The (step, prefill) pair traced under plan ``rt`` — cached per
        plan digest so a hot-swap retraces instead of reusing the old
        chunk structure."""
        key = self._binding.digest(rt)
        if key not in self._fns:
            cfg, backend, mesh = self.cfg, self.backend, self.mesh
            with self._binding.scope(rt):
                step = jax.jit(make_serve_step(cfg, backend=backend, mesh=mesh))
                prefill = jax.jit(
                    lambda p, b, c: M.forward_hidden(cfg, p, b, c,
                                                     backend=backend,
                                                     mesh=mesh)[1])
            self._fns[key] = (step, prefill)
        return self._fns[key]

    # ------------------------------------------------------------------
    def generate(self, prompts: List[np.ndarray], *, max_new: int = 32,
                 frames: Optional[np.ndarray] = None) -> List[List[int]]:
        assert len(prompts) == self.batch
        rt = self._binding.resolve(self.batch)
        step, prefill = self._compiled(rt)
        plen = max(len(p) for p in prompts)
        toks = np.zeros((self.batch, plen), np.int32)
        lens = np.asarray([len(p) for p in prompts], np.int32)
        for i, p in enumerate(prompts):    # right-pad; causal mask + per-row
            toks[i, :len(p)] = p           # slot_pos invalidation keep pads out
        with self._binding.scope(rt):
            caches = M.init_caches(self.cfg, self.batch, self.max_seq)
            if self.cfg.family == "audio":
                assert frames is not None
                caches["memory"] = jnp.asarray(frames)
            batch = {"tokens": jnp.asarray(toks)}
            caches = self._prefill_ragged(prefill, batch, caches, lens)
            # decode each row from its true last token; the shared position
            # counter sits at plen, so subtract each row's pad gap.
            cur = jnp.asarray(toks[np.arange(self.batch), lens - 1][:, None])
            offs = jnp.asarray(plen - lens, jnp.int32)
            outs: List[List[int]] = [[] for _ in range(self.batch)]
            for _ in range(max_new):
                t0 = time.perf_counter()
                cur, caches = step(self.params, cur, caches, offs)
                row = np.asarray(cur)[:, 0]          # device sync
                dt = time.perf_counter() - t0
                for i, t in enumerate(row):
                    outs[i].append(int(t))
                drifted = self._binding.health_tick(dt)
                if drifted:
                    # drift-scoped online re-tune first (zero-downtime plan
                    # swap between tokens); when the service declines —
                    # rate-limited, budget spent, or not armed — fall back
                    # to transactional demotion: the new plan's step is
                    # traced before the swap commits, then decode continues.
                    # Plans bind at trace time, so the enclosing scope
                    # (entered under the old plan) cannot leak in.
                    retuned = (self.retune_service.handle(drifted)
                               if self.retune_service is not None else None)
                    if retuned is None:
                        self._binding.demote(drifted, apply=self._compiled)
                    step, _ = self._compiled(self._binding.current)
        return outs

    def _prefill_ragged(self, prefill, batch, caches, lens: np.ndarray):
        caches = prefill(self.params, batch, caches)
        if self.cfg.family in ("ssm", "hybrid"):
            # recurrent states absorb right padding; equal-length prompts
            # only (same limitation as the continuous engine's admits).
            assert len(set(lens.tolist())) == 1, \
                "ssm/hybrid serving needs equal-length prompts"
            return caches
        return _invalidate_pad_slots(caches, jnp.asarray(lens))

    # ------------------------------------------------------------------
    def throughput_probe(self, *, steps: int = 8) -> Dict[str, float]:
        rt = self._binding.resolve(self.batch)
        step, _ = self._compiled(rt)
        with self._binding.scope(rt):
            caches = M.init_caches(self.cfg, self.batch, self.max_seq)
            if self.cfg.family == "audio":
                caches["memory"] = jnp.zeros(
                    (self.batch, self.cfg.encoder_seq, self.cfg.d_model))
            cur = jnp.zeros((self.batch, 1), jnp.int32)
            offs = jnp.zeros((self.batch,), jnp.int32)
            cur, caches = step(self.params, cur, caches, offs)   # compile
            jax.block_until_ready(cur)
            t0 = time.perf_counter()
            for _ in range(steps):
                cur, caches = step(self.params, cur, caches, offs)
            jax.block_until_ready(cur)
        dt = (time.perf_counter() - t0) / steps
        return {"s_per_token": dt, "tokens_per_s": self.batch / dt}
