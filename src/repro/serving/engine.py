"""Batched serving engine: continuous-batching-lite decode loop with
prefill-into-cache and greedy/temperature sampling.

``serve_step`` (one token against a seq_len cache) is the function the
decode-shape dry-runs lower; the Engine wraps it for the runnable examples.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M


@dataclass
class Request:
    prompt: np.ndarray            # (S,) int32
    max_new: int = 32
    out: List[int] = field(default_factory=list)


def make_serve_step(cfg, *, backend: Optional[str] = None):
    """serve_step(params, tokens (B,1), caches) -> (next (B,1), caches)."""
    def serve_step(params, tokens, caches):
        logits, caches = M.decode_step(cfg, params, tokens, caches,
                                       backend=backend)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, caches
    return serve_step


class Engine:
    """Fixed-batch decode engine (the examples' serving driver)."""

    def __init__(self, cfg, params, *, batch_size: int, max_seq: int,
                 backend: Optional[str] = None):
        self.cfg = cfg
        self.params = params
        self.batch = batch_size
        self.max_seq = max_seq
        self.backend = backend
        self._step = jax.jit(make_serve_step(cfg, backend=backend))
        self._prefill = jax.jit(
            lambda p, b, c: M.forward_hidden(cfg, p, b, c, backend=backend)[1])

    def generate(self, prompts: List[np.ndarray], *, max_new: int = 32,
                 frames: Optional[np.ndarray] = None) -> List[List[int]]:
        assert len(prompts) == self.batch
        plen = max(len(p) for p in prompts)
        toks = np.zeros((self.batch, plen), np.int32)
        for i, p in enumerate(prompts):    # left-pad-free: right-align naive
            toks[i, :len(p)] = p
        caches = M.init_caches(self.cfg, self.batch, self.max_seq)
        if self.cfg.family == "audio":
            assert frames is not None
            caches["memory"] = jnp.asarray(frames)
        batch = {"tokens": jnp.asarray(toks)}
        caches = self._prefill(self.params, batch, caches)
        cur = jnp.asarray(toks[:, -1:])
        outs: List[List[int]] = [[] for _ in range(self.batch)]
        for _ in range(max_new):
            cur, caches = self._step(self.params, cur, caches)
            for i, t in enumerate(np.asarray(cur)[:, 0]):
                outs[i].append(int(t))
        return outs

    def throughput_probe(self, *, steps: int = 8) -> Dict[str, float]:
        caches = M.init_caches(self.cfg, self.batch, self.max_seq)
        if self.cfg.family == "audio":
            caches["memory"] = jnp.zeros(
                (self.batch, self.cfg.encoder_seq, self.cfg.d_model))
        cur = jnp.zeros((self.batch, 1), jnp.int32)
        cur, caches = self._step(self.params, cur, caches)   # compile
        jax.block_until_ready(cur)
        t0 = time.perf_counter()
        for _ in range(steps):
            cur, caches = self._step(self.params, cur, caches)
        jax.block_until_ready(cur)
        dt = (time.perf_counter() - t0) / steps
        return {"s_per_token": dt, "tokens_per_s": self.batch / dt}
