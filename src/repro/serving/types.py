"""Shared serving types.

``Request`` used to exist twice — one shape in ``serving.engine``, another
in ``serving.continuous`` — so request objects could not flow between the
fixed-batch and continuous engines.  This is the one definition, re-exported
from both engine modules for compatibility.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class Request:
    """One generation request: ``rid`` caller-chosen id, ``prompt`` (S,)
    int32 token ids, ``max_new`` the decode budget, ``out`` the generated
    tokens (appended in place by the engines)."""

    rid: int = 0
    prompt: Optional[np.ndarray] = None
    max_new: int = 32
    out: List[int] = field(default_factory=list)
