"""Serving front door.

  types      — the shared Request dataclass
  engine     — fixed-batch lockstep Engine (+ make_serve_step)
  continuous — ContinuousEngine (per-slot caches, admit-time plan re-resolve)
  plans      — PlanBinding: scoped plan application + hot-swap digests
  health     — HealthMonitor drift detection + predicted site costs
  telemetry  — SiteTelemetry ring buffer (the re-tune loop's evidence)

``make_engine`` is the one constructor: pick an engine by ``mode`` and
hand both the same plan surface (``plan=`` pinned TunedPlan, ``repo=``
tolerance-band PlanRepository).  New engine implementations register with
``register_engine`` — the same registry pattern as the tuning session's
SearchBackend.
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Engine, make_serve_step
from repro.serving.plans import DEFAULT_BAND, PlanBinding
from repro.serving.telemetry import SiteTelemetry
from repro.serving.types import Request

__all__ = [
    "ContinuousEngine",
    "DEFAULT_BAND",
    "Engine",
    "PlanBinding",
    "Request",
    "SiteTelemetry",
    "available_engines",
    "make_engine",
    "make_serve_step",
    "register_engine",
]

_ENGINES: Dict[str, Callable] = {}


def register_engine(name: str, *, overwrite: bool = False):
    """Decorator registering an engine constructor under ``mode`` name."""

    def deco(ctor):
        if name in _ENGINES and not overwrite:
            raise ValueError(f"engine mode {name!r} already registered")
        _ENGINES[name] = ctor
        return ctor

    return deco


def available_engines():
    return sorted(_ENGINES)


@register_engine("fixed")
def _fixed(cfg, params, **kw):
    return Engine(cfg, params, **kw)


@register_engine("continuous")
def _continuous(cfg, params, **kw):
    return ContinuousEngine(cfg, params, **kw)


def make_engine(cfg, params, *, mode: str = "fixed", **kw):
    """Build a serving engine.

    ``mode`` — "fixed" (lockstep Engine; needs ``batch_size=``) or
    "continuous" (ContinuousEngine; needs ``slots=``).  Both accept
    ``max_seq=`` plus the plan surface: ``plan=`` / ``repo=`` /
    ``plan_hardware=`` / ``plan_parallel=`` / ``plan_band=`` / ``mesh=``,
    the fault-aware lifecycle (``fault_schedule=`` / ``health_window=`` /
    ``health_tolerance=``) and the online re-tune loop (``retune=`` —
    ``True``, a dict of ``core.retune.RetuneService`` kwargs, or a
    pre-built service).
    """
    try:
        ctor = _ENGINES[mode]
    except KeyError:
        avail = available_engines()
        raise KeyError(f"unknown engine mode {mode!r}; available: {avail}") from None
    return ctor(cfg, params, **kw)
