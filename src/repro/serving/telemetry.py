"""Live per-site timing telemetry for the serving engines.

The engines already time every decode step; this module turns that wall
clock plus the per-site observed costs the health path computes into a
structured, bounded record the online re-tune loop can consume:
``PlanBinding.health_tick`` records one ``SiteTelemetry`` row per served
batch, and ``core.retune`` reads the most recent window back out as the
observed-cost evidence it calibrates the simulator's hardware model from.

The buffer is a plain ring (``collections.deque(maxlen=...)``): serving
runs for millions of batches, the re-tuner only ever needs the recent
past, and a bounded buffer means the telemetry path can never grow the
engine's memory footprint.

    >>> tel = SiteTelemetry(capacity=2)
    >>> tel.record(0, {"serve.layer0.attn.ar": 1.0})
    >>> tel.record(1, {"serve.layer0.attn.ar": 3.0}, step_s=0.01)
    >>> tel.record(2, {"serve.layer0.attn.ar": 5.0})
    >>> len(tel)            # capacity 2: batch 0 fell off
    2
    >>> tel.latest()
    {'serve.layer0.attn.ar': 5.0}
    >>> tel.mean()["serve.layer0.attn.ar"]
    4.0
"""

from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional

DEFAULT_CAPACITY = 256


class SiteTelemetry:
    """Bounded ring buffer of per-batch observed site costs.

    Each row is ``{"batch": int, "costs": {site_id: seconds},
    "step_s": float | None}``.  ``record`` appends (evicting the oldest
    row past ``capacity``); ``latest``/``mean`` are the read surface the
    re-tune loop uses.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity!r}")
        self.capacity = capacity
        self._rows: deque = deque(maxlen=capacity)

    def record(
        self,
        batch: int,
        costs: Dict[str, float],
        *,
        step_s: Optional[float] = None,
    ) -> None:
        """Append one served batch's observed per-site costs (seconds)
        plus the measured wall time of the whole step, if known."""
        self._rows.append(
            {"batch": int(batch), "costs": dict(costs), "step_s": step_s}
        )

    def rows(self) -> List[Dict]:
        """The buffered rows, oldest first (copies — mutating a returned
        row never reaches the buffer)."""
        return [dict(r, costs=dict(r["costs"])) for r in self._rows]

    def latest(self) -> Dict[str, float]:
        """The most recent non-empty per-site cost map (``{}`` when the
        buffer is empty or holds only cost-less rows)."""
        for r in reversed(self._rows):
            if r["costs"]:
                return dict(r["costs"])
        return {}

    def mean(self, window: int = 8) -> Dict[str, float]:
        """Per-site mean cost over the last ``window`` rows — a smoother
        calibration input than a single batch when the fabric jitters.
        Sites missing from some rows average over the rows that carry
        them."""
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window!r}")
        acc: Dict[str, float] = {}
        n: Dict[str, int] = {}
        for r in list(self._rows)[-window:]:
            for sid, c in r["costs"].items():
                acc[sid] = acc.get(sid, 0.0) + c
                n[sid] = n.get(sid, 0) + 1
        return {sid: acc[sid] / n[sid] for sid in acc}

    def clear(self) -> None:
        self._rows.clear()

    def __len__(self) -> int:
        return len(self._rows)


__all__ = ["DEFAULT_CAPACITY", "SiteTelemetry"]
