"""Continuous batching: per-slot caches + request queue.

The fixed-batch Engine decodes in lockstep (one shared position counter).
This engine vmaps the single-sequence decode over a slot axis, so every
slot has its own position/cache state; finished slots are refilled from the
queue without disturbing the others — the standard continuous-batching
serving loop, built on the same ``model.decode_step``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S,) int32
    max_new: int
    out: List[int] = field(default_factory=list)


class ContinuousEngine:
    """``slots`` independent sequences decoded as one vmapped batch."""

    def __init__(self, cfg, params, *, slots: int, max_seq: int,
                 eos_id: Optional[int] = None):
        assert cfg.family != "audio", "continuous engine is decoder-only"
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.eos_id = eos_id

        # per-slot caches: the B axis of one shared pytree acts as the slot
        # axis; decode is vmapped over it so each slot keeps its own pos.
        self.caches = jax.vmap(lambda _: M.init_caches(cfg, 1, max_seq))(
            jnp.arange(slots))

        def step_one(params, tok, cache):
            logits, cache = M.decode_step(cfg, params, tok[None, None], cache)
            nxt = jnp.argmax(logits[0, -1]).astype(jnp.int32)
            return nxt, cache

        self._step = jax.jit(jax.vmap(step_one, in_axes=(None, 0, 0)))

        def prefill_one(params, toks, length, cache):
            # right-padded prompt: clamp pos back to the true length and
            # invalidate padded KV slots (slot_pos = -1) so decode never
            # attends to them.  NOTE: SSM/hybrid states absorb padding during
            # a padded prefill — those families need length-bucketed admits
            # (documented limitation of this demo engine).
            _, cache, _ = M.forward_hidden(cfg, params, {"tokens": toks[None]},
                                           cache)

            def fix(path, leaf):
                name = str(getattr(path[-1], "key", ""))
                if name == "slot_pos":        # (..., W)
                    idx = jnp.arange(leaf.shape[-1])
                    return jnp.where(idx < length, leaf, -1)
                return leaf

            cache = jax.tree_util.tree_map_with_path(fix, cache)
            return dict(cache, pos=length.astype(jnp.int32))

        self._prefill = jax.jit(jax.vmap(prefill_one, in_axes=(None, 0, 0, 0)))

        self._active: Dict[int, Request] = {}      # slot -> request
        self._queue: List[Request] = []
        self._cur = jnp.zeros((slots,), jnp.int32)

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self._queue.append(req)

    def _admit(self) -> None:
        free = [s for s in range(self.slots) if s not in self._active]
        admits = []
        while free and self._queue:
            slot = free.pop(0)
            req = self._queue.pop(0)
            self._active[slot] = req
            admits.append((slot, req))
        if not admits:
            return
        plen = max(len(r.prompt) for _, r in admits)
        toks = np.zeros((len(admits), plen), np.int32)
        lens = np.zeros((len(admits),), np.int32)
        for i, (_, r) in enumerate(admits):
            toks[i, :len(r.prompt)] = r.prompt
            lens[i] = len(r.prompt)
        fresh = jax.vmap(lambda _: M.init_caches(self.cfg, 1, self.max_seq))(
            jnp.arange(len(admits)))
        filled = self._prefill(self.params, jnp.asarray(toks),
                               jnp.asarray(lens), fresh)
        # scatter the admitted slots' caches / current tokens into place
        slot_ids = jnp.asarray([s for s, _ in admits])
        self.caches = jax.tree.map(
            lambda all_, new: all_.at[slot_ids].set(new), self.caches, filled)
        last = jnp.asarray([int(r.prompt[-1]) for _, r in admits], jnp.int32)
        self._cur = self._cur.at[slot_ids].set(last)

    # ------------------------------------------------------------------
    def run(self, *, max_ticks: int = 1000) -> List[Request]:
        """Drive until queue + active slots drain; returns finished requests."""
        done: List[Request] = []
        for _ in range(max_ticks):
            self._admit()
            if not self._active:
                break
            nxt, self.caches = self._step(self.params, self._cur, self.caches)
            self._cur = nxt
            finished = []
            for slot, req in self._active.items():
                t = int(nxt[slot])
                req.out.append(t)
                if len(req.out) >= req.max_new or t == self.eos_id:
                    finished.append(slot)
            for slot in finished:
                done.append(self._active.pop(slot))
        return done
