"""Continuous batching: per-slot caches + request queue.

The fixed-batch Engine decodes in lockstep (one shared position counter).
This engine vmaps the single-sequence decode over a slot axis, so every
slot has its own position/cache state; finished slots are refilled from the
queue without disturbing the others — the standard continuous-batching
serving loop, built on the same ``model.decode_step``.

Plan-aware serving: with ``repo=`` the engine re-resolves the tuned plan at
admit time — the in-flight batch shape drifts as requests arrive and
finish, and the repository's tolerance band (exact fingerprint first, then
nearest same-structure shape) picks the plan for the current shape.  With
``plan=`` the plan is pinned; ``set_plan`` hot-swaps it between ticks.
Compiled steps are cached per plan digest, so a swap retraces rather than
reusing chunk structure from the previous plan.

Fault-aware serving mirrors the fixed-batch engine: ``fault_schedule=``
arms per-site drift detection, and a flagged site is demoted between
ticks via a transactional plan swap — the loop naturally picks up the
degraded plan's compiled step on its next iteration.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.serving.plans import DEFAULT_BAND, PlanBinding
from repro.serving.types import Request

__all__ = ["ContinuousEngine", "Request"]


class ContinuousEngine:
    """``slots`` independent sequences decoded as one vmapped batch."""

    def __init__(self, cfg, params, *, slots: int, max_seq: int,
                 eos_id: Optional[int] = None, plan=None, repo=None,
                 plan_hardware: str = "tpu-v5e", plan_parallel=None,
                 plan_band: float = DEFAULT_BAND, mesh=None,
                 fault_schedule=None, health_window: int = 3,
                 health_tolerance: float = 0.25, retune=None,
                 plan_lint: str = "error"):
        assert cfg.family != "audio", "continuous engine is decoder-only"
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self._binding = PlanBinding(cfg, plan=plan, repo=repo,
                                    hardware=plan_hardware,
                                    parallel=plan_parallel, band=plan_band,
                                    max_seq=max_seq, lint=plan_lint)
        if fault_schedule is not None:
            self._binding.attach_faults(fault_schedule,
                                        tolerance=health_tolerance,
                                        window=health_window)
        from repro.serving.engine import _make_retune
        self.retune_service = _make_retune(self._binding, retune)
        if mesh is None and self._binding.bound and cfg.family in (
                "dense", "moe", "vlm"):
            from repro.launch.mesh import make_mesh
            mesh = make_mesh((jax.device_count(),), ("model",))
        self.mesh = mesh

        # per-slot caches: the B axis of one shared pytree acts as the slot
        # axis; decode is vmapped over it so each slot keeps its own pos.
        self.caches = jax.vmap(lambda _: M.init_caches(cfg, 1, max_seq))(
            jnp.arange(slots))

        self._fns: Dict[tuple, Tuple] = {}     # plan digest -> (step, prefill)
        self._active: Dict[int, Request] = {}      # slot -> request
        self._queue: List[Request] = []
        self._cur = jnp.zeros((slots,), jnp.int32)
        self._resolved_n: Optional[int] = None     # batch size last resolved

    # ------------------------------------------------------------------
    def set_plan(self, plan) -> None:
        """Hot-swap the tuned plan between batches (TunedPlan, path to its
        JSON, runtime dict, or None to unpin)."""
        self._binding.set_plan(plan)

    @property
    def plan_stats(self) -> Dict[str, int]:
        return dict(self._binding.stats)

    @property
    def health_events(self) -> List[Dict]:
        """Structured degradation log (drift / demotion / band events)."""
        return list(self._binding.events)

    def health_report(self) -> str:
        return self._binding.health_report()

    @property
    def telemetry(self):
        """The binding's live ``SiteTelemetry`` ring buffer."""
        return self._binding.telemetry

    def _compiled(self, rt) -> Tuple:
        key = self._binding.digest(rt)
        if key in self._fns:
            return self._fns[key]
        cfg, mesh = self.cfg, self.mesh

        def step_one(params, tok, cache):
            logits, cache = M.decode_step(cfg, params, tok[None, None], cache,
                                          mesh=mesh)
            nxt = jnp.argmax(logits[0, -1]).astype(jnp.int32)
            return nxt, cache

        def prefill_one(params, toks, length, cache):
            # right-padded prompt: clamp pos back to the true length and
            # invalidate padded KV slots (slot_pos = -1) so decode never
            # attends to them.  NOTE: SSM/hybrid states absorb padding during
            # a padded prefill — those families need length-bucketed admits
            # (documented limitation of this demo engine).
            _, cache, _ = M.forward_hidden(cfg, params, {"tokens": toks[None]},
                                           cache, mesh=mesh)

            def fix(path, leaf):
                name = str(getattr(path[-1], "key", ""))
                if name == "slot_pos":        # (..., W)
                    idx = jnp.arange(leaf.shape[-1])
                    return jnp.where(idx < length, leaf, -1)
                return leaf

            cache = jax.tree_util.tree_map_with_path(fix, cache)
            return dict(cache, pos=length.astype(jnp.int32))

        with self._binding.scope(rt):
            step = jax.jit(jax.vmap(step_one, in_axes=(None, 0, 0)))
            prefill = jax.jit(jax.vmap(prefill_one, in_axes=(None, 0, 0, 0)))
        self._fns[key] = (step, prefill)
        return self._fns[key]

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self._queue.append(req)

    def _admit(self, prefill) -> None:
        free = [s for s in range(self.slots) if s not in self._active]
        admits = []
        while free and self._queue:
            slot = free.pop(0)
            req = self._queue.pop(0)
            self._active[slot] = req
            admits.append((slot, req))
        if not admits:
            return
        plen = max(len(r.prompt) for _, r in admits)
        toks = np.zeros((len(admits), plen), np.int32)
        lens = np.zeros((len(admits),), np.int32)
        for i, (_, r) in enumerate(admits):
            toks[i, :len(r.prompt)] = r.prompt
            lens[i] = len(r.prompt)
        fresh = jax.vmap(lambda _: M.init_caches(self.cfg, 1, self.max_seq))(
            jnp.arange(len(admits)))
        filled = prefill(self.params, jnp.asarray(toks),
                         jnp.asarray(lens), fresh)
        # scatter the admitted slots' caches / current tokens into place
        slot_ids = jnp.asarray([s for s, _ in admits])
        self.caches = jax.tree.map(
            lambda all_, new: all_.at[slot_ids].set(new), self.caches, filled)
        last = jnp.asarray([int(r.prompt[-1]) for _, r in admits], jnp.int32)
        self._cur = self._cur.at[slot_ids].set(last)

    # ------------------------------------------------------------------
    def run(self, *, max_ticks: int = 1000) -> List[Request]:
        """Drive until queue + active slots drain; returns finished requests."""
        done: List[Request] = []
        for _ in range(max_ticks):
            if not self._active and not self._queue:
                break
            # admissions change the in-flight shape, so re-resolve the plan
            # (repo-bound engines may land on a different banded hit) before
            # tracing/looking up this tick's compiled functions.  Only the
            # shape matters, so an unchanged batch size keeps its plan.
            n_after = max(1, min(self.slots,
                                 len(self._active) + len(self._queue)))
            if n_after != self._resolved_n:
                self._binding.resolve(n_after)
                self._resolved_n = n_after
            rt = self._binding.current
            step, prefill = self._compiled(rt)
            with self._binding.scope(rt):
                self._admit(prefill)
                if not self._active:
                    break
                t0 = time.perf_counter()
                nxt, self.caches = step(self.params, self._cur, self.caches)
                nxt.block_until_ready()
                dt = time.perf_counter() - t0
            drifted = self._binding.health_tick(dt)
            if drifted:
                # online re-tune first; demote when the service declines.
                # Either way the loop re-fetches the compiled step from
                # the swapped plan on the next tick (zero dropped tokens).
                retuned = (self.retune_service.handle(drifted)
                           if self.retune_service is not None else None)
                if retuned is None:
                    self._binding.demote(drifted, apply=self._compiled)
            self._cur = nxt
            finished = []
            for slot, req in self._active.items():
                t = int(nxt[slot])
                req.out.append(t)
                if len(req.out) >= req.max_new or t == self.eos_id:
                    finished.append(slot)
            for slot in finished:
                done.append(self._active.pop(slot))
        return done
