"""Per-site runtime health: drift detection against a plan's predictions.

A tuned plan is a *prediction* — each ``serve.*`` comm site should cost
what the contention model priced it at on healthy hardware.  This module
closes the loop at serving time:

``predicted_site_costs``
    Re-prices every comm site embedded in a ``TunedPlan`` (the plan is
    self-contained: its ``sites`` metadata rebuilds each ``CommOp``)
    under the plan's own tuned config and hardware profile — the
    per-site baseline the monitor compares against.

``HealthMonitor``
    The K-consecutive-drift detector: feed it per-batch observed site
    costs; a site whose observed cost exceeds its prediction by more
    than ``tolerance`` (relative) for ``window`` consecutive batches is
    flagged unhealthy exactly once — the signal ``PlanBinding.demote``
    acts on.

``SimulatedTelemetry``
    Observed-cost source for drills and tests: replays a
    ``core.faults.FaultSchedule`` against the plan's sites, so observed
    == predicted while the fabric is healthy and diverges exactly when a
    bandwidth fault window (degrade/flap) covers a site.  Real
    deployments would feed ``HealthMonitor.observe`` from measured
    per-site timings instead; the monitor does not care where the
    numbers come from.
"""

from __future__ import annotations

from typing import Dict, List, Optional

from repro.core import contention
from repro.core.comm_params import vendor_default
from repro.core.faults import FaultSchedule, degraded_hardware
from repro.core.hardware import Hardware
from repro.core.session import TunedPlan, _lookup_hw
from repro.core.workload import CommOp


def _site_ops(plan: TunedPlan):
    """``(site_id, class, CommOp, CommConfig)`` for every comm site the
    plan carries metadata for (tuned config, vendor default when a site
    has none)."""
    hw = _lookup_hw(plan.hardware)
    rows = []
    for s in plan.sites:
        op = CommOp(
            name=s["name"],
            kind=s["kind"],
            bytes=s["bytes"],
            group_size=s["group_size"],
            site=s.get("site", ""),
        )
        cfg = plan.configs.get((s["group"], s["comm"])) or vendor_default(hw)
        rows.append((op.site_id, s["name"].split(".", 1)[0], op, cfg))
    return rows


def predicted_site_costs(
    plan: TunedPlan, hardware: Optional[Hardware] = None
) -> Dict[str, float]:
    """Each comm site's standalone cost (seconds) under the plan's tuned
    config on ``hardware`` (default: the plan's own profile) — the
    baseline ``HealthMonitor`` measures drift against.

    A re-tuned plan carries calibration lineage (``core.retune``): sites
    it re-searched under a degraded hardware model are priced on that
    *calibrated* fabric, so the monitor expects the degraded cost and a
    still-degraded link no longer reads as drift — only *new* movement
    beyond the calibrated state re-flags.

    Args:
        plan: the installed ``TunedPlan`` (self-contained site metadata).
        hardware: override profile; default is the plan's own.

    Returns:
        ``{site_id: seconds}`` for every comm site the plan carries.
    """
    hw = hardware if hardware is not None else _lookup_hw(plan.hardware)
    calibration = (plan.lineage or {}).get("calibration", {})
    out = {}
    for sid, _cls, op, cfg in _site_ops(plan):
        site_hw = hw
        cal = calibration.get(sid)
        if cal and cal.get("scale", 1.0) < 1.0:
            site_hw = degraded_hardware(hw, float(cal["scale"]))
        out[sid] = contention.comm_time(op, cfg, site_hw, compute_active=False)
    return out


class HealthMonitor:
    """Flag sites whose observed cost drifts beyond ``tolerance`` of the
    prediction for ``window`` consecutive observations.

    Args:
        predicted: ``{site_id: seconds}`` baseline (typically
            ``predicted_site_costs(plan)``).
        tolerance: relative drift (``observed/predicted - 1``) that
            counts as a drifted observation; must be > 0.
        window: consecutive drifted observations before a site is
            flagged (K of the K-consecutive detector); must be >= 1.

    Raises:
        ValueError: non-positive ``tolerance`` or ``window`` < 1.

    Example — two drifted batches flag at window=2, exactly once::

        >>> mon = HealthMonitor({"s": 1.0}, tolerance=0.25, window=2)
        >>> mon.observe(0, {"s": 2.0})
        []
        >>> mon.observe(1, {"s": 2.0})
        ['s']
        >>> mon.observe(2, {"s": 2.0})   # already flagged: reported once
        []
        >>> mon.reset(); mon.unhealthy   # a plan swap re-arms the site
        set()
    """

    def __init__(
        self,
        predicted: Dict[str, float],
        *,
        tolerance: float = 0.25,
        window: int = 3,
    ):
        if tolerance <= 0:
            raise ValueError(f"tolerance must be > 0, got {tolerance!r}")
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window!r}")
        self.predicted = dict(predicted)
        self.tolerance = tolerance
        self.window = window
        self.unhealthy: set = set()
        self._streak: Dict[str, int] = {}
        self.last_drift: Dict[str, float] = {}

    def observe(self, batch_idx: int, observed: Dict[str, float]) -> List[str]:
        """Record one batch's observed per-site costs; returns the sites
        that just crossed the K-consecutive threshold (each site is
        reported once — it stays in ``unhealthy`` until ``reset``)."""
        newly: List[str] = []
        for sid, cost in observed.items():
            want = self.predicted.get(sid)
            if not want:
                continue
            drift = cost / want - 1.0
            self.last_drift[sid] = drift
            if drift > self.tolerance:
                self._streak[sid] = self._streak.get(sid, 0) + 1
                if self._streak[sid] >= self.window and sid not in self.unhealthy:
                    self.unhealthy.add(sid)
                    newly.append(sid)
            else:
                self._streak[sid] = 0
        return sorted(newly)

    def reset(self, sites=None) -> None:
        """Forget drift state (all sites, or just ``sites``) — e.g. after
        the fabric recovers or a re-tuned plan replaces predictions."""
        targets = set(self.predicted) if sites is None else set(sites)
        self.unhealthy -= targets
        for sid in targets:
            self._streak.pop(sid, None)
            self.last_drift.pop(sid, None)


class SimulatedTelemetry:
    """Per-batch observed site costs generated by replaying a fault
    schedule against the plan's comm sites (see module docstring)."""

    def __init__(
        self,
        plan: TunedPlan,
        schedule: Optional[FaultSchedule] = None,
        hardware: Optional[Hardware] = None,
    ):
        self.hw = hardware if hardware is not None else _lookup_hw(plan.hardware)
        self.schedule = schedule if schedule else None
        self._rows = _site_ops(plan)
        self._healthy = {
            sid: contention.comm_time(op, cfg, self.hw, compute_active=False)
            for sid, _cls, op, cfg in self._rows
        }

    def observe(self, batch_idx: int) -> Dict[str, float]:
        """Observed cost per site at ``batch_idx`` — the healthy predicted
        cost unless a bandwidth fault window is active on that site."""
        state = self.schedule.state_at(batch_idx) if self.schedule else None
        if state is None or not state.comm_events:
            return dict(self._healthy)
        out = {}
        for sid, cls, op, cfg in self._rows:
            hw = state.hardware_for(sid, cls, self.hw)
            if hw is self.hw:
                out[sid] = self._healthy[sid]
            else:
                out[sid] = contention.comm_time(op, cfg, hw, compute_active=False)
        return out


__all__ = [
    "HealthMonitor",
    "SimulatedTelemetry",
    "predicted_site_costs",
]
