"""Plan resolution, hot-swap and fault-aware degradation state for the
serving engines.

Both engines carry a ``PlanBinding``: either a pinned ``TunedPlan``
(``plan=``, hot-swappable between batches via ``set_plan``) or a
``PlanRepository`` (``repo=``) that is re-resolved as the decode batch
shape drifts under traffic — exact fingerprint first, then the tolerance
band (``PlanRepository.resolve(band=...)``).

Two mechanics matter here:

* **Scoping** — a resolved plan is applied through the scoped
  ``collectives.use_runtime_plan`` stack, never a process-global install,
  so every exit path (normal or exceptional) restores the ambient plan
  and two engines in one process can serve under different plans.
* **Trace staleness** — plans are consumed at *trace* time, so a jitted
  decode step keeps the plan it was traced under.  Engines key their
  compiled-step caches on ``digest()``; a hot-swap lands on a different
  key and retraces instead of silently reusing the old chunk structure.

Fault-aware lifecycle (``serving.health`` + ``core.faults``):

* **Drift detection** — ``attach_faults`` arms a per-site
  ``HealthMonitor`` against the bound plan's predicted costs, fed by
  simulated telemetry replaying the fault schedule per served batch
  (``health_tick``).  Sites that drift past tolerance for K consecutive
  batches come back as demotion candidates.
* **Graceful degradation** — ``demote`` swaps in a new runtime plan whose
  affected sites carry fallback knobs (XLA default or their class
  bucket), *scoped to those sites only* and transactional: an exception
  from the engine's apply callback rolls back to the prior plan and
  re-raises.  Every demotion/rollback lands in ``events``.
* **Band backoff** — repeated repository misses widen the resolution
  band with capped exponential backoff; any hit resets it to the
  operator's configured band.
"""

from __future__ import annotations

import contextlib
import os
from typing import Dict, List, Optional, Union

from repro.core.apply import plan_digest
from repro.core.extract import ParallelPlan, extract_decode_workload, parse_parallel
from repro.core.faults import parse_fault_schedule
from repro.core.plan_repo import as_repository
from repro.core.session import TunedPlan
from repro.parallel import collectives as C
from repro.serving.telemetry import SiteTelemetry

DEFAULT_BAND = 0.5
BAND_CAP = 2.0  # backoff ceiling: 3x shape deviation is already a re-tune
_MIN_BAND = 0.05  # backoff floor so band=0.0 repos still start widening


class PlanBinding:
    """Per-engine plan state; see module docstring.  ``parallel`` names the
    deployed topology the decode workload is rebuilt with for repository
    lookups (a ``ParallelPlan`` or a ``kind:degree`` spec string; degrees
    of 1 still fingerprint, they just carry no comm sites).

    Args:
        cfg: the model config the engine serves.
        plan: pinned plan — a ``TunedPlan``, a path to its JSON, or an
            already-lowered runtime dict.
        repo: a ``PlanRepository`` (or directory) re-resolved per shape.
        hardware: profile name keying repository lookups.
        parallel: deployed topology for workload rebuilds (see above).
        band: shape tolerance for banded repository resolution.
        max_seq: decode sequence length the workload is rebuilt at.
        lint: deployment-lint gate on pinned ``TunedPlan``s —
            ``"error"`` (default) refuses a plan with ERROR-severity
            findings (``repro.analysis.lint.PlanLintError``), ``"warn"``
            surfaces findings as one ``RuntimeWarning``, ``"off"``
            disables the gate.  Findings from the last gated install are
            kept on ``lint_findings``.

    The live surfaces the engines and the retune loop read: ``current``
    (the runtime plan decode is scoped under), ``stats`` (resolution
    counters), ``events`` (structured drift/demotion/retune log),
    ``demoted`` (site -> batch), ``telemetry`` (``SiteTelemetry`` ring of
    observed per-site costs, one row per ``health_tick``) and
    ``last_batch`` (the shape most recently resolved).

    Example — an unbound binding resolves to "inherit ambient"::

        >>> from repro.configs import get_smoke_config
        >>> binding = PlanBinding(get_smoke_config("llama3-8b"))
        >>> binding.bound, binding.resolve(4) is None, binding.last_batch
        (False, True, 4)
    """

    def __init__(
        self,
        cfg,
        *,
        plan=None,
        repo=None,
        hardware: str = "tpu-v5e",
        parallel: Union[ParallelPlan, str, None] = None,
        band: float = DEFAULT_BAND,
        max_seq: int = 0,
        lint: str = "error",
    ):
        if lint not in ("off", "warn", "error"):
            raise ValueError(f"lint= must be 'off', 'warn' or 'error', "
                             f"got {lint!r}")
        self.cfg = cfg
        self.hardware = hardware
        self.band = band
        self.max_seq = max_seq
        self.lint = lint
        self.lint_findings: List = []  # last gated install's findings
        if isinstance(parallel, str):
            parallel = parse_parallel(parallel)
        self.parallel = parallel or ParallelPlan(kind="tp", tp=1)
        self.repo = as_repository(repo) if repo is not None else None
        self.stats = {"exact": 0, "banded": 0, "miss": 0, "swaps": 0}
        self.events: List[Dict] = []  # structured degradation event log
        self.demoted: Dict[str, int] = {}  # site -> batch it was demoted at
        self._fallbacks: Dict[str, C.CollectiveRuntime] = {}
        self._rt: Optional[Dict] = None
        self._digest = None  # None = never set (the first swap is free)
        self._plan: Optional[TunedPlan] = None  # last full artifact seen
        self._batch = 0  # serving-side fault/health clock
        self._band_now = band  # live band under backoff
        self._fault_schedule = None
        self._tolerance = 0.25
        self._window = 3
        self._health = None
        self._telemetry = None
        self.telemetry = SiteTelemetry()  # live observed-cost ring buffer
        self.last_batch: Optional[int] = None  # shape last resolved at
        if plan is not None:
            self.set_plan(plan)

    @property
    def bound(self) -> bool:
        """Whether this binding can ever produce a plan (pinned or repo)."""
        return self._rt is not None or self.repo is not None

    @property
    def current(self) -> Optional[Dict]:
        """The runtime plan decode is currently scoped under (``None`` =
        inherit the ambient plan, i.e. untuned unless one is installed)."""
        return self._rt

    def set_plan(self, plan) -> None:
        """Hot-swap the pinned plan: a ``TunedPlan``, a path to its JSON,
        an already-lowered runtime dict, or ``None`` (unpin).

        Installing a fresh ``TunedPlan`` resets the drift flag state —
        monitor, demotions and sticky fallbacks — so a site that drifts
        again *after* the swap is re-flagged against the new plan's
        predictions instead of being silently ignored forever.  (Repo
        re-resolution through ``resolve`` deliberately does NOT reset:
        a repo hit is the same operator intent, not a new plan decision.)
        """
        if isinstance(plan, (str, os.PathLike)):
            plan = TunedPlan.load(plan)
        if isinstance(plan, TunedPlan):
            self._gate(plan)
            self._plan = plan
            self._health = self._telemetry = None  # re-arm on the new plan
            self.demoted.clear()  # new plan: every site starts trusted and
            self._fallbacks.clear()  # re-flaggable against new predictions
            rt = plan.runtime_plan()
        else:
            rt = plan
        self._swap(rt)

    def _gate(self, plan: TunedPlan) -> None:
        """The deployment-lint refusal gate: a pinned artifact with
        ERROR-severity findings must not reach decode (``lint="error"``,
        the default) — a dead/shadowed/mis-tiered plan silently serves
        wrong knobs otherwise.  ``lint="off"`` is the operator override."""
        if self.lint == "off":
            return
        from repro.analysis.lint import PlanLintError, errors, lint_plan

        self.lint_findings = lint_plan(plan)
        bad = errors(self.lint_findings)
        if bad and self.lint == "error":
            raise PlanLintError(
                self.lint_findings,
                label=f"plan pinned to PlanBinding({self.cfg.name!r})")
        if self.lint == "warn" and self.lint_findings:
            import warnings

            from repro.analysis.lint import format_findings

            warnings.warn(format_findings(self.lint_findings,
                                          label=repr(self.cfg.name)),
                          RuntimeWarning, stacklevel=3)

    def _swap(self, rt: Optional[Dict]) -> None:
        d = plan_digest(rt) if rt is not None else ()
        if self._digest is not None and d != self._digest:
            self.stats["swaps"] += 1
        self._digest = d
        self._rt = rt

    def resolve(self, batch_size: int) -> Optional[Dict]:
        """The runtime plan for a batch of ``batch_size`` in-flight
        sequences.  Repo-bound engines rebuild the decode workload at this
        shape and re-resolve (exact > banded > miss, recorded in
        ``stats``); pinned plans are returned as-is.  Repeated misses
        widen the band with capped exponential backoff (logged to
        ``events``); a hit resets it to the configured band."""
        self.last_batch = batch_size
        if self.repo is None:
            return self._rt
        wl = extract_decode_workload(
            self.cfg, self.parallel, global_batch=batch_size, seq=self.max_seq
        )
        plan, how = self.repo.resolve_explain(
            wl, self.hardware, band=self._band_now
        )
        self.stats[how] += 1
        if how == "miss":
            widened = min(max(self._band_now * 2.0, _MIN_BAND), BAND_CAP)
            if widened != self._band_now:
                self.events.append(
                    {
                        "event": "band_widened",
                        "batch": self._batch,
                        "from": self._band_now,
                        "to": widened,
                    }
                )
                self._band_now = widened
        else:
            self._band_now = self.band
        if plan is not None:
            self._plan = plan
            if self._health is not None and self._health.predicted != (
                _predicted(plan)
            ):
                self._health = self._telemetry = None  # predictions moved
        rt = plan.runtime_plan() if plan is not None else None
        if rt is not None and self._fallbacks:
            # demoted sites stay on their fallback knobs across re-resolves
            # until the operator resets; a fresh repo hit must not silently
            # re-trust a site the monitor flagged
            rt = dict(rt)
            rt.update(self._fallbacks)
        self._swap(rt)
        return self._rt

    def scope(self, rt: Optional[Dict]):
        """Context manager applying ``rt`` via the scoped plan stack
        (no-op for ``None``: inherit the ambient plan)."""
        if rt is None:
            return contextlib.nullcontext()
        return C.use_runtime_plan(rt)

    def digest(self, rt: Optional[Dict]) -> tuple:
        """Compiled-step cache key for ``rt``.  An unbound step inherits
        the *ambient* plan at trace time, so its key must reflect that
        plan too — a later process-global install must not reuse traces
        made under the previous one."""
        return plan_digest(rt if rt is not None else C.active_runtime_plan())

    # -- fault-aware lifecycle ---------------------------------------------
    def attach_faults(
        self, schedule, *, tolerance: float = 0.25, window: int = 3
    ) -> None:
        """Arm drift detection: replay ``schedule`` (a ``FaultSchedule``,
        inline spec, or schedule-file path) as per-batch telemetry against
        the bound plan's predicted site costs.  The monitor is built
        lazily on the first ``health_tick`` so repo-bound engines arm
        against whichever plan resolution lands on."""
        self._fault_schedule = parse_fault_schedule(schedule)
        self._tolerance = tolerance
        self._window = window
        self._health = self._telemetry = None

    def attach_health(self, monitor, telemetry) -> None:
        """Inject an explicit monitor/telemetry pair (tests, or a real
        measured-timings feed) instead of the lazy simulated one."""
        self._health = monitor
        self._telemetry = telemetry

    def _arm(self) -> bool:
        if self._health is not None and self._telemetry is not None:
            return True
        if self._plan is None:
            return False
        from repro.serving.health import HealthMonitor, SimulatedTelemetry

        if self._telemetry is None:
            if self._fault_schedule is None:
                return False
            self._telemetry = SimulatedTelemetry(
                self._plan, self._fault_schedule
            )
        if self._health is None:
            self._health = HealthMonitor(
                _predicted(self._plan),
                tolerance=self._tolerance,
                window=self._window,
            )
        return True

    def health_tick(self, step_s: Optional[float] = None) -> List[str]:
        """Advance the serving-side batch clock by one served batch and
        return the sites that just crossed the drift threshold (already
        demoted sites excluded).  ``step_s`` is the measured wall time of
        the batch step, recorded on the health events for the report."""
        idx = self._batch
        self._batch += 1
        if not self._arm():
            return []
        observed = self._telemetry.observe(idx)
        # live telemetry: one structured ring-buffer row per served batch —
        # the observed-cost evidence the online re-tune loop calibrates from
        self.telemetry.record(idx, observed, step_s=step_s)
        newly = [
            s
            for s in self._health.observe(idx, observed)
            if s not in self.demoted
        ]
        if newly:
            self.events.append(
                {
                    "event": "drift",
                    "batch": idx,
                    "sites": newly,
                    "drift": {
                        s: round(self._health.last_drift.get(s, 0.0), 4)
                        for s in newly
                    },
                    "step_s": step_s,
                }
            )
        return newly

    def demote(self, sites, *, apply=None, to: str = "xla") -> Dict:
        """Gracefully degrade ``sites``: swap to a runtime plan whose exact
        entries for those sites carry fallback knobs — ``to="xla"`` the
        XLA-default ``CollectiveRuntime()``, ``to="class"`` the site's
        class-bucket entry (XLA default when the plan has none).  Sibling
        sites keep their tuned knobs.  Transactional: ``apply`` (e.g. the
        engine's compiled-step builder) runs under the new plan before it
        is committed; an exception rolls back to the prior plan, logs the
        event as rolled back, and re-raises."""
        sites = sorted(set(sites))
        if to not in ("xla", "class"):
            raise ValueError(f"demotion target must be 'xla' or 'class', got {to!r}")
        base = dict(self._rt if self._rt is not None else C.active_runtime_plan())
        fallback = {}
        for sid in sites:
            fb = C.CollectiveRuntime()
            if to == "class":
                fb = base.get(C.site_class(sid), fb)
            fallback[sid] = fb
        new = dict(base)
        new.update(fallback)
        prior_rt, prior_digest = self._rt, self._digest
        self._swap(new)
        event = {
            "event": "demotion",
            "batch": self._batch,
            "sites": sites,
            "to": to,
            "fallback": {
                s: (fb.strategy, fb.num_chunks) for s, fb in fallback.items()
            },
            "rolled_back": False,
        }
        if apply is not None:
            try:
                apply(new)
            except Exception:
                self._rt, self._digest = prior_rt, prior_digest
                event["rolled_back"] = True
                self.events.append(event)
                raise
        self.events.append(event)
        for sid in sites:
            self.demoted[sid] = self._batch
        self._fallbacks.update(fallback)
        return event

    def health_report(self) -> str:
        """One human-readable degradation summary line (the launcher
        prints this after serving)."""
        demos = [e for e in self.events if e["event"] == "demotion"]
        rolled = sum(1 for e in demos if e["rolled_back"])
        widened = [e for e in self.events if e["event"] == "band_widened"]
        if not self.events:
            return (
                f"health: {self._batch} batches, no drift detected, "
                "0 sites demoted"
            )
        parts = [
            f"health: {self._batch} batches",
            f"{len(self.demoted)} site(s) demoted",
        ]
        if self.demoted:
            parts.append(
                "["
                + ", ".join(
                    f"{s}@batch{b}" for s, b in sorted(self.demoted.items())
                )
                + "]"
            )
        if rolled:
            parts.append(f"{rolled} rolled-back swap(s)")
        if widened:
            parts.append(
                f"band widened {len(widened)}x to {self._band_now:g}"
            )
        return ", ".join(parts)


def _predicted(plan: TunedPlan) -> Dict[str, float]:
    from repro.serving.health import predicted_site_costs

    return predicted_site_costs(plan)
