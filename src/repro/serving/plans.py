"""Plan resolution and hot-swap state for the serving engines.

Both engines carry a ``PlanBinding``: either a pinned ``TunedPlan``
(``plan=``, hot-swappable between batches via ``set_plan``) or a
``PlanRepository`` (``repo=``) that is re-resolved as the decode batch
shape drifts under traffic — exact fingerprint first, then the tolerance
band (``PlanRepository.resolve(band=...)``).

Two mechanics matter here:

* **Scoping** — a resolved plan is applied through the scoped
  ``collectives.use_runtime_plan`` stack, never a process-global install,
  so every exit path (normal or exceptional) restores the ambient plan
  and two engines in one process can serve under different plans.
* **Trace staleness** — plans are consumed at *trace* time, so a jitted
  decode step keeps the plan it was traced under.  Engines key their
  compiled-step caches on ``digest()``; a hot-swap lands on a different
  key and retraces instead of silently reusing the old chunk structure.
"""
from __future__ import annotations

import contextlib
import os
from typing import Dict, Optional, Union

from repro.core.apply import plan_digest
from repro.core.extract import ParallelPlan, extract_decode_workload, parse_parallel
from repro.core.plan_repo import as_repository
from repro.core.session import TunedPlan
from repro.parallel import collectives as C

DEFAULT_BAND = 0.5


class PlanBinding:
    """Per-engine plan state; see module docstring.  ``parallel`` names the
    deployed topology the decode workload is rebuilt with for repository
    lookups (a ``ParallelPlan`` or a ``kind:degree`` spec string; degrees
    of 1 still fingerprint, they just carry no comm sites)."""

    def __init__(
        self,
        cfg,
        *,
        plan=None,
        repo=None,
        hardware: str = "tpu-v5e",
        parallel: Union[ParallelPlan, str, None] = None,
        band: float = DEFAULT_BAND,
        max_seq: int = 0,
    ):
        self.cfg = cfg
        self.hardware = hardware
        self.band = band
        self.max_seq = max_seq
        if isinstance(parallel, str):
            parallel = parse_parallel(parallel)
        self.parallel = parallel or ParallelPlan(kind="tp", tp=1)
        self.repo = as_repository(repo) if repo is not None else None
        self.stats = {"exact": 0, "banded": 0, "miss": 0, "swaps": 0}
        self._rt: Optional[Dict] = None
        self._digest = None  # None = never set (the first swap is free)
        if plan is not None:
            self.set_plan(plan)

    @property
    def bound(self) -> bool:
        """Whether this binding can ever produce a plan (pinned or repo)."""
        return self._rt is not None or self.repo is not None

    @property
    def current(self) -> Optional[Dict]:
        """The runtime plan decode is currently scoped under (``None`` =
        inherit the ambient plan, i.e. untuned unless one is installed)."""
        return self._rt

    def set_plan(self, plan) -> None:
        """Hot-swap the pinned plan: a ``TunedPlan``, a path to its JSON,
        an already-lowered runtime dict, or ``None`` (unpin)."""
        if isinstance(plan, (str, os.PathLike)):
            plan = TunedPlan.load(plan)
        rt = plan.runtime_plan() if isinstance(plan, TunedPlan) else plan
        self._swap(rt)

    def _swap(self, rt: Optional[Dict]) -> None:
        d = plan_digest(rt) if rt is not None else ()
        if self._digest is not None and d != self._digest:
            self.stats["swaps"] += 1
        self._digest = d
        self._rt = rt

    def resolve(self, batch_size: int) -> Optional[Dict]:
        """The runtime plan for a batch of ``batch_size`` in-flight
        sequences.  Repo-bound engines rebuild the decode workload at this
        shape and re-resolve (exact > banded > miss, recorded in
        ``stats``); pinned plans are returned as-is."""
        if self.repo is None:
            return self._rt
        wl = extract_decode_workload(
            self.cfg, self.parallel, global_batch=batch_size, seq=self.max_seq
        )
        plan, how = self.repo.resolve_explain(wl, self.hardware, band=self.band)
        self.stats[how] += 1
        self._swap(plan.runtime_plan() if plan is not None else None)
        return self._rt

    def scope(self, rt: Optional[Dict]):
        """Context manager applying ``rt`` via the scoped plan stack
        (no-op for ``None``: inherit the ambient plan)."""
        if rt is None:
            return contextlib.nullcontext()
        return C.use_runtime_plan(rt)

    def digest(self, rt: Optional[Dict]) -> tuple:
        """Compiled-step cache key for ``rt``.  An unbound step inherits
        the *ambient* plan at trace time, so its key must reflect that
        plan too — a later process-global install must not reuse traces
        made under the previous one."""
        return plan_digest(rt if rt is not None else C.active_runtime_plan())
