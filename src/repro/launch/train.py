"""Training launcher.

Single-host smoke scale by default; ``--mesh`` activates the pjit/GSPMD
path with the production sharding rules (works on any device count — on
real TPU pods the same flags apply, device count comes from the runtime).

    PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
        --smoke --steps 50
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.launch.plan import apply_tuned_plan, resolve_plan_repo
from repro.models import model as M
from repro.optim import adamw
from repro.parallel import constraints as CT
from repro.parallel import sharding as SH
from repro.train import checkpoint
from repro.train.trainer import TrainConfig, make_train_step, train_loop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=None,
                    help="JSON run config (CLI flags override file values)")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (2 layers, d_model<=256)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2x4 -> (data=2, model=4) pjit mesh")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--tuned-plan", default=None,
                    help="saved session.TunedPlan JSON: lowered to per-site "
                         "collective runtime knobs and installed for this "
                         "run (every explicit chunked-collective site, "
                         "incl. the plan-aware model builders' per-layer "
                         "tp.layer*/ep.layer* sites on the --mesh path)")
    ap.add_argument("--plan-repo", default=None,
                    help="PlanRepository directory: auto-resolve a stored "
                         "plan matching this launch's (workload "
                         "fingerprint, hardware) with zero tuning work; "
                         "falls back to untuned with a warning on a miss "
                         "(--tuned-plan, if also given, wins)")
    ap.add_argument("--plan-parallel", default="fsdp:8",
                    help="parallel spec the repo lookup fingerprints the "
                         "workload under: kind[:degree[:microbatches]], "
                         "e.g. fsdp:8, tp:4, ep:16, pp:4:8")
    ap.add_argument("--plan-hardware", default="tpu-v5e",
                    help="hardware profile name for the repo lookup key")
    ap.add_argument("--pods", type=int, default=1,
                    help="pod count of the hierarchical topology this run "
                         "spans; >1 makes the plan lookup key the topology "
                         "name (<island>-x<pods>-<fabric>) and marks "
                         "cross-pod sites in the rebuilt workload")
    ap.add_argument("--inter-pod", default="dcn",
                    help="inter-pod fabric joining the pods (core.topology "
                         "built-ins: dcn, wan, pcie-switch)")
    ap.add_argument("--accumulate", type=int, default=0,
                    help="ACCO gradient-accumulation steps: sets grad_accum "
                         "and registers acc.step*.{rs,ar}_grads sites in "
                         "the plan lookup so a cross-pod tune's "
                         "accumulation-overlap knobs apply")
    ap.add_argument("--outer-sync", type=int, default=0,
                    help="streamed outer-loop sync fragments (Streaming "
                         "DiLoCo): registers outer.round*.sync.* sites in "
                         "the plan lookup (needs --pods > 1)")
    args = ap.parse_args(argv)

    if args.config:
        from repro.launch.config import load_run_config, merge_cli, resolve_model
        run = merge_cli(load_run_config(args.config), args, defaults=dict(
            steps=100, seq=256, batch=8, lr=3e-4, grad_accum=1,
            mesh=None, ckpt=None, log_every=10))
        if args.arch:
            run["arch"] = args.arch
        for k, v in run.items():
            if hasattr(args, k) and k != "overrides":
                setattr(args, k, v)
        cfg = resolve_model(run)
    else:
        assert args.arch, "--arch or --config required"
        cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.accumulate > 1:
        # ACCO: the scan-accumulation path trains correctly everywhere;
        # the unrolled accum_axis path needs a shard_map-bound named axis
        # (see train.trainer.TrainConfig), which this GSPMD launcher does
        # not provide — the acc.* sites still shape the plan lookup below.
        args.grad_accum = args.accumulate
    plan_active = False
    if args.tuned_plan:
        apply_tuned_plan(args.tuned_plan, expect_arch=cfg.name)
        plan_active = True
    elif args.plan_repo:
        plan_hw = args.plan_hardware
        if args.pods > 1:
            from repro.core import topology
            plan_hw = topology.hierarchical(args.plan_hardware, args.pods,
                                            args.inter_pod).name
        rt = resolve_plan_repo(args.plan_repo, cfg,
                               parallel=args.plan_parallel,
                               hardware=plan_hw,
                               seq=args.seq, global_batch=args.batch,
                               pods=args.pods,
                               accum_steps=max(1, args.accumulate),
                               outer_frags=args.outer_sync)
        plan_active = rt is not None
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch)
    data = iter(SyntheticCorpus(dc))
    tcfg = TrainConfig(opt=adamw.AdamWConfig(lr=args.lr),
                       warmup=max(5, args.steps // 10),
                       total_steps=args.steps, grad_accum=args.grad_accum)

    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model")[:len(shape)]
        from repro.launch.mesh import make_mesh
        mesh = make_mesh(shape, axes)
        jax.sharding.set_mesh(mesh)
        if plan_active and "model" in axes:
            # an installed plan reaches the emitted program through the
            # plan-aware trunk: per-layer explicit collectives whose sites
            # resolve against it (falls back inside the model on
            # indivisible shapes)
            from dataclasses import replace as dc_replace
            tcfg = dc_replace(tcfg, sited_mesh=mesh)
        rng = jax.random.PRNGKey(0)
        with CT.use_axes(("data",), "model"):
            params = M.init_params(cfg, rng)
            p_spec = SH.param_specs(params, mesh)
            from jax.sharding import NamedSharding
            params = jax.device_put(
                params, jax.tree.map(lambda s: NamedSharding(mesh, s), p_spec))
            opt_state = adamw.init_state(params)
            step_fn = jax.jit(make_train_step(cfg, tcfg))
            for step in range(args.steps):
                batch = {k: jnp.asarray(v) for k, v in next(data).items()}
                params, opt_state, metrics = step_fn(params, opt_state, batch,
                                                     jnp.asarray(step))
                if step % args.log_every == 0:
                    print(f"step {step:4d} loss {float(metrics['loss']):.4f}")
        history = None
    else:
        params, history = train_loop(cfg, tcfg, data, steps=args.steps,
                                     log_every=args.log_every)

    if args.ckpt:
        checkpoint.save(args.ckpt, params, step=args.steps)
        print(f"checkpoint written to {args.ckpt}")
    if history:
        print(f"final loss {history['loss'][-1]:.4f} "
              f"(first {history['loss'][0]:.4f})")


if __name__ == "__main__":
    main()
