"""JSON run-config loader for the launchers.

    PYTHONPATH=src python -m repro.launch.train --config runs/smoke.json

A run config is a flat JSON object whose keys mirror the launcher flags
(``arch``, ``steps``, ``seq``, ``batch``, ``lr``, ``grad_accum``, ``mesh``,
``smoke``, ``ckpt``) plus optional ``overrides`` applied to the
ModelConfig (e.g. {"sliding_window": 8192}).  CLI flags win over file
values; ``overrides`` compose via ModelConfig.replace.
"""
from __future__ import annotations

import json
from typing import Any, Dict

from repro.configs import get_config, get_smoke_config
from repro.configs.base import ModelConfig

_LAUNCH_KEYS = ("arch", "steps", "seq", "batch", "lr", "grad_accum",
                "mesh", "smoke", "ckpt", "log_every")


def load_run_config(path: str) -> Dict[str, Any]:
    with open(path) as f:
        raw = json.load(f)
    unknown = set(raw) - set(_LAUNCH_KEYS) - {"overrides"}
    if unknown:
        raise ValueError(f"unknown run-config keys: {sorted(unknown)}")
    return raw


def resolve_model(run_cfg: Dict[str, Any]) -> ModelConfig:
    arch = run_cfg["arch"]
    cfg = get_smoke_config(arch) if run_cfg.get("smoke") else get_config(arch)
    overrides = run_cfg.get("overrides") or {}
    if overrides:
        cfg = cfg.replace(**overrides)
    return cfg


def merge_cli(run_cfg: Dict[str, Any], args, *, defaults: Dict[str, Any]):
    """File value unless the CLI flag was explicitly set (differs from its
    argparse default)."""
    out = dict(run_cfg)
    for k, dflt in defaults.items():
        v = getattr(args, k, None)
        if v is not None and v != dflt:
            out[k] = v
        out.setdefault(k, dflt)
    return out
