"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before jax init,
and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_mesh(shape, axes):
    """Version-compat ``jax.make_mesh``: jax >= 0.5 wants explicit
    axis_types; older jax has no AxisType at all."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def mesh_axes(mesh) -> Tuple[Tuple[str, ...], str]:
    """(data-parallel axes, tensor-parallel axis) for a production mesh."""
    names = mesh.axis_names
    if "pod" in names:
        return ("pod", "data"), "model"
    return ("data",), "model"


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for unit tests (run under a host-device-count subprocess)."""
    return make_mesh(shape, axes)
