"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, zero allocation.  The dry-run lowers against these."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import model as M


def input_specs(cfg, shape) -> Dict[str, Any]:
    """Batch ShapeDtypeStructs for a train/prefill step."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    batch = {
        "tokens": sds((B, S), jnp.int32),
        "targets": sds((B, S), jnp.int32),
        "mask": sds((B, S), jnp.float32),
    }
    if cfg.family == "audio":
        batch["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        batch["patches"] = sds((B, M.N_PATCHES, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


def decode_input_specs(cfg, shape, cache_dtype=None) -> Dict[str, Any]:
    """(tokens, caches) ShapeDtypeStructs for a serve step with a
    ``seq_len``-deep cache.  ``cache_dtype`` overrides the KV/state cache
    precision (e.g. float8_e4m3fn — §Perf memory-bound decode iteration)."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    caches = jax.eval_shape(
        lambda: M.init_caches(cfg, B, S, dtype=cache_dtype or cfg.dtype))
    return {"tokens": sds((B, 1), jnp.int32), "caches": caches}


def param_specs_shapes(cfg, *, ep_pad: int = 1):
    """Parameter ShapeDtypeStructs via eval_shape (no allocation)."""
    return jax.eval_shape(
        lambda r: M.init_params(cfg, r, ep_pad=ep_pad),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
