import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
against the production meshes, prove the memory fits, and dump the roofline
inputs (FLOPs / bytes / collective bytes by op kind).

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Outputs one JSON per combo under experiments/dryrun/.
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config, shape_applicable
from repro.launch.mesh import make_mesh, make_production_mesh, mesh_axes
from repro.launch.specs import decode_input_specs, input_specs, param_specs_shapes
from repro.models import model as M
from repro.optim import adamw
from repro.parallel import constraints as CT
from repro.parallel import sharding as SH
from repro.serving.engine import make_serve_step
from repro.train.trainer import TrainConfig, make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# what a failing lower/compile actually raises: shape/spec mismatches
# (ValueError/TypeError), bad axis/param lookups (KeyError/IndexError),
# model-side invariants (AssertionError), unimplemented family paths
# (NotImplementedError), and XLA compile failures (XlaRuntimeError is a
# RuntimeError subclass).  Anything else — KeyboardInterrupt, MemoryError,
# a genuine bug — propagates instead of becoming an "error" record.
_DRYRUN_ERRORS = (ValueError, TypeError, KeyError, IndexError,
                  AssertionError, NotImplementedError, RuntimeError)


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum operand bytes of every collective op in the (post-SPMD) HLO.

    Delegates to the shared op table in ``repro.analysis.ir``: same keys
    as before (base opcodes + ``count``), now covering async
    ``-start``/``-done`` forms (summed once under the base opcode)."""
    from repro.analysis.ir import collective_bytes

    return collective_bytes(hlo_text)


def build_dryrun(arch: str, shape_name: str, *, multi_pod: bool = False,
                 dtype: str = "bfloat16", microbatches: int = 1,
                 sharding: str = "2d", remat: bool = True, swa: int = 0,
                 cache_dtype: str = "", extra_tags: str = ""):
    """Lower+compile; returns the result record (raises on failure).

    sharding:
      * "2d"   — baseline FSDP(data) × TP(model) (paper-faithful default)
      * "fsdp" — pure FSDP: the model axis joins the data axes; no tensor
        parallelism, so per-layer activation all-reduces vanish (the §Perf
        hillclimb move for collective-bound small models)
    """
    cfg = get_config(arch).replace(dtype=dtype)
    if swa:   # beyond-assignment: sliding-window variant of a dense arch,
              # making it long_500k-eligible (ring-buffer cache = window)
        cfg = cfg.replace(sliding_window=swa)
    shape = INPUT_SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped", "why": why}

    if sharding.startswith("hybrid"):
        # §Perf variant: same 256 chips, reduced TP degree t — the extra
        # model-axis factor becomes another data axis (batch/FSDP), trading
        # activation all-reduce volume against parameter-gather volume.
        t = int(sharding[len("hybrid"):])
        assert not multi_pod, "perf variants are single-pod"
        mesh = make_mesh((16, 16 // t, t), ("data", "extra", "model"))
        dp_axes, tp_axis = ("data", "extra"), "model"
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        dp_axes, tp_axis = mesh_axes(mesh)
        if sharding == "fsdp":
            dp_axes = dp_axes + (tp_axis,)   # model axis becomes extra data axis
            tp_axis = None
    tp = mesh.devices.shape[-1] if tp_axis else 1
    ep_pad = (16 if cfg.is_moe else 1)   # expert padding independent of plan
    jax.sharding.set_mesh(mesh)          # ambient mesh for bare-P constraints
    # sequence parallelism when even one sample's residuals exceed budget
    seq_shard = (shape.kind == "train"
                 and 3 * cfg.num_layers * shape.seq_len * cfg.d_model * 2 > 3.5e9)
    ctx = CT.use_axes(dp_axes, tp_axis, seq_shard=seq_shard, tp_size=tp)
    ctx.__enter__()

    t0 = time.time()
    p_shapes = param_specs_shapes(cfg, ep_pad=ep_pad)
    p_spec = SH.param_specs(p_shapes, mesh, fsdp_axes=dp_axes, tp_axis=tp_axis)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_spec)

    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "multi_pod": multi_pod, "dtype": dtype,
        "params": int(sum(np.prod(leaf.shape) for leaf in jax.tree.leaves(p_shapes))),
        "tags": extra_tags,
    }

    if shape.kind in ("train", "prefill"):
        batch_shapes = input_specs(cfg, shape)
        b_spec = SH.batch_specs(cfg, batch_shapes, mesh, dp_axes=dp_axes)
        b_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), b_spec)
        if shape.kind == "train":
            # auto gradient-accumulation: bound live per-microbatch residuals
            # (≈ 3·L·S·D bytes/sample with bf16 + remat bookkeeping) to ~3.5GB
            dp = int(np.prod([mesh.devices.shape[i]
                              for i, n in enumerate(mesh.axis_names)
                              if n in dp_axes]))
            b_loc = max(1, shape.global_batch // dp)
            per_sample = 3 * cfg.num_layers * shape.seq_len * cfg.d_model * 2
            if cfg.is_moe:
                per_sample *= 4   # dispatch buffers / router tensors scale with T
            b_mb = max(1, int(3.5e9 // per_sample))
            ga = 1
            while b_loc // ga > b_mb and ga < b_loc:
                ga *= 2
            record["grad_accum"] = ga
            tcfg = TrainConfig(remat=remat, microbatches=microbatches,
                               grad_accum=ga)
            step = make_train_step(cfg, tcfg)
            o_shapes = jax.eval_shape(adamw.init_state, p_shapes)
            o_spec = {"mu": p_spec, "nu": p_spec, "count": P()}
            o_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), o_spec)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard, NamedSharding(mesh, P())),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1),   # params/opt update in place
            )
            args = (p_shapes, o_shapes, batch_shapes,
                    jax.ShapeDtypeStruct((), jnp.int32))
        else:   # prefill: forward logits only (inference)
            def prefill_step(params, batch):
                x, _, _ = M.forward_hidden(cfg, params, batch, remat=False)
                return M._unembed(cfg, params, x[:, -1:])
            jitted = jax.jit(prefill_step, in_shardings=(p_shard, b_shard),
                             out_shardings=None)
            args = (p_shapes, batch_shapes)
        lowered = jitted.lower(*args)
    else:   # decode
        dspec = decode_input_specs(cfg, shape, cache_dtype or None)
        c_spec = SH.cache_specs(cfg, dspec["caches"], mesh,
                                dp_axes=dp_axes, tp_axis=tp_axis)
        c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), c_spec)
        tok_spec = SH.batch_specs(cfg, {"tokens": dspec["tokens"]}, mesh,
                                  dp_axes=dp_axes)["tokens"]
        tok_shard = NamedSharding(mesh, tok_spec)
        step = make_serve_step(cfg)
        jitted = jax.jit(step, in_shardings=(p_shard, tok_shard, c_shard),
                         out_shardings=(tok_shard, c_shard),
                         donate_argnums=(2,))    # KV/state caches in place
        lowered = jitted.lower(p_shapes, dspec["tokens"], dspec["caches"])

    ctx.__exit__(None, None, None)
    record["lower_s"] = round(time.time() - t0, 1)
    t1 = time.time()
    compiled = lowered.compile()
    record["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    record["memory"] = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0) or 0)
                      + (getattr(mem, "argument_size_in_bytes", 0) or 0),
    }
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    record["cost"] = {k: float(v) for k, v in dict(cost or {}).items()
                      if isinstance(v, (int, float)) and k in
                      ("flops", "bytes accessed", "optimal_seconds",
                       "utilization operand 0 {}", "transcendentals")}
    record["flops"] = float((cost or {}).get("flops", 0.0))
    record["bytes_accessed"] = float((cost or {}).get("bytes accessed", 0.0))

    hlo = compiled.as_text()
    record["collectives"] = parse_collective_bytes(hlo)
    record["status"] = "ok"
    return record


def run_one(arch, shape_name, multi_pod, out_dir=OUT_DIR, **kw):
    tag = "pod2" if multi_pod else "pod1"
    try:
        rec = build_dryrun(arch, shape_name, multi_pod=multi_pod, **kw)
    except _DRYRUN_ERRORS as e:
        rec = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
    os.makedirs(out_dir, exist_ok=True)
    suffix = kw.get("extra_tags", "")
    suffix = f"_{suffix}" if suffix else ""
    path = os.path.join(out_dir, f"{arch}_{shape_name}_{tag}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    status = rec["status"]
    extra = "" if status != "ok" else (
        f" peak={rec['memory']['peak_bytes']/2**30:.2f}GiB/dev "
        f"flops={rec['flops']:.3g} coll={rec['collectives']['count']}")
    print(f"[{status:7s}] {arch} × {shape_name} × {tag}{suffix}{extra}", flush=True)
    if status == "error":
        print(rec["error"], flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--assigned-only", action="store_true", default=True)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--sharding", default="2d",
                    choices=["2d", "fsdp", "hybrid2", "hybrid4", "hybrid8"])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--swa", type=int, default=0,
                    help="override: sliding-window variant (enables long_500k)")
    ap.add_argument("--cache-dtype", default="",
                    help="KV/state cache dtype override (e.g. float8_e4m3fn)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--tuned-plan", default=None,
                    help="saved session.TunedPlan JSON: install it and print "
                         "the resolved per-site runtime table (site id -> "
                         "knobs -> source plan key) before compiling, so "
                         "operators can audit what the plan actually "
                         "changes at launch; decode-shape plans list their "
                         "serve.layer{i}.* sites here, which the serving "
                         "engines consume via the sited trunk path")
    ap.add_argument("--demote", default="",
                    help="comma-separated SiteIds to demote to XLA-default "
                         "knobs after installing --tuned-plan (audit what a "
                         "runtime health demotion would hand each site; the "
                         "table grows a 'health' column marking them)")
    ap.add_argument("--lint", action="store_true",
                    help="run the deployment linter (repro.analysis.lint) "
                         "on --tuned-plan and exit before anything "
                         "compiles: prints the analysis: summary line, "
                         "exits 1 on ERROR-severity findings, 0 otherwise")
    args = ap.parse_args(argv)

    if args.lint and not args.tuned_plan:
        ap.error("--lint requires --tuned-plan")
    if args.tuned_plan and args.lint:
        from repro.analysis.lint import errors, format_findings, lint_plan
        from repro.core.session import TunedPlan
        findings = lint_plan(TunedPlan.load(args.tuned_plan))
        print(format_findings(findings, label=args.tuned_plan), flush=True)
        sys.exit(1 if errors(findings) else 0)

    if args.tuned_plan:
        from repro.core.apply import activate
        from repro.core.session import TunedPlan
        from repro.launch.plan import print_runtime_table
        from repro.parallel import collectives as C
        plan = TunedPlan.load(args.tuned_plan)
        rt = activate(plan)
        demoted = [s for s in args.demote.split(",") if s.strip()]
        if demoted:
            rt = dict(rt)
            rt.update({s: C.CollectiveRuntime() for s in demoted})
            C.install_runtime_plan(rt)
        print_runtime_table(plan, demoted=demoted)
    elif args.demote:
        ap.error("--demote requires --tuned-plan")

    archs = [args.arch] if args.arch else ASSIGNED_ARCHS
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for mp in meshes:
        for a in archs:
            for s in shapes:
                rec = run_one(a, s, mp, microbatches=args.microbatches,
                              sharding=args.sharding, remat=not args.no_remat,
                              swa=args.swa, cache_dtype=args.cache_dtype,
                              extra_tags=args.tag)
                failures += rec["status"] == "error"
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
