"""Serving launcher: batched greedy decoding with a prefilled KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
        --smoke --batch 4 --max-new 16
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch.plan import apply_tuned_plan, resolve_plan_repo
from repro.models import model as M
from repro.serving.engine import Engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--tuned-plan", default=None,
                    help="saved session.TunedPlan JSON: lowered to per-site "
                         "collective runtime knobs and installed for this "
                         "run (every explicit chunked-collective site)")
    ap.add_argument("--plan-repo", default=None,
                    help="PlanRepository directory: auto-resolve a stored "
                         "plan for this launch's (workload fingerprint, "
                         "hardware); untuned with a warning on a miss")
    ap.add_argument("--plan-parallel", default="fsdp:8",
                    help="parallel spec for the repo lookup: "
                         "kind[:degree[:microbatches]]")
    ap.add_argument("--plan-hardware", default="tpu-v5e",
                    help="hardware profile name for the repo lookup key")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.tuned_plan:
        apply_tuned_plan(args.tuned_plan, expect_arch=cfg.name)
    elif args.plan_repo:
        resolve_plan_repo(args.plan_repo, cfg, parallel=args.plan_parallel,
                          hardware=args.plan_hardware, seq=args.max_seq,
                          global_batch=args.batch, decode=True)
    rng = jax.random.PRNGKey(0)
    params = M.init_params(cfg, rng)
    engine = Engine(cfg, params, batch_size=args.batch, max_seq=args.max_seq)

    rs = np.random.default_rng(0)
    prompts = [rs.integers(0, cfg.vocab_size, size=args.prompt_len).astype(np.int32)
               for _ in range(args.batch)]
    frames = None
    if cfg.family == "audio":
        frames = rs.standard_normal(
            (args.batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32) * 0.02
    outs = engine.generate(prompts, max_new=args.max_new, frames=frames)
    for i, o in enumerate(outs):
        print(f"request {i}: {o}")
    probe = engine.throughput_probe()
    print(f"decode throughput: {probe['tokens_per_s']:.1f} tok/s "
          f"({probe['s_per_token']*1e3:.2f} ms/step, batch {args.batch})")


if __name__ == "__main__":
    main()
