"""Serving launcher: batched greedy decoding with a prefilled KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
        --smoke --batch 4 --max-new 16

Plan-aware: ``--tuned-plan`` / ``--plan-repo`` hand the plan to the engine,
which decodes under it through the sited explicit-collective path
(``serve.layer{i}.*`` SiteIds) — per batch, via the scoped plan stack.
``--engine continuous`` swaps in the continuous-batching engine, which
re-resolves the repository plan as the in-flight batch shape drifts.
``--fault-schedule`` arms the fault-aware lifecycle: per-site drift
detection against the plan's predicted costs and transactional demotion
of drifted sites, summarized by a degradation report line at exit.
``--retune`` upgrades that lifecycle to the online re-tuning loop:
flagged drift triggers a telemetry-calibrated, drift-scoped warm re-tune
(only the affected comm groups re-searched, seeded from the installed
plan) that is published with lineage and hot-swapped mid-serve; a
``retune:`` summary line prints at exit.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch.plan import apply_tuned_plan, resolve_plan_repo
from repro.models import model as M
from repro.serving import Request, available_engines, make_engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", default="fixed", choices=available_engines(),
                    help="fixed: lockstep batch decode; continuous: per-slot "
                         "caches with admit-time plan re-resolution")
    ap.add_argument("--batch", type=int, default=4,
                    help="batch size (fixed engine) / slot count (continuous)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--tuned-plan", default=None,
                    help="saved session.TunedPlan JSON: lowered to per-site "
                         "collective runtime knobs; the engine decodes under "
                         "it via the sited serve.layer{i}.* path (dense/moe "
                         "families) and it is installed process-wide for "
                         "every other explicit chunked-collective site.  "
                         "With --retune this is the *starting* plan: the "
                         "online loop may warm re-tune and hot-swap it "
                         "mid-serve when sites drift")
    ap.add_argument("--plan-repo", default=None,
                    help="PlanRepository directory: the engine re-resolves a "
                         "stored plan for the decode-shape workload "
                         "(fingerprint x hardware, exact first then the "
                         "--plan-band tolerance band); untuned with a "
                         "warning on a miss")
    ap.add_argument("--plan-band", type=float, default=0.0,
                    help="tolerance band for --plan-repo decode lookups: "
                         "accept the nearest tuned plan whose structure "
                         "matches and whose (seq, batch) deviate at most "
                         "this relative fraction (0 = exact only)")
    ap.add_argument("--plan-parallel", default="fsdp:8",
                    help="parallel spec for the repo lookup: "
                         "kind[:degree[:microbatches]]")
    ap.add_argument("--plan-hardware", default="tpu-v5e",
                    help="hardware profile name for the repo lookup key")
    ap.add_argument("--fault-schedule", default=None,
                    help="arm per-site drift detection against a scripted "
                         "fault schedule (core.faults): a JSON schedule "
                         "file, or an inline spec like "
                         "'degrade,site=serve,scale=0.25,start=4'; sites "
                         "whose simulated observed cost drifts past "
                         "--health-tolerance for --health-window "
                         "consecutive batches are demoted to XLA-default "
                         "knobs mid-serve (transactional hot-swap)")
    ap.add_argument("--health-window", type=int, default=3,
                    help="consecutive drifted batches before a site is "
                         "demoted (K of the K-consecutive detector)")
    ap.add_argument("--health-tolerance", type=float, default=0.25,
                    help="relative per-site cost drift (observed/predicted "
                         "- 1) that counts as a drifted batch")
    ap.add_argument("--retune", action="store_true",
                    help="arm the online re-tuning loop (core.retune): "
                         "sustained drift triggers a drift-scoped warm "
                         "re-tune — only the comm groups owning flagged "
                         "sites are re-searched, calibrated from live "
                         "telemetry and seeded from the installed plan — "
                         "published to --plan-repo (when set) with lineage "
                         "and hot-swapped between batches; demotion stays "
                         "the fallback when the loop declines")
    ap.add_argument("--retune-interval", type=int, default=1,
                    help="minimum batches between re-tune publishes "
                         "(rate limit)")
    ap.add_argument("--retune-drift", type=float, default=None,
                    help="minimum relative drift before re-tuning instead "
                         "of demoting (default: any flagged drift "
                         "re-tunes)")
    ap.add_argument("--retune-max", type=int, default=4,
                    help="maximum re-tunes per run; beyond the budget "
                         "flagged sites fall back to demotion")
    ap.add_argument("--no-plan-lint", action="store_true",
                    help="override the deployment-lint refusal gate: serve "
                         "a --tuned-plan even when repro.analysis.lint "
                         "finds ERROR-severity defects in it")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    plan_kw = {}
    if args.tuned_plan:
        apply_tuned_plan(args.tuned_plan, expect_arch=cfg.name)
        # the deployed topology matters beyond repo lookups: the re-tune
        # loop rebuilds the decode workload with it, so a pinned plan
        # carries --plan-parallel too
        plan_kw = dict(plan=args.tuned_plan, plan_parallel=args.plan_parallel)
    elif args.plan_repo:
        resolve_plan_repo(args.plan_repo, cfg, parallel=args.plan_parallel,
                          hardware=args.plan_hardware, seq=args.max_seq,
                          global_batch=args.batch, serve=True,
                          band=args.plan_band)
        plan_kw = dict(repo=args.plan_repo, plan_hardware=args.plan_hardware,
                       plan_parallel=args.plan_parallel,
                       plan_band=args.plan_band)
    plan_kw["plan_lint"] = "off" if args.no_plan_lint else "error"
    if args.fault_schedule:
        plan_kw.update(fault_schedule=args.fault_schedule,
                       health_window=args.health_window,
                       health_tolerance=args.health_tolerance)
    if args.retune:
        plan_kw.update(retune=dict(interval=args.retune_interval,
                                   max_retunes=args.retune_max,
                                   drift_threshold=args.retune_drift))
    rng = jax.random.PRNGKey(0)
    params = M.init_params(cfg, rng)

    rs = np.random.default_rng(0)
    prompts = [rs.integers(0, cfg.vocab_size, size=args.prompt_len).astype(np.int32)
               for _ in range(args.batch)]

    if args.engine == "continuous":
        engine = make_engine(cfg, params, mode="continuous", slots=args.batch,
                             max_seq=args.max_seq, **plan_kw)
        for i, p in enumerate(prompts):
            engine.submit(Request(rid=i, prompt=p, max_new=args.max_new))
        done = sorted(engine.run(), key=lambda r: r.rid)
        for r in done:
            print(f"request {r.rid}: {r.out}")
        stats = engine.plan_stats
    else:
        engine = make_engine(cfg, params, mode="fixed", batch_size=args.batch,
                             max_seq=args.max_seq, **plan_kw)
        frames = None
        if cfg.family == "audio":
            frames = rs.standard_normal(
                (args.batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32) * 0.02
        outs = engine.generate(prompts, max_new=args.max_new, frames=frames)
        for i, o in enumerate(outs):
            print(f"request {i}: {o}")
        probe = engine.throughput_probe()
        print(f"decode throughput: {probe['tokens_per_s']:.1f} tok/s "
              f"({probe['s_per_token']*1e3:.2f} ms/step, batch {args.batch})")
        stats = engine.plan_stats
    if args.plan_repo:
        print(f"plan resolution: {stats['exact']} exact, {stats['banded']} "
              f"banded, {stats['miss']} miss ({stats['swaps']} hot-swaps)")
    if args.fault_schedule:
        print(engine.health_report())
    if args.retune:
        print(engine.retune_service.report())


if __name__ == "__main__":
    main()
