"""Launcher-side ``TunedPlan`` application (``--tuned-plan`` /
``--plan-repo``).

"Co-tune once, deploy the plan": a plan saved by ``session.tune(...)``
(``plan.save("plan.json")``) — or auto-stored in a ``PlanRepository``
(``tune(..., repo=...)``) — is loaded at launch, lowered to per-site
collective runtime knobs via ``core.apply``, and installed process-wide
(``parallel.collectives.runtime_for``).

Reach: the knobs apply to every explicit chunked-collective call site —
``ring_ag_matmul`` / ``mm_reduce_scatter`` / ``chunked_all_to_all`` /
the pipeline's inter-stage transfers — addressed per SiteId, including
the plan-aware model-builder path (``models.dense.trunk_fwd(mesh=...)``
emits per-layer sites ``tp.layer{i}.mlp`` / ``ep.layer{j}.moe``), so one
plan can change two layers' emitted chunk structure differently.  The
stock GSPMD scan trunk (no mesh handed to the model) is still untouched
by a plan.

The launcher has no ``Workload`` object on the ``--tuned-plan`` path, so
the plan's structural fingerprint cannot be verified there (that guard
runs in ``TunedPlan.runtime_plan(wl)`` whenever the workload is in hand);
the model-name cross-check below is the launch-time proxy for it.  The
``--plan-repo`` path *does* rebuild the workload (arch × parallel spec ×
shape) and resolves by exact (fingerprint, hardware) key — a hit installs
the stored plan with zero tuning work, a miss warns and launches untuned.
"""
from __future__ import annotations

import warnings
from typing import Dict, List, Optional, Tuple

from repro.core.apply import activate
from repro.core.extract import (ParallelPlan, extract_decode_workload,
                                extract_workload, parse_parallel)
from repro.core.plan_repo import PlanRepoError, PlanRepository
from repro.core.session import TunedPlan, workload_fingerprint

__all__ = ["apply_tuned_plan", "parse_parallel", "print_runtime_table",
           "resolve_plan_repo", "runtime_table"]


def apply_tuned_plan(path: str, *, expect_arch: Optional[str] = None,
                     quiet: bool = False) -> Dict:
    """Load, lower, and install a saved plan; returns the runtime plan
    (identical to ``TunedPlan.load(path).runtime_plan()``).  When
    ``expect_arch`` is given and does not match the model the plan was
    tuned on, a ``RuntimeWarning`` is emitted (the plan still applies —
    fallback knobs are coarse — but the tuning is unsound for a
    different model; re-tune)."""
    plan = TunedPlan.load(path)
    tuned_model = plan.workload.split(":")[0]
    if expect_arch is not None and tuned_model != expect_arch:
        warnings.warn(
            f"tuned plan {path} was tuned on workload {plan.workload!r} "
            f"but this launch runs arch {expect_arch!r} — site knobs "
            "may not correspond; re-tune for this model",
            RuntimeWarning, stacklevel=2)
    rt = activate(plan)
    if not quiet:
        classes = {k: v for k, v in rt.items() if "." not in k}
        knobs = ", ".join(f"{k}={v.strategy}/x{v.num_chunks}"
                          for k, v in sorted(classes.items()))
        print(f"tuned plan {path}: {plan.method}/{plan.mode} on "
              f"{plan.hardware} (workload {plan.workload}, "
              f"{plan.profile_count} profiles) -> {len(rt)} addressable "
              f"site entries; class fallbacks: {knobs}")
    return rt


# ---------------------------------------------------------------------------
# plan repository resolution (--plan-repo)
# ---------------------------------------------------------------------------

def resolve_plan_repo(repo_dir: str, cfg, *, parallel: str, hardware: str,
                      seq: int, global_batch: int, decode: bool = False,
                      serve: bool = False, band: float = 0.0,
                      pods: int = 1, accum_steps: int = 1,
                      outer_frags: int = 0,
                      quiet: bool = False) -> Optional[Dict]:
    """Rebuild the launch workload from (arch config × parallel spec ×
    shape), look it up in the repository by (structural fingerprint,
    hardware), and install a hit (returns the runtime plan).  A miss —
    unknown structure or stale hardware — warns and returns ``None``
    (launch proceeds untuned).

    ``serve=True`` builds the decode-shape workload with ``serve.*``
    SiteIds (``extract_decode_workload``) — the serving launcher's path —
    and ``band`` widens the lookup to tolerance-band resolution (nearest
    tuned shape with the same structure; see ``PlanRepository.resolve``).

    ``pods`` / ``accum_steps`` / ``outer_frags`` thread the hierarchical
    axes into the rebuilt workload so its fingerprint carries the
    ``acc.*`` / ``outer.*`` site classes a cross-pod tune emitted; pass
    the topology *name* (e.g. ``tpu-v5e-x2-dcn``) as ``hardware`` to hit
    plans stored under a hierarchical key."""
    import dataclasses

    pp = parse_parallel(parallel)
    if pods > 1 or accum_steps > 1 or outer_frags > 0:
        pp = dataclasses.replace(pp, pods=max(1, pods),
                                 accum_steps=max(1, accum_steps),
                                 outer_frags=max(0, outer_frags))
    if serve:
        wl = extract_decode_workload(cfg, pp, global_batch=global_batch,
                                     seq=seq)
    else:
        wl = extract_workload(cfg, pp, seq=seq, global_batch=global_batch,
                              decode=decode)
    repo = PlanRepository(repo_dir)
    try:
        plan, how = repo.resolve_explain(wl, hardware, band=band)
    except PlanRepoError as e:
        # a corrupt/misfiled entry must not brick the launch — treat it
        # as a miss, loudly
        warnings.warn(f"plan repository {repo_dir}: {e} — launching "
                      "untuned", RuntimeWarning, stacklevel=2)
        return None
    if plan is None:
        fp = workload_fingerprint(wl)
        warnings.warn(
            f"plan repository {repo_dir}: no plan for "
            f"(fingerprint {fp[:12]}…, {hardware}) — workload "
            f"{wl.name!r} launches untuned; run session.tune(..., "
            f"repo={repo_dir!r}) to populate it", RuntimeWarning,
            stacklevel=2)
        return None
    rt = activate(plan)
    if not quiet:
        shape = (f", banded hit: tuned shape {plan.shape} serves "
                 f"(seq={seq}, batch={global_batch})" if how == "banded"
                 else "")
        print(f"plan repository {repo_dir}: resolved "
              f"({plan.fingerprint[:12]}…, {plan.hardware}) -> "
              f"{plan.method}/{plan.mode} plan ({plan.profile_count} "
              f"profiles, zero tuning at launch); {len(rt)} addressable "
              f"site entries installed{shape}")
    return rt


# ---------------------------------------------------------------------------
# per-site audit table (launch/dryrun.py --tuned-plan)
# ---------------------------------------------------------------------------

# site classes with no legacy comm-name bucket: their comm *names*
# ("rs.grads.s0", "ar.grads.s0", "outer.sync.r0.f0") would otherwise fall
# into an unrelated class bucket ("rs"/"ar") owned by per-layer sites —
# these resolve by exact/prefix only, then XLA defaults
_CLASSLESS_SITES = frozenset({"acc", "outer"})


def runtime_table(plan: TunedPlan,
                  demoted=()) -> List[Tuple[str, str, int, str, str, str]]:
    """``(site_id, strategy, num_chunks, matched_plan_key, matched_tier,
    health)`` for every comm site the plan was tuned over, resolved against
    the *active* plan — what a launch with these knobs installed will
    actually hand each site.  ``matched_tier`` names the fallback level
    that supplied the knobs (``exact``/``prefix``/``class``/``default``,
    from ``collectives.resolve_runtime``).  ``demoted`` marks sites the
    fault-aware lifecycle (or an operator, via ``--demote``) has degraded
    to fallback knobs; everything else reads ``ok``."""
    from repro.parallel import collectives

    demoted = set(demoted)
    rows = []
    for s in plan.sites:
        sid = s.get("site") or s["name"]
        cls = (None if collectives.site_class(sid) in _CLASSLESS_SITES
               else s["name"].split(".")[0])
        rt, src, how = collectives.resolve_runtime(sid, cls)
        health = "demoted" if sid in demoted else "ok"
        rows.append((sid, rt.strategy, rt.num_chunks, src or "<default>",
                     how, health))
    return rows


def print_runtime_table(plan: TunedPlan, demoted=()) -> None:
    """Operator audit: site id -> knobs -> which plan key supplied them and
    at which fallback tier (plus a health column when any site is
    demoted)."""
    rows = runtime_table(plan, demoted=demoted)
    wid = max([len(r[0]) for r in rows] + [len("site")])
    print(f"{'site':<{wid}}  {'strategy':<8} {'chunks':>6}  "
          f"{'health':<8} {'tier':<8} source")
    for sid, strat, nc, src, how, health in rows:
        print(f"{sid:<{wid}}  {strat:<8} {nc:>6}  {health:<8} {how:<8} {src}")
    n_dem = sum(1 for r in rows if r[5] == "demoted")
    print(f"({len(rows)} comm sites, {n_dem} demoted; 'tier' is the "
          "fallback level resolution matched at — exact site, dotted "
          "prefix, class bucket, or XLA default — and 'source' the plan "
          "key that supplied the knobs)")
