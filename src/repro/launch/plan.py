"""Launcher-side ``TunedPlan`` application (the ``--tuned-plan`` flag).

"Co-tune once, deploy the plan": a plan saved by ``session.tune(...)``
(``plan.save("plan.json")``) is loaded at launch, lowered to per-site-class
collective runtime knobs via ``core.apply``, and installed process-wide
(``parallel.collectives.runtime_for``).

Reach, stated plainly: the knobs apply to the explicit chunked-collective
helpers (``ring_ag_matmul`` / ``mm_reduce_scatter`` / ``chunked_all_to_all``
with ``num_chunks`` unset — see examples/tune_then_lower.py).  The stock
jit/GSPMD model path does not route through those helpers yet, so its HLO
is unchanged by a plan; wiring ``runtime_for`` into the sharded model
builders is the ROADMAP follow-up.

The launcher has no ``Workload`` object, so the plan's structural
fingerprint cannot be verified here (that guard runs in
``TunedPlan.runtime_plan(wl)`` whenever the workload is in hand); the
model-name cross-check below is the launch-time proxy for it.
"""
from __future__ import annotations

import warnings
from typing import Dict, Optional

from repro.core.apply import activate
from repro.core.session import TunedPlan


def apply_tuned_plan(path: str, *, expect_arch: Optional[str] = None,
                     quiet: bool = False) -> Dict:
    """Load, lower, and install a saved plan; returns the runtime plan
    (identical to ``TunedPlan.load(path).runtime_plan()``).  When
    ``expect_arch`` is given and does not match the model the plan was
    tuned on, a ``RuntimeWarning`` is emitted (the plan still applies —
    site-class knobs are coarse — but the tuning is unsound for a
    different model; re-tune)."""
    plan = TunedPlan.load(path)
    tuned_model = plan.workload.split(":")[0]
    if expect_arch is not None and tuned_model != expect_arch:
        warnings.warn(
            f"tuned plan {path} was tuned on workload {plan.workload!r} "
            f"but this launch runs arch {expect_arch!r} — site-class knobs "
            "may not correspond; re-tune for this model",
            RuntimeWarning, stacklevel=2)
    rt = activate(plan)
    if not quiet:
        knobs = ", ".join(f"{k}={v.strategy}/x{v.num_chunks}"
                          for k, v in sorted(rt.items()))
        print(f"tuned plan {path}: {plan.method}/{plan.mode} on "
              f"{plan.hardware} (workload {plan.workload}, "
              f"{plan.profile_count} profiles) -> {knobs} "
              "[applies to chunked-collective call sites]")
    return rt
