"""Session front door: ``tune() -> TunedPlan`` must be a pure repackaging
of the per-method search paths (configs byte-identical for every method ×
mode), and the plan a faithful portable artifact — JSON round-trips
exactly, refuses structurally mismatched workloads, and lowers to the same
runtime plan live, reloaded, and through the launchers' ``--tuned-plan``
path.  Also covers the legacy ``tune_workload`` deprecation shims and the
Simulator's eager argument validation."""
import pytest

from repro.configs import get_config
from repro.core import (A40_NVLINK, ParallelPlan, PlanMismatchError,
                        Simulator, TPU_V5E, TunedPlan, Workload,
                        extract_workload, tune, workload_fingerprint)
from repro.core import autoccl, baselines, session, tuner
from repro.core.workload import CommOp, OverlapGroup, matmul_comp


def _zoo():
    """Three model-zoo workloads spanning the FSDP / EP / PP overlap
    patterns (trimmed layers: structure, not scale, is under test)."""
    return [
        ("llama3-8b/fsdp", extract_workload(
            get_config("llama3-8b"), ParallelPlan(kind="fsdp", dp=8),
            seq=2048, global_batch=16, layers=2)),
        ("deepseek-moe-16b/ep", extract_workload(
            get_config("deepseek-moe-16b"), ParallelPlan(kind="ep", ep=8),
            seq=2048, global_batch=16, layers=3)),
        ("yi-34b/pp", extract_workload(
            get_config("yi-34b"), ParallelPlan(kind="pp", pp=4,
                                               microbatches=4),
            seq=2048, global_batch=16)),
    ]


def _small_wl():
    g = OverlapGroup("g", comps=[matmul_comp("mm", 2048, 2560, 5120)],
                     comms=[CommOp("ar.x", "allreduce", 32e6, 8)])
    return Workload("small", [g])


# ---------------------------------------------------------------------------
# acceptance: tune() == the pre-redesign per-method paths, method × mode
# ---------------------------------------------------------------------------

def test_tune_matches_search_paths_every_method_and_mode():
    for name, wl in _zoo():
        for mode in ("serial", "interleaved", "shared"):
            plan = tune(wl, TPU_V5E, method="lagom", mode=mode)
            ref = tuner.search_workload(Simulator(TPU_V5E), wl, mode=mode)
            assert plan.configs == ref[0], (name, mode)
            assert plan.profile_count == ref[1], (name, mode)
            assert plan.traces == ref[2], (name, mode)

            aplan = tune(wl, TPU_V5E, method="autoccl", mode=mode)
            aref = autoccl.search_workload(Simulator(TPU_V5E), wl, mode=mode)
            assert aplan.configs == aref[0], (name, mode)
            assert aplan.profile_count == aref[1], (name, mode)

        nplan = tune(wl, TPU_V5E, method="nccl")
        assert nplan.configs == baselines.nccl_defaults(wl, TPU_V5E)
        assert nplan.profile_count == 0


def test_tune_matches_legacy_tune_workload_shim():
    wl = _small_wl()
    plan = tune(wl, A40_NVLINK, noise=0.01, seed=0)
    with pytest.warns(DeprecationWarning):
        legacy = tuner.tune_workload(Simulator(A40_NVLINK, noise=0.01,
                                               seed=0), wl)
    assert plan.configs == legacy[0]
    assert plan.profile_count == legacy[1]


# ---------------------------------------------------------------------------
# the artifact: JSON round-trip, fingerprint guard, runtime plan
# ---------------------------------------------------------------------------

def test_plan_json_roundtrip_across_zoo():
    for name, wl in _zoo():
        serial = tune(wl, TPU_V5E, mode="serial")
        inter = tune(wl, TPU_V5E, mode="interleaved")
        assert serial.configs == inter.configs, name
        back = TunedPlan.from_json(inter.to_json())
        assert back == inter, name                # full-artifact equality
        assert back.configs == serial.configs, name     # byte-identical
        assert back.fingerprint == workload_fingerprint(wl), name
        # the deserialized plan lowers without the workload object, and to
        # the same knobs as the live plan checked against the workload
        assert back.runtime_plan() == inter.runtime_plan(wl), name


def test_noisy_plan_roundtrip_preserves_traces():
    import json

    def reject_constant(c):
        raise AssertionError(f"non-RFC JSON constant emitted: {c}")

    wl = _zoo()[0][1]
    for mode_kw in (dict(noise_mode="default"), dict(noise_mode="crn")):
        plan = tune(wl, A40_NVLINK, noise=0.02, seed=7, **mode_kw)
        text = plan.to_json()
        # strict RFC JSON: the inf-H trace rows must not leak the bare
        # ``Infinity`` token (jq/JS would reject the file)
        json.loads(text, parse_constant=reject_constant)
        back = TunedPlan.from_json(text)
        assert back == plan                # traces (inf H, CommConfigs) too
        assert back.noise == 0.02 and back.seed == 7


def test_plan_save_load(tmp_path):
    wl = _small_wl()
    plan = tune(wl, TPU_V5E)
    path = str(tmp_path / "plan.json")
    plan.save(path)
    loaded = TunedPlan.load(path)
    assert loaded == plan
    assert session.load_plan(path) == plan
    # activate() takes the plan object, a str path, or a PathLike
    from repro.core.apply import activate
    from repro.parallel import collectives
    try:
        assert activate(tmp_path / "plan.json") == plan.runtime_plan()
    finally:
        collectives.install_runtime_plan({})


def test_plan_refuses_mismatched_workload():
    _, wl = _zoo()[0]
    other = extract_workload(get_config("llama3-8b"),
                             ParallelPlan(kind="fsdp", dp=8), seq=1024,
                             global_batch=16, layers=2)   # different shapes
    plan = tune(wl, TPU_V5E)
    assert plan.matches(wl) and not plan.matches(other)
    with pytest.raises(PlanMismatchError):
        plan.runtime_plan(other)
    with pytest.raises(PlanMismatchError):
        plan.evaluate(other)
    with pytest.raises(PlanMismatchError):
        plan.compare(tune(other, TPU_V5E, method="nccl"), wl)
    plan.runtime_plan(wl)                 # matching workload is fine


def test_plan_version_guard():
    plan = tune(_small_wl(), TPU_V5E, method="nccl")
    tampered = plan.to_json().replace('"version": 1', '"version": 99')
    with pytest.raises(ValueError, match="version"):
        TunedPlan.from_json(tampered)


def test_compare_produces_speedup_row():
    wl = _small_wl()
    lag = tune(wl, A40_NVLINK)
    base = tune(wl, A40_NVLINK, method="nccl")
    row = lag.compare(base, wl)
    assert row["method"] == "lagom" and row["baseline"] == "nccl"
    assert row["speedup"] == pytest.approx(
        row["baseline_z_ms"] / row["z_ms"])
    assert row["speedup"] >= 0.98         # tuned never materially worse
    assert row["profiles"] == lag.profile_count


def test_launcher_tuned_plan_path_matches_in_process(tmp_path):
    """--tuned-plan acceptance: load + lower + install through the launcher
    helper == the in-process plan's runtime_plan."""
    from repro.launch.plan import apply_tuned_plan
    from repro.parallel import collectives

    _, wl = _zoo()[0]
    plan = tune(wl, A40_NVLINK)
    path = str(tmp_path / "plan.json")
    plan.save(path)
    try:
        rt = apply_tuned_plan(path, quiet=True,
                              expect_arch=wl.name.split(":")[0])
        assert rt == plan.runtime_plan(wl)
        for site, knobs in rt.items():
            assert collectives.runtime_for(site) == knobs
            # collective call sites that leave num_chunks unset defer to
            # the installed plan; explicit values always win
            assert collectives._resolve_chunks(None, site) == knobs.num_chunks
            assert collectives._resolve_chunks(5, site) == 5
        assert collectives.runtime_for("nonexistent").strategy == "xla"
        # launching a different model against the plan warns loudly
        with pytest.warns(RuntimeWarning, match="re-tune"):
            apply_tuned_plan(path, quiet=True, expect_arch="phi2-2b")
        # the legacy process-global entry point still works, warns, and
        # resolves bit-identically to the non-deprecated install
        with pytest.warns(DeprecationWarning, match="set_runtime_plan"):
            collectives.set_runtime_plan(rt)
        assert collectives.active_runtime_plan() == rt
        for site, knobs in rt.items():
            assert collectives.runtime_for(site) == knobs
    finally:
        collectives.install_runtime_plan({})
    assert collectives._resolve_chunks(None, "ag") == 1   # plan cleared


# ---------------------------------------------------------------------------
# front-door ergonomics: registry, modes, simulator plumbing
# ---------------------------------------------------------------------------

def test_backend_registry_round_trip():
    from repro.core.comm_params import CommConfig
    from repro.core.workload import uniform_configs

    @session.register_backend("unit-test-backend")
    class FixedBackend:
        def search(self, sim, wl, *, mode, **_):
            return session.SearchOutcome(
                uniform_configs(wl, CommConfig(nc=3)), 0, [])

    try:
        assert "unit-test-backend" in session.available_methods()
        plan = tune(_small_wl(), TPU_V5E, method="unit-test-backend")
        assert plan.method == "unit-test-backend"
        assert all(c.nc == 3 for c in plan.configs.values())
        with pytest.raises(ValueError, match="already registered"):
            session.register_backend("unit-test-backend")(FixedBackend)
    finally:
        session.unregister_backend("unit-test-backend")
    with pytest.raises(KeyError, match="unit-test-backend"):
        tune(_small_wl(), TPU_V5E, method="unit-test-backend")


def test_unknown_method_lists_registered():
    with pytest.raises(KeyError, match="lagom"):
        tune(_small_wl(), TPU_V5E, method="nope")


def test_unknown_hardware_name_lists_profiles():
    with pytest.raises(KeyError, match="tpu-v5e"):
        tune(_small_wl(), "a40_nvlink")    # typo: underscore for dash


def test_third_party_nested_traces_roundtrip():
    from repro.core.comm_params import CommConfig
    from repro.core.workload import uniform_configs

    @session.register_backend("nested-trace-backend")
    class NestedTraceBackend:
        def search(self, sim, wl, *, mode):
            traces = [{"cfgs": [CommConfig(nc=4)],
                       "h_per_comm": [float("inf"), 1.0],
                       "nested": {"best": CommConfig(nc=2)}}]
            return session.SearchOutcome(
                uniform_configs(wl, CommConfig()), 0, traces)

    try:
        plan = tune(_small_wl(), TPU_V5E, method="nested-trace-backend")
        back = TunedPlan.from_json(plan.to_json())
        assert back == plan
        assert back.traces[0]["cfgs"][0] == CommConfig(nc=4)
        assert back.traces[0]["h_per_comm"][0] == float("inf")
        assert back.traces[0]["nested"]["best"] == CommConfig(nc=2)
    finally:
        session.unregister_backend("nested-trace-backend")


def test_mode_validation():
    wl = _small_wl()
    with pytest.raises(ValueError, match="mode"):
        tune(wl, TPU_V5E, mode="bogus")
    # shared requires sharing soundness: rejected under default-mode noise,
    # accepted under CRN
    with pytest.raises(ValueError, match="shared"):
        tune(wl, TPU_V5E, mode="shared", noise=0.01)
    tune(wl, TPU_V5E, mode="shared", noise=0.01, noise_mode="crn")
    # the rejection is uniform across methods, not just the built-in tuners
    with pytest.raises(ValueError, match="shared"):
        tune(wl, TPU_V5E, method="nccl", mode="shared", noise=0.01)


def test_tune_simulator_plumbing():
    wl = _small_wl()
    sim = Simulator(TPU_V5E, noise=0.01, seed=5)
    plan = tune(wl, simulator=sim)
    assert plan.hardware == "tpu-v5e"
    assert (plan.noise, plan.seed, plan.noise_mode) == (0.01, 5, "default")
    with pytest.raises(ValueError, match="conflicts"):
        tune(wl, A40_NVLINK, simulator=Simulator(TPU_V5E))
    with pytest.raises(ValueError, match="hardware"):
        tune(wl)
    # simulator kwargs alongside simulator= would be silently shadowed
    with pytest.raises(ValueError, match="simulator"):
        tune(wl, simulator=Simulator(TPU_V5E), noise=0.05)
    with pytest.raises(ValueError, match="simulator"):
        tune(wl, simulator=Simulator(TPU_V5E), seed=9)
    assert tune(wl, "tpu-v5e").configs == tune(wl, TPU_V5E).configs


def test_tune_rejects_unknown_backend_options():
    wl = _small_wl()
    with pytest.raises(TypeError):
        tune(wl, TPU_V5E, method="lagom", warm_star=True)    # typo
    with pytest.raises(TypeError):
        tune(wl, TPU_V5E, method="autoccl", warm_start=True)  # no such opt
    with pytest.raises(TypeError):
        tune(wl, TPU_V5E, method="nccl", warm_start=True)


# ---------------------------------------------------------------------------
# deprecation shims: warn, and return the legacy tuple shapes bit-identically
# ---------------------------------------------------------------------------

def test_tuner_shim_warns_and_matches_bit_identically():
    wl = _small_wl()
    for interleave, mode in ((True, "interleaved"), (False, "serial")):
        with pytest.warns(DeprecationWarning, match="session.tune"):
            legacy = tuner.tune_workload(
                Simulator(A40_NVLINK, noise=0.01, seed=2), wl,
                interleave=interleave)
        ref = tuner.search_workload(
            Simulator(A40_NVLINK, noise=0.01, seed=2), wl, mode=mode)
        assert isinstance(legacy, tuple) and len(legacy) == 3
        assert legacy == ref


def test_autoccl_shim_warns_and_matches_bit_identically():
    wl = _small_wl()
    for interleave, mode in ((True, "interleaved"), (False, "serial")):
        with pytest.warns(DeprecationWarning, match="session.tune"):
            legacy = autoccl.tune_workload(
                Simulator(A40_NVLINK, noise=0.01, seed=2), wl,
                interleave=interleave)
        ref = autoccl.search_workload(
            Simulator(A40_NVLINK, noise=0.01, seed=2), wl, mode=mode)
        assert isinstance(legacy, tuple) and len(legacy) == 2
        assert legacy == ref


# ---------------------------------------------------------------------------
# eager Simulator argument validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [1.5, "0", True, None])
def test_simulator_rejects_bad_seed(bad):
    with pytest.raises(ValueError, match="seed"):
        Simulator(TPU_V5E, seed=bad)


@pytest.mark.parametrize("bad", [-0.01, float("nan"), float("inf"), "0.1",
                                 True])
def test_simulator_rejects_bad_noise(bad):
    with pytest.raises(ValueError, match="noise"):
        Simulator(TPU_V5E, noise=bad)


def test_simulator_accepts_valid_args():
    import numpy as np

    Simulator(TPU_V5E, noise=0.0, seed=0)
    Simulator(TPU_V5E, noise=0.5, seed=123)
    # numpy scalars are valid Integral/Real values and flowed fine before
    # the eager checks existed — they must keep working
    Simulator(TPU_V5E, noise=np.float32(0.01), seed=np.int64(7))
