"""Per-site plan addressing end-to-end: scoped runtime contexts, the
hierarchical SiteId resolution, plan-aware model builders (one plan with
divergent per-site configs must change the emitted structure of two
distinct layers of the same model), the ``set_runtime_plan`` deprecation
shim, the chunked-collective divisibility warnings, and ``TunedPlan.diff``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke_config
from repro.core import ParallelPlan, extract_workload, tune
from repro.core.workload import comm_site_meta
from repro.launch.mesh import make_mesh
from repro.models import dense, model as M
from repro.parallel import collectives as C


@pytest.fixture(autouse=True)
def _clean_plan_state():
    yield
    C.install_runtime_plan({})


def _mesh1():
    return make_mesh((1,), ("model",))


def _fsdp_wl(seq=64, batch=4):
    cfg = get_smoke_config("llama3-8b")
    plan = ParallelPlan(kind="fsdp", dp=8)
    return extract_workload(cfg, plan, seq=seq, global_batch=batch)


# ---------------------------------------------------------------------------
# resolution: exact site > dotted prefix > class > default
# ---------------------------------------------------------------------------


def test_hierarchical_site_resolution():
    rt_exact = C.CollectiveRuntime("ring", 8)
    rt_layer = C.CollectiveRuntime("chunked", 4)
    rt_class = C.CollectiveRuntime("chunked", 2)
    plan = {"tp.layer0.mlp.ag": rt_exact, "tp.layer0": rt_layer, "ag": rt_class}
    with C.use_runtime_plan(plan):
        assert C.runtime_for("tp.layer0.mlp.ag") == rt_exact
        assert C.explain_runtime("tp.layer0.mlp.ag")[1] == "tp.layer0.mlp.ag"
        # no exact entry -> nearest dotted prefix
        assert C.runtime_for("tp.layer0.mlp.rs") == rt_layer
        assert C.explain_runtime("tp.layer0.mlp.rs")[1] == "tp.layer0"
        # no prefix at all -> the collective's class
        assert C.runtime_for("tp.layer9.mlp.ag", "ag") == rt_class
        assert C.explain_runtime("tp.layer9.mlp.ag", "ag")[1] == "ag"
        # nothing matches -> XLA defaults
        assert C.runtime_for("tp.layer9.mlp.rs", "rs").strategy == "xla"
        assert C.explain_runtime("tp.layer9.mlp.rs", "rs")[1] == ""
    # legacy bare-class addressing is an exact match, as before
    with C.use_runtime_plan({"ag": rt_class}):
        assert C.runtime_for("ag") == rt_class


def test_runtime_plan_lowered_per_site_not_three_buckets():
    """One tuned plan must carry distinct entries per comm site (plus the
    prefix/class fallbacks), and two sites of the same class may disagree."""
    wl = _fsdp_wl()
    plan = tune(wl, "tpu-v5e", method="nccl")
    # force divergent per-site configs: layer2's AG chunks much finer
    sites = {(s["group"], s["comm"]): s["site"] for s in comm_site_meta(wl)}
    for key, sid in sites.items():
        if sid == "fsdp.layer2.ag_params":
            plan.configs[key] = dataclasses.replace(plan.configs[key], chunk_kb=64)
    rt = plan.runtime_plan(wl)
    assert rt["fsdp.layer1.ag_params"] != rt["fsdp.layer2.ag_params"]
    # hierarchy present: exact sites, dotted prefixes, legacy class buckets
    assert "fsdp.layer1" in rt and "fsdp" in rt and "ag" in rt and "rs" in rt
    # class bucket equals the first site's knobs (legacy bit-identity)
    assert rt["ag"] == rt["fsdp.layer1.ag_params"]


# ---------------------------------------------------------------------------
# scoped application: applied() nests and restores on every exit path
# ---------------------------------------------------------------------------


def test_applied_scoping_nested_and_exception_paths():
    wl = _fsdp_wl()
    plan = tune(wl, "tpu-v5e", method="nccl")
    base = C.CollectiveRuntime("ring", 3)
    C.install_runtime_plan({"ag": base})  # process-wide base
    sid = "fsdp.layer1.ag_params"
    assert C.runtime_for(sid, "ag") == base  # class fallback pre-scope
    with plan.applied(wl) as rt:
        assert C.runtime_for(sid) == rt[sid]  # exact site inside
        inner = {sid: C.CollectiveRuntime("chunked", 7)}
        with C.use_runtime_plan(inner):  # nested scope shadows
            assert C.runtime_for(sid).num_chunks == 7
        assert C.runtime_for(sid) == rt[sid]  # inner exit restores
    assert C.runtime_for(sid, "ag") == base  # outer exit restores
    with pytest.raises(RuntimeError):  # exception path restores too
        with plan.applied(wl):
            assert C.runtime_for(sid) != base
            raise RuntimeError("boom")
    assert C.runtime_for(sid, "ag") == base
    assert C.active_runtime_plan() == {"ag": base}


def test_set_runtime_plan_shim_warns_with_bit_identical_knobs():
    rt = tune(_fsdp_wl(), "tpu-v5e").runtime_plan()
    with pytest.warns(DeprecationWarning, match="set_runtime_plan"):
        C.set_runtime_plan(rt)
    legacy = {k: C.runtime_for(k) for k in rt}
    legacy_active = C.active_runtime_plan()
    C.install_runtime_plan(rt)
    assert {k: C.runtime_for(k) for k in rt} == legacy
    assert C.active_runtime_plan() == legacy_active


# ---------------------------------------------------------------------------
# the tentpole acceptance: divergent per-site configs -> two distinct
# layers of one model emit different structure (jaxpr level; the slow
# HLO-level variant lives in test_apply_runtime.py)
# ---------------------------------------------------------------------------


def _layer_jaxpr(cfg, params, layer, site, mesh, x, pos):
    lp = jax.tree.map(lambda a: a[layer], params["trunk"]["dense_layers"])

    def one(q, v):
        out, _, _ = dense.layer_fwd(
            q, cfg, v, pos, None, use_moe=False, mesh=mesh, site=site
        )
        return out

    return str(jax.make_jaxpr(one)(lp, x))


def test_divergent_plan_changes_two_layers_structure():
    mesh = _mesh1()
    cfg = get_smoke_config("llama3-8b")  # 2 layers
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    x = jnp.ones((2, 8, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    divergent = {
        "tp.layer0.mlp": C.CollectiveRuntime("chunked", 2),
        "tp.layer1.mlp": C.CollectiveRuntime("chunked", 4),
    }
    uniform = {"tp": C.CollectiveRuntime("chunked", 2)}
    with C.use_runtime_plan(divergent):
        j0 = _layer_jaxpr(cfg, params, 0, "tp.layer0.mlp", mesh, x, pos)
        j1 = _layer_jaxpr(cfg, params, 1, "tp.layer1.mlp", mesh, x, pos)
    assert j0 != j1, "two layers must emit different chunk structure"
    with C.use_runtime_plan(uniform):
        u0 = _layer_jaxpr(cfg, params, 0, "tp.layer0.mlp", mesh, x, pos)
        u1 = _layer_jaxpr(cfg, params, 1, "tp.layer1.mlp", mesh, x, pos)
    assert u0 == u1, "a uniform plan must not split the layers"
    assert u0 == j0 and u1 != j1  # only layer1's site diverged


def test_divergent_plan_from_tuned_artifact_end_to_end():
    """Same property through the real artifact: a TunedPlan whose per-site
    configs diverge lowers+applies to per-layer different jaxprs."""
    mesh = _mesh1()
    cfg = get_smoke_config("llama3-8b")
    pp = ParallelPlan(kind="tp", tp=8)
    wl = extract_workload(cfg, pp, seq=64, global_batch=4, layers=2)
    plan = tune(wl, "tpu-v5e", method="nccl")
    sites = {s["site"]: (s["group"], s["comm"]) for s in comm_site_meta(wl)}
    key0 = sites["tp.layer0.mlp.ar.fwd.mb0"]
    plan.configs[key0] = dataclasses.replace(plan.configs[key0], chunk_kb=16)
    rt = plan.runtime_plan(wl)
    assert rt["tp.layer0.mlp"] != rt["tp.layer1.mlp"]
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    x = jnp.ones((2, 8, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    with plan.applied(wl):
        j0 = _layer_jaxpr(cfg, params, 0, "tp.layer0.mlp", mesh, x, pos)
        j1 = _layer_jaxpr(cfg, params, 1, "tp.layer1.mlp", mesh, x, pos)
    assert j0 != j1


def test_sited_trunk_matches_gspmd_numerics():
    mesh = _mesh1()
    plan = {
        "tp": C.CollectiveRuntime("chunked", 2),
        "ep": C.CollectiveRuntime("chunked", 2),
    }
    for arch in ("llama3-8b", "deepseek-moe-16b"):
        cfg = get_smoke_config(arch)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        batch = {"tokens": jnp.arange(16).reshape(2, 8) % cfg.vocab_size}
        ref, _, aux_ref = M.forward_hidden(cfg, params, batch)
        with C.use_runtime_plan(plan):
            out, _, aux = M.forward_hidden(cfg, params, batch, mesh=mesh)
        assert jnp.allclose(ref, out, atol=1e-4), arch
        assert jnp.allclose(aux_ref, aux), arch


def test_moe_per_layer_a2a_sites_change_structure():
    mesh = _mesh1()
    cfg = get_smoke_config("deepseek-moe-16b")  # 1 dense + 1 moe layer
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.arange(16).reshape(2, 8) % cfg.vocab_size}

    def trunk_jaxpr(plan):
        def one(q):
            return M.forward_hidden(cfg, q, batch, mesh=mesh)[0]

        with C.use_runtime_plan(plan):
            return str(jax.make_jaxpr(one)(params))

    a = trunk_jaxpr({"ep.layer0.moe": C.CollectiveRuntime("chunked", 2)})
    b = trunk_jaxpr({"ep.layer0.moe": C.CollectiveRuntime("chunked", 4)})
    assert a != b
    # disp and comb are separately addressable
    c = trunk_jaxpr({"ep.layer0.moe.a2a_disp": C.CollectiveRuntime("chunked", 2)})
    assert c != a and c != trunk_jaxpr({})


def test_sited_trunk_falls_back_on_inapplicable_mesh():
    cfg = get_smoke_config("llama3-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.arange(14).reshape(2, 7) % cfg.vocab_size}
    bad_mesh = make_mesh((1,), ("stage",))  # no "model" axis at all
    with pytest.warns(RuntimeWarning, match="plan-aware trunk disabled"):
        out, _, _ = M.forward_hidden(cfg, params, batch, mesh=bad_mesh)
    ref, _, _ = M.forward_hidden(cfg, params, batch)
    assert jnp.allclose(ref, out)


# ---------------------------------------------------------------------------
# satellite: indivisible chunk counts warn once, naming the site
# ---------------------------------------------------------------------------


def test_ring_ag_indivisible_chunks_warn_with_site():
    mesh = _mesh1()
    x = jnp.ones((2, 8, 16))
    w = jnp.ones((16, 8))
    with pytest.warns(RuntimeWarning, match="tp.layer0.mlp.ag"):
        C.ring_ag_matmul(
            x,
            w,
            mesh,
            axis="model",
            x_spec=P(None, "model", None),
            w_spec=P(None, "model"),
            out_spec=P(None, None, "model"),
            num_chunks=3,
            site="tp.layer0.mlp.ag",
        )


def test_mm_rs_indivisible_chunks_warn_with_site():
    mesh = _mesh1()
    x = jnp.ones((2, 8, 16))
    w = jnp.ones((16, 8))
    with pytest.warns(RuntimeWarning, match="my.rs.site"):
        C.mm_reduce_scatter(
            x,
            w,
            mesh,
            axis="model",
            x_spec=P(None, None, "model"),
            w_spec=P("model", None),
            out_spec=P(None, "model", None),
            num_chunks=3,
            site="my.rs.site",
        )


def test_a2a_indivisible_chunks_warn_with_site():
    mesh = _mesh1()
    x = jnp.ones((4, 4, 10))
    with pytest.warns(RuntimeWarning, match="ep.layer0.moe.a2a_disp"):
        C.chunked_all_to_all(
            x,
            mesh,
            axis="model",
            split_axis=1,
            concat_axis=0,
            x_spec=P("model", None, None),
            out_spec=P("model", None, None),
            num_chunks=3,
            site="ep.layer0.moe.a2a_disp",
        )


def test_moe_buffer_guard_warns_with_site_and_matches_gspmd():
    """The fifth indivisible-chunk path: an expert buffer whose (E, cap)
    does not divide the mesh axis falls back to the GSPMD expert layout —
    warning once, naming the SiteId — with numerics identical to the
    mesh-free path."""
    from repro.models import layers as L, model as M

    cfg = get_smoke_config("deepseek-moe-16b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    mp = jax.tree.map(lambda a: a[0], params["trunk"]["moe_layers"])["moe"]
    x = jnp.ones((2, 8, cfg.d_model)) * 0.1

    class FakeMesh:  # guard reads mesh.shape before discarding the mesh
        shape = {"model": 3}  # E=4, cap=10: neither divides 3

    ref, aux_ref = L.moe_block(mp, cfg, x)
    with pytest.warns(RuntimeWarning, match="ep.layer0.moe"):
        out, aux = L.moe_block(mp, cfg, x, mesh=FakeMesh(), site="ep.layer0.moe")
    assert jnp.allclose(ref, out)
    assert jnp.allclose(aux_ref, aux)


def test_pipeline_p2p_site_resolves_and_warns_on_indivisible():
    from repro.parallel.pipeline import pipeline_apply

    mesh = make_mesh((1,), ("stage",))
    params = {"w": jnp.ones((1, 5, 5))}

    def fn(p, x):
        return x @ p["w"]

    def make_run():
        # fresh callable per trace: jax caches traces per function object,
        # and the plan is read at trace time
        def run(v):
            return pipeline_apply(
                fn, params, v, mesh=mesh, axis="stage", microbatches=2
            )

        return run

    x = jnp.ones((4, 5))
    with C.use_runtime_plan({"pp": C.CollectiveRuntime("chunked", 3)}):
        with pytest.warns(RuntimeWarning, match="pp.tick.p2p"):
            y = pipeline_apply(
                fn,
                params,
                x,
                mesh=mesh,
                axis="stage",
                microbatches=2,
                site="pp.tick.p2p",
            )
    assert jnp.allclose(y, x @ params["w"][0])
    # divisible chunk counts lower silently and change the jaxpr
    with C.use_runtime_plan({"p2p": C.CollectiveRuntime("chunked", 5)}):
        j5 = str(jax.make_jaxpr(make_run())(x))
    j1 = str(jax.make_jaxpr(make_run())(x))
    assert j5 != j1


# ---------------------------------------------------------------------------
# satellite: TunedPlan.diff
# ---------------------------------------------------------------------------


def test_plan_diff_field_level_per_site():
    wl = _fsdp_wl()
    a = tune(wl, "tpu-v5e", method="nccl")
    b = tune(wl, "tpu-v5e", method="nccl")
    d = a.diff(b)
    assert d["changed"] == {} and d["only_self"] == [] == d["only_other"]
    assert d["meta"] == {}
    # mutate one site, two fields
    key = next(iter(b.configs))
    sid = {(s["group"], s["comm"]): s["site"] for s in b.sites}[key]
    b.configs[key] = dataclasses.replace(b.configs[key], nc=99, chunk_kb=1)
    b.method = "autoccl"
    d = a.diff(b)
    assert set(d["changed"]) == {sid}
    assert set(d["changed"][sid]) == {"nc", "chunk_kb"}
    assert d["changed"][sid]["nc"][1] == 99
    assert d["meta"]["method"] == ["nccl", "autoccl"]
    # one-sided sites are reported, not diffed
    dropped = dict(b.configs)
    dropped.pop(key)
    b.configs = dropped
    d = a.diff(b)
    assert sid in d["only_self"] and sid not in d["changed"]


def test_plan_diff_cli(tmp_path, capsys):
    from repro.core import session

    a = tune(_fsdp_wl(), "tpu-v5e", method="nccl")
    pa, pb = str(tmp_path / "a.json"), str(tmp_path / "b.json")
    a.save(pa)
    key = next(iter(a.configs))
    a.configs[key] = dataclasses.replace(a.configs[key], nt=7)
    a.save(pb)
    assert session._main(["diff", pa, pa]) == 0
    out = capsys.readouterr().out
    assert "identical" in out
    assert session._main(["diff", pa, pb]) == 1
    out = capsys.readouterr().out
    assert "nt" in out and "7" in out
