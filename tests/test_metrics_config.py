"""Metrics (MFU accounting) and the JSON run-config loader."""
import json
import tempfile

import pytest

from repro.configs import get_config
from repro.launch.config import load_run_config, resolve_model
from repro.train import metrics as MET


def test_train_step_flops_and_mfu():
    cfg = get_config("llama3-8b")
    tokens = 4096 * 256
    f = MET.train_step_flops(cfg, tokens)
    assert f.model == pytest.approx(6 * cfg.param_count(active_only=True) * tokens)
    assert f.executed > f.model
    # perfect-efficiency sanity: executing model flops at peak -> MFU ~0.75
    ideal_t = f.executed / (256 * MET.TPU_V5E_PEAK)
    assert 0.70 < MET.mfu(cfg, tokens, ideal_t, chips=256) < 0.78


def test_tracker_window():
    cfg = get_config("phi2-2b")
    tr = MET.Tracker(cfg, tokens_per_step=1024, window=3)
    for t in (1.0, 1.0, 2.0, 2.0, 2.0):
        m = tr.update(t)
    assert m["step_s"] == 2.0
    assert m["tokens_per_s"] == pytest.approx(512.0)


def test_run_config_roundtrip():
    raw = {"arch": "h2o-danube-1.8b", "smoke": True, "steps": 5,
           "overrides": {"sliding_window": 16}}
    with tempfile.NamedTemporaryFile("w", suffix=".json", delete=False) as f:
        json.dump(raw, f)
        path = f.name
    run = load_run_config(path)
    cfg = resolve_model(run)
    assert cfg.sliding_window == 16
    assert cfg.num_layers <= 2        # smoke reduction applied


def test_run_config_rejects_unknown_keys():
    with tempfile.NamedTemporaryFile("w", suffix=".json", delete=False) as f:
        json.dump({"arch": "yi-34b", "typo_key": 1}, f)
        path = f.name
    with pytest.raises(ValueError):
        load_run_config(path)
