"""Tuned configs are real compile-time artifacts: a Lagom chunk count of n
must produce n partial collectives in the lowered HLO (subprocess with an
8-device host mesh)."""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_test_mesh
from repro.parallel.collectives import mm_reduce_scatter, chunked_all_to_all
from repro.core.apply import to_runtime
from repro.core.comm_params import CommConfig

mesh = make_test_mesh((2, 4), ("data", "model"))
x = jnp.ones((2, 32, 64))
w = jnp.ones((64, 32))

def count(hlo, op):
    return hlo.count(f" {op}(") + hlo.count(f" {op}-start(")

for nc in (1, 2, 4):
    f = jax.jit(lambda x, w: mm_reduce_scatter(
        x, w, mesh, axis="model", x_spec=P("data", None, "model"),
        w_spec=P("model", None), out_spec=P("data", "model", None),
        num_chunks=nc))
    hlo = f.lower(x, w).compile().as_text()
    n_rs = count(hlo, "reduce-scatter")
    assert n_rs >= 1, (nc, n_rs)
    # chunked variants run the scatter inside a loop body (or unrolled):
    # the HLO must contain the loop / n partial scatters, never a single
    # monolithic scatter for nc>1
    if nc > 1:
        assert ("while" in hlo) or n_rs >= nc, (nc, n_rs, "no chunk structure")

# the tuner's chunk_kb maps to ceil(bytes/chunk)
rt = to_runtime(CommConfig(algorithm="ring", chunk_kb=64), 512 * 1024)
assert rt.num_chunks == 8 and rt.strategy == "ring"
print("SUBPROCESS_OK")
"""


@pytest.mark.slow
def test_tuned_chunks_visible_in_hlo():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert "SUBPROCESS_OK" in out.stdout, out.stdout + out.stderr
