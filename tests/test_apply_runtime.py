"""Tuned configs are real compile-time artifacts: a Lagom chunk count of n
must produce n partial collectives in the lowered HLO (subprocess with an
8-device host mesh)."""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_test_mesh
from repro.parallel.collectives import mm_reduce_scatter, chunked_all_to_all
from repro.core.apply import to_runtime
from repro.core.comm_params import CommConfig

mesh = make_test_mesh((2, 4), ("data", "model"))
x = jnp.ones((2, 32, 64))
w = jnp.ones((64, 32))

def count(hlo, op):
    return hlo.count(f" {op}(") + hlo.count(f" {op}-start(")

for nc in (1, 2, 4):
    f = jax.jit(lambda x, w: mm_reduce_scatter(
        x, w, mesh, axis="model", x_spec=P("data", None, "model"),
        w_spec=P("model", None), out_spec=P("data", "model", None),
        num_chunks=nc))
    hlo = f.lower(x, w).compile().as_text()
    n_rs = count(hlo, "reduce-scatter")
    assert n_rs >= 1, (nc, n_rs)
    # chunked variants run the scatter inside a loop body (or unrolled):
    # the HLO must contain the loop / n partial scatters, never a single
    # monolithic scatter for nc>1
    if nc > 1:
        assert ("while" in hlo) or n_rs >= nc, (nc, n_rs, "no chunk structure")

# the tuner's chunk_kb maps to ceil(bytes/chunk)
rt = to_runtime(CommConfig(algorithm="ring", chunk_kb=64), 512 * 1024)
assert rt.num_chunks == 8 and rt.strategy == "ring"
print("SUBPROCESS_OK")
"""


@pytest.mark.slow
def test_tuned_chunks_visible_in_hlo():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert "SUBPROCESS_OK" in out.stdout, out.stdout + out.stderr


_SITED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.parallel import collectives as C

mesh = make_mesh((8,), ("model",))
cfg = get_smoke_config("llama3-8b")          # 2 layers
params = M.init_params(cfg, jax.random.PRNGKey(0))
batch = {"tokens": jnp.arange(2 * 32).reshape(2, 32) % cfg.vocab_size}

def hlo(plan):
    with C.use_runtime_plan(plan):
        f = jax.jit(lambda p: M.forward_hidden(cfg, p, batch, mesh=mesh)[0])
        return f.lower(params).compile().as_text()

rt = C.CollectiveRuntime
uniform1 = hlo({"tp": rt("chunked", 1)})
uniform2 = hlo({"tp": rt("chunked", 2)})
divergent = hlo({"tp.layer0.mlp": rt("chunked", 2),
                 "tp.layer1.mlp": rt("chunked", 4)})
# a plan with divergent per-site configs produces observably different
# compiled structure from either uniform plan of the same 2-layer model
assert divergent != uniform1 and divergent != uniform2
assert uniform1 != uniform2
# and the emitted values are the plan-independent model function
ref = M.forward_hidden(cfg, params, batch)[0]
with C.use_runtime_plan({"tp.layer0.mlp": rt("chunked", 2),
                         "tp.layer1.mlp": rt("chunked", 4)}):
    out = M.forward_hidden(cfg, params, batch, mesh=mesh)[0]
assert float(jnp.abs(ref - out).max()) < 1e-3

# overlap verifier acceptance: every tuned chunked site MATERIALIZED at
# both the jaxpr and the compiled-HLO level, and the same trace flips to
# ABSENT when the plan is deliberately not installed
from repro.analysis.overlap import trace_and_verify
plan = {"tp.layer0.mlp": rt("chunked", 2), "tp.layer1.mlp": rt("chunked", 4)}
fn = lambda p: M.forward_hidden(cfg, p, batch, mesh=mesh)[0]
jrep, hrep = trace_and_verify(plan, fn, params, hlo=divergent)
for rep in (jrep, hrep):
    assert rep.ok() and len(rep.verdicts) == 4, rep.format()
    for site, nc in (("tp.layer0.mlp.ag", 2), ("tp.layer0.mlp.rs", 2),
                     ("tp.layer1.mlp.ag", 4), ("tp.layer1.mlp.rs", 4)):
        v = next(x for x in rep.verdicts if x.site == site)
        assert (v.verdict, v.num_chunks) == ("MATERIALIZED", nc), (
            rep.source, site, v)
off_j, off_h = trace_and_verify(plan, fn, params, install=False,
                                hlo=uniform1)
assert [v.verdict for v in off_j.verdicts] == ["ABSENT"] * 4, off_j.format()
assert [v.verdict for v in off_h.verdicts] == ["ABSENT"] * 4, off_h.format()
print("SUBPROCESS_OK")
"""


@pytest.mark.slow
def test_divergent_per_site_plan_changes_two_layers_hlo():
    """Tentpole acceptance at the HLO level: on a real 8-device mesh, one
    plan whose per-site configs diverge compiles a 2-layer model to
    different collective structure than any uniform plan — per-layer sites
    flow into the emitted program."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _SITED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert "SUBPROCESS_OK" in out.stdout, out.stdout + out.stderr
