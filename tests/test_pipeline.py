"""GPipe pipeline parallelism: shard_map ppermute schedule vs sequential
stages (subprocess, 4 host devices), and the Lagom-tunable PP workload."""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
import jax.numpy as jnp
from repro.parallel.pipeline import pipeline_apply
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((4,), ("stage",))
rng = jax.random.PRNGKey(0)
S, D = 4, 16
ws = jax.random.normal(rng, (S, D, D)) * 0.3
bs = jnp.zeros((S, D))
params = {"w": ws, "b": bs}

def stage_fn(p, x):
    return jax.nn.relu(x @ p["w"] + p["b"])

x = jax.random.normal(jax.random.PRNGKey(1), (8, D))
ref = x
for i in range(S):
    ref = stage_fn({"w": ws[i], "b": bs[i]}, ref)
for M in (2, 4, 8):
    y = pipeline_apply(stage_fn, params, x, mesh=mesh, microbatches=M)
    assert float(jnp.abs(y - ref).max()) < 1e-5, M
print("SUBPROCESS_OK")
"""


@pytest.mark.slow
def test_pipeline_matches_sequential():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert "SUBPROCESS_OK" in out.stdout, out.stdout + out.stderr


def test_pp_workload_extract_and_tune():
    from repro.configs import get_config
    from repro.core import (ParallelPlan, Simulator, TPU_V5E, extract_workload,
                            tuner)
    from repro.core.baselines import nccl_defaults
    cfg = get_config("llama3-8b")
    wl = extract_workload(cfg, ParallelPlan(kind="pp", pp=8, microbatches=8),
                          seq=2048, global_batch=32)
    assert wl.num_comms == 2 * (8 + 8 - 1)     # fwd + bwd ticks
    sim = Simulator(TPU_V5E, noise=0.01, seed=0)
    base = sim.profile(wl, nccl_defaults(wl, TPU_V5E))
    cfgs, _, _ = tuner.search_workload(sim, wl)
    tuned = sim.profile(wl, cfgs)
    assert tuned.Z <= base.Z * 1.02
