"""Chunked overlapped collectives vs dense references, on an 8-device host
mesh (spawned in a subprocess so the main test session keeps 1 device)."""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.parallel import collectives as C
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((2, 4), ("data", "model"))
rng = jax.random.PRNGKey(0)
x = jax.random.normal(rng, (2, 16, 32))
w = jax.random.normal(rng, (32, 64))
for nc in (1, 2, 4):
    y = C.ring_ag_matmul(x, w, mesh, axis="model",
                         x_spec=P("data", "model", None), w_spec=P(None, "model"),
                         out_spec=P("data", None, "model"), num_chunks=nc)
    assert float(jnp.abs(y - x @ w).max()) < 1e-4, ("ring_ag", nc)

xf = jax.random.normal(rng, (2, 16, 64))
wf = jax.random.normal(rng, (64, 32))
for nc in (1, 2, 4):
    y = C.mm_reduce_scatter(xf, wf, mesh, axis="model",
                            x_spec=P("data", None, "model"), w_spec=P("model", None),
                            out_spec=P("data", "model", None), num_chunks=nc)
    assert float(jnp.abs(y - xf @ wf).max()) < 1e-3, ("mm_rs", nc)

xa = jax.random.normal(rng, (8, 4, 16))
ref = None
for nc in (1, 2, 4):
    y = C.chunked_all_to_all(xa, mesh, axis="model", split_axis=1, concat_axis=0,
                             x_spec=P("model", None, None),
                             out_spec=P("model", None, None), num_chunks=nc)
    ref = y if ref is None else ref
    assert float(jnp.abs(y - ref).max()) < 1e-6, ("a2a", nc)

# sharding rules produce valid NamedShardings on this mesh
from repro.configs import get_smoke_config
from repro.models import model as M
from repro.parallel import sharding as SH
cfg = get_smoke_config("h2o-danube-1.8b")
params = M.init_params(cfg, rng)
spec = SH.param_specs(params, mesh)
from jax.sharding import NamedSharding
sharded = jax.device_put(params, jax.tree.map(lambda s: NamedSharding(mesh, s), spec))
assert jax.tree.all(jax.tree.map(lambda a: bool(jnp.isfinite(a).all()), sharded))
print("SUBPROCESS_OK")
"""


@pytest.mark.slow
def test_collectives_on_8_devices():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert "SUBPROCESS_OK" in out.stdout, out.stdout + out.stderr


# ---------------------------------------------------------------------------
# resolve_runtime precedence edge cases (in-process: resolution is pure)
# ---------------------------------------------------------------------------

from repro.parallel import collectives as C  # noqa: E402


def test_resolve_classless_acc_and_outer_sites():
    """``acc.*``/``outer.*`` sites have no legacy class bucket — their
    first dotted component doubles as both prefix entry and the ``cls``
    fallback, so both routes must land on the same entry (and report the
    tier of whichever matched first: prefix)."""
    rt = C.CollectiveRuntime
    plan = {"acc": rt("chunked", 4), "outer": rt("ring", 2)}
    with C.use_runtime_plan(plan):
        for sid, want in (("acc.step3.rs_grads", plan["acc"]),
                          ("outer.round1.sync.w", plan["outer"])):
            cls = C.site_class(sid)
            knobs, key, tier = C.resolve_runtime(sid, cls)
            assert (knobs, key, tier) == (want, cls, "prefix"), sid
            # the class route alone (site unknown) still resolves
            knobs, key, tier = C.resolve_runtime("", cls)
            assert (knobs, key, tier) == (want, cls, "class"), sid


def test_resolve_exact_beats_prefix_beats_class_with_empty_class():
    rt = C.CollectiveRuntime
    exact, prefix, klass = rt("ring", 8), rt("ring", 4), rt("chunked", 2)
    plan = {"a.b.c": exact, "a.b": prefix, "": klass}
    with C.use_runtime_plan(plan):
        assert C.resolve_runtime("a.b.c", "")[1:] == ("a.b.c", "exact")
        assert C.resolve_runtime("a.b.d", "")[1:] == ("a.b", "prefix")
        # nothing dotted matches: the empty-string class entry is a real
        # key, not the "no match" sentinel
        knobs, key, tier = C.resolve_runtime("z.y", "")
        assert (knobs, key, tier) == (klass, "", "class")
        # empty site + empty class: the site loop never runs, class wins
        assert C.resolve_runtime("", "")[2] == "class"
        # cls=None opts out entirely -> XLA default, matched_key ""
        knobs, key, tier = C.resolve_runtime("z.y", None)
        assert tier == "default" and key == "" and knobs.num_chunks == 1


def test_resolve_prefix_shadowed_by_exhaustive_exact_entries():
    """When every site under a prefix also has an exact entry, the prefix
    entry is never the winning key for those sites — it only serves
    *novel* siblings (the first-wins ``setdefault`` lowering depends on
    this to stay bit-identical to pre-per-site plans)."""
    rt = C.CollectiveRuntime
    exacts = {f"tp.layer{i}.mlp.ag": rt("ring", i + 2) for i in range(3)}
    plan = dict(exacts)
    plan["tp"] = rt("chunked", 16)
    with C.use_runtime_plan(plan):
        for sid, want in exacts.items():
            knobs, key, tier = C.resolve_runtime(sid, "ag")
            assert (knobs, key, tier) == (want, sid, "exact")
        # a sibling with no exact entry falls through to the prefix
        knobs, key, tier = C.resolve_runtime("tp.layer9.mlp.ag", "ag")
        assert (knobs, key, tier) == (plan["tp"], "tp", "prefix")
