"""PlanRepository: (fingerprint × hardware) round-trips, miss semantics
(unknown structure / stale hardware), tamper refusal, ``tune(repo=...)``
auto-put, and the launchers' ``--plan-repo`` startup path end-to-end (a
repository hit installs the stored plan with zero tuning work)."""
import json

import pytest

from repro.configs import get_smoke_config
from repro.core import (
    ParallelPlan,
    PlanRepoError,
    PlanRepository,
    extract_decode_workload,
    extract_workload,
    tune,
    workload_fingerprint,
)
from repro.core.plan_repo import as_repository
from repro.parallel import collectives as C


@pytest.fixture(autouse=True)
def _clean_plan_state():
    yield
    C.install_runtime_plan({})


def _wl(seq=64, batch=4):
    cfg = get_smoke_config("llama3-8b")
    plan = ParallelPlan(kind="fsdp", dp=8)
    return extract_workload(cfg, plan, seq=seq, global_batch=batch)


def test_repo_round_trip(tmp_path):
    repo = PlanRepository(tmp_path / "repo")
    wl = _wl()
    plan = tune(wl, "tpu-v5e", method="nccl")
    path = repo.put(plan)
    assert (plan.fingerprint, "tpu-v5e") in repo and len(repo) == 1
    assert repo.entries()[0][:2] == (plan.fingerprint, "tpu-v5e")
    back = repo.get(plan.fingerprint, "tpu-v5e")
    assert back == plan  # full-artifact equality
    assert repo.resolve(wl, "tpu-v5e") == plan
    assert path.endswith(f"{plan.fingerprint}__tpu-v5e.json")
    with pytest.raises(FileExistsError, match="overwrite"):
        repo.put(plan, overwrite=False)
    repo.put(plan)  # overwrite=True default


def test_repo_misses(tmp_path):
    repo = PlanRepository(tmp_path)
    wl = _wl()
    plan = tune(wl, "tpu-v5e", method="nccl")
    repo.put(plan)
    # stale-hardware miss: same structure, different hardware key
    assert repo.resolve(wl, "a40-nvlink") is None
    assert repo.get(plan.fingerprint, "a40-nvlink") is None
    # unknown-structure miss
    other = _wl(seq=32, batch=2)
    assert workload_fingerprint(other) != plan.fingerprint
    assert repo.resolve(other, "tpu-v5e") is None


def test_repo_refuses_misfiled_or_tampered_entries(tmp_path):
    repo = PlanRepository(tmp_path)
    wl = _wl()
    plan = tune(wl, "tpu-v5e", method="nccl")
    path = repo.put(plan)
    # tamper: rewrite the stored fingerprint but keep the filename key
    with open(path) as f:
        doc = json.load(f)
    doc["fingerprint"] = "0" * 64
    with open(path, "w") as f:
        json.dump(doc, f)
    with pytest.raises(PlanRepoError, match="misfiled/tampered"):
        repo.get(plan.fingerprint, "tpu-v5e")
    with pytest.raises(PlanRepoError):
        repo.resolve(wl, "tpu-v5e")
    # truncated entry (interrupted writer of a pre-atomic-put era): the
    # repository refuses it rather than crashing with a decode error
    with open(path, "w") as f:
        f.write('{"method": "lagom", "mo')
    with pytest.raises(PlanRepoError, match="truncated or corrupt"):
        repo.get(plan.fingerprint, "tpu-v5e")


def test_train_launcher_corrupt_entry_warns_and_runs_untuned(tmp_path):
    from repro.launch import train

    wl = _wl(seq=32, batch=2)
    plan = tune(wl, "tpu-v5e", method="nccl", repo=str(tmp_path))
    path = PlanRepository(tmp_path).path_for(plan.fingerprint, "tpu-v5e")
    with open(path, "w") as f:
        f.write("{not json")
    argv = ["--arch", "llama3-8b", "--smoke", "--steps", "1"]
    argv += ["--seq", "32", "--batch", "2", "--plan-repo", str(tmp_path)]
    with pytest.warns(RuntimeWarning, match="launching untuned"):
        train.main(argv)
    assert C.active_runtime_plan() == {}


def test_tune_repo_auto_put(tmp_path):
    wl = _wl()
    plan = tune(wl, "tpu-v5e", method="nccl", repo=str(tmp_path))
    repo = PlanRepository(tmp_path)
    assert repo.resolve(wl, "tpu-v5e") == plan
    # a PlanRepository instance is accepted too, and coerces to itself
    assert as_repository(repo) is repo
    plan2 = tune(wl, "a40-nvlink", method="nccl", repo=repo)
    assert repo.resolve(wl, "a40-nvlink") == plan2
    assert len(repo) == 2


# ---------------------------------------------------------------------------
# acceptance: launch/train.py --plan-repo installs the stored plan with
# zero tuning work; a miss warns and launches untuned
# ---------------------------------------------------------------------------


def test_train_launcher_resolves_repo_plan_end_to_end(tmp_path, capsys):
    from repro.launch import train

    wl = _wl(seq=32, batch=2)
    plan = tune(wl, "tpu-v5e", repo=str(tmp_path))
    argv = ["--arch", "llama3-8b", "--smoke", "--steps", "1"]
    argv += ["--seq", "32", "--batch", "2"]
    argv += ["--plan-repo", str(tmp_path)]
    argv += ["--plan-parallel", "fsdp:8", "--plan-hardware", "tpu-v5e"]
    train.main(argv)
    out = capsys.readouterr().out
    assert "zero tuning at launch" in out
    # the launcher-installed knobs are exactly the stored plan's lowering
    rt = plan.runtime_plan(wl)
    assert C.active_runtime_plan() == rt
    for sid, knobs in rt.items():
        assert C.runtime_for(sid) == knobs


def test_train_launcher_repo_miss_warns_and_runs_untuned(tmp_path):
    from repro.launch import train

    argv = ["--arch", "llama3-8b", "--smoke", "--steps", "1"]
    argv += ["--seq", "32", "--batch", "2", "--plan-repo", str(tmp_path)]
    with pytest.warns(RuntimeWarning, match="launches untuned"):
        train.main(argv)
    assert C.active_runtime_plan() == {}


def test_serve_launcher_resolves_repo_plan(tmp_path, capsys):
    from repro.launch import serve

    cfg = get_smoke_config("llama3-8b")
    pp = ParallelPlan(kind="tp", tp=2)
    # the serving launcher resolves the decode-shape workload (serve.* sites)
    wl = extract_decode_workload(cfg, pp, global_batch=2, seq=32)
    plan = tune(wl, "tpu-v5e", repo=str(tmp_path))
    argv = ["--arch", "llama3-8b", "--smoke", "--batch", "2"]
    argv += ["--prompt-len", "4", "--max-new", "2", "--max-seq", "32"]
    argv += ["--plan-repo", str(tmp_path), "--plan-parallel", "tp:2"]
    serve.main(argv)
    out = capsys.readouterr().out
    assert "zero tuning at launch" in out
    # one resolve per batch (generate + throughput probe), both exact
    assert "2 exact, 0 banded, 0 miss" in out
    assert C.active_runtime_plan() == plan.runtime_plan(wl)
    assert any(s.startswith("serve.layer") for s in plan.runtime_plan(wl))


def test_serve_launcher_banded_repo_hit(tmp_path, capsys):
    from repro.launch import serve

    cfg = get_smoke_config("llama3-8b")
    pp = ParallelPlan(kind="tp", tp=2)
    wl = extract_decode_workload(cfg, pp, global_batch=4, seq=32)
    tune(wl, "tpu-v5e", repo=str(tmp_path))
    argv = ["--arch", "llama3-8b", "--smoke", "--batch", "6"]
    argv += ["--prompt-len", "4", "--max-new", "2", "--max-seq", "32"]
    argv += ["--plan-repo", str(tmp_path), "--plan-parallel", "tp:2"]
    argv += ["--plan-band", "0.5"]
    serve.main(argv)
    out = capsys.readouterr().out
    assert "banded hit" in out
    assert "0 exact, 2 banded, 0 miss" in out


# ---------------------------------------------------------------------------
# tolerance-band resolution: same structure modulo (seq, batch), nearest wins
# ---------------------------------------------------------------------------


def _decode_wl(arch="llama3-8b", kind="tp", degree=2, batch=4, seq=32):
    cfg = get_smoke_config(arch)
    kw = {"tp": degree} if kind == "tp" else {"ep": degree}
    pp = ParallelPlan(kind=kind, **kw)
    return extract_decode_workload(cfg, pp, global_batch=batch, seq=seq)


def test_banded_resolve_hits_nearby_shape(tmp_path):
    repo = PlanRepository(tmp_path)
    plan = tune(_decode_wl(batch=4), "tpu-v5e", method="nccl", repo=repo)
    want = _decode_wl(batch=6)  # 6/4 - 1 = 0.5: inside band 0.5
    # band=0.0 default preserves the exact-only pre-band behavior
    assert repo.resolve(want, "tpu-v5e") is None
    got, how = repo.resolve_explain(want, "tpu-v5e", band=0.5)
    assert how == "banded" and got == plan
    # out of band: miss
    far = _decode_wl(batch=32)
    assert repo.resolve_explain(far, "tpu-v5e", band=0.5) == (None, "miss")
    # exact hit stays exact even with a band
    got, how = repo.resolve_explain(_decode_wl(batch=4), "tpu-v5e", band=0.5)
    assert how == "exact"


def test_banded_resolve_nearest_shape_wins(tmp_path):
    repo = PlanRepository(tmp_path)
    near = tune(_decode_wl(batch=4), "tpu-v5e", method="nccl", repo=repo)
    far = tune(_decode_wl(batch=8), "tpu-v5e", method="nccl", repo=repo)
    got, how = repo.resolve_explain(_decode_wl(batch=5), "tpu-v5e", band=0.6)
    assert how == "banded" and got == near
    got, how = repo.resolve_explain(_decode_wl(batch=7), "tpu-v5e", band=0.6)
    assert how == "banded" and got == far


def test_banded_resolve_refuses_structural_mismatch(tmp_path):
    repo = PlanRepository(tmp_path)
    moe = _decode_wl("olmoe-1b-7b", kind="ep", batch=4)
    tune(moe, "tpu-v5e", method="nccl", repo=repo)
    # a dense workload must never borrow the MoE plan, however wide the band
    dense = _decode_wl("llama3-8b", batch=4)
    assert repo.resolve_explain(dense, "tpu-v5e", band=100.0) == (None, "miss")
    # seq deviation is banded too, not just batch
    long_seq = _decode_wl("olmoe-1b-7b", kind="ep", batch=4, seq=256)
    assert repo.resolve_explain(long_seq, "tpu-v5e", band=0.5)[1] == "miss"
    near_seq = _decode_wl("olmoe-1b-7b", kind="ep", batch=4, seq=40)
    assert repo.resolve_explain(near_seq, "tpu-v5e", band=0.5)[1] == "banded"


def test_banded_resolve_quarantines_corrupt_neighbor(tmp_path):
    import os

    repo = PlanRepository(tmp_path)
    plan = tune(_decode_wl(batch=4), "tpu-v5e", method="nccl", repo=repo)
    path = repo.path_for(plan.fingerprint, "tpu-v5e")
    with open(path) as f:
        doc = json.load(f)
    doc["fingerprint"] = "0" * 64
    with open(path, "w") as f:
        json.dump(doc, f)
    # the banded scan still get()s each candidate, but a bad neighbor is
    # quarantined and skipped rather than aborting the whole lookup
    with pytest.warns(RuntimeWarning, match="quarantined"):
        got = repo.resolve_explain(_decode_wl(batch=6), "tpu-v5e", band=0.5)
    assert got == (None, "miss")
    assert not os.path.exists(path)
    assert os.path.exists(path + ".corrupt")
    assert len(repo) == 0  # .corrupt files drop out of entries()
    # a healthy sibling put after the quarantine resolves normally
    good = tune(_decode_wl(batch=8), "tpu-v5e", method="nccl", repo=repo)
    with pytest.warns(RuntimeWarning, match="quarantined"):
        # re-corrupt an entry to prove the scan skips it *and* still
        # returns the surviving banded hit
        bad2 = tune(_decode_wl(batch=4), "tpu-v5e", method="nccl", repo=repo)
        p2 = repo.path_for(bad2.fingerprint, "tpu-v5e")
        with open(p2, "w") as f:
            f.write("{not json")
        got, how = repo.resolve_explain(_decode_wl(batch=6), "tpu-v5e",
                                        band=0.5)
    assert how == "banded" and got == good
    # direct get() of a corrupt entry you explicitly ask for stays strict
    p3 = repo.path_for(good.fingerprint, "tpu-v5e")
    with open(p3, "w") as f:
        f.write("{not json")
    with pytest.raises(PlanRepoError, match="truncated or corrupt"):
        repo.get(good.fingerprint, "tpu-v5e")


def test_parse_parallel_specs():
    from repro.launch.plan import parse_parallel

    assert parse_parallel("fsdp:8").dp == 8
    assert parse_parallel("tp:4").tp == 4
    assert parse_parallel("ep:16").ep == 16
    pp = parse_parallel("pp:4:8")
    assert pp.pp == 4 and pp.microbatches == 8
    with pytest.raises(ValueError, match="unknown parallel kind"):
        parse_parallel("zz:2")


# ---------------------------------------------------------------------------
# retune lineage: round-trips, chain walks, malformed-lineage quarantine
# ---------------------------------------------------------------------------


def test_lineage_survives_json_round_trip(tmp_path):
    from repro.core import TunedPlan, retune

    wl = _decode_wl(batch=4)
    parent = tune(wl, "tpu-v5e", method="lagom")
    assert parent.lineage == {}  # a cold tune carries no lineage
    child = retune(parent, wl, sites=None, telemetry=None)
    path = str(tmp_path / "child.json")
    child.save(path)
    back = TunedPlan.load(path)
    assert back == child
    assert back.lineage["retuned_from"] == parent.artifact_digest()
    assert back.lineage["chain"] == [parent.artifact_digest()]
    assert back.artifact_digest() == child.artifact_digest()
    # pre-lineage artifacts (the previous plan format) still load
    doc = json.loads(child.to_json())
    del doc["lineage"]
    old = TunedPlan.from_json(json.dumps(doc))
    assert old.lineage == {}


def test_retune_chain_reconstruction(tmp_path):
    from repro.core import retune

    repo = PlanRepository(tmp_path)
    wl = _decode_wl(batch=4)
    parent = tune(wl, "tpu-v5e", method="lagom", repo=repo)
    # a cold entry chains to itself; a missing key to nothing
    assert repo.retune_chain(parent.fingerprint, "tpu-v5e") == [
        parent.artifact_digest()
    ]
    assert repo.retune_chain("0" * 64, "tpu-v5e") == []
    child = retune(parent, wl, repo=repo)
    grand = retune(child, wl, repo=repo)
    # put() advanced the same key in place; ancestors live only as the
    # embedded chain digests, and the walk recovers all three generations
    assert len(repo) == 1
    assert repo.retune_chain(parent.fingerprint, "tpu-v5e") == [
        grand.artifact_digest(),
        child.artifact_digest(),
        parent.artifact_digest(),
    ]


def test_retune_chain_quarantines_malformed_lineage(tmp_path):
    import os

    from repro.core import retune

    repo = PlanRepository(tmp_path)
    wl = _decode_wl(batch=4)
    parent = tune(wl, "tpu-v5e", method="lagom", repo=repo)
    child = retune(parent, wl, repo=repo)
    path = repo.path_for(parent.fingerprint, "tpu-v5e")
    # tamper: a chain whose head disagrees with retuned_from is exactly
    # the inconsistency a hand-edited entry would introduce
    with open(path) as f:
        doc = json.load(f)
    doc["lineage"]["chain"] = ["beef" * 16]
    with open(path, "w") as f:
        json.dump(doc, f)
    with pytest.warns(RuntimeWarning, match="malformed"):
        assert repo.retune_chain(parent.fingerprint, "tpu-v5e") == []
    assert not os.path.exists(path)  # quarantined, same path as banded scans
    assert os.path.exists(path + ".corrupt")
    assert len(repo) == 0
    # an unreadable entry quarantines through the walk too (PR 7's path)
    repo.put(child)
    with open(path, "w") as f:
        f.write("{not json")
    with pytest.warns(RuntimeWarning, match="quarantined"):
        assert repo.retune_chain(parent.fingerprint, "tpu-v5e") == []
    assert os.path.exists(path + ".corrupt")
