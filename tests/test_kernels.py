"""Per-kernel correctness: Pallas (interpret mode) and chunked-matmul forms
vs the naive per-step jnp oracle, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssd import ssd_pallas
from repro.kernels.wkv6 import wkv6_pallas


def _wkv_inputs(B, S, H, K, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, K), dtype) for i in range(3))
    w_log = -jnp.exp(jax.random.normal(ks[3], (B, S, H, K)) * 0.5).astype(jnp.float32)
    u = (jax.random.normal(ks[4], (H, K)) * 0.1).astype(dtype)
    return r, k, v, w_log, u


@pytest.mark.parametrize("B,S,H,K", [(1, 32, 1, 8), (2, 64, 3, 16), (2, 96, 2, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_pallas_matches_ref(B, S, H, K, dtype):
    r, k, v, w_log, u = _wkv_inputs(B, S, H, K, dtype)
    y_ref, s_ref = ref.wkv6_ref(r, k, v, w_log, u)
    y, s = wkv6_pallas(r, k, v, w_log, u, chunk=32)
    scale_y = float(jnp.abs(y_ref.astype(jnp.float32)).max()) or 1.0
    rtol = 3e-2 if dtype == jnp.bfloat16 else 1e-3
    assert jnp.abs(y.astype(jnp.float32) - y_ref.astype(jnp.float32)).max() < rtol * scale_y
    assert jnp.abs(s - s_ref).max() < rtol * max(1.0, float(jnp.abs(s_ref).max()))


def test_wkv6_chunked_matches_ref_with_state():
    r, k, v, w_log, u = _wkv_inputs(2, 64, 2, 16, jnp.float32)
    y1, s1 = ref.wkv6_ref(r, k, v, w_log, u)
    # split into two halves with state carry
    ya, sa = ref.wkv6_chunked_ref(r[:, :32], k[:, :32], v[:, :32], w_log[:, :32], u, chunk=16)
    yb, sb = ref.wkv6_chunked_ref(r[:, 32:], k[:, 32:], v[:, 32:], w_log[:, 32:], u,
                                  state=sa, chunk=16)
    assert jnp.abs(jnp.concatenate([ya, yb], 1) - y1).max() < 1e-3
    assert jnp.abs(sb - s1).max() < 1e-3


def _ssd_inputs(B, S, H, P, N, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, H, N), dtype)
    Cm = jax.random.normal(ks[4], (B, S, H, N), dtype)
    D = jnp.ones((H,))
    return x, dt, A, Bm, Cm, D


@pytest.mark.parametrize("B,S,H,P,N", [(1, 32, 1, 4, 8), (2, 64, 3, 8, 16), (1, 128, 2, 16, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_pallas_matches_ref(B, S, H, P, N, dtype):
    x, dt, A, Bm, Cm, D = _ssd_inputs(B, S, H, P, N, dtype)
    y_ref, s_ref = ref.ssd_ref(x, dt, A, Bm, Cm, D)
    y, s = ssd_pallas(x, dt, A, Bm, Cm, D, chunk=32)
    scale_y = float(jnp.abs(y_ref.astype(jnp.float32)).max()) or 1.0
    rtol = 3e-2 if dtype == jnp.bfloat16 else 1e-3
    assert jnp.abs(y.astype(jnp.float32) - y_ref.astype(jnp.float32)).max() < rtol * scale_y
    assert jnp.abs(s - s_ref).max() < rtol * max(1.0, float(jnp.abs(s_ref).max()))


def test_ssd_state_continuation():
    x, dt, A, Bm, Cm, D = _ssd_inputs(2, 64, 2, 8, 16, jnp.float32)
    y1, s1 = ref.ssd_ref(x, dt, A, Bm, Cm, D)
    ya, sa = ref.ssd_chunked_ref(x[:, :32], dt[:, :32], A, Bm[:, :32], Cm[:, :32], D, chunk=16)
    yb, sb = ref.ssd_chunked_ref(x[:, 32:], dt[:, 32:], A, Bm[:, 32:], Cm[:, 32:], D,
                                 state=sa, chunk=16)
    assert jnp.abs(jnp.concatenate([ya, yb], 1) - y1).max() < 1e-3
    assert jnp.abs(sb - s1).max() < 1e-3


@pytest.mark.parametrize("shape", [(4, 64), (2, 7, 128), (3, 5, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_pallas(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), shape, dtype)
    scale = jnp.linspace(0.5, 1.5, shape[-1])
    y = rmsnorm_pallas(x, scale)
    y_ref = ref.rmsnorm_ref(x, scale)
    assert jnp.abs(y.astype(jnp.float32) - y_ref.astype(jnp.float32)).max() < 2e-2


def test_ops_dispatch_backends():
    r, k, v, w_log, u = _wkv_inputs(1, 64, 2, 16, jnp.float32)
    outs = [ops.wkv6(r, k, v, w_log, u, backend=b)[0]
            for b in ("ref", "chunked", "pallas")]
    for o in outs[1:]:
        assert jnp.abs(o - outs[0]).max() < 1e-3
    x, dt, A, Bm, Cm, D = _ssd_inputs(1, 64, 2, 8, 16, jnp.float32)
    outs = [ops.ssd(x, dt, A, Bm, Cm, D, backend=b)[0]
            for b in ("ref", "chunked", "pallas")]
    for o in outs[1:]:
        assert jnp.abs(o - outs[0]).max() < 1e-3


def test_ops_pad_non_multiple_seq():
    r, k, v, w_log, u = _wkv_inputs(1, 50, 2, 16, jnp.float32)   # 50 % 32 != 0
    y_ref, s_ref = ref.wkv6_ref(r, k, v, w_log, u)
    y, s = ops.wkv6(r, k, v, w_log, u, backend="chunked", chunk=32)
    assert y.shape == y_ref.shape
    assert jnp.abs(y - y_ref).max() < 1e-3
    assert jnp.abs(s - s_ref).max() < 1e-3


@pytest.mark.parametrize("B,S,Hq,Hkv,h", [(1, 64, 2, 2, 16), (2, 128, 4, 2, 32),
                                           (1, 96, 6, 3, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_pallas(B, S, Hq, Hkv, h, causal):
    import math
    from repro.kernels.flash import flash_attention
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, h))
    k = jax.random.normal(ks[1], (B, S, Hkv, h))
    v = jax.random.normal(ks[2], (B, S, Hkv, h))
    o = flash_attention(q, k, v, causal=causal, q_block=32, kv_block=32)
    G = Hq // Hkv
    kk, vv = jnp.repeat(k, G, axis=2), jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / math.sqrt(h)
    if causal:
        s = jnp.where(jnp.tril(jnp.ones((S, S), bool))[None, None], s, -1e30)
    ref_o = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vv)
    assert jnp.abs(o - ref_o).max() < 1e-4
