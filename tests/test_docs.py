"""The docs lane: every relative link and anchor in README.md + docs/
must resolve to a real file/heading, and the public-surface doctests
(session, plan repository, retune loop, serving health/plans/telemetry)
must pass — the examples in the docstrings are executable contracts, not
decoration."""

import doctest
import os
import re
import warnings

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DOC_FILES = [
    "README.md",
    "docs/architecture.md",
    "docs/plan-lifecycle.md",
    "docs/operations.md",
    "docs/analysis.md",
]


def _strip_code(text: str) -> str:
    """Drop fenced code blocks and inline code spans — bash snippets and
    mermaid diagrams are not hyperlinks."""
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    return re.sub(r"`[^`]*`", "", text)


def _github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, strip punctuation, spaces to
    dashes (the scheme README anchors are written against)."""
    heading = re.sub(r"[*_`]", "", heading.strip())
    heading = re.sub(r"[^\w\s-]", "", heading.lower())
    return re.sub(r"\s+", "-", heading).strip("-")


def _anchors(path: str) -> set:
    slugs = set()
    with open(path) as f:
        text = f.read()
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for m in re.finditer(r"^#{1,6}\s+(.*)$", text, flags=re.M):
        slugs.add(_github_slug(m.group(1)))
    return slugs


def _links(path: str):
    with open(path) as f:
        text = _strip_code(f.read())
    for m in re.finditer(r"\[[^\]]*\]\(([^)\s]+)\)", text):
        yield m.group(1)


@pytest.mark.parametrize("doc", DOC_FILES)
def test_docs_exist_and_are_nonempty(doc):
    path = os.path.join(ROOT, doc)
    assert os.path.exists(path), f"{doc} is missing"
    with open(path) as f:
        assert len(f.read()) > 500, f"{doc} is a stub"


@pytest.mark.parametrize("doc", DOC_FILES)
def test_relative_links_resolve(doc):
    src = os.path.join(ROOT, doc)
    broken = []
    for link in _links(src):
        if link.startswith(("http://", "https://", "mailto:")):
            continue
        target, _, frag = link.partition("#")
        if target:
            dest = os.path.normpath(os.path.join(os.path.dirname(src), target))
            if not dest.startswith(ROOT + os.sep):
                continue  # GitHub-web-relative (badges etc.), not a file
            if not os.path.exists(dest):
                broken.append(f"{doc}: {link} -> missing file {target}")
                continue
        else:
            dest = src  # same-page anchor
        if frag and dest.endswith(".md") and frag not in _anchors(dest):
            broken.append(f"{doc}: {link} -> missing anchor #{frag}")
    assert not broken, "\n".join(broken)


def test_docs_name_real_tests_and_modules():
    """Every `tests/test_*.py` and `src/...` path the docs cite must
    exist — stale references rot faster than prose."""
    missing = []
    for doc in DOC_FILES:
        with open(os.path.join(ROOT, doc)) as f:
            text = f.read()
        for m in re.finditer(r"\btests/test_\w+\.py\b", text):
            if not os.path.exists(os.path.join(ROOT, m.group(0))):
                missing.append(f"{doc}: {m.group(0)}")
        for m in re.finditer(
            r"\b(?:src/repro|core|serving|launch|train)/\w+\.py\b", text
        ):
            rel = m.group(0)
            if not rel.startswith("src/"):
                rel = f"src/repro/{rel}"
            if not os.path.exists(os.path.join(ROOT, rel)):
                missing.append(f"{doc}: {m.group(0)}")
    assert not missing, "\n".join(missing)


# ---------------------------------------------------------------------------
# doctests: the public surface's examples run for real
# ---------------------------------------------------------------------------

DOCTEST_MODULES = [
    "repro.core.session",
    "repro.core.plan_repo",
    "repro.core.retune",
    "repro.serving.health",
    "repro.serving.plans",
    "repro.serving.telemetry",
]


@pytest.mark.parametrize("modname", DOCTEST_MODULES)
def test_module_doctests(modname):
    import importlib

    from repro.parallel import collectives as C

    mod = importlib.import_module(modname)
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")  # tune() may warn benignly
            result = doctest.testmod(mod, verbose=False, optionflags=doctest.ELLIPSIS)
    finally:
        C.install_runtime_plan({})  # doctests must not leak installs
    assert result.failed == 0, f"{modname}: {result.failed} doctest failures"


def test_doctest_coverage_is_nonzero():
    """The docstring-example pass stays real: the six public modules
    carry a meaningful number of executable examples between them."""
    import importlib

    total = 0
    finder = doctest.DocTestFinder()
    for modname in DOCTEST_MODULES:
        mod = importlib.import_module(modname)
        total += sum(len(t.examples) for t in finder.find(mod))
    assert total >= 20, f"only {total} doctest examples across the surface"
