"""Plan static analysis (``repro.analysis``): the op-graph walkers, the
overlap-materialization verdicts, the LAG0xx deployment linter, and the
refusal gates wired into ``tune()``, ``PlanRepository.put``,
``PlanBinding`` and the CLIs."""
import copy
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis import (ChunkLoop, CollectiveOp, Finding, OpGraph,
                            PlanLintError, check_plan, collective_bytes,
                            errors, format_findings, graph_from_hlo,
                            graph_from_jaxpr, lint_plan, rules)
from repro.analysis.__main__ import main as analysis_main
from repro.configs import get_config, get_smoke_config
from repro.core import (ParallelPlan, TunedPlan, extract_decode_workload,
                        extract_workload, session, tune)
from repro.core.comm_params import CommConfig
from repro.core.plan_repo import PlanRepository
from repro.launch.mesh import make_mesh
from repro.parallel import collectives as C

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(autouse=True)
def _clean_plan_state():
    yield
    C.install_runtime_plan({})


def _fsdp_wl(layers=2):
    return extract_workload(get_config("llama3-8b"),
                            ParallelPlan(kind="fsdp", dp=8),
                            seq=2048, global_batch=16, layers=layers)


@pytest.fixture(scope="module")
def wl():
    return _fsdp_wl()


@pytest.fixture(scope="module")
def plan(wl):
    return tune(wl, "tpu-v5e", method="nccl")


def _mutant(plan):
    """A deep, independently mutable copy of a tuned plan."""
    return copy.deepcopy(plan)


# ---------------------------------------------------------------------------
# ir: jaxpr walker
# ---------------------------------------------------------------------------

def test_jaxpr_walker_finds_collective_chunk_loop():
    mesh = make_mesh((jax.device_count(),), ("dp",))
    grads = {"w": jnp.ones((8, 4))}
    fn = C.shard_map(
        lambda t: C.psum_tree_chunked(t, "dp", num_chunks=4),
        mesh=mesh, in_specs=({"w": P("dp")},), out_specs={"w": P("dp")})
    g = graph_from_jaxpr(jax.make_jaxpr(fn)(grads))
    loops = g.chunk_loops("allreduce", trip=4)
    assert loops and loops[0].n_collectives == 1
    assert g.count("allreduce") >= 1
    # the in-loop collective carries the loop's trip count
    assert any(c.kind == "allreduce" and c.trip == 4 for c in g.collectives)


def test_jaxpr_walker_compute_only_loop():
    def f(x):
        def body(c, _):
            return c @ x, None
        out, _ = jax.lax.scan(body, x, None, length=3)
        return out

    g = graph_from_jaxpr(jax.make_jaxpr(f)(jnp.ones((4, 4))))
    loops = g.chunk_loops(None, trip=3)
    assert loops and loops[0].has_compute and not loops[0].kinds
    assert not g.collectives


# ---------------------------------------------------------------------------
# ir: HLO text walker (format-stable fixture)
# ---------------------------------------------------------------------------

# trimmed but syntactically faithful post-SPMD dump: a counted while whose
# body holds a reduce-scatter + dot (tuple-typed params — the regression
# that hid loop bodies from the block parser), plus an async all-gather
# pair and a collective-permute at top level
_HLO_FIXTURE = """\
HloModule toy, entry_computation_layout={(f32[8,16])->f32[8,16]}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(f32[] %a, f32[] %b)
}

%wide.body (param.1: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[8,16]) %p), index=0
  %x = f32[8,16]{1,0} get-tuple-element((s32[], f32[8,16]) %p), index=1
  %rs = f32[2,16]{1,0} reduce-scatter(f32[8,16]{1,0} %x), channel_id=1, replica_groups={{0,1,2,3}}, dimensions={0}, to_apply=%add
  %d = f32[2,16]{1,0} dot(f32[2,16]{1,0} %rs, f32[16,16]{1,0} %rs), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,16]) tuple(s32[] %i, f32[8,16] %x)
}

%wide.cond (param.2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]) parameter(0)
  %i2 = s32[] get-tuple-element((s32[], f32[8,16]) %p2), index=0
  %n = s32[] constant(4)
  ROOT %lt = pred[] compare(s32[] %i2, s32[] %n), direction=LT
}

ENTRY %main (param.0: f32[8,16]) -> f32[8,16] {
  %x0 = f32[8,16]{1,0} parameter(0)
  %ags = (f32[4,16], f32[8,16]) all-gather-start(f32[4,16]{1,0} %x0), channel_id=2, replica_groups={{0,1}}, dimensions={0}
  %agd = f32[8,16]{1,0} all-gather-done((f32[4,16], f32[8,16]) %ags)
  %cp = f32[8,16]{1,0} collective-permute(f32[8,16]{1,0} %agd), channel_id=3, source_target_pairs={{0,1},{1,0}}
  %w = (s32[], f32[8,16]) while((s32[], f32[8,16]) %cp), condition=%wide.cond, body=%wide.body
  ROOT %out = f32[8,16]{1,0} get-tuple-element((s32[], f32[8,16]) %w), index=1
}
"""


def test_hlo_walker_counted_while_with_tuple_params():
    g = graph_from_hlo(_HLO_FIXTURE)
    loops = g.chunk_loops("reducescatter", trip=4)
    assert loops and loops[0].has_compute and loops[0].source == "while"
    # async pair counted once; -done skipped
    assert g.count("allgather") == 1
    assert g.count("permute") == 1
    assert g.count("reducescatter") == 1
    rs = next(c for c in g.collectives if c.kind == "reducescatter")
    assert rs.trip == 4   # loop-body collective inherits the while's trip


def test_collective_bytes_counts_async_pairs_once():
    out = collective_bytes(_HLO_FIXTURE)
    assert out["count"] == 3
    assert out["all-gather"] == 4 * 16 * 4.0     # -start result, once
    assert out["reduce-scatter"] == 2 * 16 * 4.0
    assert out["collective-permute"] == 8 * 16 * 4.0
    assert out["all-reduce"] == 0.0 and out["all-to-all"] == 0.0


def test_dryrun_parser_delegates_to_shared_op_table():
    from repro.launch.dryrun import parse_collective_bytes

    assert parse_collective_bytes(_HLO_FIXTURE) == collective_bytes(
        _HLO_FIXTURE)


# ---------------------------------------------------------------------------
# overlap: verdict semantics on synthetic graphs
# ---------------------------------------------------------------------------

def _row(site, cls, strategy, nc, tier="exact"):
    return C.SiteResolution(site=site, cls=cls, strategy=strategy,
                            num_chunks=nc, matched_key=site, tier=tier)


def _verify(plan, graph, rows):
    from repro.analysis.overlap import verify

    return verify(plan, graph, rows)


def test_verdict_materialized_degraded_absent():
    plan = {"tp.l0.rs": C.CollectiveRuntime("chunked", 4)}
    rows = [_row("tp.l0.rs", "rs", "chunked", 4)]
    loop = ChunkLoop(trip=4, kinds=("reducescatter",), n_collectives=1,
                     has_compute=True, depth=0)
    coll = CollectiveOp(kind="reducescatter", raw="reduce-scatter")

    good = OpGraph(source="hlo", collectives=[coll], loops=[loop])
    assert _verify(plan, good, rows).verdict_for("tp.l0.rs") == "MATERIALIZED"

    # collective present but monolithic (no trip-4 loop) -> DEGRADED
    flat = OpGraph(source="hlo", collectives=[coll])
    r = _verify(plan, flat, rows)
    assert r.verdict_for("tp.l0.rs") == "DEGRADED" and not r.ok()
    assert r.ok(allow_degraded=True)

    # class collective missing entirely -> ABSENT
    empty = OpGraph(source="hlo")
    r = _verify(plan, empty, rows)
    assert r.verdict_for("tp.l0.rs") == "ABSENT"
    assert not r.ok(allow_degraded=True)


def test_verdict_absent_when_trace_missed_the_plan():
    plan = {"tp.l0.rs": C.CollectiveRuntime("chunked", 4)}
    # trace recorded XLA defaults: the plan was not installed
    rows = [_row("tp.l0.rs", "rs", "xla", 1, tier="default")]
    loop = ChunkLoop(trip=4, kinds=("reducescatter",), n_collectives=1,
                     has_compute=True, depth=0)
    g = OpGraph(source="jaxpr", loops=[loop],
                collectives=[CollectiveOp(kind="reducescatter", raw="rs")])
    v = _verify(plan, g, rows).verdicts[0]
    assert v.verdict == "ABSENT" and "not installed" in v.detail


def test_verdict_nc1_trivially_materialized_and_untuned_excluded():
    plan = {"tp.l0.rs": C.CollectiveRuntime("chunked", 1)}
    rows = [_row("tp.l0.rs", "rs", "chunked", 1),
            _row("other.ar", "ar", "xla", 1, tier="default")]
    r = _verify(plan, OpGraph(source="jaxpr"), rows)
    assert r.verdict_for("tp.l0.rs") == "MATERIALIZED"
    assert r.untuned == ["other.ar"] and r.ok()


def test_two_sites_same_signature_need_two_loops():
    plan = {"a.rs": C.CollectiveRuntime("chunked", 2),
            "b.rs": C.CollectiveRuntime("chunked", 2)}
    rows = [_row("a.rs", "rs", "chunked", 2), _row("b.rs", "rs", "chunked", 2)]
    loop = ChunkLoop(trip=2, kinds=("reducescatter",), n_collectives=1,
                     has_compute=True, depth=0)
    coll = CollectiveOp(kind="reducescatter", raw="rs")
    one = OpGraph(source="hlo", collectives=[coll], loops=[loop])
    r = _verify(plan, one, rows)
    # multiset supply: a single loop cannot vouch for both tuned sites
    assert sorted(v.verdict for v in r.verdicts) == ["DEGRADED",
                                                     "MATERIALIZED"]
    two = OpGraph(source="hlo", collectives=[coll, coll], loops=[loop, loop])
    assert all(v.verdict == "MATERIALIZED"
               for v in _verify(plan, two, rows).verdicts)


def test_unobserved_plan_sites_are_not_false_positives(plan):
    r = _verify(plan, OpGraph(source="jaxpr"), [])
    assert not r.verdicts and r.ok()
    assert set(r.unobserved) == {s.get("site") or s["name"]
                                 for s in plan.sites}


# ---------------------------------------------------------------------------
# overlap: trace_and_verify on a real traced program
# ---------------------------------------------------------------------------

def test_trace_and_verify_roundtrip_and_no_install_control():
    from repro.analysis.overlap import trace_and_verify

    mesh = make_mesh((jax.device_count(),), ("dp",))
    plan = {"acc.step0.rs_grads": C.CollectiveRuntime("chunked", 4)}
    grads = {"w": jnp.ones((8, 4))}

    def fn(t):
        return C.shard_map(
            lambda g: C.psum_tree_chunked(g, "dp", site="acc.step0.rs_grads"),
            mesh=mesh, in_specs=({"w": P("dp")},),
            out_specs={"w": P("dp")})(t)

    rep = trace_and_verify(plan, fn, grads)
    assert rep.verdict_for("acc.step0.rs_grads") == "MATERIALIZED"
    # deliberately-uninstalled control: the same trace flips to ABSENT
    off = trace_and_verify(plan, fn, grads, install=False)
    assert off.verdict_for("acc.step0.rs_grads") == "ABSENT"


def test_record_site_resolutions_tiers_and_nesting():
    plan = {"a.b": C.CollectiveRuntime("chunked", 2)}
    with C.use_runtime_plan(plan):
        with C.record_site_resolutions() as outer:
            C.runtime_for("a.b.c", "rs")
            with C.record_site_resolutions() as inner:
                C.runtime_for("zz", "rs")
            C.runtime_for("a.b", None)
    assert [(r.site, r.tier) for r in outer] == [("a.b.c", "prefix"),
                                                 ("a.b", "exact")]
    assert [(r.site, r.tier, r.matched_key) for r in inner] == [
        ("zz", "default", "")]


# ---------------------------------------------------------------------------
# lint: healthy plans are quiet; each rule catches its seeded defect
# ---------------------------------------------------------------------------

def test_rule_catalog_is_stable():
    cat = rules()
    assert set(cat) == {"LAG001", "LAG002", "LAG003", "LAG004", "LAG010",
                        "LAG020", "LAG021", "LAG030", "LAG031", "LAG040"}
    assert {c for c, r in cat.items() if r.severity == "error"} == {
        "LAG001", "LAG003", "LAG004", "LAG020", "LAG030", "LAG040"}
    assert all(r.doc for r in cat.values())


def test_healthy_plan_lints_clean(plan, wl):
    assert lint_plan(plan) == []
    assert lint_plan(plan, workload=wl) == []
    assert check_plan(plan, workload=wl) == []


def _codes(findings):
    return {f.code for f in findings}


def test_lag001_dead_entry(plan):
    m = _mutant(plan)
    m.configs[(999, 0)] = CommConfig()
    f = lint_plan(m)
    assert _codes(f) == {"LAG001"} and errors(f)
    assert "(group=999, comm=0)" in f[0].message


def test_lag002_untuned_site(plan):
    m = _mutant(plan)
    key = next(iter(m.configs))
    del m.configs[key]
    f = lint_plan(m)
    assert "LAG002" in _codes(f) and not errors(f)
    assert all(x.severity == "warning" for x in f)


def test_lag003_lag004_duplicate_shadowed_site(plan):
    m = _mutant(plan)
    first = m.sites[0]
    dup = dict(first, group="dup-group")
    # conflicting knobs for the same SiteId: huge chunk_kb lowers to nc=1
    m.configs[("dup-group", dup["comm"])] = CommConfig(
        algorithm="ring", chunk_kb=1 << 20)
    m.sites.append(dup)
    f = lint_plan(m)
    assert {"LAG003", "LAG004"} <= _codes(f)
    sid = first.get("site") or first["name"]
    assert any(x.code == "LAG004" and x.site == sid for x in f)


def test_lag010_indivisible_chunk(plan):
    m = _mutant(plan)
    row = next(s for s in m.sites if s["kind"] != "reducescatter")
    row["bytes"] = 1000003.0   # prime-ish payload: no nc>1 divides it
    m.configs[(row["group"], row["comm"])] = CommConfig(
        algorithm="ring", chunk_kb=256)   # lowers to nc=4
    f = lint_plan(m, select=["LAG010"])
    assert f and f[0].site == (row.get("site") or row["name"])
    assert "cannot evenly divide" in f[0].message


def test_lag020_inter_site_in_flat_plan(plan):
    m = _mutant(plan)
    m.sites[0]["tier"] = "inter"
    f = lint_plan(m, select=["LAG020"])
    assert f and f[0].severity == "error"
    assert "topology" in f[0].message


def test_lag021_hierarchical_plan_with_no_inter_site(plan):
    m = _mutant(plan)
    m.topology = {"fingerprint": "f" * 12, "name": "two_pod",
                  "spec": {"pods": 2}}
    f = lint_plan(m, select=["LAG021"])
    assert f and f[0].severity == "warning" and "2 pods" in f[0].message


def test_lag030_provenance_drift(plan, wl):
    from repro.core import two_pod

    # (a) hand-edited topology fingerprint
    topo = two_pod("tpu-v5e", "dcn")
    hwl = extract_workload(get_config("llama3-8b"),
                           ParallelPlan(kind="fsdp", dp=8, pods=2,
                                        accum_steps=2),
                           seq=2048, global_batch=16, layers=2)
    hplan = tune(hwl, topology=topo, method="nccl")
    assert lint_plan(hplan, select=["LAG030"]) == []
    hm = _mutant(hplan)
    hm.topology["fingerprint"] = "deadbeef"
    f = lint_plan(hm, select=["LAG030"])
    assert f and "hand-edited" in f[0].message

    # (b) plan applied against a structurally different workload
    other = _fsdp_wl(layers=4)
    f = lint_plan(plan, workload=other, select=["LAG030"])
    assert f and "fingerprint" in f[0].message


def test_lag031_band_unservable(plan):
    m = _mutant(plan)
    m.structure = ""
    f = lint_plan(m, select=["LAG031"])
    assert f and "tolerance-band" in f[0].message
    m2 = _mutant(plan)
    m2.shape = {"seq": 0, "global_batch": 16}
    f2 = lint_plan(m2, select=["LAG031"])
    assert f2 and "seq" in f2[0].message


def test_lag040_malformed_lineage(plan):
    good = _mutant(plan)
    good.lineage = {"retuned_from": "abc", "chain": ["abc"], "generation": 1}
    assert lint_plan(good, select=["LAG040"]) == []
    for lineage in ({"retuned_from": "b", "chain": ["a"]},
                    {"retuned_from": "b", "chain": []},
                    {"retuned_from": None, "chain": ["a"]},
                    {"chain": "not-a-list"}):
        m = _mutant(plan)
        m.lineage = lineage
        assert _codes(lint_plan(m, select=["LAG040"])) == {"LAG040"}, lineage


def test_findings_sorted_and_formatted(plan):
    m = _mutant(plan)
    m.configs[(999, 0)] = CommConfig()       # LAG001 error
    del m.configs[next(k for k in m.configs if k != (999, 0))]
    f = lint_plan(m)                                 # + LAG002 warnings
    assert f[0].severity == "error"                  # most severe first
    text = format_findings(f, label="demo.json")
    assert text.startswith(f"analysis: {len(f)} finding(s) (1 error(s), ")
    assert "in demo.json" in text and "LAG001 error:" in text


# ---------------------------------------------------------------------------
# refusal gates: check_plan, tune(lint=), put(lint=), PlanBinding
# ---------------------------------------------------------------------------

def _broken(plan):
    m = _mutant(plan)
    m.configs[(999, 0)] = CommConfig()   # one LAG001 ERROR
    return m


def test_check_plan_raises_with_findings_attached(plan):
    b = _broken(plan)
    with pytest.raises(PlanLintError, match="LAG001.*lint='off'") as ei:
        check_plan(b, label="unit plan")
    assert ei.value.findings and "unit plan" in str(ei.value)


def test_tune_lint_gate(wl):
    p = tune(wl, "tpu-v5e", method="nccl", lint="error")
    assert isinstance(p, TunedPlan)
    with pytest.raises(ValueError, match="lint="):
        tune(wl, "tpu-v5e", method="nccl", lint="bogus")


def test_repo_put_lint_gate(tmp_path, plan):
    repo = PlanRepository(tmp_path)
    b = _broken(plan)
    with pytest.raises(PlanLintError, match="LAG001"):
        repo.put(b, lint="error")
    repo.put(plan, lint="error")    # healthy plan passes the gate
    with pytest.raises(ValueError, match="lint="):
        repo.put(plan, lint="bogus")


def _decode_plan():
    cfg = get_smoke_config("llama3-8b")
    wl = extract_decode_workload(cfg, ParallelPlan(kind="tp", tp=2),
                                 global_batch=4, seq=64)
    return cfg, tune(wl, "tpu-v5e", method="nccl")


def test_plan_binding_refuses_error_plans_with_override():
    from repro.serving.plans import PlanBinding

    cfg, dplan = _decode_plan()
    broken = _broken(dplan)
    with pytest.raises(PlanLintError, match="LAG001"):
        PlanBinding(cfg, plan=broken)
    # override flag: same plan binds, findings kept for inspection
    b = PlanBinding(cfg, plan=broken, lint="off")
    assert b.bound and b.lint_findings == []
    w = PlanBinding(cfg, plan=dplan, lint="warn")
    assert w.lint_findings == []
    with pytest.raises(ValueError, match="lint="):
        PlanBinding(cfg, plan=dplan, lint="loud")


def test_engines_plumb_plan_lint():
    from repro.models import model as M
    from repro.serving import make_engine

    cfg, dplan = _decode_plan()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    broken = _broken(dplan)
    for mode, kw in (("fixed", dict(batch_size=2)), ("continuous",
                                                     dict(slots=2))):
        with pytest.raises(PlanLintError, match="LAG001"):
            make_engine(cfg, params, mode=mode, max_seq=32, plan=broken, **kw)
        eng = make_engine(cfg, params, mode=mode, max_seq=32, plan=broken,
                          plan_lint="off", **kw)
        assert eng is not None


# ---------------------------------------------------------------------------
# runtime LAG010 warning (satellite: structured + deduped)
# ---------------------------------------------------------------------------

def test_degraded_warning_structured_and_deduped():
    mesh = make_mesh((jax.device_count(),), ("dp",))
    grads = {"w": jnp.ones((5, 2))}   # 5 % 2 != 0
    fn = C.shard_map(
        lambda t: C.psum_tree_chunked(t, "dp", num_chunks=2,
                                      site="acc.step0.rs_grads"),
        mesh=mesh, in_specs=({"w": P("dp")},), out_specs={"w": P("dp")})
    with pytest.warns(C.CollectiveDegradedWarning) as rec:
        jax.make_jaxpr(fn)(grads)
    ws = [w.message for w in rec
          if isinstance(w.message, C.CollectiveDegradedWarning)]
    assert len(ws) == 1
    assert ws[0].code == "LAG010" and ws[0].site == "acc.step0.rs_grads"
    assert "[LAG010]" in str(ws[0]) and "acc.step0.rs_grads" in str(ws[0])
    # deduped per site per process: a retrace stays silent...
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", C.CollectiveDegradedWarning)
        jax.make_jaxpr(lambda t: fn(t))(grads)
    # ...until the dedupe state is reset
    C.reset_degraded_warnings()
    with pytest.warns(C.CollectiveDegradedWarning):
        jax.make_jaxpr(lambda t: fn(t))(grads)


# ---------------------------------------------------------------------------
# CLIs: repro.analysis lint exit codes; session diff on malformed input
# ---------------------------------------------------------------------------

def test_cli_lint_exit_codes(tmp_path, plan, capsys):
    good = tmp_path / "good.json"
    plan.save(str(good))
    assert analysis_main(["lint", str(good)]) == 0
    out = capsys.readouterr().out
    assert "analysis: 0 finding(s)" in out and str(good) in out

    broken = tmp_path / "broken.json"
    _broken(plan).save(str(broken))
    assert analysis_main(["lint", str(broken)]) == 1
    # seeded-fixture contract: exact expected codes invert the exit
    assert analysis_main(["lint", str(broken), "--expect", "LAG001"]) == 0
    assert analysis_main(["lint", str(broken), "--expect",
                          "LAG001,LAG002"]) == 1
    capsys.readouterr()

    mangled = tmp_path / "mangled.json"
    mangled.write_text("{this is not a plan")
    assert analysis_main(["lint", str(mangled)]) == 2
    assert "not a readable TunedPlan artifact" in capsys.readouterr().err
    notaplan = tmp_path / "notaplan.json"
    notaplan.write_text(json.dumps({"version": 999}))
    assert analysis_main(["lint", str(notaplan)]) == 2


def test_session_diff_cli_malformed_input_exits_2(tmp_path, plan, capsys):
    good = tmp_path / "a.json"
    plan.save(str(good))
    assert session._main(["diff", str(good), str(good)]) == 0
    capsys.readouterr()
    for text in ("{oops", json.dumps([1, 2, 3]), json.dumps({"v": 1})):
        bad = tmp_path / "bad.json"
        bad.write_text(text)
        assert session._main(["diff", str(good), str(bad)]) == 2
        err = capsys.readouterr().err
        assert "not a readable TunedPlan artifact" in err
    assert session._main(["diff", str(good),
                          str(tmp_path / "missing.json")]) == 2


# ---------------------------------------------------------------------------
# verify-overlap end to end on an 8-device mesh (subprocess)
# ---------------------------------------------------------------------------

_VERIFY_SCRIPT = r"""
import sys
from repro.configs import get_config
from repro.core import ParallelPlan, extract_workload, tune
from repro.analysis.exercise import exercise_plan

wl = extract_workload(get_config("llama3-8b"),
                      ParallelPlan(kind="fsdp", dp=8, accum_steps=2),
                      seq=2048, global_batch=64, layers=2)
plan = tune(wl, "tpu-v5e")
plan.save(sys.argv[1])

report = exercise_plan(plan)
print(report.format())
assert report.verdicts and report.ok(), report.format()
chunked = [v for v in report.verdicts if v.num_chunks > 1]
assert chunked, "tuned plan must chunk at least one site"
off = exercise_plan(plan, install=False)
assert all(v.verdict == "ABSENT" for v in off.verdicts), off.format()
print("SUBPROCESS_OK")
"""


@pytest.mark.slow
def test_verify_overlap_exercises_tuned_plan(tmp_path):
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    saved = tmp_path / "plan.json"
    out = subprocess.run([sys.executable, "-c", _VERIFY_SCRIPT, str(saved)],
                         env=env, capture_output=True, text=True, timeout=560)
    assert "SUBPROCESS_OK" in out.stdout, out.stdout + out.stderr

    # the CLI front door agrees: lint clean + verify-overlap exit 0
    cli = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "lint", str(saved)],
        env=env, capture_output=True, text=True, timeout=560)
    assert cli.returncode == 0, cli.stdout + cli.stderr
    cli = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "verify-overlap", str(saved)],
        env=env, capture_output=True, text=True, timeout=560)
    assert cli.returncode == 0 and "MATERIALIZED" in cli.stdout, (
        cli.stdout + cli.stderr)
