"""Lagom core behaviour: simulator, tuners, baselines, cost model."""
import math

import pytest

from repro.configs import get_config
from repro.core import (A40_NVLINK, A40_PCIE, TPU_V5E, CommConfig, ParallelPlan,
                        Simulator, extract_workload, min_config, vendor_default)
from repro.core import autoccl, contention, cost_model, tuner
from repro.core.baselines import nccl_defaults
from repro.core.priority import metric_h
from repro.core.workload import CommOp, matmul_comp


def _fsdp_workload(model="phi2-2b", dp=8, layers=4):
    cfg = get_config(model)
    return extract_workload(cfg, ParallelPlan(kind="fsdp", dp=dp),
                            seq=2048, global_batch=16, layers=layers)


def test_simulator_z_at_least_busy_times():
    wl = _fsdp_workload()
    sim = Simulator(A40_NVLINK)
    m = sim.profile(wl, nccl_defaults(wl, A40_NVLINK))
    for g in m.groups:
        assert g.Z >= g.X - 1e-9
        assert g.Z >= g.Y - 1e-9
        assert g.Z <= g.X + g.Y + 1e-9


def test_lagom_beats_nccl_and_autoccl_fsdp():
    wl = _fsdp_workload(layers=6)
    for hw in (A40_NVLINK, A40_PCIE):
        sim = Simulator(hw, noise=0.01, seed=0)
        base = sim.profile(wl, nccl_defaults(wl, hw))
        cfgs, _, _ = tuner.search_workload(sim, wl)
        lag = sim.profile(wl, cfgs)
        ac_cfgs, _ = autoccl.search_workload(Simulator(hw, noise=0.01, seed=1), wl)
        ac = sim.profile(wl, ac_cfgs)
        assert base.Z / lag.Z > 1.01, hw.name            # beats NCCL
        assert ac.Z / lag.Z > 1.05, hw.name              # beats AutoCCL


def test_autoccl_overallocates_in_compute_bound():
    """The paper's Fig. 8 phenomenon: a comm-only tuner lands below NCCL."""
    wl = _fsdp_workload(layers=6)
    hw = A40_NVLINK
    sim = Simulator(hw, noise=0.01, seed=0)
    base = sim.profile(wl, nccl_defaults(wl, hw))
    ac_cfgs, _ = autoccl.search_workload(Simulator(hw, noise=0.01, seed=1), wl)
    ac = sim.profile(wl, ac_cfgs)
    assert ac.Z > base.Z                     # worse end-to-end
    assert ac_cfgs[(0, 0)].nc >= 32          # over-allocated channels


def test_lagom_config_shape_matches_paper():
    """Fig. 8: Lagom lands at low NC + sub-default chunk (NC=2..8, C<2MB)."""
    wl = _fsdp_workload(layers=6)
    sim = Simulator(A40_NVLINK, noise=0.01, seed=0)
    cfgs, _, _ = tuner.search_workload(sim, wl)
    s = cfgs[(0, 0)]
    assert s.nc <= A40_NVLINK.default_nc
    assert s.chunk_kb <= A40_NVLINK.default_chunk_kb


def test_tuner_linear_complexity():
    """Profile count grows ~linearly in the number of communications."""
    iters = {}
    for layers in (2, 4, 8):
        wl = _fsdp_workload(layers=layers)
        sim = Simulator(A40_NVLINK, noise=0.0, seed=0)
        _, n, _ = tuner.search_workload(sim, wl)
        iters[layers] = n
    r1 = iters[4] / iters[2]
    r2 = iters[8] / iters[4]
    assert 1.5 < r1 < 2.8 and 1.5 < r2 < 2.8     # ~2x per comm doubling


def test_nt_negligible():
    """Sec. 3.2: NT affects neither comm nor comp time appreciably."""
    op = CommOp("ar", "allreduce", 32e6, 8)
    comp = matmul_comp("ffn", 4096, 2560, 10240)
    for hw in (A40_NVLINK, TPU_V5E):
        lo = CommConfig(nc=8, nt=64, chunk_kb=1024)
        hi = CommConfig(nc=8, nt=640, chunk_kb=1024)
        x_lo = contention.comm_time(op, lo, hw)
        x_hi = contention.comm_time(op, hi, hw)
        assert abs(x_lo - x_hi) / x_lo < 0.01
        assert contention.comp_time(comp, lo, hw) == contention.comp_time(comp, hi, hw)


def test_wave_model_calibration_fig3():
    """NC 16->32 slows an FFN by ~30% ((84-16)/(84-32) = 1.308, paper: +30.2%)."""
    comp = matmul_comp("ffn", 4096, 2560, 10240)
    hw = A40_PCIE
    t16 = contention.comp_time(comp, CommConfig(nc=16, chunk_kb=16), hw)
    t32 = contention.comp_time(comp, CommConfig(nc=32, chunk_kb=16), hw)
    assert 1.25 < t32 / t16 < 1.40


def test_metric_h():
    assert metric_h(1.0, 1.1, 2.0, 1.5) == pytest.approx(0.2)
    assert metric_h(1.0, 1.1, 1.5, 2.0) == math.inf     # comm got slower


def test_cost_model_consistent_with_simulator():
    wl = _fsdp_workload(layers=3)
    hw = A40_NVLINK
    cfgs = nccl_defaults(wl, hw)
    z_cm = cost_model.workload_makespan(wl, cfgs, hw)
    z_sim = Simulator(hw).profile(wl, cfgs).Z
    assert abs(z_cm - z_sim) / z_sim < 0.35    # closed form ~= event-driven


@pytest.mark.parametrize("kind,model", [("tp", "llama3-8b"), ("ep", "olmoe-1b-7b")])
def test_tp_ep_workloads_tune(kind, model):
    cfg = get_config(model)
    plan = ParallelPlan(kind=kind, tp=8 if kind == "tp" else 1,
                        ep=8 if kind == "ep" else 1)
    wl = extract_workload(cfg, plan, seq=2048, global_batch=16, layers=4)
    sim = Simulator(A40_NVLINK, noise=0.01, seed=0)
    base = sim.profile(wl, nccl_defaults(wl, A40_NVLINK))
    cfgs, _, _ = tuner.search_workload(sim, wl)
    tuned = sim.profile(wl, cfgs)
    assert base.Z / tuned.Z > 1.0


def test_decode_workload_extracts():
    cfg = get_config("yi-34b")
    wl = extract_workload(cfg, ParallelPlan(kind="tp", tp=16), seq=32768,
                          global_batch=128, decode=True, layers=4)
    assert wl.num_comms > 0
    assert all(g.total_flops >= 0 for g in wl.groups)


def test_warm_start_fewer_profiles_same_quality():
    """Beyond-paper: cost-model warm-start matches cold-start quality with
    meaningfully fewer ProfileTime invocations."""
    wl = _fsdp_workload(layers=6)
    hw = A40_NVLINK
    res = {}
    for warm in (False, True):
        sim = Simulator(hw, noise=0.01, seed=0)
        base = sim.profile(wl, nccl_defaults(wl, hw))
        cfgs, iters, _ = tuner.search_workload(sim, wl, warm_start=warm)
        tuned = sim.profile(wl, cfgs)
        res[warm] = (base.Z / tuned.Z, iters)
    assert res[True][0] > res[False][0] - 0.02       # quality parity
    assert res[True][1] < res[False][1] * 0.85       # >=15% fewer profiles
