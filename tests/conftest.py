import os
import sys

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# must see exactly 1 device (multi-device tests spawn subprocesses).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


import pytest


@pytest.fixture(autouse=True)
def _reset_degraded_warning_dedupe():
    # CollectiveDegradedWarning (LAG010) dedupes per site per process so
    # production retraces warn once; tests that expect the warning must
    # each see a fresh dedupe set.
    from repro.parallel import collectives as C

    C.reset_degraded_warnings()
    yield
