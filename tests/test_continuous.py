"""Continuous-batching engine: per-slot positions, ragged prompts, refill."""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving.continuous import ContinuousEngine, Request
from repro.serving.engine import Engine

CFG = get_smoke_config("stablelm-3b")
PARAMS = M.init_params(CFG, jax.random.PRNGKey(0))


def test_matches_lockstep_engine():
    rs = np.random.default_rng(0)
    p = rs.integers(0, CFG.vocab_size, size=6).astype(np.int32)
    ref = Engine(CFG, PARAMS, batch_size=2, max_seq=48).generate([p, p], max_new=4)[0]
    eng = ContinuousEngine(CFG, PARAMS, slots=1, max_seq=48)
    eng.submit(Request(0, p, max_new=4))
    assert eng.run()[0].out == ref


def test_ragged_prompts_isolated_slots():
    """Each ragged request must produce the same tokens as a solo run."""
    rs = np.random.default_rng(1)
    prompts = [rs.integers(0, CFG.vocab_size, size=n).astype(np.int32)
               for n in (3, 7, 5)]
    solo = []
    for p in prompts:
        e = ContinuousEngine(CFG, PARAMS, slots=1, max_seq=48)
        e.submit(Request(0, p, max_new=3))
        solo.append(e.run()[0].out)
    eng = ContinuousEngine(CFG, PARAMS, slots=3, max_seq=48)
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new=3))
    done = {r.rid: r.out for r in eng.run()}
    for i in range(3):
        assert done[i] == solo[i], i


def test_slot_refill_more_requests_than_slots():
    rs = np.random.default_rng(2)
    eng = ContinuousEngine(CFG, PARAMS, slots=2, max_seq=48)
    for i in range(5):
        eng.submit(Request(i, rs.integers(0, CFG.vocab_size, size=4 + i).astype(np.int32),
                           max_new=2 + i % 3))
    done = eng.run()
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]
    assert all(len(r.out) == 2 + r.rid % 3 for r in done)
