"""Per-architecture smoke tests (reduced configs, one forward/train step on
CPU: output shapes + finite values) and serving-path consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_smoke_config
from repro.models import model as M

# Every test here XLA-compiles a full (reduced) model — 3-12s per arch x
# step kind.  That is the slow tier by construction; the CI fast lane keeps
# model coverage through test_substrate's end-to-end training tests.
pytestmark = pytest.mark.slow

RNG = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32, seed=0):
    rs = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(rs.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
         "targets": jnp.asarray(rs.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.family == "audio":
        b["frames"] = jnp.asarray(rs.standard_normal((B, cfg.encoder_seq, cfg.d_model)),
                                  jnp.float32) * 0.02
    return b


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_and_loss(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, RNG)
    batch = _batch(cfg)
    x, caches, aux = M.forward_hidden(cfg, params, batch)
    assert x.shape == (2, 32, cfg.d_model)
    assert caches is None
    assert bool(jnp.isfinite(x).all())
    loss, metrics = M.loss_and_metrics(cfg, params, batch)
    assert bool(jnp.isfinite(loss))
    assert 1.0 < float(loss) < 20.0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_train_step(arch):
    from repro.train.trainer import TrainConfig, make_train_step
    from repro.optim import adamw
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, RNG)
    opt = adamw.init_state(params)
    step = jax.jit(make_train_step(cfg, TrainConfig(warmup=1, total_steps=10)))
    p2, o2, metrics = step(params, opt, _batch(cfg), jnp.asarray(0))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, b: a + float(jnp.abs(b[0] - b[1]).sum()),
        jax.tree.map(lambda x, y: (x, y), params, p2), 0.0)
    assert delta > 0.0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_decode_steps(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, RNG)
    B = 2
    caches = M.init_caches(cfg, B, 64)
    if cfg.family == "audio":
        caches["memory"] = jnp.ones((B, cfg.encoder_seq, cfg.d_model)) * 0.01
    toks = jnp.ones((B, 1), jnp.int32)
    for i in range(3):
        logits, caches = M.decode_step(cfg, params, toks, caches)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())
    assert int(caches["pos"]) == 3


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "stablelm-3b", "rwkv6-1.6b",
                                   "zamba2-7b", "yi-34b"])
def test_prefill_decode_matches_full_forward(arch):
    """Cache-consistency: prefill S-1 tokens then decode token S == full fwd.
    (MoE archs excluded: capacity-based token dropping is T-dependent.)"""
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, RNG)
    B, S = 2, 12
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    x, _, _ = M.forward_hidden(cfg, params, {"tokens": toks})
    full_logits = M._unembed(cfg, params, x)[:, -1]
    caches = M.init_caches(cfg, B, 32)
    _, caches, _ = M.forward_hidden(cfg, params, {"tokens": toks[:, :S - 1]}, caches)
    logits, _ = M.decode_step(cfg, params, toks[:, S - 1:], caches)
    assert jnp.abs(logits[:, 0] - full_logits).max() < 5e-3


def test_vlm_patch_splice_and_mask():
    cfg = get_smoke_config("qwen2-vl-72b")
    params = M.init_params(cfg, RNG)
    B, S = 2, 300
    batch = dict(_batch(cfg, B, S),
                 patches=jnp.ones((B, M.N_PATCHES, cfg.d_model)) * 0.01)
    loss, _ = M.loss_and_metrics(cfg, params, batch)
    assert bool(jnp.isfinite(loss))


def test_swa_restricts_attention():
    """Sliding window: tokens beyond the window cannot influence the output."""
    cfg = get_smoke_config("h2o-danube-1.8b")       # window 16 after smoke()
    params = M.init_params(cfg, RNG)
    S = 40
    toks = jax.random.randint(RNG, (1, S), 0, cfg.vocab_size)
    x1, _, _ = M.forward_hidden(cfg, params, {"tokens": toks})
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)  # outside window of last token
    x2, _, _ = M.forward_hidden(cfg, params, {"tokens": toks2})
    # last position (pos 39) attends [24..39]; changing token 0 must not move it
    assert jnp.abs(x1[0, -1] - x2[0, -1]).max() < 1e-5
    # but an early position does change
    assert jnp.abs(x1[0, 1] - x2[0, 1]).max() > 1e-6


def test_moe_aux_loss_decreases_imbalance_signal():
    cfg = get_smoke_config("olmoe-1b-7b")
    params = M.init_params(cfg, RNG)
    loss, m = M.loss_and_metrics(cfg, params, _batch(cfg))
    assert float(m["aux"]) > 0.9      # ~E * Σ me·ce ≈ 1 for near-uniform router


def test_fp8_kv_cache_decode():
    """fp8 KV caches (memory-bound decode iteration): decode stays finite
    and close to the f32-cache output."""
    cfg = get_smoke_config("yi-34b")
    params = M.init_params(cfg, RNG)
    B = 2
    toks = jax.random.randint(RNG, (B, 6), 0, cfg.vocab_size)
    outs = {}
    for dt in ("float32", "float8_e4m3fn"):
        caches = M.init_caches(cfg, B, 16, dtype=dt)
        _, caches, _ = M.forward_hidden(cfg, params, {"tokens": toks[:, :5]}, caches)
        logits, _ = M.decode_step(cfg, params, toks[:, 5:6], caches)
        assert bool(jnp.isfinite(logits).all()), dt
        outs[dt] = logits
    # fp8 quantization error is bounded (same argmax region, small drift)
    diff = jnp.abs(outs["float8_e4m3fn"] - outs["float32"]).max()
    assert float(diff) < 2.0
