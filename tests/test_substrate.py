"""Substrate: optimizer, schedules, checkpointing, trainer, serving engine."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models import model as M
from repro.optim import adamw, schedules
from repro.serving.engine import Engine
from repro.train import checkpoint
from repro.train.trainer import TrainConfig, make_train_step, train_loop


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init_state(params)
    cfg = adamw.AdamWConfig(lr=0.2, weight_decay=0.0)
    for _ in range(120):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - 1.0) ** 2))(params)
        params, state, _ = adamw.apply_updates(params, grads, state, cfg)
    assert jnp.abs(params["w"] - 1.0).max() < 0.05


def test_grad_clipping():
    params = {"w": jnp.zeros(3)}
    state = adamw.init_state(params)
    cfg = adamw.AdamWConfig(clip_norm=1.0)
    _, _, m = adamw.apply_updates(params, {"w": jnp.ones(3) * 1e6}, state, cfg)
    assert float(m["grad_norm"]) > 1e5      # reported pre-clip


def test_schedule_shapes():
    s = schedules.warmup_cosine(jnp.arange(0, 1000, 100), warmup=100, total=1000)
    assert float(s[0]) == 0.0
    assert float(s.max()) <= 1.0


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, tree, step=7)
        restored, step = checkpoint.restore(d, tree)
        assert step == 7
        assert np.array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
        checkpoint.save(d, tree, step=8)
        checkpoint.save(d, tree, step=9)
        _, step = checkpoint.restore(d, tree)
        assert step == 9


def test_checkpoint_corrupt_falls_back_to_earlier_step():
    import os
    import pytest
    tree = {"a": jnp.arange(4.0), "b": jnp.ones((2,), jnp.int32)}
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, tree, step=1)
        checkpoint.save(d, tree, step=2)
        checkpoint.save(d, tree, step=3)
        # truncate the newest checkpoint's arrays mid-write
        with open(os.path.join(d, "step_00000003", "arrays.npz"), "wb") as f:
            f.write(b"PK\x03\x04 torn write")
        with pytest.warns(RuntimeWarning, match="falling back"):
            restored, step = checkpoint.restore(d, tree)
        assert step == 2
        assert np.array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
        # explicit-step restores fall back the same way
        with open(os.path.join(d, "step_00000002", "manifest.json"), "w") as f:
            f.write("{not json")
        with pytest.warns(RuntimeWarning, match="falling back"):
            _, step = checkpoint.restore(d, tree, step=2)
        assert step == 1
        # every candidate corrupt -> a clear error naming what was tried
        with open(os.path.join(d, "step_00000001", "arrays.npz"), "wb") as f:
            f.write(b"")
        with pytest.warns(RuntimeWarning):
            with pytest.raises(FileNotFoundError, match="no intact checkpoint"):
                checkpoint.restore(d, tree, step=1)


def test_training_reduces_loss():
    cfg = get_smoke_config("stablelm-3b")
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4)
    tcfg = TrainConfig(warmup=3, total_steps=25)
    _, hist = train_loop(cfg, tcfg, iter(SyntheticCorpus(dc)), steps=25,
                         log_every=0)
    assert np.mean(hist["loss"][-5:]) < np.mean(hist["loss"][:5]) - 0.2


def test_grad_accum_matches_full_batch():
    cfg = get_smoke_config("h2o-danube-1.8b")
    rng = jax.random.PRNGKey(0)
    params = M.init_params(cfg, rng)
    opt = adamw.init_state(params)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    batch = {k: jnp.asarray(v) for k, v in SyntheticCorpus(dc).batch(0).items()}
    s1 = jax.jit(make_train_step(cfg, TrainConfig(warmup=1, total_steps=10)))
    s2 = jax.jit(make_train_step(cfg, TrainConfig(warmup=1, total_steps=10,
                                                  grad_accum=2)))
    p1, _, m1 = s1(params, opt, batch, jnp.asarray(0))
    p2, _, m2 = s2(params, opt, batch, jnp.asarray(0))
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-2
    d = jax.tree.reduce(lambda a, b: max(a, float(jnp.abs(b).max())),
                        jax.tree.map(lambda x, y: x - y, p1, p2), 0.0)
    assert d < 5e-3     # same update up to microbatch loss-normalization noise


def test_engine_generate_and_probe():
    cfg = get_smoke_config("stablelm-3b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, batch_size=2, max_seq=48)
    prompts = [np.array([1, 2, 3], np.int32), np.array([4, 5, 6], np.int32)]
    outs = eng.generate(prompts, max_new=4)
    assert len(outs) == 2 and all(len(o) == 4 for o in outs)
    assert all(0 <= t < cfg.vocab_size for o in outs for t in o)
    # greedy decode is deterministic
    outs2 = eng.generate(prompts, max_new=4)
    assert outs == outs2
