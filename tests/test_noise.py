"""RNG stream contracts for the counter-based noise engine (core.noise).

Three layers of guarantees, each asserted with ``==`` (never approx):

  * stream primitives: Philox reads are pure functions of (key, submission
    index), the cached hot-path reader equals the reference constructor
    path bit-for-bit, and the Box-Muller transform is invariant to batch
    shape and requested width;
  * default mode: the vectorized engine consumes the identical stream as
    the ``batched=False`` scalar reference across the model zoo, for any
    split of submissions into calls, straddling ``_VECTOR_MIN``;
  * CRN mode: draws are keyed by (seed, structural fingerprint, trajectory
    position), so results are seed-reproducible, invariant to submission
    interleaving order, and identical between shared, interleaved, serial,
    and scalar-reference schedules.
"""
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.core import (
    A40_NVLINK,
    TPU_V5E,
    CommConfig,
    ParallelPlan,
    Simulator,
    extract_workload,
)
from repro.core import autoccl, tuner
from repro.core.noise import (
    NOISE_MODES,
    WORDS_PER_SUBMISSION,
    NoiseModel,
    lognormal_rows,
    stream_key,
    uniform_rows,
)
from repro.core.workload import CommOp, OverlapGroup, matmul_comp

_MOE = {"qwen2-moe-a2.7b", "deepseek-v2-lite-16b", "deepseek-moe-16b", "olmoe-1b-7b"}


def _same(a, b):
    return (
        a.Z == b.Z
        and a.X == b.X
        and a.Y == b.Y
        and list(a.comm_times) == list(b.comm_times)
        and list(a.comp_times) == list(b.comp_times)
    )


def _rand_cfg(rng):
    return CommConfig(
        algorithm=("ring", "tree", "bidir")[int(rng.integers(0, 3))],
        protocol=("latency", "mixed", "bulk")[int(rng.integers(0, 3))],
        transport=("p2p", "shm", "net")[int(rng.integers(0, 3))],
        nc=int(rng.integers(1, 64)),
        nt=int(rng.integers(64, 640)),
        chunk_kb=int(rng.integers(32, 8192)),
    )


def _group(m=3, n=2):
    return OverlapGroup(
        "g",
        comps=[matmul_comp(f"m{i}", 1024, 512, 2048) for i in range(m)],
        comms=[CommOp(f"c{i}", "allgather", 3e7, 8) for i in range(n)],
    )


def _zoo_workloads(layers=2):
    wls = []
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        if arch in _MOE:
            plan = ParallelPlan(kind="ep", ep=8)
            nl = max(3, cfg.first_dense_layers + 2)
        else:
            plan = ParallelPlan(kind="fsdp", dp=8)
            nl = layers
        wls.append(
            (arch, extract_workload(cfg, plan, seq=2048, global_batch=16, layers=nl))
        )
    return wls


# -- stream primitives ---------------------------------------------------


def test_uniform_rows_pure_function_of_index():
    key = stream_key(7, "default")
    block = uniform_rows(key, 5, 9)
    for i in range(9):
        assert np.array_equal(block[i], uniform_rows(key, 5 + i, 1)[0])


def test_cached_reader_equals_reference_bit_for_bit():
    nm = NoiseModel(3, 0.02)
    key = stream_key(3, "default")
    # repeated, overlapping, and out-of-order reads through the cached
    # generator must equal fresh construction every time
    for first, count in ((0, 4), (100, 7), (0, 4), (3, 1), (2, 64)):
        assert np.array_equal(
            nm.uniforms(key, first, count), uniform_rows(key, first, count)
        )
    other = stream_key(3, ("crn", "x"))
    assert np.array_equal(nm.uniforms(other, 1, 2), uniform_rows(other, 1, 2))


def test_lognormal_rows_invariant_to_batch_shape_and_width():
    key = stream_key(0, "default")
    u = uniform_rows(key, 0, 16)
    full = lognormal_rows(u, 0.05, 10)
    for i in range(16):
        assert np.array_equal(lognormal_rows(u[i : i + 1], 0.05, 10)[0], full[i])
    # jitter j depends only on its own Box-Muller pair, not on width
    wider = lognormal_rows(u, 0.05, WORDS_PER_SUBMISSION)
    assert np.array_equal(wider[:, :10], full)
    assert np.isfinite(full).all() and (full > 0).all()


def test_lognormal_rows_width_guard():
    u = uniform_rows(stream_key(0, "default"), 0, 1)
    with pytest.raises(ValueError, match="WORDS_PER_SUBMISSION"):
        lognormal_rows(u, 0.05, WORDS_PER_SUBMISSION + 1)


def test_stream_keys_distinct_and_stable():
    assert stream_key(0, "default") != stream_key(1, "default")
    assert stream_key(0, "default") != stream_key(0, ("crn", ()))
    assert stream_key(5, ("crn", (1, 2))) == stream_key(5, ("crn", (1, 2)))


def test_noise_mode_validated():
    assert NOISE_MODES == ("default", "crn")
    with pytest.raises(ValueError, match="noise_mode"):
        Simulator(A40_NVLINK, noise=0.01, noise_mode="bogus")
    with pytest.raises(ValueError, match="noise_mode"):
        NoiseModel(0, 0.01, mode="bogus")


# -- default mode: batched engine == scalar reference --------------------


def test_default_mode_split_invariant():
    """Draws are a pure function of the submission index, so ANY split of
    the same submission sequence into calls yields identical measurements."""
    rng = np.random.default_rng(0)
    g = _group()
    lists = [[_rand_cfg(rng) for _ in g.comms] for _ in range(7)]
    one = Simulator(A40_NVLINK, noise=0.02, seed=5).profile_many(g, lists)
    split_sim = Simulator(A40_NVLINK, noise=0.02, seed=5)
    split = (
        split_sim.profile_many(g, lists[:1])
        + split_sim.profile_many(g, lists[1:4])
        + [split_sim.profile_group(g, cfgs) for cfgs in lists[4:]]
    )
    assert all(_same(a, b) for a, b in zip(one, split))


def test_default_noisy_tuning_identical_batched_vs_scalar_across_zoo():
    """Acceptance: the vectorized engine's default noisy mode is
    byte-identical to the ``batched=False`` scalar reference — configs,
    traces, and ``profile_count`` — on every model-zoo workload."""
    for name, wl in _zoo_workloads():
        s_ref = Simulator(TPU_V5E, noise=0.01, seed=0, batched=False)
        s_eng = Simulator(TPU_V5E, noise=0.01, seed=0)
        r_ref = tuner.search_workload(s_ref, wl)
        r_eng = tuner.search_workload(s_eng, wl)
        assert r_ref == r_eng, name
        assert s_ref.profile_count == s_eng.profile_count, name


@pytest.mark.parametrize("n", [1, 2, 47, 48, 49, 96])
def test_default_noisy_batches_straddling_vector_min(n):
    rng = np.random.default_rng(n)
    g = _group()
    lists = [[_rand_cfg(rng) for _ in g.comms] for _ in range(n)]
    s_ref = Simulator(A40_NVLINK, noise=0.02, seed=9, batched=False)
    s_eng = Simulator(A40_NVLINK, noise=0.02, seed=9)
    ref = s_ref.profile_many(g, lists)
    eng = s_eng.profile_many(g, lists)
    assert all(_same(a, b) for a, b in zip(ref, eng))


def test_property_noisy_batch_sizes_straddle_vector_min():
    """Hypothesis sweep: for any batch size around ``_VECTOR_MIN`` and any
    (M, N) group shape, the engine path equals the scalar reference."""
    pytest.importorskip(
        "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)"
    )
    from hypothesis import given, settings, strategies as st

    vmin = Simulator(A40_NVLINK).engine._VECTOR_MIN

    @settings(max_examples=12, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=2 * vmin + 4),
        m=st.integers(min_value=0, max_value=4),
        k=st.integers(min_value=0, max_value=3),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def run(n, m, k, seed):
        rng = np.random.default_rng(seed)
        g = _group(m, k)
        lists = [[_rand_cfg(rng) for _ in g.comms] for _ in range(n)]
        ref = Simulator(A40_NVLINK, noise=0.02, seed=seed, batched=False)
        eng = Simulator(A40_NVLINK, noise=0.02, seed=seed)
        assert all(
            _same(a, b)
            for a, b in zip(ref.profile_many(g, lists), eng.profile_many(g, lists))
        )

    run()


# -- CRN mode ------------------------------------------------------------


def test_crn_schedules_identical_across_zoo():
    """Acceptance: under CRN, shared, serial, and scalar-reference
    schedules return byte-identical results and ``profile_count`` —
    trajectory sharing is sound under jitter."""
    for name, wl in _zoo_workloads():
        sims = [
            Simulator(TPU_V5E, noise=0.02, seed=1, noise_mode="crn"),
            Simulator(TPU_V5E, noise=0.02, seed=1, noise_mode="crn"),
            Simulator(TPU_V5E, noise=0.02, seed=1, noise_mode="crn", batched=False),
        ]
        shared = tuner.search_workload(sims[0], wl, mode="interleaved")
        serial = tuner.search_workload(sims[1], wl, mode="serial")
        scalar = tuner.search_workload(sims[2], wl, mode="interleaved")
        assert shared == serial == scalar, name
        assert sims[0].profile_count == sims[1].profile_count, name


def test_crn_invariant_to_request_interleaving_order():
    """Engine-level order independence: each group's draws are keyed by its
    own fingerprint and trajectory position, so permuting the grouped
    requests cannot change any group's measurements."""
    rng = np.random.default_rng(2)
    groups = [_group(3, 2), _group(2, 1), _group(3, 2)]
    reqs = [
        (g, [[_rand_cfg(rng) for _ in g.comms] for _ in range(3)]) for g in groups
    ]
    fwd = Simulator(A40_NVLINK, noise=0.02, seed=4, noise_mode="crn")
    rev = Simulator(A40_NVLINK, noise=0.02, seed=4, noise_mode="crn")
    out_f = fwd.profile_many_grouped(reqs)
    out_r = rev.profile_many_grouped(list(reversed(reqs)))
    for rf, rr in zip(out_f, reversed(out_r)):
        assert all(_same(a, b) for a, b in zip(rf, rr))


def test_crn_identical_groups_walk_identical_trajectories():
    wl = extract_workload(
        get_config("phi2-2b"),
        ParallelPlan(kind="fsdp", dp=8),
        seq=2048,
        global_batch=16,
        layers=4,
    )
    sim = Simulator(A40_NVLINK, noise=0.05, seed=3, noise_mode="crn")
    cfgs, iters, _ = tuner.search_workload(sim, wl)
    n0 = len(wl.groups[0].comms)
    # the four fwd layers are structurally identical
    layer_cfgs = [tuple(cfgs[(gi, ci)] for ci in range(n0)) for gi in range(4)]
    assert len(set(layer_cfgs)) == 1
    assert iters == sim.profile_count
    # ...while default mode legitimately diverges on the same workload
    cfgs2, _, _ = tuner.search_workload(Simulator(A40_NVLINK, noise=0.05, seed=3), wl)
    layer_cfgs2 = [tuple(cfgs2[(gi, ci)] for ci in range(n0)) for gi in range(4)]
    assert len(set(layer_cfgs2)) > 1


def test_crn_seed_reproducible_and_seed_sensitive():
    wl = extract_workload(
        get_config("phi2-2b"),
        ParallelPlan(kind="fsdp", dp=8),
        seq=2048,
        global_batch=16,
        layers=3,
    )

    def make(s):
        return Simulator(A40_NVLINK, noise=0.03, seed=s, noise_mode="crn")

    r1 = tuner.search_workload(make(11), wl)
    r2 = tuner.search_workload(make(11), wl)
    r3 = tuner.search_workload(make(12), wl)
    assert r1 == r2
    assert r1[2] != r3[2]  # different seed, different noisy traces


def test_crn_autoccl_shared_equals_serial():
    wl = extract_workload(
        get_config("deepseek-moe-16b"),
        ParallelPlan(kind="ep", ep=8),
        seq=2048,
        global_batch=16,
        layers=3,
    )
    a1 = autoccl.search_workload(
        Simulator(TPU_V5E, noise=0.02, seed=1, noise_mode="crn"), wl
    )
    a2 = autoccl.search_workload(
        Simulator(TPU_V5E, noise=0.02, seed=1, noise_mode="crn"), wl, mode="serial"
    )
    assert a1 == a2


def test_crn_trajectory_memo_purges_dead_groups_and_guards_live():
    """The CRN position memo is weak: collected groups purge silently
    (their trajectories can never resume), but a memo full of LIVE groups
    raises rather than silently restarting anyone's stream."""
    sim = Simulator(A40_NVLINK, noise=0.02, seed=0, noise_mode="crn", batched=False)
    nm = sim._noise
    nm._TRAJ_MEMO_MAX = 4
    cfg = [CommConfig()]
    for _ in range(12):  # ephemeral churn: dead entries purge, no error
        sim.profile_group(_group(1, 1), cfg)
    assert len(nm._traj) <= 4
    live = [_group(1, 1) for _ in range(6)]
    with pytest.raises(RuntimeError, match="live CRN group"):
        for g in live:
            sim.profile_group(g, cfg)


def test_crn_noisy_measurements_still_fresh_draws():
    """CRN correlates draws across identical groups at equal positions; it
    does NOT replay draws within one group's trajectory."""
    g = _group()
    sim = Simulator(A40_NVLINK, noise=0.05, seed=0, noise_mode="crn")
    cfg = [CommConfig(nc=4, chunk_kb=512), CommConfig(nc=2, chunk_kb=256)]
    m1 = sim.profile_group(g, cfg)
    m2 = sim.profile_group(g, cfg)
    assert len(sim.engine.cache) == 0  # measurement cache still bypassed
    assert m1.Z != m2.Z  # position advanced -> fresh draw
