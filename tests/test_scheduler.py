"""Cross-group interleaved tuning: the scheduler must be a pure
re-scheduling of the serial group walk.  Deterministic mode: configs,
traces, and ``profile_count`` byte-identical to ``mode="serial"`` on
every multi-group model-zoo workload.  Noisy mode: results follow the
documented RNG contract (jitter drawn in flat submission order) — they are
seed-reproducible and identical between the batched engine and the
``batched=False`` reference path, though legitimately different from the
serial interleaving."""
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.core import (A40_NVLINK, TPU_V5E, CommConfig, ParallelPlan,
                        Simulator, extract_workload)
from repro.core import autoccl, tuner
from repro.core.scheduler import StepSearch
from repro.core.workload import CommOp, OverlapGroup, matmul_comp

_MOE = {"qwen2-moe-a2.7b", "deepseek-v2-lite-16b", "deepseek-moe-16b",
        "olmoe-1b-7b"}


def _zoo_workloads():
    """One multi-group workload per model-zoo arch (EP for the MoE configs,
    FSDP otherwise) plus pipeline / tensor-parallel plans — every overlap
    pattern the extractor produces, all with ≥2 groups."""
    wls = []
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        if arch in _MOE:
            plan = ParallelPlan(kind="ep", ep=8)
            layers = max(3, cfg.first_dense_layers + 2)
        else:
            plan = ParallelPlan(kind="fsdp", dp=8)
            layers = 2
        wls.append((arch, extract_workload(cfg, plan, seq=2048,
                                           global_batch=16, layers=layers)))
    wls.append(("yi-34b/pp", extract_workload(
        get_config("yi-34b"), ParallelPlan(kind="pp", pp=4, microbatches=4),
        seq=2048, global_batch=16)))
    wls.append(("llama3-8b/tp", extract_workload(
        get_config("llama3-8b"), ParallelPlan(kind="tp", tp=8),
        seq=2048, global_batch=16, layers=2)))
    return wls


def test_interleaved_identical_to_serial_across_model_zoo():
    for name, wl in _zoo_workloads():
        assert len(wl.groups) >= 2, name
        s_ser = Simulator(TPU_V5E, seed=0)
        c1, i1, t1 = tuner.search_workload(s_ser, wl, mode="serial")
        s_int = Simulator(TPU_V5E, seed=0)
        c2, i2, t2 = tuner.search_workload(s_int, wl, mode="interleaved")
        assert c1 == c2, name
        assert i1 == i2, name
        assert t1 == t2, name                       # byte-identical traces
        assert s_ser.profile_count == s_int.profile_count, name


def test_interleaved_identical_to_serial_warm_start():
    wl = extract_workload(get_config("llama3-8b"),
                          ParallelPlan(kind="fsdp", dp=8),
                          seq=2048, global_batch=16, layers=3)
    r1 = tuner.search_workload(Simulator(A40_NVLINK, seed=0), wl,
                             warm_start=True, mode="serial")
    r2 = tuner.search_workload(Simulator(A40_NVLINK, seed=0), wl,
                             warm_start=True, mode="interleaved")
    assert r1 == r2


def test_autoccl_interleaved_identical_to_serial():
    for name, wl in (("deepseek-moe-16b", extract_workload(
            get_config("deepseek-moe-16b"), ParallelPlan(kind="ep", ep=8),
            seq=2048, global_batch=16, layers=3)),
                     ("phi2-2b", extract_workload(
            get_config("phi2-2b"), ParallelPlan(kind="fsdp", dp=8),
            seq=2048, global_batch=16, layers=2))):
        a1 = autoccl.search_workload(Simulator(TPU_V5E, seed=1), wl,
                                   mode="serial")
        a2 = autoccl.search_workload(Simulator(TPU_V5E, seed=1), wl,
                                   mode="interleaved")
        assert a1 == a2, name


@pytest.mark.parametrize("tune", [
    lambda sim, wl: tuner.search_workload(sim, wl),
    lambda sim, wl: autoccl.search_workload(sim, wl),
], ids=["lagom", "autoccl"])
def test_noisy_interleaved_seed_reproducible(tune):
    """The RNG contract: same seed + same workload -> same results, and the
    batched engine consumes the identical stream as the ``batched=False``
    reference path replaying ``run_group`` in flat submission order."""
    wl = extract_workload(get_config("phi2-2b"),
                          ParallelPlan(kind="fsdp", dp=8),
                          seq=2048, global_batch=16, layers=3)
    r1 = tune(Simulator(A40_NVLINK, noise=0.02, seed=7), wl)
    r2 = tune(Simulator(A40_NVLINK, noise=0.02, seed=7), wl)
    assert r1 == r2
    r3 = tune(Simulator(A40_NVLINK, noise=0.02, seed=7, batched=False), wl)
    assert r1 == r3


def test_noisy_mode_never_shares_trajectories():
    """Structurally identical layers must tune independently under jitter —
    each group's search consumes its own draws.  (With trajectory sharing
    they would be byte-equal by construction.)"""
    wl = extract_workload(get_config("phi2-2b"),
                          ParallelPlan(kind="fsdp", dp=8),
                          seq=2048, global_batch=16, layers=4)
    sim = Simulator(A40_NVLINK, noise=0.05, seed=3)
    cfgs, _, _ = tuner.search_workload(sim, wl)
    n0 = len(wl.groups[0].comms)
    layer_cfgs = [tuple(cfgs[(gi, ci)] for ci in range(n0))
                  for gi in range(4)]         # the four fwd layers
    assert len(set(layer_cfgs)) > 1


def _toy_group():
    return OverlapGroup("g", comps=[matmul_comp("m", 1024, 512, 2048)],
                        comms=[CommOp("c", "allgather", 3e7, 8)])


@pytest.mark.parametrize("batched", [True, False])
def test_empty_candidate_lists_touch_nothing(batched):
    g = _toy_group()
    sim = Simulator(A40_NVLINK, batched=batched)
    assert sim.profile_many(g, []) == []
    assert sim.profile_many_grouped([]) == []
    assert sim.profile_many_grouped([(g, []), (g, [])]) == [[], []]
    assert sim.profile_count == 0
    if batched:
        assert sim.engine.measure_many(g, []) == []
        assert len(sim.engine.cache) == 0
        assert len(sim.engine.columns) == 0


def test_profile_many_grouped_counts_and_aligns():
    g1 = _toy_group()
    g2 = OverlapGroup("h", comps=[matmul_comp("m", 512, 512, 512)],
                      comms=[CommOp("c", "allreduce", 1e7, 8),
                             CommOp("d", "allreduce", 1e7, 8)])
    sim = Simulator(A40_NVLINK)
    reqs = [(g1, [[CommConfig(nc=n)] for n in (1, 2, 4)]),
            (g2, []),
            (g2, [[CommConfig(), CommConfig(nc=2)]])]
    out = sim.profile_many_grouped(reqs)
    assert sim.profile_count == 4
    assert [len(r) for r in out] == [3, 0, 1]
    # aligned with a per-request sequential evaluation
    ref = Simulator(A40_NVLINK, batched=False)
    for (g, lists), res in zip(reqs, out):
        for cfgs, m in zip(lists, res):
            r = ref.run_group(g, cfgs)
            assert (m.Z, m.X, m.Y) == (r.Z, r.X, r.Y)
            assert list(m.comm_times) == list(r.comm_times)


def test_cache_stats_accessor():
    wl = extract_workload(get_config("phi2-2b"),
                          ParallelPlan(kind="fsdp", dp=8),
                          seq=2048, global_batch=16, layers=2)
    sim = Simulator(A40_NVLINK, seed=0)
    tuner.search_workload(sim, wl)
    stats = sim.engine.cache_stats()
    for section in ("measurements", "columns"):
        for key in ("size", "hits", "misses", "evictions"):
            assert isinstance(stats[section][key], int)
    assert stats["columns"]["size"] > 0
    assert stats["measurements"]["misses"] > 0
    assert isinstance(stats["dedup_shared"], int)
    # eviction counter moves under a tiny cache
    small = Simulator(A40_NVLINK, cache_size=4)
    g = _toy_group()
    for n in range(1, 12):
        small.profile_group(g, [CommConfig(nc=n)])
    assert small.engine.cache_stats()["measurements"]["evictions"] > 0


def test_step_search_protocol_guards():
    class Empty(StepSearch):
        def _search(self):
            return
            yield
    s = Empty()
    assert s.done and s.pending is None and s.requests == 0
    with pytest.raises(RuntimeError):
        s.feed([])


def test_group_search_result_requires_completion():
    g = _toy_group()
    gs = tuner.GroupSearch(g, A40_NVLINK)
    assert not gs.done and len(gs.pending) == 4      # subspace probes first
    with pytest.raises(RuntimeError):
        gs.result()
