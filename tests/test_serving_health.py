"""Fault-aware plan lifecycle at serving time: the K-consecutive drift
detector, simulated telemetry replaying a fault schedule, transactional
site demotion with rollback, resolution-band backoff, and the end-to-end
drill — a mid-serve link degradation on ``serve.*`` sites must be
detected within the health window and demoted to fallback knobs while
generation completes."""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import ParallelPlan, extract_decode_workload, tune
from repro.core.faults import FaultEvent, FaultSchedule
from repro.models import model as M
from repro.parallel import collectives as C
from repro.serving import make_engine
from repro.serving.health import (
    HealthMonitor,
    SimulatedTelemetry,
    predicted_site_costs,
)
from repro.serving.plans import BAND_CAP, PlanBinding

CFG = get_smoke_config("llama3-8b")  # 2 dense layers

DEGRADE_AT_2 = FaultSchedule(
    events=(FaultEvent("degrade", site="serve", scale=0.1, start=2),)
)


@pytest.fixture(autouse=True)
def _clean_plan_state():
    yield
    C.install_runtime_plan({})


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def decode_plan():
    pp = ParallelPlan(kind="tp", tp=2)
    wl = extract_decode_workload(CFG, pp, global_batch=32, seq=128)
    return tune(wl, "tpu-v5e", method="nccl")


def _prompts(n, size=8):
    rs = np.random.default_rng(0)
    return [
        rs.integers(0, CFG.vocab_size, size=size).astype(np.int32)
        for _ in range(n)
    ]


# ---------------------------------------------------------------------------
# HealthMonitor: the K-consecutive drift detector
# ---------------------------------------------------------------------------


def test_monitor_requires_k_consecutive_drifted_batches():
    mon = HealthMonitor({"a": 1.0, "b": 1.0}, tolerance=0.25, window=3)
    drifted = {"a": 2.0, "b": 1.0}
    assert mon.observe(0, drifted) == []
    assert mon.observe(1, drifted) == []
    assert mon.observe(2, drifted) == ["a"]  # third consecutive -> flagged
    assert mon.observe(3, drifted) == []  # reported exactly once
    assert mon.unhealthy == {"a"}
    assert mon.last_drift["a"] == pytest.approx(1.0)


def test_monitor_streak_resets_on_recovery():
    mon = HealthMonitor({"a": 1.0}, tolerance=0.25, window=2)
    assert mon.observe(0, {"a": 2.0}) == []
    assert mon.observe(1, {"a": 1.0}) == []  # recovered: streak resets
    assert mon.observe(2, {"a": 2.0}) == []
    assert mon.observe(3, {"a": 2.0}) == ["a"]  # needs 2 fresh in a row


def test_monitor_reset_and_unknown_sites():
    mon = HealthMonitor({"a": 1.0}, tolerance=0.25, window=1)
    # sites without a prediction are ignored, not crashed on
    assert mon.observe(0, {"a": 2.0, "ghost": 9.0}) == ["a"]
    mon.reset()
    assert mon.unhealthy == set() and mon.last_drift == {}
    assert mon.observe(1, {"a": 2.0}) == ["a"]  # flaggable again
    with pytest.raises(ValueError, match="tolerance"):
        HealthMonitor({}, tolerance=0.0)
    with pytest.raises(ValueError, match="window"):
        HealthMonitor({}, window=0)


# ---------------------------------------------------------------------------
# predicted costs + simulated telemetry
# ---------------------------------------------------------------------------


def test_predicted_costs_cover_every_serve_site(decode_plan):
    costs = predicted_site_costs(decode_plan)
    assert costs and all(c > 0 for c in costs.values())
    assert all(s.startswith("serve.") for s in costs)


def test_telemetry_replays_fault_windows_per_site(decode_plan):
    tel = SimulatedTelemetry(decode_plan, DEGRADE_AT_2)
    healthy = predicted_site_costs(decode_plan)
    assert tel.observe(0) == healthy  # pre-fault: observed == predicted
    degraded = tel.observe(2)
    assert all(degraded[s] > healthy[s] * 1.25 for s in healthy)
    # a filter that matches nothing leaves every site healthy
    elsewhere = FaultSchedule(
        events=(FaultEvent("degrade", site="fsdp", scale=0.1),)
    )
    assert SimulatedTelemetry(decode_plan, elsewhere).observe(0) == healthy


# ---------------------------------------------------------------------------
# end-to-end drill: mid-serve degradation -> detect -> demote -> complete
# ---------------------------------------------------------------------------


def test_fixed_engine_detects_and_demotes_mid_generate(params, decode_plan):
    eng = make_engine(
        CFG,
        params,
        mode="fixed",
        batch_size=32,
        max_seq=128,
        plan=decode_plan,
        fault_schedule=DEGRADE_AT_2,
        health_window=2,
        health_tolerance=0.25,
    )
    outs = eng.generate(_prompts(32), max_new=8)
    assert all(len(o) == 8 for o in outs)  # generation completed

    kinds = [e["event"] for e in eng.health_events]
    assert "drift" in kinds and "demotion" in kinds
    drift = next(e for e in eng.health_events if e["event"] == "drift")
    # fault starts at batch 2; window=2 flags on the second drifted batch
    assert drift["batch"] == 3
    assert all(d > 0.25 for d in drift["drift"].values())
    demo = next(e for e in eng.health_events if e["event"] == "demotion")
    assert not demo["rolled_back"]
    assert demo["sites"] and all(s.startswith("serve.") for s in demo["sites"])

    # fallback knobs actually resolve at the demoted sites (exact match)
    rt = eng._binding.current
    for sid in demo["sites"]:
        assert rt[sid] == C.CollectiveRuntime()
        with eng._binding.scope(rt):
            got, src = C.explain_runtime(sid, C.site_class(sid))
            assert src == sid and got.strategy == "xla"
    # the demoted plan was retraced, not reused (distinct digest)
    assert len(eng._fns) == 2
    assert "demoted" in eng.health_report()


def test_continuous_engine_demotes_between_ticks(params, decode_plan):
    from repro.serving import Request

    eng = make_engine(
        CFG,
        params,
        mode="continuous",
        slots=32,
        max_seq=128,
        plan=decode_plan,
        fault_schedule=DEGRADE_AT_2,
        health_window=2,
        health_tolerance=0.25,
    )
    prompts = _prompts(32)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=8))
    done = eng.run()
    assert len(done) == 32 and all(len(r.out) == 8 for r in done)
    kinds = [e["event"] for e in eng.health_events]
    assert "drift" in kinds and "demotion" in kinds
    assert eng._binding.demoted


def test_engine_without_schedule_reports_healthy(params):
    eng = make_engine(CFG, params, mode="fixed", batch_size=2, max_seq=32)
    eng.generate(_prompts(2), max_new=4)
    assert eng.health_events == []
    assert "no drift detected" in eng.health_report()


# ---------------------------------------------------------------------------
# demotion mechanics: transactional rollback + fallback persistence
# ---------------------------------------------------------------------------


def test_demotion_rolls_back_when_apply_fails(decode_plan):
    binding = PlanBinding(CFG, plan=decode_plan)
    before = dict(binding.current)
    sid = next(iter(predicted_site_costs(decode_plan)))

    def bad_apply(rt):
        raise RuntimeError("trace boom")

    with pytest.raises(RuntimeError, match="trace boom"):
        binding.demote([sid], apply=bad_apply)
    assert binding.current == before  # swapped back
    assert sid not in binding.demoted
    event = binding.events[-1]
    assert event["event"] == "demotion" and event["rolled_back"]

    # the same demotion commits once apply succeeds
    seen = []
    binding.demote([sid], apply=seen.append)
    assert seen and seen[0][sid] == C.CollectiveRuntime()
    assert binding.current[sid] == C.CollectiveRuntime()
    assert sid in binding.demoted


def test_demote_to_class_falls_back_to_class_bucket(decode_plan):
    binding = PlanBinding(CFG, plan=decode_plan)
    sid = next(s for s in predicted_site_costs(decode_plan) if s.endswith(".ag"))
    cls = C.site_class(sid)
    want = binding.current.get(cls, C.CollectiveRuntime())
    event = binding.demote([sid], to="class")
    assert binding.current[sid] == want
    assert event["fallback"][sid] == (want.strategy, want.num_chunks)
    with pytest.raises(ValueError, match="demotion target"):
        binding.demote([sid], to="nope")


def test_demoted_fallbacks_survive_repo_re_resolution(tmp_path):
    pp = ParallelPlan(kind="tp", tp=2)
    wl = extract_decode_workload(CFG, pp, global_batch=4, seq=32)
    tune(wl, "tpu-v5e", method="nccl", repo=str(tmp_path))
    binding = PlanBinding(
        CFG, repo=str(tmp_path), parallel="tp:2", band=0.5, max_seq=32
    )
    rt = binding.resolve(4)
    sid = next(s for s in rt if s.startswith("serve."))
    assert rt[sid] != C.CollectiveRuntime()
    binding.demote([sid])
    # a fresh repo hit must not silently re-trust the flagged site
    rt2 = binding.resolve(4)
    assert rt2[sid] == C.CollectiveRuntime()
    sibling = next(s for s in rt if s.startswith("serve.") and s != sid)
    assert rt2[sibling] == rt[sibling]  # siblings keep their tuned knobs


# ---------------------------------------------------------------------------
# resolution-band backoff: misses widen (capped), hits reset
# ---------------------------------------------------------------------------


def test_band_backoff_widens_on_miss_and_resets_on_hit(tmp_path):
    binding = PlanBinding(
        CFG, repo=str(tmp_path), parallel="tp:2", band=0.1, max_seq=32
    )
    bands = []
    for _ in range(6):  # empty repo: every resolve misses
        assert binding.resolve(4) is None
        bands.append(binding._band_now)
    assert bands == [0.2, 0.4, 0.8, 1.6, BAND_CAP, BAND_CAP]
    widened = [e for e in binding.events if e["event"] == "band_widened"]
    assert len(widened) == 5  # the capped repeat logs no event
    assert widened[0] == {"event": "band_widened", "batch": 0, "from": 0.1, "to": 0.2}
    # a hit resets the live band to the operator's configured value
    pp = ParallelPlan(kind="tp", tp=2)
    wl = extract_decode_workload(CFG, pp, global_batch=4, seq=32)
    tune(wl, "tpu-v5e", method="nccl", repo=str(tmp_path))
    assert binding.resolve(4) is not None
    assert binding._band_now == 0.1
