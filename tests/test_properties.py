"""Hypothesis property tests on the system's invariants: contention model
monotonicity, simulator conservation laws, tuner termination, comm-config
clamping, data-pipeline determinism."""
import math

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import contention
from repro.core.comm_params import (C_MAX_KB, C_MIN_KB, NC_MAX, NC_MIN,
                                    CommConfig, min_config)
from repro.core.hardware import A40_NVLINK, A40_PCIE, TPU_V5E
from repro.core.simulator import Simulator
from repro.core.workload import CommOp, OverlapGroup, matmul_comp
from repro.data.pipeline import DataConfig, SyntheticCorpus

HW = st.sampled_from([A40_NVLINK, A40_PCIE, TPU_V5E])
NC = st.integers(NC_MIN, NC_MAX)
CHUNK = st.integers(C_MIN_KB, C_MAX_KB)
BYTES = st.floats(1e4, 1e9)


@settings(max_examples=60, deadline=None)
@given(hw=HW, nc=NC, chunk=CHUNK)
def test_comp_time_macro_monotone_in_nc(hw, nc, chunk):
    """Eq. 5: more channels -> never-meaningfully-faster computation.
    (Wave quantization — the ceil in g — permits sub-0.1% wiggles when the
    wave count stays constant while per-wave width shrinks, so monotonicity
    is asserted at the 2% level plus strictly on the wave count itself.)"""
    import math
    comp = matmul_comp("m", 2048, 2048, 2048)
    c1 = CommConfig(nc=nc, chunk_kb=chunk)
    c2 = CommConfig(nc=min(NC_MAX, nc + 4), chunk_kb=chunk)
    t1 = contention.comp_time(comp, c1, hw)
    t2 = contention.comp_time(comp, c2, hw)
    assert t2 >= t1 * 0.98
    lam = hw.num_slots
    g1 = math.ceil(comp.threadblocks / ((lam - min(c1.nc, int(lam * 0.75)))))
    g2 = math.ceil(comp.threadblocks / ((lam - min(c2.nc, int(lam * 0.75)))))
    assert g2 >= g1                     # strict monotonicity of the wave count


@settings(max_examples=60, deadline=None)
@given(hw=HW, nc=NC, chunk=CHUNK)
def test_comp_time_bounded_below_by_alone(hw, nc, chunk):
    comp = matmul_comp("m", 1024, 1024, 4096)
    cfg = CommConfig(nc=nc, chunk_kb=chunk)
    assert contention.comp_time(comp, cfg, hw) >= contention.comp_time_alone(comp, hw) - 1e-12


@settings(max_examples=60, deadline=None)
@given(hw=HW, nc=NC, chunk=CHUNK, nbytes=BYTES)
def test_bandwidth_draw_bounded(hw, nc, chunk, nbytes):
    cfg = CommConfig(nc=nc, chunk_kb=chunk)
    v = contention.comm_bandwidth_draw(cfg, hw)
    assert 0.0 <= v <= 0.85 * hw.hbm_bw
    assert contention.wire_bandwidth(cfg, hw) <= hw.link_bw + 1e-6


@settings(max_examples=40, deadline=None)
@given(hw=HW, nbytes=BYTES, n=st.integers(2, 64))
def test_comm_time_positive_and_decreasing_in_bw(hw, nbytes, n):
    op = CommOp("c", "allreduce", nbytes, n)
    slow = CommConfig(nc=1, chunk_kb=C_MIN_KB)
    fast = CommConfig(nc=16, chunk_kb=2048)
    assert contention.comm_time(op, fast, hw) <= contention.comm_time(op, slow, hw)
    assert contention.comm_time(op, slow, hw) > 0


@settings(max_examples=25, deadline=None)
@given(hw=HW,
       comps=st.lists(st.tuples(st.integers(64, 2048), st.integers(64, 2048)),
                      min_size=1, max_size=4),
       comms=st.lists(st.floats(1e5, 5e8), min_size=0, max_size=4),
       nc=NC, chunk=CHUNK)
def test_simulator_conservation(hw, comps, comms, nc, chunk):
    """Z >= max stream busy time; Z <= X + Y (two streams can only overlap)."""
    g = OverlapGroup(
        "g",
        comps=[matmul_comp(f"m{i}", m, 512, n) for i, (m, n) in enumerate(comps)],
        comms=[CommOp(f"c{i}", "allgather", b, 8) for i, b in enumerate(comms)])
    cfgs = [CommConfig(nc=nc, chunk_kb=chunk)] * len(g.comms)
    r = Simulator(hw).run_group(g, cfgs)
    assert r.Z >= max(r.X, r.Y) - 1e-9
    assert r.Z <= r.X + r.Y + 1e-9
    assert all(x > 0 for x in r.comm_times)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 20), nc=NC, chunk=CHUNK, nt=st.integers(-1000, 10000))
def test_comm_config_clamp(seed, nc, chunk, nt):
    c = CommConfig(nc=nc * 7, chunk_kb=chunk * 3, nt=nt).clamp()
    assert NC_MIN <= c.nc <= NC_MAX
    assert C_MIN_KB <= c.chunk_kb <= C_MAX_KB


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000),
       comm_bytes=st.lists(st.floats(1e6, 1e9), min_size=1, max_size=3))
def test_tuner_always_terminates(seed, comm_bytes):
    from repro.core import tuner
    g = OverlapGroup(
        "g", comps=[matmul_comp("m", 4096, 2048, 8192)],
        comms=[CommOp(f"c{i}", "allgather", b, 8)
               for i, b in enumerate(comm_bytes)])
    sim = Simulator(A40_NVLINK, noise=0.01, seed=seed)
    res = tuner.tune_group(sim, g)
    assert len(res.configs) == len(comm_bytes)
    assert all(c.done for c in res.configs)
    # linear: bounded profiles per communication (dials have log-range steps
    # x 3 candidates + subspace probes + bisection)
    assert res.iterations <= 160 * len(comm_bytes)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), step=st.integers(0, 50))
def test_data_pipeline_deterministic_and_sharded(seed, step):
    dc = DataConfig(vocab_size=512, seq_len=32, global_batch=8, seed=seed)
    full = SyntheticCorpus(dc).batch(step)
    sharded = [SyntheticCorpus(dc, shard=i, num_shards=2).batch(step)
               for i in range(2)]
    again = SyntheticCorpus(dc).batch(step)
    assert np.array_equal(full["tokens"], again["tokens"])        # deterministic
    assert all(s["tokens"].shape == (4, 32) for s in sharded)
    assert full["tokens"].max() < 512
    # targets are next tokens of the same stream
    assert full["tokens"].dtype == np.int32
