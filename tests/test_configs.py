"""Config registry + analytic parameter counts vs advertised sizes."""
import pytest

from repro.configs import (ALL_ARCHS, ASSIGNED_ARCHS, INPUT_SHAPES,
                           get_config, get_smoke_config, shape_applicable)

ADVERTISED_B = {
    "rwkv6-1.6b": 1.6, "zamba2-7b": 7.0, "h2o-danube-1.8b": 1.8,
    "qwen2-moe-a2.7b": 14.3, "stablelm-3b": 3.0, "whisper-small": 0.24,
    "phi4-mini-3.8b": 3.8, "qwen2-vl-72b": 72.0, "yi-34b": 34.0,
    "deepseek-v2-lite-16b": 15.7,
}


def test_registry_complete():
    assert len(ASSIGNED_ARCHS) == 10
    assert len(ALL_ARCHS) == 15


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_counts_match_advertised(arch):
    cfg = get_config(arch)
    got = cfg.param_count() / 1e9
    want = ADVERTISED_B[arch]
    assert abs(got - want) / want < 0.25, f"{arch}: {got:.2f}B vs {want}B"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_reduction_bounds(arch):
    s = get_smoke_config(arch)
    assert s.num_layers <= 2
    assert s.d_model <= 512
    assert s.num_experts <= 4
    assert s.family == get_config(arch).family


def test_moe_active_params():
    cfg = get_config("qwen2-moe-a2.7b")
    active = cfg.param_count(active_only=True) / 1e9
    assert 2.0 < active < 3.5          # the "A2.7B" in the name


def test_long_context_applicability():
    long = INPUT_SHAPES["long_500k"]
    eligible = [a for a in ASSIGNED_ARCHS
                if shape_applicable(get_config(a), long)[0]]
    assert sorted(eligible) == ["h2o-danube-1.8b", "rwkv6-1.6b", "zamba2-7b"]
    for a in ASSIGNED_ARCHS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(get_config(a), INPUT_SHAPES[s])[0]
