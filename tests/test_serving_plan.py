"""Plan-aware serving: sited ``serve.layer{i}.*`` decode collectives, the
engines' plan surface (pinned plan hot-swap + repository tolerance-band
re-resolution), the fixed-batch engine's ragged-prompt correctness, and the
``make_engine`` factory/registry."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import ParallelPlan, extract_decode_workload, tune
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.parallel import collectives as C
from repro.serving import Request, available_engines, make_engine, make_serve_step

CFG = get_smoke_config("llama3-8b")  # 2 dense layers
MOE_CFG = get_smoke_config("olmoe-1b-7b")  # 2 MoE layers


@pytest.fixture(autouse=True)
def _clean_plan_state():
    yield
    C.install_runtime_plan({})


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def moe_params():
    return M.init_params(MOE_CFG, jax.random.PRNGKey(1))


def _prompts(n, rng_seed=0, lo=4, hi=9):
    rs = np.random.default_rng(rng_seed)
    sizes = [int(rs.integers(lo, hi)) for _ in range(n)]
    return [rs.integers(0, CFG.vocab_size, size=s).astype(np.int32) for s in sizes]


def _moe_prompt(rs, size=6):
    return rs.integers(0, MOE_CFG.vocab_size, size=size).astype(np.int32)


# ---------------------------------------------------------------------------
# serve.* site resolution precedence: exact > dotted prefix > class
# ---------------------------------------------------------------------------


def test_serve_site_precedence():
    exact = C.CollectiveRuntime("ring", 8)
    prefix = C.CollectiveRuntime("ring", 4)
    klass = C.CollectiveRuntime("chunked", 2)
    plan = {"serve.layer0.mlp.ag": exact, "serve.layer0": prefix, "ag": klass}
    with C.use_runtime_plan(plan):
        rt, src = C.explain_runtime("serve.layer0.mlp.ag", "ag")
        assert (rt, src) == (exact, "serve.layer0.mlp.ag")
        # sibling site in the same layer: falls to the layer prefix
        rt, src = C.explain_runtime("serve.layer0.mlp.rs", "rs")
        assert (rt, src) == (prefix, "serve.layer0")
        # other layer, no prefix entry: class bucket
        rt, src = C.explain_runtime("serve.layer1.mlp.ag", "ag")
        assert (rt, src) == (klass, "ag")
        # nothing matches: XLA default
        rt, src = C.explain_runtime("serve.layer1.mlp.rs", None)
        assert src == "" and rt.num_chunks == 1


# ---------------------------------------------------------------------------
# acceptance: one plan drives two decode layers to different chunk structure
# ---------------------------------------------------------------------------


def test_one_plan_two_layers_diverge_in_jaxpr(params):
    mesh = make_mesh((jax.device_count(),), ("model",))
    caches = M.init_caches(CFG, 4, 32)
    toks = jnp.zeros((4, 1), jnp.int32)

    def trace(plan):
        # a FRESH closure per trace: jax caches traces per function object,
        # and plans are consumed at trace time (the staleness hazard the
        # engines' per-digest compiled caches exist for)
        step = make_serve_step(CFG, mesh=mesh)
        if plan is None:
            return str(jax.make_jaxpr(step)(params, toks, caches))
        with C.use_runtime_plan(plan):
            return str(jax.make_jaxpr(step)(params, toks, caches))

    plan = {
        "serve.layer0.mlp.ag": C.CollectiveRuntime("ring", 2),
        "serve.layer1.mlp.ag": C.CollectiveRuntime("ring", 4),
    }
    uni = {
        "serve.layer0.mlp.ag": C.CollectiveRuntime("ring", 2),
        "serve.layer1.mlp.ag": C.CollectiveRuntime("ring", 2),
    }
    tuned, plain, uniform = trace(plan), trace(None), trace(uni)
    assert tuned != plain
    # chunked ag emits one lax.map scan per chunked matmul (2 ag per swiglu
    # layer); both tuned layers chunk, the plain trace has none
    assert tuned.count("scan[") == plain.count("scan[") + 4
    # nc=2 vs nc=4 on layer1 is visible structure, not just knob metadata
    assert tuned != uniform

    # the SAME function object re-traced under a new plan is a cache hit —
    # the documented reason engines key compiled steps on the plan digest
    step = make_serve_step(CFG, mesh=mesh)
    with C.use_runtime_plan(plan):
        first = str(jax.make_jaxpr(step)(params, toks, caches))
    stale = str(jax.make_jaxpr(step)(params, toks, caches))
    assert first == stale


# ---------------------------------------------------------------------------
# fixed-batch engine: ragged right-padded prompts decode correctly
# ---------------------------------------------------------------------------


def test_engine_ragged_prompts_match_solo_runs(params):
    short = np.asarray([7, 11, 13], np.int32)
    long = np.asarray([5, 3, 2, 19, 23, 29, 31], np.int32)
    eng = make_engine(CFG, params, mode="fixed", batch_size=2, max_seq=32)
    outs = eng.generate([short, long], max_new=6)
    solo = make_engine(CFG, params, mode="fixed", batch_size=1, max_seq=32)
    assert outs[0] == solo.generate([short], max_new=6)[0]
    assert outs[1] == solo.generate([long], max_new=6)[0]


def test_engine_equal_length_unchanged(params):
    # the pre-fix path (no padding) must be bit-identical to itself under
    # the offset machinery: offsets are all zero for equal lengths
    prompts = _prompts(2, lo=6, hi=7)
    eng = make_engine(CFG, params, mode="fixed", batch_size=2, max_seq=32)
    assert eng.generate(prompts, max_new=4) == eng.generate(prompts, max_new=4)


# ---------------------------------------------------------------------------
# hot-swap: plans scope per batch and restore on every exit path
# ---------------------------------------------------------------------------


def test_fixed_engine_plan_scoped_and_restored(params):
    plan = {
        "serve.layer0.mlp.ag": C.CollectiveRuntime("ring", 2),
        "serve.layer1.mlp.ag": C.CollectiveRuntime("ring", 4),
    }
    prompts = _prompts(4, lo=8, hi=9)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # no unchunked/fallback warnings
        base = make_engine(CFG, params, mode="fixed", batch_size=4, max_seq=32)
        want = base.generate(prompts, max_new=4)
        eng = make_engine(
            CFG, params, mode="fixed", batch_size=4, max_seq=32, plan=plan
        )
        got = eng.generate(prompts, max_new=4)
    assert got == want  # chunking is numerically identity
    assert C.active_runtime_plan() == {}  # scoped, not installed

    # exception inside the scoped region must restore the ambient plan too
    binding = eng._binding
    with pytest.raises(RuntimeError, match="boom"):
        with binding.scope(binding.current):
            assert C.active_runtime_plan() == plan
            raise RuntimeError("boom")
    assert C.active_runtime_plan() == {}


def test_continuous_engine_hot_swap_between_batches(moe_params):
    plan = {
        "serve.layer0.moe.a2a_disp": C.CollectiveRuntime("chunked", 2),
        "serve.layer1.moe.a2a_comb": C.CollectiveRuntime("chunked", 4),
    }

    def run_batch(eng, seed):
        rs = np.random.default_rng(seed)
        for i in range(3):
            eng.submit(Request(rid=i, prompt=_moe_prompt(rs), max_new=4))
        return [r.out for r in sorted(eng.run(), key=lambda r: r.rid)]

    with warnings.catch_warnings():
        warnings.simplefilter("error")
        base = make_engine(MOE_CFG, moe_params, mode="continuous", slots=2, max_seq=32)
        want1, want2 = run_batch(base, 1), run_batch(base, 2)
        eng = make_engine(
            MOE_CFG, moe_params, mode="continuous", slots=2, max_seq=32, plan=plan
        )
        got1 = run_batch(eng, 1)  # tuned batch
        eng.set_plan(None)  # hot-swap to untuned between batches
        got2 = run_batch(eng, 2)
    assert got1 == want1 and got2 == want2  # bit-identical tokens
    assert eng.plan_stats["swaps"] == 1
    assert len(eng._fns) == 2  # retraced per digest, not reused
    assert C.active_runtime_plan() == {}


# ---------------------------------------------------------------------------
# repository binding: banded resolution as the serving shape drifts
# ---------------------------------------------------------------------------


def test_engine_repo_banded_resolution(params, tmp_path):
    pp = ParallelPlan(kind="tp", tp=2)
    wl = extract_decode_workload(CFG, pp, global_batch=4, seq=32)
    tune(wl, "tpu-v5e", method="nccl", repo=str(tmp_path))
    prompts = _prompts(6, lo=8, hi=9)

    eng = make_engine(
        CFG,
        params,
        mode="fixed",
        batch_size=6,
        max_seq=32,
        repo=str(tmp_path),
        plan_parallel="tp:2",
        plan_band=0.5,
    )
    eng.generate(prompts, max_new=2)
    assert eng.plan_stats["banded"] == 1 and eng.plan_stats["miss"] == 0
    assert any(s.startswith("serve.") for s in eng._binding.current)

    exact = make_engine(
        CFG,
        params,
        mode="fixed",
        batch_size=4,
        max_seq=32,
        repo=str(tmp_path),
        plan_parallel="tp:2",
        plan_band=0.5,
    )
    exact.generate(prompts[:4], max_new=2)
    assert exact.plan_stats["exact"] == 1

    narrow = make_engine(
        CFG,
        params,
        mode="fixed",
        batch_size=6,
        max_seq=32,
        repo=str(tmp_path),
        plan_parallel="tp:2",
        plan_band=0.1,
    )
    narrow.generate(prompts, max_new=2)
    assert narrow.plan_stats["miss"] == 1
    assert narrow._binding.current is None  # miss serves untuned


def test_continuous_engine_readmits_resolve_on_shape_drift(moe_params, tmp_path):
    pp = ParallelPlan(kind="ep", ep=2)
    wl = extract_decode_workload(MOE_CFG, pp, global_batch=3, seq=32)
    tune(wl, "tpu-v5e", method="nccl", repo=str(tmp_path))
    eng = make_engine(
        MOE_CFG,
        moe_params,
        mode="continuous",
        slots=3,
        max_seq=32,
        repo=str(tmp_path),
        plan_parallel="ep:2",
        plan_band=0.5,
    )
    rs = np.random.default_rng(0)
    # 2 requests in flight first (banded: tuned shape is batch 3,
    # 3/2 - 1 = 0.5 within band), then 3 (exact)
    for rid in range(2):
        eng.submit(Request(rid=rid, prompt=_moe_prompt(rs, 5), max_new=2))
    eng.run()
    for rid in range(2, 5):
        eng.submit(Request(rid=rid, prompt=_moe_prompt(rs, 5), max_new=2))
    eng.run()
    stats = eng.plan_stats
    assert stats["banded"] >= 1 and stats["exact"] >= 1 and stats["miss"] == 0


# ---------------------------------------------------------------------------
# make_engine factory + unified Request
# ---------------------------------------------------------------------------


def test_make_engine_modes(params):
    from repro.serving.continuous import ContinuousEngine
    from repro.serving.engine import Engine

    assert available_engines() == ["continuous", "fixed"]
    e = make_engine(CFG, params, mode="fixed", batch_size=2, max_seq=32)
    assert isinstance(e, Engine)
    c = make_engine(CFG, params, mode="continuous", slots=2, max_seq=32)
    assert isinstance(c, ContinuousEngine)
    with pytest.raises(KeyError, match="unknown engine mode 'nope'"):
        make_engine(CFG, params, mode="nope")


def test_request_is_one_class():
    import repro.serving.continuous as cont
    import repro.serving.engine as eng
    from repro.serving.types import Request as R

    assert eng.Request is R and cont.Request is R and Request is R
    r = Request(rid=3, prompt=np.asarray([1, 2], np.int32), max_new=5)
    assert (r.rid, r.max_new, r.out) == (3, 5, [])
