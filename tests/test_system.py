"""End-to-end system tests: the full tune -> apply -> runtime pipeline, and
workload extraction across every assigned architecture."""
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core import (ParallelPlan, Simulator, TPU_V5E, extract_workload,
                        tuner)
from repro.core.apply import runtime_plan, to_runtime
from repro.core.baselines import nccl_defaults
from repro.core.comm_params import CommConfig


def _plan_for(cfg):
    if cfg.is_moe:
        return ParallelPlan(kind="ep", ep=16)
    return ParallelPlan(kind="fsdp", dp=16)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_extract_workload_every_arch(arch):
    cfg = get_config(arch)
    wl = extract_workload(cfg, _plan_for(cfg), seq=4096, global_batch=256,
                          layers=min(4, cfg.num_layers))
    assert len(wl.groups) > 0
    assert wl.num_comms > 0
    assert wl.meta["flops"] > 0


def test_full_pipeline_tune_apply():
    """The paper's loop on the TPU profile: extract -> tune -> runtime plan."""
    cfg = get_config("qwen2-moe-a2.7b")
    wl = extract_workload(cfg, ParallelPlan(kind="ep", ep=16), seq=4096,
                          global_batch=256, layers=4)
    sim = Simulator(TPU_V5E, noise=0.01, seed=0)
    base = sim.profile(wl, nccl_defaults(wl, TPU_V5E))
    cfgs, iters, trace = tuner.search_workload(sim, wl)
    tuned = sim.profile(wl, cfgs)
    assert tuned.Z <= base.Z * 1.02       # never materially worse
    rt = runtime_plan(wl, cfgs)
    assert "a2a" in rt
    assert rt["a2a"].num_chunks >= 1


def test_to_runtime_mapping():
    rt = to_runtime(CommConfig(algorithm="ring", chunk_kb=1024), 8 * 1024 * 1024)
    assert rt.strategy == "ring" and rt.num_chunks == 8
    rt = to_runtime(CommConfig(algorithm="tree", chunk_kb=512), 1024 * 512)
    assert rt.strategy == "chunked" and rt.num_chunks == 1


def test_mesh_import_no_device_pollution():
    """Importing launch.mesh must not initialize 512 devices."""
    import jax
    from repro.launch import mesh as mesh_mod
    assert callable(mesh_mod.make_production_mesh)
    assert jax.device_count() == 1
