"""Fault injection in the cost model: schedule parsing + round-trip,
byte-identity of the empty schedule, deterministic replay, the physics of
each fault kind (degrade / straggler / jitter / flap), and minimax-regret
robust tuning over a fault ensemble."""

import dataclasses

import pytest

from repro.configs import get_smoke_config
from repro.core import (
    ParallelPlan,
    Simulator,
    extract_workload,
    tune,
)
from repro.core.faults import (
    FaultEvent,
    FaultSchedule,
    degraded_hardware,
    parse_fault_schedule,
)
from repro.core.hardware import PROFILES
from repro.core.plan_repo import PlanRepository


def _wl(seq=64, batch=4):
    cfg = get_smoke_config("llama3-8b")
    plan = ParallelPlan(kind="fsdp", dp=8)
    return extract_workload(cfg, plan, seq=seq, global_batch=batch)


# ---------------------------------------------------------------------------
# schedule construction, parsing, serialization
# ---------------------------------------------------------------------------


def test_inline_spec_parses_and_roundtrips(tmp_path):
    sched = parse_fault_schedule(
        "seed=7;degrade,site=serve,scale=0.25,start=2;"
        "flap,period=4,duty=0.5,scale=0.5;straggler,scale=1.5,start=6,stop=9"
    )
    assert sched.seed == 7
    assert [ev.kind for ev in sched.events] == ["degrade", "flap", "straggler"]
    assert sched.events[0].site == "serve" and sched.events[0].scale == 0.25
    # JSON round-trip is exact (frozen dataclasses compare by value)
    assert FaultSchedule.from_json(sched.to_json()) == sched
    path = tmp_path / "sched.json"
    sched.save(str(path))
    assert FaultSchedule.load(str(path)) == sched
    # parse_fault_schedule: None / FaultSchedule pass through, paths load
    assert parse_fault_schedule(None) is None
    assert parse_fault_schedule(sched) is sched
    assert parse_fault_schedule(str(path)) == sched


def test_spec_and_event_validation():
    with pytest.raises(ValueError, match="fault kind"):
        parse_fault_schedule("meteor,scale=0.5")
    with pytest.raises(ValueError, match="unknown fault event field"):
        parse_fault_schedule("degrade,wat=1")
    with pytest.raises(ValueError, match="not key=value"):
        parse_fault_schedule("degrade,0.5")
    with pytest.raises(ValueError, match="empty or negative"):
        FaultEvent("degrade", start=5, stop=5)
    with pytest.raises(ValueError, match="positive multiplier"):
        FaultEvent("degrade", scale=0.0)
    with pytest.raises(ValueError, match="period > 0"):
        FaultEvent("flap")
    with pytest.raises(ValueError, match="duty"):
        FaultEvent("flap", period=4, duty=0.0)
    with pytest.raises(ValueError, match="sigma"):
        FaultEvent("jitter", sigma=-1.0)


def test_event_windows_and_site_matching():
    ev = FaultEvent("degrade", start=2, stop=5, site="serve.layer0.")
    got = [ev.active(s) for s in range(7)]
    assert got == [False, False, True, True, True, False, False]
    assert ev.site == "serve.layer0"  # trailing dot normalized away
    assert ev.matches("serve.layer0", "ag")  # exact
    assert ev.matches("serve.layer0.mlp.ag", "ag")  # dotted prefix
    assert not ev.matches("serve.layer1.mlp.ag", "ag")
    by_class = FaultEvent("degrade", site="ag")
    assert by_class.matches("anything.at.all.ag", "ag")
    assert not by_class.matches("anything.at.all.rs", "rs")
    everything = FaultEvent("degrade")
    assert everything.matches("x", "rs")


def test_flap_duty_cycle_and_state_composition():
    sched = FaultSchedule(
        events=(
            FaultEvent("flap", period=4, duty=0.5, scale=0.5, stop=8),
            FaultEvent("straggler", scale=2.0, start=1, stop=3),
            FaultEvent("jitter", sigma=0.3, start=2, stop=3),
        )
    )
    # flap: degraded for the first duty fraction of each cycle
    def comm_on(s):
        st = sched.state_at(s)
        return st is not None and bool(st.comm_events)

    on = [comm_on(s) for s in range(8)]
    assert on == [True, True, False, False, True, True, False, False]
    # composition at step 2: flap off, straggler + jitter on
    st = sched.state_at(2)
    assert st.comp_scale == 2.0 and st.sigma == 0.3 and not st.comm_events
    # quiet steps are None (the simulator's fast path)
    assert sched.state_at(3) is None and sched.state_at(100) is None


def test_degraded_hardware_physics_and_memoization():
    hw = PROFILES["tpu-v5e"]
    assert degraded_hardware(hw, 1.0) is hw
    deg = degraded_hardware(hw, 0.25)
    assert deg.link_bw == hw.link_bw * 0.25
    assert deg.chan_bw == hw.chan_bw * 0.25
    assert degraded_hardware(hw, 0.25) is deg  # memoized
    st = FaultSchedule(
        events=(FaultEvent("degrade", site="serve", scale=0.25),)
    ).state_at(0)
    assert st.hardware_for("serve.layer0.mlp.ag", "ag", hw) is deg
    assert st.hardware_for("fsdp.layer0.ag", "ag", hw) is hw  # unmatched


def test_burst_jitters_deterministic_in_seed_and_step():
    sched = FaultSchedule(events=(FaultEvent("jitter", sigma=0.3),), seed=7)
    a = sched.state_at(0).burst_jitters(3, 2)
    b = sched.state_at(0).burst_jitters(3, 2)
    assert a == b  # pure function of (seed, step)
    c = sched.state_at(1).burst_jitters(3, 2)
    assert a != c  # a different step draws a different burst
    other = FaultSchedule(events=(FaultEvent("jitter", sigma=0.3),), seed=8)
    assert other.state_at(0).burst_jitters(3, 2) != a
    calm = FaultSchedule(events=(FaultEvent("straggler", scale=2.0),))
    assert calm.state_at(0).burst_jitters(2, 2) == ([1.0, 1.0], [1.0, 1.0])


# ---------------------------------------------------------------------------
# simulator integration: empty schedule is byte-identical, replay is
# deterministic, and each kind moves the physics the right way
# ---------------------------------------------------------------------------


def test_empty_schedule_is_byte_identical_to_fault_free():
    wl = _wl()
    p0 = tune(wl, "tpu-v5e", method="nccl")
    p1 = tune(wl, "tpu-v5e", method="nccl", faults=FaultSchedule())
    p2 = tune(wl, "tpu-v5e", method="nccl", faults="")
    assert p0.configs == p1.configs == p2.configs
    assert p0.traces == p1.traces == p2.traces
    assert p0.profile_count == p1.profile_count == p2.profile_count
    assert p1.faults == {} and p2.faults == {}
    # an armed simulator with an empty schedule keeps the fault-free path
    assert Simulator(PROFILES["tpu-v5e"], faults=FaultSchedule()).faults is None


def test_faulted_tuning_is_deterministic_and_records_provenance():
    wl = _wl()
    spec = "degrade,scale=0.5"
    p0 = tune(wl, "tpu-v5e", method="nccl", faults=spec)
    p1 = tune(wl, "tpu-v5e", method="nccl", faults=spec)
    assert p0.configs == p1.configs and p0.traces == p1.traces
    sched = p0.faults["schedule"]
    assert FaultSchedule.from_dict(sched).events[0].kind == "degrade"
    # provenance survives the JSON round-trip (backward-compatible field)
    clone = type(p0).from_json(p0.to_json())
    assert clone.faults == p0.faults


def test_degrade_raises_comm_busy_time():
    wl = _wl(seq=128, batch=32)  # enough payload to leave the latency floor
    plan = tune(wl, "tpu-v5e", method="nccl")
    ok = plan.evaluate(wl)
    bad = plan.evaluate(wl, faults="degrade,scale=0.1")
    assert bad.X > ok.X * 1.2  # comm busy time rises on the degraded link
    with pytest.raises(ValueError, match="sim= carries its own"):
        plan.evaluate(wl, sim=Simulator(PROFILES["tpu-v5e"]), faults="")


def test_straggler_slows_compute():
    wl = _wl()
    plan = tune(wl, "tpu-v5e", method="nccl")
    ok = plan.evaluate(wl)
    slow = plan.evaluate(wl, faults="straggler,scale=2.0")
    # not exactly 2x: doubling compute durations reshuffles the comm
    # overlap, so the contention penalty inside Y moves too
    assert slow.Y > ok.Y * 1.5
    assert slow.Z > ok.Z


def test_jitter_burst_perturbs_measurements_reproducibly():
    wl = _wl()
    plan = tune(wl, "tpu-v5e", method="nccl")
    calm = plan.evaluate(wl)
    j0 = plan.evaluate(wl, faults="seed=1;jitter,sigma=0.3")
    j1 = plan.evaluate(wl, faults="seed=1;jitter,sigma=0.3")
    j2 = plan.evaluate(wl, faults="seed=2;jitter,sigma=0.3")
    assert j0.Z == j1.Z  # same seed -> bit-equal replay
    assert j0.Z != calm.Z and j0.Z != j2.Z


def test_windowed_fault_hits_only_scheduled_steps():
    hw = PROFILES["tpu-v5e"]
    wl = _wl(seq=128, batch=32)
    plan = tune(wl, "tpu-v5e", method="nccl")
    # the fault clock advances one step per profile: steps 0,1 healthy,
    # step 2 onward degraded
    sim = Simulator(hw, faults=parse_fault_schedule("degrade,scale=0.1,start=2"))
    z = [sim.profile(wl, plan.configs).Z for _ in range(4)]
    assert z[0] == z[1]
    assert z[2] > z[0] and z[3] == z[2]


# ---------------------------------------------------------------------------
# robust tuning: minimax regret over a fault ensemble
# ---------------------------------------------------------------------------


def test_robust_tuning_minimax_regret_provenance(tmp_path):
    wl = _wl()
    ensemble = ["degrade,scale=0.25", "straggler,scale=1.5"]
    plan = tune(
        wl, "tpu-v5e", method="nccl", fault_ensemble=ensemble, repo=str(tmp_path)
    )
    meta = plan.faults
    assert meta["robust"] is True
    assert len(meta["ensemble"]) == 2
    assert set(meta["regrets"]) == {"nominal", "robust[0]", "robust[1]"}
    assert all(r >= 0 for r in meta["regrets"].values())
    assert meta["selected"] in meta["regrets"]
    assert meta["worst_case_regret"] == meta["regrets"][meta["selected"]]
    assert meta["worst_case_regret"] == min(meta["regrets"].values())
    # total search cost spans every candidate + the scoring pass
    assert meta["total_profiles"] > plan.profile_count
    # the artifact (with its fault provenance) landed in the repository
    stored, how = PlanRepository(str(tmp_path)).resolve_explain(wl, "tpu-v5e")
    assert how == "exact" and stored.faults["robust"] is True


def test_fault_kwarg_conflicts_are_rejected():
    wl = _wl()
    ens = ["degrade,scale=0.25"]
    with pytest.raises(ValueError, match="faults|fault_ensemble"):
        tune(wl, "tpu-v5e", faults="degrade,scale=0.5", fault_ensemble=ens)
    sim = Simulator(PROFILES["tpu-v5e"])
    with pytest.raises(ValueError, match="simulator"):
        tune(wl, simulator=sim, faults="degrade,scale=0.5")
    with pytest.raises(ValueError, match="fault_ensemble|simulator"):
        tune(wl, simulator=sim, fault_ensemble=["degrade,scale=0.5"])
    with pytest.raises(ValueError, match="empty"):
        tune(wl, "tpu-v5e", fault_ensemble=[""])


def test_dataclass_replace_keeps_schedule_frozen():
    ev = FaultEvent("degrade", scale=0.5, site="serve")
    with pytest.raises(dataclasses.FrozenInstanceError):
        ev.scale = 0.25
    assert dataclasses.replace(ev, scale=0.25).scale == 0.25
