"""Hierarchical fabric subsystem (``core.topology``): pod-aware pricing in
the simulator, the ``acc.*`` / ``outer.*`` site classes in extraction, the
flat-topology byte-identity guarantee, topology provenance + refusal in
``TunedPlan``, and tier-aware runtime resolution for the new site classes.
"""

import pytest

from repro.configs import get_config
from repro.core import (
    ParallelPlan,
    PlanMismatchError,
    Simulator,
    extract_workload,
    tune,
)
from repro.core import topology as T
from repro.core.comm_params import CommConfig
from repro.core.workload import CommOp, OverlapGroup, matmul_comp
from repro.parallel import collectives as C


@pytest.fixture(autouse=True)
def _clean_plan_state():
    yield
    C.install_runtime_plan({})


def _fsdp_wl(layers=1, **plan_kw):
    cfg = get_config("llama3-8b")
    plan = ParallelPlan(kind="fsdp", dp=8, **plan_kw)
    return extract_workload(cfg, plan, seq=2048, global_batch=16, layers=layers)


def _acc_wl(layers=1, accum=2, pods=2, **kw):
    return _fsdp_wl(layers=layers, pods=pods, accum_steps=accum, **kw)


# ---------------------------------------------------------------------------
# the topology model itself
# ---------------------------------------------------------------------------


def test_topology_round_trip_and_identity(tmp_path):
    topo = T.two_pod("tpu-v5e", "dcn")
    assert not topo.is_flat
    assert topo.name == "tpu-v5e-x2-dcn"
    again = T.HierarchicalHardware.from_json(topo.to_json())
    assert again == topo
    assert again.fingerprint() == topo.fingerprint()
    # file round-trip + string resolution
    path = tmp_path / "topo.json"
    topo.save(str(path))
    assert T.resolve_topology(str(path)) == topo
    assert T.resolve_topology(topo.to_dict()) == topo
    assert T.resolve_topology(None) is None
    # a different fabric is a different identity
    assert T.two_pod("tpu-v5e", "wan").fingerprint() != topo.fingerprint()


def test_fabric_registry_and_validation():
    assert "dcn" in T.FABRICS and "wan" in T.FABRICS
    with pytest.raises(KeyError):
        T.fabric_by_name("infiniband-gossip")
    with pytest.raises(ValueError):
        T.Fabric(name="bad", link_bw=-1.0, chan_bw=1.0, launch_us=1.0)


def test_flat_collapses_to_island():
    flat = T.hierarchical("tpu-v5e", 1, "dcn")
    assert flat.is_flat and flat.fabric is None
    assert flat.name == "tpu-v5e"
    sim = Simulator(flat)
    assert sim.topology is None and sim.hw == flat.island
    # the inter tier of a real hierarchy carries the fabric's link terms on
    # the island's compute side
    topo = T.two_pod("tpu-v5e", "wan")
    inter = topo.inter_hardware
    assert inter.link_bw == T.WAN_10G.link_bw
    assert inter.peak_flops == topo.island.peak_flops
    assert topo.tier_hardware("") == topo.island
    assert topo.tier_hardware("inter") == inter


def test_site_tier_classification():
    assert T.site_tier("outer.round0.sync.frag3") == "inter"
    assert T.site_tier("acc.step1.ar_grads") == "inter"
    assert T.site_tier("acc.step1.rs_grads") == ""
    assert T.site_tier("fsdp.layer0.ag_params") == ""


# ---------------------------------------------------------------------------
# simulator: per-tier pricing, flat byte-identity
# ---------------------------------------------------------------------------


def _one_comm_group(tier):
    return OverlapGroup(
        "g",
        comps=[matmul_comp("mm", 4096, 2560, 10240)],
        comms=[CommOp("ar.g", "allreduce", 64e6, 2, site="s.ar", tier=tier)],
    )


def test_inter_tier_prices_on_fabric():
    sim = Simulator(T.two_pod("tpu-v5e", "wan"))
    intra = sim.run_group(_one_comm_group(""), [CommConfig()])
    inter = sim.run_group(_one_comm_group("inter"), [CommConfig()])
    # same payload, same config: the cross-pod op pays the slow fabric
    assert inter.comm_times[0] > 2 * intra.comm_times[0]


def test_flat_topology_tune_is_byte_identical():
    wl = _fsdp_wl(layers=1)
    hw = T.flat("tpu-v5e").island
    p_hw = tune(wl, hw)
    p_flat = tune(wl, topology=T.flat("tpu-v5e"))
    # configs, traces, profile_count, provenance — the whole artifact
    assert p_flat.to_json() == p_hw.to_json()
    assert p_flat.profile_count == p_hw.profile_count
    assert p_flat.topology == {}
    # and the raw oracle agrees measurement-by-measurement
    g = _one_comm_group("")
    m1 = Simulator(hw).run_group(g, [CommConfig()])
    m2 = Simulator(T.flat("tpu-v5e")).run_group(g, [CommConfig()])
    assert (m1.Z, m1.X, m1.Y, m1.comm_times, m1.comp_times) == (
        m2.Z,
        m2.X,
        m2.Y,
        m2.comm_times,
        m2.comp_times,
    )


# ---------------------------------------------------------------------------
# extraction: acc.* / outer.* site classes
# ---------------------------------------------------------------------------


def test_extract_accumulation_sites():
    wl = _acc_wl(accum=2, pods=2)
    acc = [g for g in wl.groups if g.name.startswith("acc.step")]
    assert [g.name for g in acc] == ["acc.step0", "acc.step1"]
    sites = [c.site_id for c in acc[0].comms]
    assert sites == ["acc.step0.rs_grads", "acc.step0.ar_grads"]
    tiers = [c.tier for c in acc[0].comms]
    assert tiers == ["", "inter"]  # dp reduce pod-local, pods inter
    # step k's reduce overlaps microbatch k+1's compute; the last step has
    # nothing left to hide under
    assert len(acc[0].comps) == 1 and acc[1].comps == []
    # per-layer grad reduce-scatter moves into the acc groups wholesale
    assert not any(
        c.site_id.endswith(".rs_grads")
        for g in wl.groups
        if not g.name.startswith("acc.")
        for c in g.comms
    )
    assert wl.meta["accum_steps"] == 2.0 and wl.meta["pods"] == 2.0


def test_extract_outer_sync_sites():
    wl = _fsdp_wl(pods=2, outer_frags=4, outer_rounds=2)
    outer = [g for g in wl.groups if g.name.startswith("outer.round")]
    assert [g.name for g in outer] == ["outer.round0", "outer.round1"]
    assert [c.site_id for c in outer[0].comms] == [
        f"outer.round0.sync.frag{f}" for f in range(4)
    ]
    assert all(c.tier == "inter" and c.group_size == 2 for g in outer for c in g.comms)
    # a single pod has no cross-pod sync to stream
    assert not any(
        g.name.startswith("outer.") for g in _fsdp_wl(pods=1, outer_frags=4).groups
    )


def test_tier_joins_fingerprint():
    from repro.core.session import workload_fingerprint

    flat_wl = _fsdp_wl(layers=1)
    assert workload_fingerprint(_acc_wl()) != workload_fingerprint(flat_wl)
    assert workload_fingerprint(_acc_wl(pods=2)) != workload_fingerprint(
        _acc_wl(pods=4)
    )


# ---------------------------------------------------------------------------
# tune(topology=): provenance, refusal, the overlap the plan buys
# ---------------------------------------------------------------------------


def test_topology_plan_provenance_and_refusal():
    topo = T.two_pod()
    wl = _acc_wl()
    plan = tune(wl, topology=topo, method="nccl")
    assert plan.hardware == "tpu-v5e-x2-dcn"
    assert plan.topology["fingerprint"] == topo.fingerprint()
    # refusals: flat evaluation of a cross-pod plan, and vice versa
    with pytest.raises(PlanMismatchError):
        plan.check_topology(None)
    with pytest.raises(PlanMismatchError):
        plan.check_topology(T.two_pod("tpu-v5e", "wan"))
    plan.check_topology(topo)  # the tuned fabric passes
    flat_plan = tune(wl, "tpu-v5e", method="nccl")
    with pytest.raises(PlanMismatchError):
        flat_plan.check_topology(topo)
    flat_plan.check_topology(None)


def test_topology_plan_round_trips_and_evaluates():
    from repro.core.session import TunedPlan

    topo = T.two_pod()
    wl = _acc_wl()
    plan = tune(wl, topology=topo, method="nccl")
    again = TunedPlan.from_json(plan.to_json())
    assert again.topology == plan.topology
    assert again.artifact_digest() == plan.artifact_digest()
    # evaluate rebuilds the hierarchical simulator from the embedded spec
    m = again.evaluate(wl)
    assert m.Z > 0 and len(m.groups) == len(wl.groups)


def test_cross_pod_tune_hides_grad_reduce():
    """The acceptance scenario: a 2-pod accumulation tune yields distinct
    cross-pod CommConfigs and demonstrably hides the grad reduce under the
    next microbatch's compute in the simulator trace."""
    topo = T.two_pod()
    wl = _acc_wl(accum=2)
    plan = tune(wl, topology=topo)
    site_of = {(s["group"], s["comm"]): s.get("site") or s["name"] for s in plan.sites}
    cfg_by_site = {site_of[k]: v for k, v in plan.configs.items()}
    assert "acc.step0.ar_grads" in cfg_by_site
    intra = next(v for s, v in cfg_by_site.items() if s.startswith("fsdp."))
    assert cfg_by_site["acc.step0.ar_grads"] != intra
    m = plan.evaluate(wl)
    acc0 = next(g for g in m.groups if g.name == "acc.step0")
    # busy-window overlap: comm busy + comp busy exceed the makespan only
    # if some of the reduce ran under the compute
    hidden = acc0.X + acc0.Y - acc0.Z
    assert hidden > 0.05 * acc0.X


# ---------------------------------------------------------------------------
# runtime resolution for the new site classes
# ---------------------------------------------------------------------------


def test_resolve_runtime_reports_matched_tier():
    rt_exact = C.CollectiveRuntime("ring", 8)
    rt_acc = C.CollectiveRuntime("chunked", 4)
    rt_class = C.CollectiveRuntime("chunked", 2)
    plan = {"acc.step0.ar_grads": rt_exact, "acc": rt_acc, "rs": rt_class}
    with C.use_runtime_plan(plan):
        assert C.resolve_runtime("acc.step0.ar_grads") == (
            rt_exact,
            "acc.step0.ar_grads",
            "exact",
        )
        assert C.resolve_runtime("acc.step1.rs_grads") == (rt_acc, "acc", "prefix")
        assert C.resolve_runtime("zz.site", "rs") == (rt_class, "rs", "class")
        assert C.resolve_runtime("zz.site")[1:] == ("", "default")


def test_runtime_table_does_not_bleed_acc_into_name_class():
    """An ``acc.step0.rs_grads`` site whose comm is *named* ``rs.grads.s0``
    must not claim the per-layer ``rs`` class bucket — the audit table
    reports it at the ``default`` tier when no acc entry exists."""
    from repro.launch.plan import runtime_table

    plan = tune(_acc_wl(), topology=T.two_pod(), method="nccl")
    C.install_runtime_plan({"rs": C.CollectiveRuntime("chunked", 7)})
    rows = {r[0]: r for r in runtime_table(plan)}
    sid, strategy, chunks, src, how, health = rows["acc.step0.rs_grads"]
    assert (how, src) == ("default", "<default>")
    # with the plan's own knobs installed every site resolves exactly
    plan.runtime_plan()
    from repro.core.apply import activate

    activate(plan)
    rows = {r[0]: r for r in runtime_table(plan)}
    assert all(r[4] == "exact" for r in rows.values())
    assert rows["acc.step0.rs_grads"][5] == "ok"
