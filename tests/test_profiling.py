"""Batched profiling engine: batched measurements must equal the sequential
event loop EXACTLY (``==``, not approx) in noise-free mode, reproduce the
identical RNG stream in noisy mode, and cache hits must never change what
the tuners decide."""
import numpy as np
import pytest

from repro.core import (A40_NVLINK, A40_PCIE, TPU_V5E, CommConfig,
                        ParallelPlan, Simulator, extract_workload)
from repro.core import autoccl, contention, tuner
from repro.core.profiling import ProfileCache, group_fingerprint
from repro.core.workload import CommOp, OverlapGroup, matmul_comp

HWS = (A40_NVLINK, A40_PCIE, TPU_V5E)
KINDS = ("allgather", "allreduce", "reducescatter", "alltoall", "permute")


def _rand_cfg(rng):
    return CommConfig(
        algorithm=("ring", "tree", "bidir")[int(rng.integers(0, 3))],
        protocol=("latency", "mixed", "bulk")[int(rng.integers(0, 3))],
        transport=("p2p", "shm", "net")[int(rng.integers(0, 3))],
        nc=int(rng.integers(1, 64)), nt=int(rng.integers(64, 640)),
        chunk_kb=int(rng.integers(32, 8192)))


def _rand_group(rng, max_comps=5, max_comms=4):
    M = int(rng.integers(0, max_comps))
    N = int(rng.integers(0, max_comms))
    return OverlapGroup(
        "g",
        comps=[matmul_comp(f"m{i}", int(rng.integers(64, 4096)), 512,
                           int(rng.integers(64, 4096))) for i in range(M)],
        comms=[CommOp(f"c{i}", KINDS[int(rng.integers(0, len(KINDS)))],
                      float(rng.uniform(1e5, 1e9)), int(rng.integers(2, 64)))
               for i in range(N)])


def _same(a, b):
    return (a.Z == b.Z and a.X == b.X and a.Y == b.Y
            and list(a.comm_times) == list(b.comm_times)
            and list(a.comp_times) == list(b.comp_times))


def test_batched_equals_sequential_exact():
    rng = np.random.default_rng(0)
    for trial in range(60):
        hw = HWS[trial % 3]
        g = _rand_group(rng)
        lists = [[_rand_cfg(rng) for _ in g.comms]
                 for _ in range(int(rng.integers(1, 6)))]
        sim = Simulator(hw)
        seq = [sim.run_group(g, cl) for cl in lists]
        bat = sim.engine.measure_many(g, lists)
        assert all(_same(s, b) for s, b in zip(seq, bat))


def test_lockstep_large_batch_equals_sequential_exact():
    rng = np.random.default_rng(1)
    g = OverlapGroup(
        "g", comps=[matmul_comp(f"m{i}", 1024, 512, 2048) for i in range(3)],
        comms=[CommOp(f"c{i}", "allgather", 3e7, 8) for i in range(2)])
    lists = [[_rand_cfg(rng) for _ in g.comms] for _ in range(120)]
    sim = Simulator(A40_NVLINK)
    assert len(lists) >= sim.engine._VECTOR_MIN
    seq = [sim.run_group(g, cl) for cl in lists]
    bat = sim.engine.measure_many(g, lists)
    assert all(_same(s, b) for s, b in zip(seq, bat))


def test_noisy_mode_reproduces_sequential_rng_stream():
    rng = np.random.default_rng(2)
    for trial in range(20):
        g = _rand_group(rng, max_comps=4, max_comms=3)
        lists = [[_rand_cfg(rng) for _ in g.comms] for _ in range(3)]
        s_seq = Simulator(A40_NVLINK, noise=0.02, seed=trial, batched=False)
        s_bat = Simulator(A40_NVLINK, noise=0.02, seed=trial)
        seq = [s_seq.profile_group(g, cl) for cl in lists]
        bat = s_bat.profile_many(g, lists)
        assert all(_same(s, b) for s, b in zip(seq, bat))
        assert s_seq.profile_count == s_bat.profile_count == 3


def test_noisy_lockstep_large_batch_reproduces_rng_stream():
    """The lock-step array path must consume the RNG candidate-by-candidate
    exactly like a sequence of run_group calls (big noisy batch)."""
    rng = np.random.default_rng(5)
    g = OverlapGroup(
        "g", comps=[matmul_comp(f"m{i}", 1024, 512, 2048) for i in range(3)],
        comms=[CommOp(f"c{i}", "allgather", 3e7, 8) for i in range(2)])
    lists = [[_rand_cfg(rng) for _ in g.comms] for _ in range(110)]
    s_seq = Simulator(A40_NVLINK, noise=0.02, seed=9, batched=False)
    s_bat = Simulator(A40_NVLINK, noise=0.02, seed=9)
    assert len(lists) >= s_bat.engine._VECTOR_MIN
    seq = [s_seq.profile_group(g, cl) for cl in lists]
    bat = s_bat.profile_many(g, lists)
    assert all(_same(s, b) for s, b in zip(seq, bat))


def test_vectorized_contention_kernels_match_scalar():
    rng = np.random.default_rng(3)
    op = CommOp("c", "allreduce", 5e7, 16)
    comp = matmul_comp("m", 2048, 1024, 4096)
    for hw in HWS:
        for _ in range(50):
            cfg = _rand_cfg(rng)
            ceil_, cmult = contention.PROTO_PARAMS[cfg.protocol]
            tmult = contention.TRANSPORT_MULT[cfg.transport]
            wb = contention.wire_bytes(op, cfg.algorithm)
            ns = contention.comm_steps(op, cfg.algorithm)
            for active in (False, True):
                got = contention.comm_time_v(
                    op.bytes, wb, ns, cfg.nc, cfg.nt, cfg.chunk_kb,
                    ceil_, cmult, tmult, hw, compute_active=active)
                want = contention.comm_time(op, cfg, hw, compute_active=active)
                assert float(got) == want
            V = contention.comm_bandwidth_draw(cfg, hw)
            assert float(contention.comm_bandwidth_draw_v(
                cfg.nc, cfg.chunk_kb, ceil_, tmult, hw)) == V
            lam = hw.num_slots
            theta_base = (comp.flops / comp.threadblocks * comp.tb_per_slot
                          * lam / hw.achieved_flops)
            got = contention.comp_time_v(
                theta_base, comp.threadblocks, comp.tb_per_slot,
                comp.bytes_per_tb, cfg.nc, cfg.chunk_kb, V, hw)
            assert float(got) == contention.comp_time(comp, cfg, hw)
            got0 = contention.comp_time_v(
                theta_base, comp.threadblocks, comp.tb_per_slot,
                comp.bytes_per_tb, 0, 0, 0.0, hw)
            assert float(got0) == contention.comp_time_alone(comp, hw)


def _small_workload(layers=3):
    from repro.configs import get_config
    return extract_workload(get_config("phi2-2b"),
                            ParallelPlan(kind="fsdp", dp=8),
                            seq=2048, global_batch=16, layers=layers)


@pytest.mark.parametrize("noise", [0.0, 0.01])
def test_tuner_trajectory_identical_batched_vs_sequential(noise):
    wl = _small_workload()
    s_seq = Simulator(A40_NVLINK, noise=noise, seed=0, batched=False)
    s_bat = Simulator(A40_NVLINK, noise=noise, seed=0)
    c1, i1, t1 = tuner.search_workload(s_seq, wl)
    c2, i2, t2 = tuner.search_workload(s_bat, wl)
    assert c1 == c2
    assert i1 == i2
    assert len(t1) == len(t2)
    assert all(a["Z"] == b["Z"] and a["cfg"] == b["cfg"]
               for a, b in zip(t1, t2))


def test_autoccl_identical_batched_vs_sequential():
    wl = _small_workload(layers=2)
    a1 = autoccl.search_workload(Simulator(A40_NVLINK, noise=0.01, seed=1,
                                         batched=False), wl)
    a2 = autoccl.search_workload(Simulator(A40_NVLINK, noise=0.01, seed=1), wl)
    assert a1 == a2


def test_cache_hits_do_not_change_tuned_configs():
    wl = _small_workload()
    sim = Simulator(A40_NVLINK, seed=0)
    c1, i1, _ = tuner.search_workload(sim, wl)
    hits_before = sim.engine.cache.hits
    c2, i2, _ = tuner.search_workload(sim, wl)       # fully warm cache
    assert c1 == c2
    assert i1 == i2                                # logical count unchanged
    assert sim.engine.cache.hits > hits_before


def test_structural_sharing_across_identical_layers():
    """A stack of structurally identical groups shares one search: the
    deterministic scheduler classes groups by structural fingerprint and
    walks each class's trajectory ONCE, so the engine's physical activity
    (cache hits + misses) stays far below the logical ``profile_count``
    (which still accounts every layer, like the serial walk's cache hits
    did)."""
    wl = _small_workload(layers=6)
    g0, g1 = wl.groups[0], wl.groups[1]
    assert g0.name != g1.name
    assert group_fingerprint(g0) == group_fingerprint(g1)
    sim = Simulator(A40_NVLINK, seed=0)
    cfgs, iters, _ = tuner.search_workload(sim, wl)
    eng = sim.engine
    physical = eng.cache.hits + eng.cache.misses + eng.dedup_shared
    assert physical < sim.profile_count    # shared trajectories: logical >
    assert iters == sim.profile_count      # ...but accounting is unchanged
    # the serial walk reuses through the measurement cache instead
    sim2 = Simulator(A40_NVLINK, seed=0)
    c2, i2, _ = tuner.search_workload(sim2, wl, mode="serial")
    assert sim2.engine.cache.hits > sim2.engine.cache.misses
    assert (c2, i2) == (cfgs, iters)
    n0 = len(wl.groups[0].comms)
    assert all(cfgs[(0, ci)] == cfgs[(1, ci)] for ci in range(n0))


def test_cache_key_ignores_done_flag():
    g = OverlapGroup("g", comps=[matmul_comp("m", 1024, 512, 2048)],
                     comms=[CommOp("c", "allgather", 3e7, 8)])
    sim = Simulator(A40_NVLINK)
    cfg = CommConfig(nc=4, chunk_kb=512)
    m1 = sim.profile_group(g, [cfg])
    misses = sim.engine.cache.misses
    m2 = sim.profile_group(g, [cfg.with_(done=True)])
    assert sim.engine.cache.misses == misses       # hit despite done=True
    assert _same(m1, m2)


def test_noisy_mode_bypasses_measurement_cache():
    g = OverlapGroup("g", comps=[matmul_comp("m", 1024, 512, 2048)],
                     comms=[CommOp("c", "allgather", 3e7, 8)])
    sim = Simulator(A40_NVLINK, noise=0.05, seed=0)
    cfg = CommConfig(nc=4, chunk_kb=512)
    m1 = sim.profile_group(g, [cfg])
    m2 = sim.profile_group(g, [cfg])
    assert len(sim.engine.cache) == 0              # never filled
    assert m1.Z != m2.Z                            # fresh jitter draw


def test_gather_stores_compact_under_eviction_churn():
    """The append-only gather stores must not defeat ``cache_size``'s
    memory bound: once eviction churn grows them past twice the column
    cache bound they compact from the live cache at the next engine call,
    and measurements stay exact across the id remap."""
    g = OverlapGroup("g", comps=[matmul_comp("m", 1024, 512, 2048)],
                     comms=[CommOp("c", "allgather", 3e7, 8)])
    sim = Simulator(A40_NVLINK, cache_size=8)
    cfgs = [CommConfig(nc=1 + i % 30, chunk_kb=64 + 8 * (i // 30 + i % 30))
            for i in range(60)]
    first = [sim.profile_group(g, [c]) for c in cfgs]
    # churn pushed ~60 distinct columns through an 8-entry LRU; the stores
    # stay within 2x the cache bound (+1 sentinel, +1 in-call append)
    assert sim.engine._act.n <= 2 * 8 + 2
    again = [sim.profile_group(g, [c]) for c in cfgs]
    assert all(_same(a, b) for a, b in zip(first, again))
    # lock-step gathers stay exact right after a compaction remap
    bat = sim.engine.measure_many(g, [[c] for c in cfgs] * 2)
    ref = Simulator(A40_NVLINK, batched=False)
    assert all(_same(ref.run_group(g, cl), m)
               for cl, m in zip([[c] for c in cfgs] * 2, bat))


def test_lru_eviction_keeps_results_exact():
    rng = np.random.default_rng(4)
    g = OverlapGroup("g", comps=[matmul_comp("m", 1024, 512, 2048)],
                     comms=[CommOp("c", "allgather", 3e7, 8)])
    sim = Simulator(A40_NVLINK, cache_size=8)
    cfgs = [_rand_cfg(rng) for _ in range(30)]
    first = [sim.profile_group(g, [c]) for c in cfgs]
    assert len(sim.engine.cache) <= 8
    again = [sim.profile_group(g, [c]) for c in cfgs]
    assert all(_same(a, b) for a, b in zip(first, again))


def test_profile_cache_lru_order():
    c = ProfileCache(maxsize=2)
    c.put(("a",), 1)
    c.put(("b",), 2)
    assert c.get(("a",)) == 1                      # refreshes "a"
    c.put(("c",), 3)                               # evicts "b", not "a"
    assert c.get(("b",)) is None
    assert c.get(("a",)) == 1
    assert c.get(("c",)) == 3


def test_profile_many_counts_logical_invocations():
    g = OverlapGroup("g", comps=[matmul_comp("m", 1024, 512, 2048)],
                     comms=[CommOp("c", "allgather", 3e7, 8)])
    sim = Simulator(A40_NVLINK)
    lists = [[CommConfig(nc=n, chunk_kb=512)] for n in (1, 2, 4, 2, 1)]
    sim.profile_many(g, lists)
    assert sim.profile_count == 5                  # hits count as invocations
    sim.profile_many(g, lists)
    assert sim.profile_count == 10
