"""The online re-tuning loop: the SiteTelemetry ring buffer, contention-
model inversion (calibration), drift-scoped warm re-search (an order of
magnitude fewer profiles than a cold tune under the same degradation),
lineage provenance, RetuneService rate limiting, the set_plan flag-state
reset, and the end-to-end drill — a mid-serve link degradation must be
detected, warm re-tuned (scoped to the drifted groups), published with
lineage and hot-swapped while generation completes with zero dropped
tokens."""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (
    ParallelPlan,
    PlanRepository,
    extract_decode_workload,
    retune,
    tune,
)
from repro.core import contention
from repro.core.faults import FaultEvent, FaultSchedule, degraded_hardware
from repro.core.hardware import PROFILES
from repro.core.retune import (
    RetuneService,
    _calibrate_scale,
    calibrate_sites,
    retune_plan,
)
from repro.core.session import PlanMismatchError
from repro.models import model as M
from repro.parallel import collectives as C
from repro.serving import SiteTelemetry, make_engine
from repro.serving.plans import PlanBinding

CFG = get_smoke_config("llama3-8b")  # 2 dense layers
HW = PROFILES["tpu-v5e"]
PP = ParallelPlan(kind="tp", tp=2)

# every serve.layer0.* site degraded to 10% bandwidth from batch 2 on —
# the same mid-serve drill test_serving_health runs, but layer-scoped so
# the re-tune must touch groups {0, 1} and leave layer 1 alone
DEGRADE_L0_AT_2 = FaultSchedule(
    events=(FaultEvent("degrade", site="serve.layer0", scale=0.1, start=2),)
)


@pytest.fixture(autouse=True)
def _clean_plan_state():
    yield
    C.install_runtime_plan({})


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def wl():
    return extract_decode_workload(CFG, PP, global_batch=32, seq=128)


@pytest.fixture(scope="module")
def lagom_plan(wl):
    return tune(wl, "tpu-v5e", method="lagom")


def _prompts(n, size=8):
    rs = np.random.default_rng(0)
    return [
        rs.integers(0, CFG.vocab_size, size=size).astype(np.int32) for _ in range(n)
    ]


def _layer0_sites(wl):
    return sorted(
        op.site_id
        for g in wl.groups
        for op in g.comms
        if op.site_id.startswith("serve.layer0")
    )


def _degraded_costs(plan, wl, sites, scale):
    """What telemetry would observe for ``sites`` on a fabric running at
    ``scale`` bandwidth under the plan's installed configs."""
    deg = degraded_hardware(HW, scale)
    out = {}
    for gi, g in enumerate(wl.groups):
        for ci, op in enumerate(g.comms):
            if op.site_id in sites:
                out[op.site_id] = contention.comm_time(
                    op, plan.configs[(gi, ci)], deg, compute_active=False
                )
    return out


# ---------------------------------------------------------------------------
# SiteTelemetry: the bounded evidence ring
# ---------------------------------------------------------------------------


def test_telemetry_ring_evicts_oldest():
    tel = SiteTelemetry(capacity=3)
    for b in range(5):
        tel.record(b, {"a": float(b)}, step_s=0.01 * b)
    assert len(tel) == 3
    assert [r["batch"] for r in tel.rows()] == [2, 3, 4]
    assert tel.latest() == {"a": 4.0}
    tel.clear()
    assert len(tel) == 0 and tel.latest() == {}


def test_telemetry_latest_skips_costless_rows():
    tel = SiteTelemetry()
    tel.record(0, {"a": 1.5})
    tel.record(1, {})  # a batch served with health not yet armed
    assert tel.latest() == {"a": 1.5}


def test_telemetry_mean_windows_and_partial_sites():
    tel = SiteTelemetry()
    tel.record(0, {"a": 100.0})  # outside window=2
    tel.record(1, {"a": 1.0, "b": 3.0})
    tel.record(2, {"a": 3.0})  # b missing: averages over rows carrying it
    m = tel.mean(window=2)
    assert m["a"] == pytest.approx(2.0)
    assert m["b"] == pytest.approx(3.0)
    with pytest.raises(ValueError, match="window"):
        tel.mean(window=0)
    with pytest.raises(ValueError, match="capacity"):
        SiteTelemetry(capacity=0)


def test_telemetry_rows_are_copies():
    tel = SiteTelemetry()
    costs = {"a": 1.0}
    tel.record(0, costs)
    costs["a"] = 9.0  # caller mutation must not reach the buffer
    assert tel.latest() == {"a": 1.0}
    tel.rows()[0]["costs"]["a"] = 9.0
    assert tel.latest() == {"a": 1.0}


# ---------------------------------------------------------------------------
# calibration: inverting the contention model
# ---------------------------------------------------------------------------


def test_calibrate_scale_recovers_planted_degradation(wl, lagom_plan):
    g = wl.groups[0]
    op = g.comms[0]
    cfg = lagom_plan.configs[(0, 0)]
    for planted in (0.5, 0.1, 0.02):
        observed = contention.comm_time(
            op, cfg, degraded_hardware(HW, planted), compute_active=False
        )
        scale, predicted = _calibrate_scale(op, cfg, HW, observed)
        assert scale == pytest.approx(planted, rel=1e-3)
        assert predicted == pytest.approx(
            contention.comm_time(op, cfg, HW, compute_active=False)
        )


def test_calibrate_scale_healthy_and_clamped(wl, lagom_plan):
    op = wl.groups[0].comms[0]
    cfg = lagom_plan.configs[(0, 0)]
    healthy = contention.comm_time(op, cfg, HW, compute_active=False)
    assert _calibrate_scale(op, cfg, HW, healthy * 0.5)[0] == 1.0
    # an observation beyond what any modeled fabric could produce clamps
    worst = contention.comm_time(
        op, cfg, degraded_hardware(HW, 1e-3), compute_active=False
    )
    assert _calibrate_scale(op, cfg, HW, worst * 10)[0] == 1e-3


def test_calibrate_sites_schedule_and_rows(wl, lagom_plan):
    sites = _layer0_sites(wl)
    observed = _degraded_costs(lagom_plan, wl, sites, 0.1)
    cal, sched = calibrate_sites(lagom_plan, wl, observed, sites, HW)
    assert sorted(cal) == sites
    for sid in sites:
        assert cal[sid]["scale"] == pytest.approx(0.1, rel=1e-3)
        assert cal[sid]["observed"] > cal[sid]["predicted"]
    assert sched is not None and len(sched.events) == len(sites)
    assert all(ev.kind == "degrade" and ev.start == 0 for ev in sched.events)
    # a healthy observation calibrates to scale 1.0 and emits no event
    healthy_obs = _degraded_costs(lagom_plan, wl, sites, 1.0)
    cal2, sched2 = calibrate_sites(lagom_plan, wl, healthy_obs, sites, HW)
    assert sched2 is None
    assert all(row["scale"] == 1.0 for row in cal2.values())
    # sites without evidence are skipped, unknown sites refused
    cal3, _ = calibrate_sites(lagom_plan, wl, {}, sites, HW)
    assert cal3 == {}
    with pytest.raises(ValueError, match="unknown drift site"):
        calibrate_sites(lagom_plan, wl, observed, ["serve.ghost"], HW)


# ---------------------------------------------------------------------------
# drift-scoped warm re-tune: scope, cost, quality, lineage
# ---------------------------------------------------------------------------


def test_retune_scopes_to_drifted_groups(wl, lagom_plan):
    sites = _layer0_sites(wl)
    observed = _degraded_costs(lagom_plan, wl, sites, 0.1)
    child = retune_plan(lagom_plan, wl, sites=sites, telemetry=observed)
    assert child.lineage["groups"] == [0, 1]  # layer 0's attn + mlp groups
    assert child.lineage["sites"] == sites
    # untouched groups keep the parent's configs verbatim
    for (gi, ci), cfg in lagom_plan.configs.items():
        if gi not in (0, 1):
            assert child.configs[(gi, ci)] == cfg
    # the drifted groups actually moved off the healthy-fabric optimum
    assert any(
        child.configs[(gi, ci)] != lagom_plan.configs[(gi, ci)]
        for gi in (0, 1)
        for ci in range(len(wl.groups[gi].comms))
    )
    # the calibration schedule rides along as provenance
    sched = FaultSchedule.from_dict(child.faults["calibrated"])
    assert {ev.site for ev in sched.events} == set(sites)


def test_retune_profiles_under_quarter_of_cold_tune(wl, lagom_plan):
    """The acceptance bar: a scoped warm re-tune must cost < 25% of the
    ProfileTime calls a cold full tune needs on the same degraded fabric,
    while landing on a plan of the same quality."""
    sites = _layer0_sites(wl)
    observed = _degraded_costs(lagom_plan, wl, sites, 0.1)
    child = retune_plan(lagom_plan, wl, sites=sites, telemetry=observed)
    sched = FaultSchedule.from_dict(child.faults["calibrated"])
    cold = tune(wl, "tpu-v5e", method="lagom", faults=sched)
    assert child.profile_count > 0
    assert child.profile_count < 0.25 * cold.profile_count
    # same-quality check: price both plans' layer-0 groups on the
    # calibrated (degraded) simulator — warm must be within 10% of cold
    from repro.core.simulator import Simulator

    sim = Simulator(HW, faults=sched)
    for gi in (0, 1):
        g = wl.groups[gi]
        warm_z = sim.profile_group(
            g, [child.configs[(gi, ci)] for ci in range(len(g.comms))]
        ).Z
        cold_z = sim.profile_group(
            g, [cold.configs[(gi, ci)] for ci in range(len(g.comms))]
        ).Z
        assert warm_z <= cold_z * 1.10


def test_retune_lineage_chain_and_repo_publish(tmp_path, wl, lagom_plan):
    repo = PlanRepository(tmp_path)
    repo.put(lagom_plan)
    sites = _layer0_sites(wl)
    observed = _degraded_costs(lagom_plan, wl, sites, 0.1)
    child = retune(lagom_plan, wl, sites=sites, telemetry=observed, repo=repo)
    assert child.lineage["retuned_from"] == lagom_plan.artifact_digest()
    assert child.lineage["generation"] == 1
    assert child.lineage["chain"] == [lagom_plan.artifact_digest()]
    # the repo entry advanced in place: same key, child content
    stored = repo.get(lagom_plan.fingerprint, "tpu-v5e")
    assert stored.artifact_digest() == child.artifact_digest()
    # grandchild: chain grows newest-parent-first
    observed2 = _degraded_costs(child, wl, sites, 0.05)
    grand = retune(child, wl, sites=sites, telemetry=observed2, repo=repo)
    assert grand.lineage["generation"] == 2
    assert grand.lineage["chain"] == [
        child.artifact_digest(),
        lagom_plan.artifact_digest(),
    ]
    assert repo.retune_chain(lagom_plan.fingerprint, "tpu-v5e") == [
        grand.artifact_digest(),
        child.artifact_digest(),
        lagom_plan.artifact_digest(),
    ]


def test_retune_refuses_mismatched_workload_and_unknown_sites(wl, lagom_plan):
    other = extract_decode_workload(CFG, PP, global_batch=4, seq=32)
    with pytest.raises(PlanMismatchError):
        retune(lagom_plan, other)
    with pytest.raises(ValueError, match="unknown drift site"):
        retune(lagom_plan, wl, sites=["serve.ghost.ar"])


def test_retune_accepts_telemetry_buffer(wl, lagom_plan):
    sites = _layer0_sites(wl)
    tel = SiteTelemetry()
    tel.record(7, _degraded_costs(lagom_plan, wl, sites, 0.1))
    child = retune(lagom_plan, wl, sites=sites, telemetry=tel)
    for sid in sites:
        assert child.lineage["calibration"][sid]["scale"] == pytest.approx(
            0.1, rel=1e-3
        )


# ---------------------------------------------------------------------------
# RetuneService: rate limits, declines, report
# ---------------------------------------------------------------------------


def _bound_binding(plan):
    b = PlanBinding(CFG, plan=plan, parallel="tp:2", max_seq=128)
    b.last_batch = 32
    return b


def test_service_declines_without_plan_or_sites(lagom_plan):
    svc = RetuneService(PlanBinding(CFG))
    assert svc.handle(["serve.layer0.attn.ar"]) is None  # unbound binding
    svc2 = RetuneService(_bound_binding(lagom_plan))
    assert svc2.handle([]) is None
    assert svc2.history == []  # empty site list isn't even logged


def test_service_budget_and_interval(wl, lagom_plan):
    b = _bound_binding(lagom_plan)
    sites = _layer0_sites(wl)
    b.telemetry.record(0, _degraded_costs(lagom_plan, wl, sites, 0.1))
    svc = RetuneService(b, max_retunes=1, interval=4)
    assert svc.handle(sites) is not None
    assert svc.retunes == 1
    # budget of 1 is spent: the next flag declines and logs why
    assert svc.handle(sites) is None
    assert svc.history[-1]["event"] == "retune_skipped"
    assert "budget" in svc.history[-1]["reason"]
    # interval declines come before the budget is consulted a second time
    b2 = _bound_binding(lagom_plan)
    b2.telemetry.record(0, _degraded_costs(lagom_plan, wl, sites, 0.1))
    svc2 = RetuneService(b2, max_retunes=8, interval=1000)
    assert svc2.handle(sites) is not None
    assert svc2.handle(sites) is None
    assert "interval" in svc2.history[-1]["reason"]
    with pytest.raises(ValueError, match="interval"):
        RetuneService(b, interval=0)
    with pytest.raises(ValueError, match="max_retunes"):
        RetuneService(b, max_retunes=0)


def test_service_drift_threshold_floor(wl, lagom_plan):
    from repro.serving.health import HealthMonitor

    b = _bound_binding(lagom_plan)
    sites = _layer0_sites(wl)
    mon = HealthMonitor({s: 1.0 for s in sites}, tolerance=0.25, window=1)
    mon.observe(0, {s: 1.5 for s in sites})  # 50% drift
    b.attach_health(mon, None)
    svc = RetuneService(b, drift_threshold=2.0)
    assert svc.handle(sites) is None
    assert "below threshold" in svc.history[-1]["reason"]
    assert "declined" in svc.report()


def test_service_report_lines(wl, lagom_plan):
    b = _bound_binding(lagom_plan)
    sites = _layer0_sites(wl)
    b.telemetry.record(0, _degraded_costs(lagom_plan, wl, sites, 0.1))
    svc = RetuneService(b)
    assert "armed, 0 re-tunes" in svc.report()
    svc.handle(sites)
    rep = svc.report()
    assert "1 re-tune(s)" in rep and "generation 1" in rep


# ---------------------------------------------------------------------------
# set_plan resets drift flag state (the once-per-install fix)
# ---------------------------------------------------------------------------


def test_set_plan_resets_monitor_and_fallbacks(wl, lagom_plan):
    b = PlanBinding(CFG, plan=lagom_plan, parallel="tp:2", max_seq=128)
    b.attach_faults(DEGRADE_L0_AT_2, tolerance=0.25, window=1)
    for i in range(3):
        drifted = b.health_tick()
        if drifted:
            break
    assert drifted and all(s.startswith("serve.layer0") for s in drifted)
    b.demote(drifted)
    assert b.demoted and b._fallbacks
    # hot-swapping a fresh TunedPlan must re-arm the detector: demotions
    # and sticky fallbacks clear, and the same site is re-flaggable
    # against the new plan's predictions instead of ignored forever
    child = retune_plan(
        lagom_plan,
        wl,
        sites=drifted,
        telemetry=_degraded_costs(lagom_plan, wl, drifted, 0.1),
    )
    b.set_plan(child)
    assert b.demoted == {} and b._fallbacks == {}
    assert b._health is None  # lazily rebuilt on the next tick
    # repo re-resolution, by contrast, keeps the flag state sticky
    # (test_demoted_fallbacks_survive_repo_re_resolution covers it)


def test_set_plan_reflags_after_swap(lagom_plan):
    """Regression: before the reset, a site that drifted again after a
    set_plan hot-swap was never re-flagged (the monitor's reported set
    survived the swap)."""
    b = PlanBinding(CFG, plan=lagom_plan, parallel="tp:2", max_seq=128)
    b.attach_faults(
        FaultSchedule(
            events=(FaultEvent("degrade", site="serve", scale=0.1, start=0),)
        ),
        tolerance=0.25,
        window=1,
    )
    first = b.health_tick()
    assert first  # flagged immediately (window=1, fault from batch 0)
    b.set_plan(lagom_plan)  # swap (same artifact is fine: state must reset)
    b.attach_faults(
        FaultSchedule(
            events=(FaultEvent("degrade", site="serve", scale=0.1, start=0),)
        ),
        tolerance=0.25,
        window=1,
    )
    assert b.health_tick() == first  # re-flagged, not silently ignored


# ---------------------------------------------------------------------------
# end-to-end drill: degrade -> detect -> warm re-tune -> hot-swap -> recover
# ---------------------------------------------------------------------------


def test_fixed_engine_retunes_mid_generate(tmp_path, params, wl, lagom_plan):
    repo = PlanRepository(tmp_path)
    repo.put(lagom_plan)
    eng = make_engine(
        CFG,
        params,
        mode="fixed",
        batch_size=32,
        max_seq=128,
        plan=lagom_plan,
        plan_parallel="tp:2",
        fault_schedule=DEGRADE_L0_AT_2,
        health_window=2,
        health_tolerance=0.25,
        retune=dict(repo=repo),
    )
    outs = eng.generate(_prompts(32), max_new=8)
    assert all(len(o) == 8 for o in outs)  # zero dropped tokens

    kinds = [e["event"] for e in eng.health_events]
    assert "drift" in kinds and "retune" in kinds
    assert "demotion" not in kinds  # the re-tune preempted demotion
    assert eng._binding.demoted == {}
    ev = next(e for e in eng.health_events if e["event"] == "retune")
    # fault starts at batch 2; window=2 flags on the second drifted batch
    assert ev["batch"] == 4
    assert ev["groups"] == [0, 1]  # drift-scoped: layer 1 untouched
    assert ev["generation"] == 1 and ev["published"]
    assert sorted(ev["sites"]) == _layer0_sites(wl)

    # the swap hot-installed the child (a different artifact; whether it
    # retraces depends on whether the lowered knobs moved — the compiled
    # cache keys on the runtime digest either way), and the monitor did
    # not re-flag: calibrated predictions price the degraded fabric
    new = eng._binding._plan
    assert new.artifact_digest() != lagom_plan.artifact_digest()
    assert new.lineage["retuned_from"] == lagom_plan.artifact_digest()
    assert sum(1 for k in kinds if k == "drift") == 1
    # published: the repo entry advanced to the retuned child
    assert repo.get(lagom_plan.fingerprint, "tpu-v5e").lineage
    # recovery: under the calibrated fabric the retuned plan beats the
    # stale parent's makespan on the drifted groups
    from repro.core.simulator import Simulator

    sched = FaultSchedule.from_dict(new.faults["calibrated"])
    sim = Simulator(HW, faults=sched)
    for gi in ev["groups"]:
        g = wl.groups[gi]
        stale = sim.profile_group(
            g, [lagom_plan.configs[(gi, ci)] for ci in range(len(g.comms))]
        ).Z
        tuned = sim.profile_group(
            g, [new.configs[(gi, ci)] for ci in range(len(g.comms))]
        ).Z
        assert tuned < stale
    assert "re-tune(s)" in eng.retune_service.report()


def test_continuous_engine_retunes_between_ticks(params, wl, lagom_plan):
    from repro.serving import Request

    eng = make_engine(
        CFG,
        params,
        mode="continuous",
        slots=32,
        max_seq=128,
        plan=lagom_plan,
        plan_parallel="tp:2",
        fault_schedule=DEGRADE_L0_AT_2,
        health_window=2,
        health_tolerance=0.25,
        retune=True,
    )
    for i, p in enumerate(_prompts(32)):
        eng.submit(Request(rid=i, prompt=p, max_new=8))
    done = eng.run()
    assert len(done) == 32 and all(len(r.out) == 8 for r in done)
    kinds = [e["event"] for e in eng.health_events]
    assert "retune" in kinds and "demotion" not in kinds
    assert eng._binding.demoted == {}
    assert eng.retune_service.retunes == 1
    assert len(eng.telemetry) > 0  # the ring buffer saw every tick


def test_engine_demotes_when_budget_spent(params, lagom_plan):
    """The fallback chain: a declining service (budget 0 left after one
    publish, faults persist) hands drift back to demotion."""
    # degrade *everything* but let the service do at most one re-tune;
    # window=1 so the second layer's drift (if calibration on layer0
    # somehow missed it) falls back to demote... here the whole plan is
    # degraded at once, one retune handles all sites, so force declines
    # by exhausting the budget with interval=1000 instead.
    eng = make_engine(
        CFG,
        params,
        mode="fixed",
        batch_size=32,
        max_seq=128,
        plan=lagom_plan,
        plan_parallel="tp:2",
        fault_schedule=FaultSchedule(
            events=(
                FaultEvent("degrade", site="serve.layer0", scale=0.1, start=2),
                FaultEvent("degrade", site="serve.layer1", scale=0.1, start=8),
            )
        ),
        health_window=2,
        health_tolerance=0.25,
        retune=dict(max_retunes=1),
    )
    outs = eng.generate(_prompts(32), max_new=12)
    assert all(len(o) == 12 for o in outs)
    kinds = [e["event"] for e in eng.health_events]
    # first drift re-tuned; the later layer-1 drift found the budget
    # spent, was logged as skipped, and demoted instead
    assert "retune" in kinds and "retune_skipped" in kinds
    assert "demotion" in kinds
    skip = next(e for e in eng.health_events if e["event"] == "retune_skipped")
    assert "budget" in skip["reason"]
    assert any(s.startswith("serve.layer1") for s in eng._binding.demoted)


def test_serve_launcher_retune_flag(tmp_path, capsys, wl, lagom_plan):
    from repro.launch import serve

    path = str(tmp_path / "plan.json")
    lagom_plan.save(path)
    argv = ["--arch", "llama3-8b", "--smoke", "--batch", "32"]
    argv += ["--prompt-len", "8", "--max-new", "8", "--max-seq", "128"]
    argv += ["--tuned-plan", path, "--plan-parallel", "tp:2"]
    argv += ["--fault-schedule", "degrade,site=serve.layer0,scale=0.1,start=2"]
    argv += ["--health-window", "2", "--health-tolerance", "0.25"]
    argv += ["--retune", "--retune-max", "2"]
    serve.main(argv)
    out = capsys.readouterr().out
    assert "retune: 1 re-tune(s)" in out
    assert "0 site(s) demoted" in out  # health line: re-tune preempted demote
